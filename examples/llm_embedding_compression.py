"""Scenario: compress an LM's token-embedding table with CompresSAE.

DESIGN.md §Arch-applicability: for the assigned LM archs the paper's
technique applies to the embedding/unembedding tables (command-r: 2×2.1 GB)
and to LM-produced sentence embeddings — not to attention/FFN compute.
Here we compress a (smoke-scale) qwen3 embedding table and check that
nearest-neighbour token structure survives, which is what embedding-table
compression must preserve for retrieval-style uses (e.g. speculative
vocab pruning, semantic token lookup).

    PYTHONPATH=src python examples/llm_embedding_compression.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, build_index, encode, init_train_state, score_dense,
    score_sparse, top_n, train_step,
)
from repro.models import transformer as T
from repro.models.registry import arch_module
from repro.optim import AdamConfig


def main():
    cfg = arch_module("qwen3-1.7b").smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    # give the table some structure (random init has none): low-rank mix
    key = jax.random.PRNGKey(1)
    basis = jax.random.normal(key, (16, cfg.d_model))
    mix = jax.random.normal(jax.random.fold_in(key, 1), (cfg.vocab, 16))
    table = mix @ basis + 0.3 * params["embed"]
    print(f"embedding table: {cfg.vocab} x {cfg.d_model} "
          f"({table.size*4/2**20:.2f} MiB)")

    sae_cfg = SAEConfig(d=cfg.d_model, h=8 * cfg.d_model, k=8)
    state = init_train_state(sae_cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, sae_cfg, AdamConfig(lr=3e-3)))
    for _ in range(150):
        state, m = step(state, table)
    codes = encode(state.params, table, sae_cfg.k)
    print(f"compressed to {codes.nbytes_logical/2**20:.2f} MiB "
          f"({table.size*4/codes.nbytes_logical:.1f}x), "
          f"cos loss {float(m['loss']):.4f}")

    # nearest-token structure: top-5 neighbours of 50 probe tokens
    probes = table[:50]
    truth = top_n(score_dense(table, probes), 5)[1]
    index = build_index(codes)
    got = top_n(score_sparse(index, encode(state.params, probes, sae_cfg.k)), 5)[1]
    overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / 5
                       for a, b in zip(np.asarray(got), np.asarray(truth))])
    print(f"token-neighbourhood overlap@5: {overlap:.2f}")


if __name__ == "__main__":
    main()
