"""Scenario: compress a recommender's item catalog (the paper's production
use case) and serve retrieval from the compressed index.

A DLRM-style model's item embedding table is compressed post-training with
CompresSAE; user vectors from the model's query tower are encoded on the
fly and scored against the sparse catalog with the scatter-query SpMV —
exactly the `retrieval_cand` production cell, at laptop scale.

    PYTHONPATH=src python examples/recsys_catalog_compression.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, encode, init_train_state, score_dense, top_n, train_step,
)
from repro.data.synthetic import criteo_like_batch
from repro.models import recsys as R
from repro.models.retrieval_head import compressed_retrieval, dense_retrieval
from repro.optim import AdamConfig


def main():
    # 1. A (toy) trained DLRM; table_0 is the item catalog.
    cfg = R.DLRMConfig(vocab_sizes=(20000, 50, 200, 30), n_dense=13,
                       embed_dim=64, bot_mlp=(64, 64), top_mlp=(64, 32, 1),
                       n_user_fields=2)
    params = R.dlrm_init(cfg, jax.random.PRNGKey(0))
    # a trained item table is clustered (co-engagement structure); random
    # init is isotropic and has no neighbourhoods to preserve — install a
    # realistic catalog in its place
    from repro.data import clustered_embeddings

    catalog = clustered_embeddings(jax.random.PRNGKey(7), 20000, d=64,
                                   n_clusters=128)
    params["tables"]["table_0"] = catalog           # (20000, 64) item vectors

    # 2. Post-hoc compression — no model retraining (paper's key property).
    sae_cfg = SAEConfig(d=64, h=512, k=8)           # 4x compression
    state = init_train_state(sae_cfg, jax.random.PRNGKey(1))
    step = jax.jit(lambda s, b: train_step(s, b, sae_cfg, AdamConfig(lr=3e-3)))
    for i in range(250):
        key = jax.random.fold_in(jax.random.PRNGKey(2), i)
        idx = jax.random.randint(key, (4096,), 0, catalog.shape[0])
        state, _ = step(state, catalog[idx])
    codes = encode(state.params, catalog, sae_cfg.k)
    norms = jnp.linalg.norm(codes.values, axis=-1)
    print(f"catalog {catalog.size*4/2**20:.2f} MiB -> "
          f"{codes.nbytes_logical/2**20:.2f} MiB")

    # 3. Serve: user vector = mean of recently-engaged items (classic
    #    retrieval-tower construction — lives in the item-embedding space).
    #    Real histories are coherent (co-engagement): take each user's
    #    history as the neighbourhood of a seed item, not uniform draws —
    #    a uniform-random centroid is a near-zero noise vector whose
    #    "nearest neighbours" are arbitrary under ANY compression.
    seeds = jax.random.randint(jax.random.PRNGKey(3), (32,), 0,
                               catalog.shape[0])
    _, hist = top_n(score_dense(catalog, catalog[seeds]), 20)
    user_vec = jnp.mean(catalog[hist], axis=1)            # (32, 64)
    v_c, ids_c = compressed_retrieval(user_vec, state.params, codes, norms,
                                      n=20, k=sae_cfg.k)
    v_d, ids_d = dense_retrieval(user_vec, catalog, n=20)
    overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / 20
                       for a, b in zip(np.asarray(ids_c), np.asarray(ids_d))])
    print(f"compressed vs dense top-20 overlap: {overlap:.2f} "
          f"(catalog bytes 4x smaller, scan bytes 4x fewer)")
    assert overlap > 0.15, overlap


if __name__ == "__main__":
    main()
