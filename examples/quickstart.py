"""Quickstart: train CompresSAE, compress a catalog, retrieve.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, build_index, encode, init_train_state, retrieve, score_dense,
    score_reconstructed, score_sparse, top_n, train_step,
)
from repro.core import sparse as sparse_fmt
from repro.data import clustered_embeddings
from repro.optim import AdamConfig


def main():
    # 1. A catalog of dense embeddings (stand-in for a production encoder).
    cfg = SAEConfig(d=256, h=1024, k=16)       # paper: d=768, h=4096, k=32
    catalog = clustered_embeddings(jax.random.PRNGKey(0), 20_000, d=cfg.d)
    queries = clustered_embeddings(jax.random.PRNGKey(1), 100, d=cfg.d)

    # 2. Train the sparse autoencoder (paper §3.1: minutes, not hours).
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(200):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(3), i),
                                 (4096,), 0, catalog.shape[0])
        state, metrics = step(state, catalog[idx])
    print(f"trained: cosine loss {float(metrics['loss']):.4f}, "
          f"active latents {float(metrics['frac_active_latents']):.2f}")

    # 3. Compress the catalog: fixed-k sparse codes (== uniform CSR).
    codes = encode(state.params, catalog, cfg.k)
    dense_mb = catalog.size * 4 / 2**20
    sparse_mb = codes.nbytes_logical / 2**20
    print(f"catalog: {dense_mb:.1f} MiB dense -> {sparse_mb:.1f} MiB "
          f"compressed ({dense_mb/sparse_mb:.1f}x)")
    data, indices, indptr = sparse_fmt.to_csr(codes)   # pgvector/scipy interop
    print(f"CSR export: nnz={data.size}, uniform row length {cfg.k}")

    # 4. Retrieve — sparse-space (fast) and reconstructed-space (precise).
    index = build_index(codes, state.params)
    q_codes = encode(state.params, queries, cfg.k)
    truth = top_n(score_dense(catalog, queries), 10)[1]

    def recall(ids):
        return np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                        for a, b in zip(np.asarray(ids), np.asarray(truth))])

    ids_sp = top_n(score_sparse(index, q_codes), 10)[1]
    ids_rc = top_n(score_reconstructed(index, q_codes, state.params), 10)[1]
    print(f"recall@10 vs exact dense: sparse-space {recall(ids_sp):.3f}, "
          f"reconstructed-space {recall(ids_rc):.3f}")

    # 5. Serving path: fused score+select — same ids, never materializes
    #    the (Q, N) score matrix (Pallas kernel on TPU, chunked scan on CPU).
    _, ids_served = retrieve(index, q_codes, 10, mode="sparse")
    assert (np.asarray(ids_served) == np.asarray(ids_sp)).all()
    print(f"retrieve() serving path: recall@10 {recall(ids_served):.3f} "
          f"(identical ids to the full-score path)")

    # 6. Distributed retrieval: once the catalog outgrows one chip's HBM,
    #    shard the index (its k-sparse codes + norms) along the candidate
    #    axis of a mesh.  Each shard runs the same streaming score+select
    #    over its slice; per-shard top-n sets merge with one small
    #    all-gather — results are BIT-identical to single-device serving.
    #    Same flow as the CLI: `python -m repro.launch.serve --shards 4`
    #    (on CPU, run with XLA_FLAGS=--xla_force_host_platform_device_count=4).
    n_shards = min(4, jax.device_count())
    if n_shards > 1:
        from repro.launch.mesh import make_candidate_mesh

        mesh = make_candidate_mesh(n_shards)
        vals_sh, ids_sh = retrieve(index, q_codes, 10, mode="sparse", mesh=mesh)
        assert (np.asarray(ids_sh) == np.asarray(ids_served)).all()
        print(f"distributed retrieve() over {n_shards} candidate shards: "
              f"identical ids ({index.codes.nbytes_logical/n_shards/2**20:.1f} "
              f"MiB of codes per shard)")
    else:
        print("distributed retrieve(): single device visible — rerun under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=4 or try "
              "`python -m repro.launch.serve --shards 4`")

    # 7. The serving engine: the deployment story in one object.  A
    #    RetrievalEngine owns (params, index, mode, backend, mesh) and
    #    serves whole requests — raw dense embeddings in, top-n out —
    #    under a single jit.  On TPU the request flows
    #    fused_encode -> fused_retrieve_sparse_q: the query codes are
    #    scored AS CODES (the dense query panel exists only in VMEM
    #    scratch), so only (Q, k) codes and (Q, n) results touch HBM.
    #    Results are bit-identical to the composed encode() + retrieve()
    #    calls above, on every backend and mesh.
    from repro.serving import EngineConfig, RetrievalEngine

    engine = RetrievalEngine(index, state.params,
                             config=EngineConfig(mode="sparse"))
    vals_e, ids_e, *_ = engine.retrieve_dense(queries, 10)
    assert (np.asarray(ids_e) == np.asarray(ids_served)).all()
    print(f"RetrievalEngine.retrieve_dense: recall@10 {recall(ids_e):.3f} "
          f"(bit-identical to the composed encode+retrieve path; "
          f"steady-state requests reuse one cached jit)")

    # 8. Quantized serving (compound compression, beyond the paper): build
    #    the index with quantize=True and the thing living in HBM is the
    #    compressed format itself — int8 values + int16 indices + fp32
    #    per-row scales, ~2.6x smaller than the fp32 codes — streamed
    #    straight into the quantized fused-retrieve generation, which
    #    dequantizes candidate tiles in VMEM.  Scores, ids and ties are
    #    bit-identical to serving the dequantized index: quantization
    #    error is a build-time choice, never a serving-path one.
    #    Same flow as the CLI: `python -m repro.launch.serve --quantized`.
    from repro.core import dequantize_index

    qindex = build_index(codes, state.params, quantize=True)
    engine_q = RetrievalEngine(qindex, state.params,
                               config=EngineConfig(mode="sparse"))
    vals_q, ids_q, *_ = engine_q.retrieve_dense(queries, 10)
    engine_dq = RetrievalEngine(
        dequantize_index(qindex), state.params,
        config=EngineConfig(mode="sparse"),
    )
    vals_dq, ids_dq, *_ = engine_dq.retrieve_dense(queries, 10)
    assert (np.asarray(ids_q) == np.asarray(ids_dq)).all()
    assert (np.asarray(vals_q) == np.asarray(vals_dq)).all()
    q_mb = qindex.codes.nbytes_logical / 2**20
    print(f"quantized serving: {sparse_mb:.1f} MiB fp32 codes -> {q_mb:.2f} "
          f"MiB int8/int16 in HBM "
          f"({qindex.codes.nbytes_logical / codes.nbytes_logical:.0%} of "
          f"fp32, {dense_mb/q_mb:.1f}x vs dense), recall@10 "
          f"{recall(ids_q):.3f}, bit-identical to the dequantized index")


if __name__ == "__main__":
    main()
