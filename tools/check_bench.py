"""Bench-regression gate (ISSUE 5): diff a freshly written
``BENCH_retrieval.json`` against the committed baseline.

Applies docs/BENCHMARKS.md's comparison rules mechanically so CI can
gate what is gateable and only warn about what is noise:

GATES (exit 1):
  * schema — every fresh record carries the required fields
    (name/us_per_call/recall/path/shards, plus the quantized and int8
    rows' extra fields);
  * row-set — a baseline row name may not disappear (new rows are fine:
    that is how the record grows PR by PR);
  * recall — for rows whose configuration matches the baseline (same
    path, shards, n, q, topn — records of different configurations are
    not comparable), any ``recall*`` field may not drop by more than
    ``--recall-tol`` (default 0.02; CPU runs are seeded and
    deterministic, so a real drop means a serving-path change);
  * quality floor — the ``retrieval_two_stage``,
    ``retrieval_two_stage_device`` and ``retrieval_segmented`` rows'
    ``recall_vs_exact`` must be >= 0.95 ABSOLUTE at full benchmark size
    (baseline-independent; smoke records are exempt);
  * two-stage host/device parity — ``retrieval_two_stage_device``'s
    ``recall_vs_exact`` must EQUAL ``retrieval_two_stage``'s (the
    device union is bit-identical to the host oracle by contract; no
    tolerance, no smoke exemption);
  * segmented compaction parity — ``retrieval_segmented``'s
    ``compaction_parity`` must equal 1 EXACTLY (compact() reproduces a
    fresh build_index over the surviving rows checksum-for-checksum;
    bit-identity is size-independent, so no smoke exemption).

WARN-ONLY (exit 0):
  * ``us_per_call`` movement in either direction — CPU-runner timing is
    noise-dominated at smoke sizes (see docs/BENCHMARKS.md §Comparing);
  * rows whose configuration changed (reported as not comparable).

Serving schema (ISSUE 10, ``--schema serving``): the same gate for
``BENCH_serving.json`` written by ``repro.launch.loadtest``.

GATES (exit 1):
  * schema — every serving record carries the traffic-shaped fields
    (latency percentiles, throughput/offered load, occupancy, shed rate,
    request count, path, coalescing deadline);
  * row-set — a baseline row name may not disappear;
  * sanity — ``0 <= shed_rate <= 1``, ``0 <= occupancy_mean <= 1`` and
    ``p50_ms <= p95_ms <= p99_ms`` (a violated ordering means the
    percentile computation broke, not that the machine was slow);
  * shed-rate regression — for configuration-matched rows, ``shed_rate``
    may not grow by more than ``--shed-tol`` (default 0.05): admission
    control shedding more traffic at the same offered load is a serving
    regression even when latency is noise.

WARN-ONLY: every latency/throughput/occupancy movement — wall-clock
under concurrent load on a shared CPU runner is the noisiest number in
the repo.

Usage:
    python tools/check_bench.py BASELINE.json FRESH.json \
        [--schema retrieval|serving] [--recall-tol 0.02] \
        [--shed-tol 0.05] [--summary PATH]

``--summary`` appends a markdown report (for ``$GITHUB_STEP_SUMMARY``).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REQUIRED = {"name", "us_per_call", "recall", "path", "shards"}
EXTRA_REQUIRED = {
    "retrieval_sparse_quantized": {"k", "index_bytes", "index_bytes_fp32"},
    "retrieval_sparse_quantized_mxu": {
        "k", "precision", "recall_vs_exact", "score_mae",
        "rank_displacement", "quality_n",
    },
    # hardened serving (ISSUE 6): the recovery-path fields gate
    # (recall_vs_exact_min is a recall* field, so a drop beyond tol also
    # gates against the baseline); timing stays warn-only like every row
    "retrieval_fault_matrix": {
        "faults", "recovered_exact", "degraded", "recall_vs_exact_min",
        "coverage_min",
    },
    # two-stage serving (ISSUE 7): recall_vs_exact additionally carries an
    # ABSOLUTE floor at full size (see compare()), on top of the usual
    # baseline-drop gate every recall* field gets
    "retrieval_two_stage": {
        "recall_vs_exact", "scanned_fraction", "candidate_fraction",
        "quality_n",
    },
    # device stage 1 (ISSUE 8): same schema and same absolute floor as
    # the host row — PLUS a hard host/device divergence gate (the device
    # union is bit-identical by contract, so any recall difference means
    # the contract broke)
    "retrieval_two_stage_device": {
        "recall_vs_exact", "scanned_fraction", "candidate_fraction",
        "quality_n",
    },
    "retrieval_inverted_index": {"cap", "scan_frac"},
    # segmented mutable index (ISSUE 9): recall_vs_exact carries the
    # same absolute floor as the two-stage rows; compaction_parity is a
    # hard equality gate (see compare()) — compact() must reproduce the
    # rebuilt index's content checksum bit-for-bit at ANY size
    "retrieval_segmented": {
        "recall_vs_exact", "compaction_parity", "quality_n",
        "n_alive", "adds", "deletes", "base_coverage",
    },
}

# absolute quality floor for the two-stage and segmented rows at full
# benchmark size (smoke-size records skip it — tiny corpora + a briefly
# trained SAE make absolute recall noise; the relative baseline gate
# still applies)
TWO_STAGE_RECALL_FLOOR = 0.95
RECALL_FLOOR_ROWS = (
    "retrieval_two_stage", "retrieval_two_stage_device",
    "retrieval_segmented",
)
# records are only comparable within an identical configuration
CONFIG_FIELDS = ("path", "shards", "n", "q", "topn")

# ------------------------------------------------- serving schema (ISSUE 10)
SERVING_REQUIRED = {
    "name", "p50_ms", "p95_ms", "p99_ms", "throughput_rps", "offered_rps",
    "occupancy_mean", "shed_rate", "requests", "path", "max_wait_us",
}
# a serving row is only comparable against a baseline run of the same
# engine path AND the same traffic shape / admission settings
SERVING_CONFIG_FIELDS = (
    "path", "shards", "n", "users", "topn",
    "max_wait_us", "max_queue_rows", "smoke",
)


def load(path: pathlib.Path) -> dict:
    records = json.loads(path.read_text())
    if not isinstance(records, list):
        raise ValueError(f"{path}: expected a JSON list of records")
    for i, r in enumerate(records):
        if not isinstance(r, dict) or "name" not in r:
            raise ValueError(f"{path}: record #{i} has no 'name' field")
    return {r["name"]: r for r in records}


def compare(baseline: dict, fresh: dict, recall_tol: float
            ) -> tuple[list[str], list[str]]:
    """-> (failures, warnings)."""
    failures, warnings = [], []

    for name, rec in fresh.items():
        missing = (REQUIRED | EXTRA_REQUIRED.get(name, set())) - set(rec)
        if missing:
            failures.append(f"schema: row `{name}` missing {sorted(missing)}")

    for ts_name in RECALL_FLOOR_ROWS:
        ts = fresh.get(ts_name)
        if ts is not None and not ts.get("smoke") \
                and "recall_vs_exact" in ts \
                and ts["recall_vs_exact"] < TWO_STAGE_RECALL_FLOOR:
            failures.append(
                f"quality floor: `{ts_name}`."
                f"recall_vs_exact {ts['recall_vs_exact']:.4f} < "
                f"{TWO_STAGE_RECALL_FLOOR} at full benchmark size"
            )

    # segmented compaction parity: compact() must reproduce a fresh
    # build_index over the surviving rows checksum-for-checksum.  Bit
    # -identity does not depend on corpus size, so smoke records gate too.
    seg = fresh.get("retrieval_segmented")
    if seg is not None and "compaction_parity" in seg \
            and seg["compaction_parity"] != 1:
        failures.append(
            "segmented compaction parity: `retrieval_segmented`."
            f"compaction_parity {seg['compaction_parity']!r} != 1 — "
            "compact() must rebuild the index bit-for-bit"
        )

    # host/device two-stage parity: the device union is bit-identical to
    # the host oracle by contract, so the two rows' recall_vs_exact must
    # MATCH exactly (at any size — bit-equality does not get a tolerance)
    ts_host = fresh.get("retrieval_two_stage")
    ts_dev = fresh.get("retrieval_two_stage_device")
    if ts_host is not None and ts_dev is not None \
            and "recall_vs_exact" in ts_host and "recall_vs_exact" in ts_dev \
            and ts_dev["recall_vs_exact"] != ts_host["recall_vs_exact"]:
        failures.append(
            "two-stage host/device divergence: "
            f"`retrieval_two_stage_device`.recall_vs_exact "
            f"{ts_dev['recall_vs_exact']:.4f} != `retrieval_two_stage`."
            f"recall_vs_exact {ts_host['recall_vs_exact']:.4f} — the "
            "device union must be bit-identical to the host oracle"
        )

    gone = sorted(set(baseline) - set(fresh))
    if gone:
        failures.append(f"row-set: baseline rows disappeared: {gone}")
    for name in sorted(set(fresh) - set(baseline)):
        warnings.append(f"new row `{name}` (no baseline to compare)")

    for name in sorted(set(baseline) & set(fresh)):
        b, f = baseline[name], fresh[name]
        cfg_b = tuple(b.get(c) for c in CONFIG_FIELDS)
        cfg_f = tuple(f.get(c) for c in CONFIG_FIELDS)
        if cfg_b != cfg_f:
            warnings.append(
                f"`{name}`: configuration changed "
                f"{dict(zip(CONFIG_FIELDS, cfg_b))} -> "
                f"{dict(zip(CONFIG_FIELDS, cfg_f))} — not comparable, "
                "recall gate skipped"
            )
            continue
        for field in sorted(set(b) & set(f)):
            if not field.startswith("recall"):
                continue
            drop = b[field] - f[field]
            if drop > recall_tol:
                failures.append(
                    f"recall regression: `{name}`.{field} "
                    f"{b[field]:.4f} -> {f[field]:.4f} "
                    f"(drop {drop:.4f} > tol {recall_tol})"
                )
        if b.get("us_per_call") and f.get("us_per_call"):
            ratio = f["us_per_call"] / b["us_per_call"]
            if ratio > 1.5 or ratio < 0.67:
                warnings.append(
                    f"`{name}`: us_per_call {b['us_per_call']:.0f} -> "
                    f"{f['us_per_call']:.0f} ({ratio:.2f}x) — timing is "
                    "warn-only (CPU-runner noise)"
                )
    return failures, warnings


def compare_serving(baseline: dict, fresh: dict, shed_tol: float
                    ) -> tuple[list[str], list[str]]:
    """-> (failures, warnings) for the serving schema."""
    failures, warnings = [], []

    for name, rec in fresh.items():
        missing = SERVING_REQUIRED - set(rec)
        if missing:
            failures.append(f"schema: row `{name}` missing {sorted(missing)}")

    # internal-consistency gates: these fail on ANY machine if the driver
    # or the batcher bookkeeping is wrong, independent of timing noise
    for name, rec in fresh.items():
        sr = rec.get("shed_rate")
        if sr is not None and not 0.0 <= sr <= 1.0:
            failures.append(f"sanity: `{name}`.shed_rate {sr!r} not in [0, 1]")
        occ = rec.get("occupancy_mean")
        if occ is not None and not 0.0 <= occ <= 1.0:
            failures.append(
                f"sanity: `{name}`.occupancy_mean {occ!r} not in [0, 1]"
            )
        ps = [rec.get(k) for k in ("p50_ms", "p95_ms", "p99_ms")]
        if None not in ps and not ps[0] <= ps[1] <= ps[2]:
            failures.append(
                f"sanity: `{name}` percentile ordering broken: "
                f"p50 {ps[0]:.2f} / p95 {ps[1]:.2f} / p99 {ps[2]:.2f}"
            )

    gone = sorted(set(baseline) - set(fresh))
    if gone:
        failures.append(f"row-set: baseline rows disappeared: {gone}")
    for name in sorted(set(fresh) - set(baseline)):
        warnings.append(f"new row `{name}` (no baseline to compare)")

    for name in sorted(set(baseline) & set(fresh)):
        b, f = baseline[name], fresh[name]
        cfg_b = tuple(b.get(c) for c in SERVING_CONFIG_FIELDS)
        cfg_f = tuple(f.get(c) for c in SERVING_CONFIG_FIELDS)
        if cfg_b != cfg_f:
            warnings.append(
                f"`{name}`: configuration changed "
                f"{dict(zip(SERVING_CONFIG_FIELDS, cfg_b))} -> "
                f"{dict(zip(SERVING_CONFIG_FIELDS, cfg_f))} — not "
                "comparable, shed-rate gate skipped"
            )
            continue
        grow = f.get("shed_rate", 0.0) - b.get("shed_rate", 0.0)
        if grow > shed_tol:
            failures.append(
                f"shed-rate regression: `{name}`.shed_rate "
                f"{b['shed_rate']:.4f} -> {f['shed_rate']:.4f} "
                f"(grew {grow:.4f} > tol {shed_tol}) at the same "
                "offered load"
            )
        for field in ("p50_ms", "p99_ms", "throughput_rps"):
            if b.get(field) and f.get(field):
                ratio = f[field] / b[field]
                if ratio > 1.5 or ratio < 0.67:
                    warnings.append(
                        f"`{name}`: {field} {b[field]:.1f} -> "
                        f"{f[field]:.1f} ({ratio:.2f}x) — latency/"
                        "throughput is warn-only (concurrent-load timing "
                        "on a shared runner)"
                    )
    return failures, warnings


def render_summary(failures: list[str], warnings: list[str]) -> str:
    lines = ["## Bench-regression gate",
             f"**{'FAIL' if failures else 'OK'}** — "
             f"{len(failures)} failure(s), {len(warnings)} warning(s)"]
    lines += [f"- :x: {f}" for f in failures]
    lines += [f"- :warning: {w}" for w in warnings]
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", type=pathlib.Path)
    ap.add_argument("fresh", type=pathlib.Path)
    ap.add_argument("--schema", choices=["retrieval", "serving"],
                    default="retrieval",
                    help="which record schema to gate: 'retrieval' "
                         "(BENCH_retrieval.json) or 'serving' "
                         "(BENCH_serving.json from repro.launch.loadtest)")
    ap.add_argument("--recall-tol", type=float, default=0.02)
    ap.add_argument("--shed-tol", type=float, default=0.05,
                    help="serving schema: max allowed shed_rate growth on "
                         "configuration-matched rows")
    ap.add_argument("--summary", type=pathlib.Path, default=None,
                    help="append a markdown report to this file "
                         "(e.g. $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)
    try:
        baseline, fresh = load(args.baseline), load(args.fresh)
    except (ValueError, json.JSONDecodeError) as e:
        # an unreadable record is a gate failure with a clean report, not
        # a traceback that skips the summary
        failures, warnings = [f"unreadable record: {e}"], []
    else:
        if args.schema == "serving":
            failures, warnings = compare_serving(baseline, fresh,
                                                 args.shed_tol)
        else:
            failures, warnings = compare(baseline, fresh, args.recall_tol)
    for w in warnings:
        print(f"WARN: {w}")
    for f in failures:
        print(f"FAIL: {f}")
    if args.summary is not None:
        with args.summary.open("a") as fh:
            fh.write(render_summary(failures, warnings))
    if failures:
        return 1
    print(f"[check_bench] OK ({len(warnings)} warning(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
