"""Docs gate (ISSUE 4): internal links resolve and every command shown in
README/docs bash blocks is real.

Two levels, matching how the checks are consumed:

* static (default; also run in-process by ``tests/test_docs.py``):
    - every relative markdown link in README.md + docs/*.md points at a
      file that exists (external http(s)/mailto links and pure #anchors
      are skipped);
    - every non-comment line inside a fenced ```bash block parses as a
      command this repo can actually run: an optional ``ENV=value``
      prefix, then ``pip install …``, ``python -m <importable module> …``
      or ``python <existing file> …``.  Unrecognized commands FAIL — the
      docs may only show commands this checker can vouch for.
* ``--run`` (the CI docs job): additionally executes the canonical
  commands the docs promise — the tier-1 verify line (smoke-checked via
  ``--collect-only`` so the docs job doesn't duplicate the tier-1 job's
  full run) and the benchmark smoke — after asserting both appear
  verbatim in the README.

Usage:
    python tools/check_docs.py          # static checks
    python tools/check_docs.py --run    # static + execute canonical cmds
"""
from __future__ import annotations

import importlib.util
import os
import re
import shlex
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
# the required docs are listed explicitly (a deleted file must be REPORTED
# missing, which a glob of existing files cannot do); extra docs/*.md are
# picked up by the glob
_REQUIRED = [REPO / "README.md", REPO / "docs" / "ARCHITECTURE.md",
             REPO / "docs" / "BENCHMARKS.md"]
DOC_FILES = _REQUIRED + [
    p for p in sorted((REPO / "docs").glob("*.md")) if p not in _REQUIRED
]

TIER1_CMD = "PYTHONPATH=src python -m pytest -x -q"
SMOKE_CMD = "PYTHONPATH=src python -m benchmarks.run --smoke"

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```bash\n(.*?)```", re.DOTALL)
ENV_TOKEN_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*=")


def check_links(doc: Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(doc.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = (doc.parent / target.split("#", 1)[0]).resolve()
        if not path.exists():
            errors.append(f"{doc.relative_to(REPO)}: broken link -> {target}")
    return errors


def _module_exists(name: str) -> bool:
    sys.path.insert(0, str(REPO / "src"))
    sys.path.insert(0, str(REPO))
    try:
        return importlib.util.find_spec(name) is not None
    except (ImportError, ModuleNotFoundError):
        return False
    finally:
        sys.path.remove(str(REPO / "src"))
        sys.path.remove(str(REPO))


def check_command(line: str, doc: Path) -> list[str]:
    where = f"{doc.relative_to(REPO)}: `{line}`"
    try:
        tokens = shlex.split(line)
    except ValueError as e:
        return [f"{where}: unparseable ({e})"]
    while tokens and ENV_TOKEN_RE.match(tokens[0]):
        tokens = tokens[1:]
    if not tokens:
        return []
    if tokens[0] == "pip":
        if len(tokens) > 1 and tokens[1] == "install":
            return []
        return [f"{where}: only `pip install` is vouched for"]
    if tokens[0] != "python":
        return [f"{where}: unrecognized command `{tokens[0]}` — docs may "
                "only show python/pip commands this checker can verify"]
    if len(tokens) > 2 and tokens[1] == "-m":
        if not _module_exists(tokens[2]):
            return [f"{where}: module `{tokens[2]}` not importable"]
        return []
    if len(tokens) > 1:
        if not (REPO / tokens[1]).exists():
            return [f"{where}: script `{tokens[1]}` does not exist"]
        return []
    return [f"{where}: bare `python` invocation"]


def check_bash_blocks(doc: Path) -> list[str]:
    errors = []
    for block in FENCE_RE.findall(doc.read_text()):
        for line in block.splitlines():
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            errors.extend(check_command(line, doc))
    return errors


def static_checks() -> list[str]:
    errors = []
    for doc in DOC_FILES:
        if not doc.exists():
            errors.append(f"missing doc: {doc.relative_to(REPO)}")
            continue
        errors.extend(check_links(doc))
        errors.extend(check_bash_blocks(doc))
    # the three docs must be cross-linked (absence itself is already
    # reported above — don't crash on a missing file, report everything)
    if (REPO / "README.md").exists():
        readme = (REPO / "README.md").read_text()
        for target in ("docs/ARCHITECTURE.md", "docs/BENCHMARKS.md"):
            if target not in readme:
                errors.append(f"README.md does not link {target}")
    for name, sibling in [("ARCHITECTURE.md", "BENCHMARKS.md"),
                          ("BENCHMARKS.md", "ARCHITECTURE.md")]:
        doc = REPO / "docs" / name
        if not doc.exists():
            continue
        text = doc.read_text()
        if "../README.md" not in text:
            errors.append(f"docs/{name} does not link back to README.md")
        if sibling not in text:
            errors.append(f"docs/{name} does not link docs/{sibling}")
    return errors


def run_canonical() -> list[str]:
    readme = (REPO / "README.md").read_text()
    errors = [f"README.md must show the canonical command: `{cmd}`"
              for cmd in (TIER1_CMD, SMOKE_CMD) if cmd not in readme]
    if errors:
        return errors
    env = {**os.environ, "PYTHONPATH": str(REPO / "src")}
    # tier-1 line: smoke-check runnability via collect-only (the full run
    # is the tier1 CI job's business, not the docs job's)
    for label, argv in [
        ("tier-1 verify (collect-only)",
         [sys.executable, "-m", "pytest", "-x", "-q", "--collect-only"]),
        ("benchmark smoke",
         [sys.executable, "-m", "benchmarks.run", "--smoke"]),
    ]:
        print(f"[check_docs] running {label} ...", flush=True)
        proc = subprocess.run(argv, cwd=REPO, env=env,
                              capture_output=True, text=True, timeout=900)
        if proc.returncode != 0:
            errors.append(
                f"{label} failed (rc={proc.returncode}):\n"
                f"{proc.stdout[-1500:]}\n{proc.stderr[-1500:]}"
            )
    return errors


def main(argv: list[str]) -> int:
    errors = static_checks()
    if "--run" in argv and not errors:
        errors += run_canonical()
    if errors:
        print("\n".join(f"FAIL: {e}" for e in errors))
        return 1
    docs = ", ".join(str(d.relative_to(REPO)) for d in DOC_FILES)
    print(f"[check_docs] OK: {docs}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
