"""GuardedEngine unit contract (ISSUE 6 tentpole): admission, deadline
budget, degradation-ladder composition, startup self-check, counters.

The fault-matrix acceptance suite (every injected fault end-to-end) lives
in tests/test_fault_matrix.py; this file pins the guard layer's pieces in
isolation so a matrix failure is attributable.
"""
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, build_index, dequantize_index, encode, init_params,
    verify_index,
)
from repro.errors import (
    DeadlineExceededError,
    DegradationExhaustedError,
    IndexIntegrityError,
    InvalidQueryError,
    SelfCheckError,
)
from repro.launch.mesh import make_candidate_mesh
from repro.serving import (
    Deadline,
    EngineConfig,
    FaultInjector,
    GuardedEngine,
    RetrievalEngine,
    ServingStatus,
    flip_index_byte,
    self_check,
)

CFG = SAEConfig(d=32, h=128, k=8)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (310, CFG.d))
    queries = jax.random.normal(jax.random.PRNGKey(2), (9, CFG.d))
    codes = encode(params, corpus, CFG.k)
    index = build_index(codes, params)
    qindex = build_index(codes, params, quantize=True)
    return params, index, qindex, queries


# ---------------------------------------------------------------- deadline
def test_deadline_unbounded_never_expires():
    d = Deadline(None)
    assert not d.expired and d.remaining_ms == float("inf")
    d.check("anything")  # no raise


def test_deadline_expires_and_names_the_stage():
    d = Deadline(0.01)
    time.sleep(0.005)
    assert d.expired
    with pytest.raises(DeadlineExceededError, match="shard retry"):
        d.check("shard retry backoff")
    # typed AND a TimeoutError for generic callers
    with pytest.raises(TimeoutError):
        d.check("again")


# ------------------------------------------------------ ladder composition
def test_ladder_fp32_unsharded(setup):
    params, index, _, _ = setup
    g = GuardedEngine(RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False)))
    # the dequant pre-floor rung coincides with the primary -> deduped
    assert g.ladder == ("fp32-ref", "fp32-fullscore")


def test_ladder_int8(setup):
    params, _, qindex, _ = setup
    g = GuardedEngine(
        RetrievalEngine(qindex, params,
                    config=EngineConfig(use_kernel=False, precision="int8"))
    )
    assert g.ladder == ("int8-ref", "quantized-ref", "fp32-ref",
                        "fp32-fullscore")


@pytest.mark.distributed
def test_ladder_sharded_sheds_mesh_first(setup, forced_device_count):
    if forced_device_count < 2:
        pytest.skip("needs 2 devices")
    params, index, _, _ = setup
    mesh = make_candidate_mesh(2)
    g = GuardedEngine(
        RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False, mesh=mesh))
    )
    assert g.ladder == ("fp32-ref-sharded", "fp32-ref", "fp32-fullscore")


# ------------------------------------------------------------- admission
def test_healthy_request_is_not_degraded(setup):
    params, index, _, queries = setup
    g = GuardedEngine(RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False)))
    scores, ids, status, *_ = g.retrieve_dense(queries, 7)
    assert isinstance(status, ServingStatus)
    assert status.path == "fp32-ref" and status.step == 0
    assert not status.degraded and status.fault is None
    assert status.coverage == 1.0 and status.sanitized == 0
    # bit-identical to the bare engine
    bv, bi, *_ = g.engine.retrieve_dense(queries, 7)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(bi))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(bv))
    assert g.counters["requests"] == 1 and g.counters["degraded"] == 0


def test_reject_names_position_and_counts(setup):
    params, index, _, queries = setup
    g = GuardedEngine(RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False)))
    bad = np.asarray(queries).copy()
    bad[2, 5] = np.nan
    with pytest.raises(InvalidQueryError,
                       match=r"x: 1 non-finite value\(s\).*\(2, 5\)"):
        g.retrieve_dense(bad, 5)
    assert g.counters["rejected"] == 1
    # typed errors still read as ValueError for legacy callers
    with pytest.raises(ValueError):
        g.retrieve_dense(bad, 5)


def test_sanitize_serves_degraded_with_count(setup):
    params, index, _, queries = setup
    g = GuardedEngine(RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False)),
                      on_invalid="sanitize")
    bad = np.asarray(queries).copy()
    bad[0, 0] = np.inf
    bad[3, 7] = np.nan
    scores, ids, status, *_ = g.retrieve_dense(bad, 5)
    assert status.degraded and status.sanitized == 2
    assert "sanitized 2 non-finite" in status.fault
    assert np.all(np.isfinite(np.asarray(scores)))
    # the sanitized request equals serving the zeroed batch
    clean = np.where(np.isfinite(bad), bad, 0.0).astype(bad.dtype)
    wv, wi, *_ = g.engine.retrieve_dense(jnp.asarray(clean), 5)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
    assert g.counters["sanitized"] == 1 and g.counters["degraded"] == 1


def test_typed_shape_dtype_topn_rejections(setup):
    params, index, _, queries = setup
    g = GuardedEngine(RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False)))
    with pytest.raises(InvalidQueryError, match="expected an array"):
        g.retrieve_dense([[1.0, 2.0]], 5)
    with pytest.raises(InvalidQueryError, match="rank-3"):
        g.retrieve_dense(jnp.zeros((2, 3, CFG.d)), 5)
    with pytest.raises(InvalidQueryError, match="embedding dim mismatch"):
        g.retrieve_dense(jnp.zeros((2, CFG.d + 1)), 5)
    with pytest.raises(InvalidQueryError, match="floating dtype"):
        g.retrieve_dense(jnp.zeros((2, CFG.d), dtype=jnp.int32), 5)
    with pytest.raises(InvalidQueryError, match="top-n must be >= 1"):
        g.retrieve_dense(queries, 0)
    with pytest.raises(InvalidQueryError, match="exceeds candidate count"):
        g.retrieve_dense(queries, index.codes.n + 1)
    with pytest.raises(InvalidQueryError, match="expected a Python int"):
        g.retrieve_dense(queries, 5.0)
    assert g.counters["rejected"] == 7
    assert g.counters["requests"] == 7 and g.counters["degraded"] == 0


def test_on_invalid_validated(setup):
    params, index, _, _ = setup
    engine = RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False))
    with pytest.raises(ValueError, match="'reject' or 'sanitize'"):
        GuardedEngine(engine, on_invalid="explode")


# ------------------------------------------------------------- the ladder
def test_kernel_fault_steps_down_and_recovers(setup):
    params, _, qindex, queries = setup
    inj = FaultInjector("kernel-exception")
    g = GuardedEngine(
        RetrievalEngine(qindex, params,
                    config=EngineConfig(use_kernel=False, precision="int8")),
        injector=inj,
    )
    scores, ids, status, *_ = g.retrieve_dense(queries, 10)
    assert status.degraded and status.step == 1
    assert status.path == "quantized-ref"
    assert "injected kernel fault" in status.fault
    # the step-down rung is the exact path over the SAME index: equals the
    # exact oracle bit-for-bit
    oracle = RetrievalEngine(qindex, params,
                    config=EngineConfig(use_kernel=False))
    wv, wi, *_ = oracle.retrieve_dense(queries, 10)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(wv))
    # trip_once: the next request serves healthy on the primary again
    _, _, status2, *_ = g.retrieve_dense(queries, 10)
    assert not status2.degraded and status2.step == 0
    assert g.counters["degraded"] == 1


def test_unanticipated_exception_degrades_not_crashes(setup):
    """A bare RuntimeError on the primary rung (not a typed
    RetrievalError) must also step the ladder down."""
    params, index, _, queries = setup
    g = GuardedEngine(RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False)))

    class Boom:
        mesh = None

        def retrieve_dense(self, x, n):
            raise RuntimeError("boom: simulated runtime fault")

    g._rung_engines[0] = Boom()
    scores, ids, status, *_ = g.retrieve_dense(queries, 6)
    assert status.degraded and status.step == 1
    assert status.path == "fp32-fullscore"
    assert "RuntimeError: boom" in status.fault
    # the floor is the battle-tested oracle composition
    oracle = RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False))
    wv, wi, *_ = oracle.retrieve_dense(queries, 6)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
    # same ids; scores agree to f32 rounding (full-score vs streaming sum)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(wv),
                               rtol=1e-6, atol=1e-6)


def test_degradation_exhausted_chains_every_rung(setup):
    params, index, _, queries = setup
    g = GuardedEngine(RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False)))

    class Boom:
        mesh = None

        def retrieve_dense(self, x, n):
            raise RuntimeError("boom")

    g._rung_engines = {i: Boom() for i in range(len(g._ladder))}
    with pytest.raises(DegradationExhaustedError,
                       match="every degradation-ladder rung failed"):
        g.retrieve_dense(queries, 5)


def test_rung_engines_are_memoized(setup):
    params, _, qindex, queries = setup
    inj = FaultInjector("kernel-exception", trip_once=False)
    g = GuardedEngine(
        RetrievalEngine(qindex, params,
                    config=EngineConfig(use_kernel=False, precision="int8")),
        injector=inj,
    )
    g.retrieve_dense(queries, 5)
    rung1 = g._rung_engines[1]
    g.retrieve_dense(queries, 5)
    assert g._rung_engines[1] is rung1  # same engine (and jit cache) reused


# ----------------------------------------------------------- self-check
def test_self_check_passes_on_healthy_engine(setup):
    params, index, _, _ = setup
    report = self_check(RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False)))
    assert report.index_verified
    assert report.canary_q >= 1 and report.canary_n >= 1
    assert report.path == "fp32-ref"
    assert report.kernel_vs_ref is None  # primary already IS the ref path


def test_self_check_int8_kernel_vs_ref_bit_identical(setup):
    params, _, qindex, _ = setup
    report = self_check(
        RetrievalEngine(qindex, params,
                    config=EngineConfig(use_kernel=True, precision="int8")),
        canary_q=2, canary_n=4,
    )
    assert report.kernel_vs_ref == "bit-identical"
    assert report.max_abs_diff == 0.0


def test_self_check_catches_flipped_byte(setup):
    params, _, qindex, _ = setup
    corrupt = flip_index_byte(qindex, byte=17, bit=2)
    with pytest.raises(IndexIntegrityError, match="checksum mismatch"):
        self_check(RetrievalEngine(corrupt, params,
                    config=EngineConfig(use_kernel=False)))


def test_self_check_requires_checksum_by_default(setup):
    params, index, _, _ = setup
    bare = index._replace(checksum=None)
    with pytest.raises(IndexIntegrityError, match="no stored checksum"):
        self_check(RetrievalEngine(bare, params,
                    config=EngineConfig(use_kernel=False)))
    # opt out for ad-hoc indexes: canary still runs
    report = self_check(RetrievalEngine(bare, params,
                    config=EngineConfig(use_kernel=False)),
                        require_checksum=False)
    assert not report.index_verified


def test_self_check_catches_poisoned_norms(setup):
    """A checksumless index with NaN norms must fail the canary's own
    sanity gate, not slip through to traffic."""
    params, index, _, _ = setup
    poisoned = index._replace(
        sparse_norms=index.sparse_norms.at[0].set(jnp.nan),
        inv_sparse_norms=None, checksum=None,
    )
    with pytest.raises(SelfCheckError, match="non-finite"):
        self_check(RetrievalEngine(poisoned, params,
                    config=EngineConfig(use_kernel=False)),
                   require_checksum=False)


def test_guard_startup_self_check_and_fallback(setup):
    params, index, qindex, queries = setup
    corrupt = flip_index_byte(qindex, byte=17, bit=2)
    # no fallback: the integrity failure surfaces typed
    with pytest.raises(IndexIntegrityError):
        GuardedEngine(
            RetrievalEngine(corrupt, params,
                    config=EngineConfig(use_kernel=False, precision="int8")),
            run_self_check=True,
        )
    # with a verified fallback: serve from it, degraded from the start
    fp_index = dequantize_index(qindex)
    assert verify_index(fp_index)
    g = GuardedEngine(
        RetrievalEngine(corrupt, params,
                    config=EngineConfig(use_kernel=False, precision="int8")),
        run_self_check=True, fallback_index=fp_index,
    )
    assert g.degraded_from_start is not None
    assert "failed integrity check" in g.degraded_from_start
    assert g.engine.index is fp_index and g.engine.precision == "exact"
    scores, ids, status, *_ = g.retrieve_dense(queries, 8)
    assert status.degraded and "fallback index" in status.fault
    # the fallback answer is the fp32 oracle's answer
    wv, wi, *_ = RetrievalEngine(fp_index, params,
                    config=EngineConfig(use_kernel=False)).retrieve_dense(queries, 8)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(wv))


# ------------------------------------------------- segmented engines (ISSUE 9)
def _segmented(params, index, *, adds=8, deletes=(5, 9)):
    """A mutated SegmentedIndex over ``index``: ``adds`` delta rows with
    ids starting at N, then ``deletes`` masked out of the base."""
    from repro.core.segments import SegmentedIndex

    n = index.codes.n
    extra = jax.random.normal(jax.random.PRNGKey(7), (adds, CFG.d))
    ecodes = encode(params, extra, CFG.k)
    seg = SegmentedIndex.from_index(index)
    seg = seg.add_items(ecodes, ids=range(n, n + adds))
    if deletes:
        seg = seg.delete_items(list(deletes))
    return seg


def test_segmented_self_check_per_segment_crc(setup):
    """self_check verifies EVERY segment's CRC32: a healthy segmented
    kernel engine passes with the int8 bit-identity contract intact, and
    one flipped delta byte is a typed startup failure."""
    from repro.serving import flip_delta_byte

    params, _, qindex, _ = setup
    seg = _segmented(params, qindex)
    rep = self_check(RetrievalEngine(seg, params,
                    config=EngineConfig(use_kernel=True, precision="int8")))
    assert rep.kernel_vs_ref == "bit-identical"
    bad = RetrievalEngine(flip_delta_byte(seg), params,
                    config=EngineConfig(use_kernel=True, precision="int8"))
    with pytest.raises(IndexIntegrityError, match="checksum mismatch"):
        self_check(bad)


def test_segmented_ladder_serves_segments_on_every_rung(setup):
    """Rungs below a segmented primary keep serving (base + delta +
    masks) — stepping down a generation must not resurrect deleted rows
    or drop the delta — and the base-alone dequant rung is suppressed."""
    params, _, qindex, _ = setup
    seg = _segmented(params, qindex)
    g = GuardedEngine(
        RetrievalEngine(seg, params,
                    config=EngineConfig(use_kernel=False, precision="int8")))
    assert g.ladder == ("int8-ref", "quantized-ref", "fp32-fullscore")
    for step in range(len(g.ladder) - 1):
        assert g._engine_for(step).segments is not None


def test_segmented_floor_serves_survivors_only(setup):
    """The full-score floor for a segmented engine scores the COMPACTED
    survivors: deleted ids cannot surface even on the last rung, added
    ids can, and the ids agree with the engine's own exact answer."""
    params, index, _, queries = setup
    seg = _segmented(params, index)
    g = GuardedEngine(
        RetrievalEngine(seg, params,
                    config=EngineConfig(use_kernel=False)),
        injector=FaultInjector("kernel-exception"),
    )
    assert g.ladder == ("fp32-ref", "fp32-fullscore")
    scores, ids, status, *_ = g.retrieve_dense(queries, 16)
    assert status.path == "fp32-fullscore" and status.degraded
    alive = set(int(v) for v in seg.alive_ids())
    assert set(np.asarray(ids).ravel().tolist()) <= alive | {-1}
    assert {5, 9}.isdisjoint(set(np.asarray(ids).ravel().tolist()))
    wv, wi, *_ = RetrievalEngine(seg, params,
                    config=EngineConfig(use_kernel=False)).retrieve_dense(queries, 16)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(scores), np.asarray(wv),
                               rtol=1e-5, atol=1e-5)


def test_segmented_topn_admission_spans_all_segments(setup):
    """Admission caps top-n at the segmented index's TOTAL physical rows
    (base + delta), not the base alone."""
    params, index, _, queries = setup
    seg = _segmented(params, index, adds=8)
    g = GuardedEngine(RetrievalEngine(seg, params,
                    config=EngineConfig(use_kernel=False)))
    n_total = seg.n_rows
    scores, ids = g.retrieve_dense(queries, n_total)[:2]
    assert np.asarray(ids).shape == (queries.shape[0], n_total)
    with pytest.raises(InvalidQueryError, match="top-n"):
        g.retrieve_dense(queries, n_total + 1)
