"""Runs under 4 fake CPU devices (spawned by test_distributed_equiv.py,
which forwards the XLA_FLAGS device-count forcing set by tests/conftest.py;
the flag-append below keeps the script standalone-runnable).

Checks the shard_map implementations against their single-device oracles
through the repro.compat jax-version shim (works on jax 0.4.x and >= 0.6):
  1. moe_ffn_sharded     == moe_ffn          (expert-parallel dispatch)
  2. nequip sharded      == nequip dense     (dst-partitioned message passing)
  3. compressae retrieval shard_map == unsharded scoring
  4. encode_sharded      == encode           (h-sharded distributed top-k)
  5. distributed_retrieve == core.retrieve   (candidate-sharded serving)
"""
import os

_FORCE = "xla_force_host_platform_device_count"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"{os.environ.get('XLA_FLAGS', '')} --{_FORCE}=4"
    ).strip()

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import compat
from repro.compat import P

DATA, MODEL = 2, 2    # 4-device (data, model) mesh


def check_moe(mesh):
    from repro.layers.moe import moe_ffn, moe_ffn_sharded

    key = jax.random.PRNGKey(0)
    n, d, e, f, topk = 64, 16, 8, 32, 2
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (n, d))
    rw = jax.random.normal(ks[1], (d, e)) * 0.3
    wg = jax.random.normal(ks[2], (e, d, f)) * 0.2
    wu = jax.random.normal(ks[3], (e, d, f)) * 0.2
    wd = jax.random.normal(ks[4], (e, f, d)) * 0.2

    ref = moe_ffn(x, rw, wg, wu, wd, top_k=topk, capacity_factor=8.0)

    with compat.set_mesh(mesh):
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        out = jax.jit(
            lambda *a: moe_ffn_sharded(
                *a, top_k=topk, capacity_factor=8.0,
                batch_axes=("data",), model_axis="model",
            )
        )(xs, rw, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(ref.y),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(out.aux_loss), float(ref.aux_loss),
                               rtol=1e-4)
    assert float(out.dropped_frac) == 0.0
    print("moe OK")


def check_nequip(mesh):
    from repro.models.nequip import (
        NequIPConfig, nequip_forward, nequip_forward_sharded, nequip_init,
    )

    cfg = NequIPConfig(n_layers=2, d_hidden=8, l_max=2, n_rbf=4, d_feat=8,
                       n_out=5, radial_hidden=16, avg_degree=4.0)
    params = nequip_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_nodes, shards_nodes, shards_edges = 16, DATA, DATA * MODEL
    n_loc = n_nodes // shards_nodes
    # edges grouped by dst shard, padded to equal per-shard counts
    raw_e = 40
    src = rng.integers(0, n_nodes, raw_e).astype(np.int32)
    dst = rng.integers(0, n_nodes, raw_e).astype(np.int32)
    groups = [[] for _ in range(shards_nodes)]
    for s, t in zip(src, dst):
        groups[t // n_loc].append((s, t))
    # per dst-shard edge count must split evenly over the model axis
    per = (max(len(g) for g in groups) + MODEL - 1) // MODEL * MODEL
    es, ed, em = [], [], []
    for g in groups:
        g = g[:per]
        pad = per - len(g)
        es += [s for s, _ in g] + [0] * pad
        ed += [t for _, t in g] + [0] * pad
        em += [1.0] * len(g) + [0.0] * pad
    edge_index = jnp.asarray(np.stack([es, ed]), jnp.int32)
    edge_mask = jnp.asarray(em, jnp.float32)
    node_feat = jnp.asarray(rng.standard_normal((n_nodes, cfg.d_feat)),
                            jnp.float32)
    positions = jnp.asarray(rng.standard_normal((n_nodes, 3)), jnp.float32)

    ref = nequip_forward(params, node_feat, edge_index, positions, cfg,
                         edge_mask=edge_mask)
    with compat.set_mesh(mesh):
        out = jax.jit(
            lambda p, nf, ei, pos, m: nequip_forward_sharded(
                p, nf, ei, pos, cfg, m,
                node_axes=("data",), model_axis="model",
            )
        )(params, node_feat, edge_index, positions, edge_mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    print("nequip OK")


def check_sae_retrieval(mesh):
    from repro.distributed.sharding import AxisRules, axis_rules
    from repro.models import registry

    cell = registry.build_cell("compressae", "retrieval_100m", full=False)
    rng = np.random.default_rng(1)
    sae_a, vals_a, idx_a, norms_a, q_a = cell.abstract_args
    params = jax.tree.map(
        lambda s: jnp.asarray(rng.standard_normal(s.shape), s.dtype), sae_a
    )
    vals = jnp.asarray(rng.standard_normal(vals_a.shape), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 128, idx_a.shape), jnp.int32)
    norms = jnp.abs(jnp.asarray(rng.standard_normal(norms_a.shape), jnp.float32)) + 0.5
    q = jnp.asarray(rng.standard_normal(q_a.shape), jnp.float32)

    v_ref, i_ref = cell.fn(params, vals, idx, norms, q)   # no rules: unsharded
    with compat.set_mesh(mesh), axis_rules(AxisRules(batch=("data",))):
        v_sh, i_sh = jax.jit(cell.fn)(params, vals, idx, norms, q)
    np.testing.assert_allclose(np.asarray(v_sh), np.asarray(v_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(i_sh), np.asarray(i_ref))
    print("sae retrieval OK")


def check_encode_sharded(mesh):
    from repro.core import SAEConfig, encode, init_params
    from repro.core.sae import encode_sharded

    cfg = SAEConfig(d=32, h=128, k=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d))
    ref = encode(params, x, cfg.k)
    with compat.set_mesh(mesh):
        got = jax.jit(
            lambda p, xx: encode_sharded(p, xx, cfg.k, batch_axes=("data",),
                                         model_axis="model")
        )(params, x)
    # same selected (index -> value) mapping per row (order may differ)
    import repro.core.sparse as sp

    np.testing.assert_allclose(np.asarray(sp.densify(got)),
                               np.asarray(sp.densify(ref)),
                               rtol=1e-5, atol=1e-6)
    print("encode_sharded OK")


def check_distributed_retrieve():
    from repro.core import SAEConfig, build_index, encode, init_params, retrieve
    from repro.launch.mesh import make_candidate_mesh

    cfg = SAEConfig(d=32, h=128, k=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (203, cfg.d))  # ragged
    codes = encode(params, corpus, cfg.k)
    index = build_index(codes, params)
    q = encode(params, jax.random.normal(jax.random.PRNGKey(2), (7, cfg.d)),
               cfg.k)
    cand_mesh = make_candidate_mesh(DATA * MODEL)
    for mode in ("sparse", "reconstructed"):
        v0, i0 = retrieve(index, q, 20, mode=mode, params=params,
                          use_kernel=False)
        v1, i1 = retrieve(index, q, 20, mode=mode, params=params,
                          use_kernel=False, mesh=cand_mesh)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v0))
    print("distributed_retrieve OK")


def main():
    assert jax.device_count() >= DATA * MODEL, jax.devices()
    mesh = compat.make_mesh((DATA, MODEL), ("data", "model"))
    check_moe(mesh)
    check_nequip(mesh)
    check_sae_retrieval(mesh)
    check_encode_sharded(mesh)
    check_distributed_retrieve()
    print("ALL DISTRIBUTED EQUIV OK")


if __name__ == "__main__":
    main()
