"""The bench-regression gate itself (tools/check_bench.py) on hand-built
records — CI trusts it to tell schema/row-set/recall regressions (gate)
apart from timing noise (warn-only)."""
import json
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "tools"))
from check_bench import (  # noqa: E402
    compare,
    compare_serving,
    main,
    render_summary,
)


def rec(name, **over):
    base = {"name": name, "us_per_call": 1000.0, "recall": 0.5,
            "path": "jnp-chunked", "shards": 1, "n": 1024, "q": 16,
            "topn": 5, "smoke": True}
    base.update(over)
    return base


def by_name(*records):
    return {r["name"]: r for r in records}


def test_identical_records_pass():
    b = by_name(rec("retrieval_sparse"), rec("retrieval_dense"))
    failures, warnings = compare(b, dict(b), recall_tol=0.02)
    assert failures == [] and warnings == []


def test_missing_baseline_row_fails_new_row_warns():
    b = by_name(rec("retrieval_sparse"), rec("retrieval_dense"))
    f = by_name(rec("retrieval_sparse"), rec("retrieval_new"))
    failures, warnings = compare(b, f, recall_tol=0.02)
    assert any("disappeared" in x and "retrieval_dense" in x
               for x in failures)
    assert any("new row" in w and "retrieval_new" in w for w in warnings)


def test_recall_regression_gates_but_improvement_passes():
    b = by_name(rec("retrieval_sparse", recall=0.50))
    worse = by_name(rec("retrieval_sparse", recall=0.40))
    failures, _ = compare(b, worse, recall_tol=0.02)
    assert any("recall regression" in x for x in failures)
    better = by_name(rec("retrieval_sparse", recall=0.60))
    failures, _ = compare(b, better, recall_tol=0.02)
    assert failures == []
    # a drop within tolerance passes too
    close = by_name(rec("retrieval_sparse", recall=0.49))
    failures, _ = compare(b, close, recall_tol=0.02)
    assert failures == []


def test_recall_star_fields_are_gated_too():
    # the int8 row's recall_vs_exact is a recall* field: regression gates
    b = by_name(rec("retrieval_sparse_quantized_mxu", k=32,
                    precision="int8", recall_vs_exact=0.99,
                    score_mae=1e-4, rank_displacement=0.1, quality_n=32))
    f = by_name(rec("retrieval_sparse_quantized_mxu", k=32,
                    precision="int8", recall_vs_exact=0.80,
                    score_mae=1e-4, rank_displacement=0.1, quality_n=32))
    failures, _ = compare(b, f, recall_tol=0.02)
    assert any("recall_vs_exact" in x for x in failures)


def test_fault_matrix_row_schema_and_recall_gate():
    """ISSUE 6: the fault-matrix row's recovery-path fields are required,
    and its recall_vs_exact_min is a recall* field — a drop gates."""
    fm = dict(faults=["corrupt-index", "nonfinite-query"],
              recovered_exact=1, degraded=1,
              recall_vs_exact_min=0.98, coverage_min=0.75)
    # missing recovery-path fields fail the schema gate
    f = by_name(rec("retrieval_fault_matrix"))
    failures, _ = compare({}, f, recall_tol=0.02)
    assert any("schema" in x and "recovered_exact" in x for x in failures)
    # complete row passes
    f = by_name(rec("retrieval_fault_matrix", **fm))
    failures, _ = compare(dict(f), f, recall_tol=0.02)
    assert failures == []
    # a recall_vs_exact_min drop beyond tolerance gates
    worse = by_name(rec("retrieval_fault_matrix",
                        **{**fm, "recall_vs_exact_min": 0.70}))
    failures, _ = compare(f, worse, recall_tol=0.02)
    assert any("recall_vs_exact_min" in x for x in failures)
    # timing movement on the row stays warn-only
    slow = by_name(rec("retrieval_fault_matrix",
                       **{**fm, "us_per_call": 9000.0}))
    failures, warnings = compare(f, slow, recall_tol=0.02)
    assert failures == []
    assert any("us_per_call" in w for w in warnings)


def test_two_stage_row_schema_and_absolute_floor():
    """ISSUE 7: the two-stage row's quality fields are required, and its
    recall_vs_exact carries an ABSOLUTE 0.95 floor at full benchmark
    size — baseline-independent, so a quality collapse gates even when
    the baseline already collapsed."""
    ts = dict(recall_vs_exact=0.97, scanned_fraction=0.3125,
              candidate_fraction=0.3, quality_n=32)
    # missing quality fields fail the schema gate
    f = by_name(rec("retrieval_two_stage"))
    failures, _ = compare({}, f, recall_tol=0.02)
    assert any("schema" in x and "scanned_fraction" in x for x in failures)
    # complete full-size row above the floor passes
    f = by_name(rec("retrieval_two_stage", smoke=False, **ts))
    failures, _ = compare(dict(f), f, recall_tol=0.02)
    assert failures == []
    # below the floor fails EVEN against an identical (bad) baseline
    bad = by_name(rec("retrieval_two_stage", smoke=False,
                      **{**ts, "recall_vs_exact": 0.90}))
    failures, _ = compare(dict(bad), bad, recall_tol=0.02)
    assert any("quality floor" in x for x in failures)
    # smoke records are exempt from the absolute floor (tiny corpora make
    # absolute recall noise) but still get the relative recall* gate
    smoke = by_name(rec("retrieval_two_stage", smoke=True,
                        **{**ts, "recall_vs_exact": 0.90}))
    failures, _ = compare(dict(smoke), smoke, recall_tol=0.02)
    assert failures == []
    dropped = by_name(rec("retrieval_two_stage", smoke=True,
                          **{**ts, "recall_vs_exact": 0.70}))
    failures, _ = compare(smoke, dropped, recall_tol=0.02)
    assert any("recall_vs_exact" in x for x in failures)


def test_two_stage_device_row_schema_floor_and_parity():
    """ISSUE 8: the device two-stage row shares the host row's schema and
    absolute floor, and additionally must MATCH the host row's
    recall_vs_exact exactly — the device union is bit-identical to the
    host oracle by contract, so ANY divergence gates (no tolerance, no
    smoke exemption, and a device value ABOVE the host's gates too)."""
    ts = dict(recall_vs_exact=0.97, scanned_fraction=0.3125,
              candidate_fraction=0.3, quality_n=32)
    # missing quality fields fail the schema gate
    f = by_name(rec("retrieval_two_stage_device"))
    failures, _ = compare({}, f, recall_tol=0.02)
    assert any("schema" in x and "scanned_fraction" in x for x in failures)
    # complete full-size host+device pair above the floor passes
    f = by_name(rec("retrieval_two_stage", smoke=False, **ts),
                rec("retrieval_two_stage_device", smoke=False, **ts))
    failures, _ = compare(dict(f), f, recall_tol=0.02)
    assert failures == []
    # the absolute floor applies to the device row too
    bad = by_name(rec("retrieval_two_stage_device", smoke=False,
                      **{**ts, "recall_vs_exact": 0.90}))
    failures, _ = compare(dict(bad), bad, recall_tol=0.02)
    assert any("quality floor" in x and "device" in x for x in failures)
    # host/device divergence gates even at smoke size and even when the
    # device row reads HIGHER — bit-equality has no better-or-worse
    div = by_name(rec("retrieval_two_stage", smoke=True, **ts),
                  rec("retrieval_two_stage_device", smoke=True,
                      **{**ts, "recall_vs_exact": 0.99}))
    failures, _ = compare(dict(div), div, recall_tol=0.02)
    assert any("divergence" in x for x in failures)


def test_segmented_row_schema_floor_and_compaction_parity():
    """ISSUE 9: the segmented-index row must carry its mutation-trace and
    quality fields; recall_vs_exact shares the two-stage rows' absolute
    0.95 floor at full size; compaction_parity must equal 1 EXACTLY at
    ANY size — compact() reproducing the rebuilt index's checksum is a
    bit-identity contract, not a statistic."""
    sg = dict(recall_vs_exact=1.0, compaction_parity=1, quality_n=32,
              n_alive=1034, adds=24, deletes=14, base_coverage=0.9923)
    # missing mutation/quality fields fail the schema gate
    f = by_name(rec("retrieval_segmented"))
    failures, _ = compare({}, f, recall_tol=0.02)
    assert any("schema" in x and "compaction_parity" in x for x in failures)
    # complete full-size row passes
    f = by_name(rec("retrieval_segmented", smoke=False, **sg))
    failures, _ = compare(dict(f), f, recall_tol=0.02)
    assert failures == []
    # the absolute recall floor applies at full size, baseline or not
    bad = by_name(rec("retrieval_segmented", smoke=False,
                      **{**sg, "recall_vs_exact": 0.90}))
    failures, _ = compare(dict(bad), bad, recall_tol=0.02)
    assert any("quality floor" in x and "segmented" in x for x in failures)
    # ... but smoke records are exempt from it
    smoke = by_name(rec("retrieval_segmented", smoke=True,
                        **{**sg, "recall_vs_exact": 0.90}))
    failures, _ = compare(dict(smoke), smoke, recall_tol=0.02)
    assert failures == []
    # compaction parity gates exactly, smoke included
    broken = by_name(rec("retrieval_segmented", smoke=True,
                         **{**sg, "compaction_parity": 0}))
    failures, _ = compare(dict(broken), broken, recall_tol=0.02)
    assert any("compaction parity" in x for x in failures)


def test_inverted_index_row_schema():
    """ISSUE 7: the candidate-generator row must carry its cap and scan
    fraction so the work-reduction claim stays auditable."""
    f = by_name(rec("retrieval_inverted_index"))
    failures, _ = compare({}, f, recall_tol=0.02)
    assert any("schema" in x and "scan_frac" in x for x in failures)
    f = by_name(rec("retrieval_inverted_index", cap=4096, scan_frac=0.209))
    failures, _ = compare(dict(f), f, recall_tol=0.02)
    assert failures == []


def test_us_per_call_is_warn_only():
    b = by_name(rec("retrieval_sparse", us_per_call=1000.0))
    f = by_name(rec("retrieval_sparse", us_per_call=3000.0))
    failures, warnings = compare(b, f, recall_tol=0.02)
    assert failures == []
    assert any("us_per_call" in w and "warn-only" in w for w in warnings)


def test_changed_configuration_skips_recall_gate_with_warning():
    # different shape/path/shards: not comparable per docs/BENCHMARKS.md
    b = by_name(rec("retrieval_sparse", n=1024, recall=0.9))
    f = by_name(rec("retrieval_sparse", n=16384, recall=0.2))
    failures, warnings = compare(b, f, recall_tol=0.02)
    assert failures == []
    assert any("not comparable" in w for w in warnings)


def test_schema_gate_on_required_and_extra_fields():
    f = by_name({"name": "retrieval_sparse", "us_per_call": 1.0})
    failures, _ = compare({}, f, recall_tol=0.02)
    assert any("schema" in x and "recall" in x for x in failures)
    # the int8 row's extra fields are required on the fresh side
    f = by_name(rec("retrieval_sparse_quantized_mxu", k=32))
    failures, _ = compare({}, f, recall_tol=0.02)
    assert any("recall_vs_exact" in x for x in failures)


def test_main_end_to_end_with_summary(tmp_path):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    summary = tmp_path / "summary.md"
    base.write_text(json.dumps([rec("retrieval_sparse", recall=0.5)]))
    fresh.write_text(json.dumps([rec("retrieval_sparse", recall=0.5)]))
    assert main([str(base), str(fresh), "--summary", str(summary)]) == 0
    assert "**OK**" in summary.read_text()
    fresh.write_text(json.dumps([rec("retrieval_sparse", recall=0.1)]))
    assert main([str(base), str(fresh), "--summary", str(summary)]) == 1
    assert "**FAIL**" in summary.read_text()


def test_nameless_record_fails_cleanly(tmp_path):
    # a record without "name" must be a clean gate failure (reported in
    # the summary), not a KeyError traceback
    bad = tmp_path / "bad.json"
    good = tmp_path / "good.json"
    summary = tmp_path / "summary.md"
    bad.write_text(json.dumps([{"us_per_call": 1.0}]))
    good.write_text(json.dumps([rec("retrieval_sparse")]))
    assert main([str(bad), str(good), "--summary", str(summary)]) == 1
    assert "no 'name' field" in summary.read_text()


def test_render_summary_lists_findings():
    md = render_summary(["bad thing"], ["meh thing"])
    assert ":x: bad thing" in md and ":warning: meh thing" in md


# ------------------------------------------ serving schema (ISSUE 10)
def srec(name, **over):
    base = {"name": name, "p50_ms": 5.0, "p95_ms": 9.0, "p99_ms": 12.0,
            "throughput_rps": 800.0, "offered_rps": 900.0,
            "occupancy_mean": 0.8, "shed_rate": 0.0, "requests": 200,
            "path": "jnp-chunked", "shards": 1, "n": 2000, "users": 200,
            "topn": 10, "max_wait_us": 2000.0, "max_queue_rows": 256,
            "smoke": True}
    base.update(over)
    return base


def test_serving_identical_records_pass():
    b = by_name(srec("serving_closed_loop"), srec("serving_open_loop"))
    failures, warnings = compare_serving(b, dict(b), shed_tol=0.05)
    assert failures == [] and warnings == []


def test_serving_schema_gate():
    f = by_name({"name": "serving_closed_loop", "p50_ms": 5.0})
    failures, _ = compare_serving({}, f, shed_tol=0.05)
    assert any("schema" in x and "shed_rate" in x for x in failures)


def test_serving_sanity_gates_fire_without_a_baseline():
    """Bookkeeping bugs (a shed_rate of 1.2, inverted percentiles) gate
    on ANY machine, baseline or not — they are driver bugs, not noise."""
    f = by_name(srec("serving_closed_loop", shed_rate=1.2))
    failures, _ = compare_serving({}, f, shed_tol=0.05)
    assert any("shed_rate" in x and "not in [0, 1]" in x for x in failures)
    f = by_name(srec("serving_closed_loop", occupancy_mean=-0.1))
    failures, _ = compare_serving({}, f, shed_tol=0.05)
    assert any("occupancy_mean" in x for x in failures)
    f = by_name(srec("serving_open_loop", p50_ms=20.0, p95_ms=9.0))
    failures, _ = compare_serving({}, f, shed_tol=0.05)
    assert any("percentile ordering broken" in x for x in failures)


def test_serving_row_set_gate_and_new_row_warning():
    b = by_name(srec("serving_closed_loop"), srec("serving_open_loop"))
    f = by_name(srec("serving_closed_loop"), srec("serving_burst_loop"))
    failures, warnings = compare_serving(b, f, shed_tol=0.05)
    assert any("disappeared" in x and "serving_open_loop" in x
               for x in failures)
    assert any("new row" in w and "serving_burst_loop" in w
               for w in warnings)


def test_serving_shed_rate_regression_gates_within_tol_passes():
    b = by_name(srec("serving_open_loop", shed_rate=0.02))
    worse = by_name(srec("serving_open_loop", shed_rate=0.20))
    failures, _ = compare_serving(b, worse, shed_tol=0.05)
    assert any("shed-rate regression" in x for x in failures)
    close = by_name(srec("serving_open_loop", shed_rate=0.06))
    failures, _ = compare_serving(b, close, shed_tol=0.05)
    assert failures == []
    # shedding LESS is an improvement, never a failure
    better = by_name(srec("serving_open_loop", shed_rate=0.0))
    failures, _ = compare_serving(
        by_name(srec("serving_open_loop", shed_rate=0.2)), better,
        shed_tol=0.05)
    assert failures == []


def test_serving_config_change_skips_shed_gate_with_warning():
    # a different admission bound (or smoke vs full) is a different
    # serving system — shed rates are not comparable across them
    b = by_name(srec("serving_open_loop", max_queue_rows=256,
                     shed_rate=0.0))
    f = by_name(srec("serving_open_loop", max_queue_rows=64,
                     shed_rate=0.5))
    failures, warnings = compare_serving(b, f, shed_tol=0.05)
    assert failures == []
    assert any("not comparable" in w for w in warnings)


def test_serving_latency_and_throughput_are_warn_only():
    b = by_name(srec("serving_closed_loop", p50_ms=5.0, p95_ms=9.0,
                     p99_ms=12.0, throughput_rps=800.0))
    f = by_name(srec("serving_closed_loop", p50_ms=15.0, p95_ms=27.0,
                     p99_ms=36.0, throughput_rps=300.0))
    failures, warnings = compare_serving(b, f, shed_tol=0.05)
    assert failures == []
    assert any("p50_ms" in w and "warn-only" in w for w in warnings)
    assert any("throughput_rps" in w for w in warnings)


def test_serving_main_end_to_end(tmp_path):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    summary = tmp_path / "summary.md"
    rows = [srec("serving_closed_loop"), srec("serving_open_loop")]
    base.write_text(json.dumps(rows))
    fresh.write_text(json.dumps(rows))
    assert main([str(base), str(fresh), "--schema", "serving",
                 "--summary", str(summary)]) == 0
    assert "**OK**" in summary.read_text()
    fresh.write_text(json.dumps(
        [srec("serving_closed_loop", shed_rate=0.9),
         srec("serving_open_loop")]))
    assert main([str(base), str(fresh), "--schema", "serving",
                 "--summary", str(summary)]) == 1
    assert "**FAIL**" in summary.read_text()


def test_serving_gate_accepts_the_committed_record():
    """The committed BENCH_serving.json must pass its own gate against
    itself — otherwise the CI loadtest step is born red."""
    bench = pathlib.Path(__file__).parents[1] / "BENCH_serving.json"
    if not bench.exists():
        pytest.skip("no committed serving record")
    assert main([str(bench), str(bench), "--schema", "serving"]) == 0


def test_gate_accepts_the_committed_record():
    """The committed BENCH_retrieval.json must pass its own gate against
    itself — otherwise the CI step is born red."""
    bench = pathlib.Path(__file__).parents[1] / "BENCH_retrieval.json"
    if not bench.exists():
        pytest.skip("no committed perf record")
    assert main([str(bench), str(bench)]) == 0
