"""The retrieval-quality harness itself (repro.core.eval, ISSUE 5) on
hand-built cases with known answers — the harness gates the approximate
int8 serving path, so its own semantics (ties, clamping, the n > matches
edge) must be pinned before anything trusts it."""
import numpy as np
import pytest

from repro.core.eval import (
    rank_displacement,
    recall_at_n,
    retrieval_quality,
    score_mae,
)


# ------------------------------------------------------------- recall_at_n
def test_recall_known_overlap():
    # 3 of 4 reference ids recovered, order-insensitive
    assert recall_at_n([9, 1, 3, 7], [1, 3, 5, 9]) == pytest.approx(0.75)
    # perfect and zero overlap
    assert recall_at_n([1, 2], [2, 1]) == 1.0
    assert recall_at_n([5, 6], [1, 2]) == 0.0


def test_recall_batched_means_over_queries():
    got = [[1, 2, 3], [10, 11, 12]]
    ref = [[1, 2, 3], [12, 99, 98]]
    assert recall_at_n(got, ref) == pytest.approx((1.0 + 1 / 3) / 2)


def test_recall_truncates_to_n():
    # only the first n entries of both lists count
    assert recall_at_n([1, 2, 99, 98], [1, 2, 3, 4], n=2) == 1.0
    assert recall_at_n([99, 98, 1, 2], [1, 2, 3, 4], n=2) == 0.0


def test_recall_n_exceeds_matches_edge():
    # n beyond the rows' length clamps: a 3-long list measured at n=10 is
    # recall over the 3 ids actually present, not 3/10
    assert recall_at_n([4, 5, 6], [6, 5, 4], n=10) == 1.0
    assert recall_at_n([4, 5, 7], [6, 5, 4], n=10) == pytest.approx(2 / 3)


def test_recall_duplicate_reference_ids_count_once():
    # ties in a hand-built reference can duplicate an id: denominator is
    # the number of DISTINCT reference ids, keeping recall within [0, 1]
    assert recall_at_n([7, 8], [7, 7, 8]) == 1.0
    assert recall_at_n([7, 1], [7, 7, 8]) == pytest.approx(0.5)


def test_recall_query_count_mismatch_raises():
    with pytest.raises(ValueError, match="query-count mismatch"):
        recall_at_n([[1, 2]], [[1, 2], [3, 4]])


# --------------------------------------------------------------- score_mae
def test_score_mae_known_values():
    assert score_mae([3.0, 2.0, 1.0], [3.0, 2.0, 1.0]) == 0.0
    # positional |Δ| after both sides sort descending: (.5 + .5 + 0) / 3
    assert score_mae([2.5, 1.5, 1.0], [3.0, 2.0, 1.0]) == pytest.approx(0.5 * 2 / 3)


def test_score_mae_sorts_before_comparing():
    # provider order must not matter — only the score curves
    assert score_mae([1.0, 3.0, 2.0], [3.0, 2.0, 1.0]) == 0.0


def test_score_mae_ties_cost_nothing():
    # exactly tied scores compare equal positionally regardless of which
    # tied candidate each path surfaced first
    assert score_mae([2.0, 2.0, 1.0], [2.0, 2.0, 1.0]) == 0.0


def test_score_mae_truncates_to_common_width():
    # different lengths: compare the overlapping (sorted) prefix
    assert score_mae([3.0, 2.0], [3.0, 2.0, 1.0]) == 0.0
    assert score_mae([3.0, 2.0, 1.0], [3.0, 1.0], n=2) == pytest.approx(0.5)


# -------------------------------------------------------- rank_displacement
def test_rank_displacement_identity_is_zero():
    assert rank_displacement([5, 6, 7], [5, 6, 7]) == 0.0


def test_rank_displacement_adjacent_swap():
    # one adjacent transposition: two ids displaced by 1 each, one exact
    assert rank_displacement([6, 5, 7], [5, 6, 7]) == pytest.approx(2 / 3)


def test_rank_displacement_missing_id_charged_n():
    # 99 is absent from the reference: worst-case charge n (=3 here)
    assert rank_displacement([5, 6, 99], [5, 6, 7]) == pytest.approx(3 / 3)


def test_rank_displacement_duplicate_ref_resolves_to_best_rank():
    # a duplicated reference id maps to its FIRST (best) position: the 7
    # at approx rank 0 costs |0-0|, not |0-1|; 9 sits 1 rank off
    assert rank_displacement([7, 9], [7, 7, 9], n=3) == pytest.approx(0.5)


def test_rank_displacement_clamps_n():
    assert rank_displacement([5, 6], [6, 5], n=10) == 1.0


# -------------------------------------------------------- retrieval_quality
def test_retrieval_quality_bundle():
    approx = (np.array([[0.9, 0.8, 0.7]]), np.array([[4, 5, 9]]))
    exact = (np.array([[0.95, 0.8, 0.7]]), np.array([[5, 4, 6]]))
    out = retrieval_quality(approx, exact)
    assert out["n"] == 3
    assert out["recall"] == pytest.approx(2 / 3)
    assert out["score_mae"] == pytest.approx(0.05 / 3)
    # 4 and 5 swapped (1 each), 9 missing (charged 3): (1 + 1 + 3) / 3
    assert out["rank_displacement"] == pytest.approx(5 / 3)


def test_retrieval_quality_single_query_layout():
    # (n,) single-query layout, exactly as the squeezed serving API returns
    approx = (np.array([0.9, 0.8]), np.array([1, 2]))
    exact = (np.array([0.9, 0.8]), np.array([1, 2]))
    out = retrieval_quality(approx, exact)
    assert out == {"n": 2, "recall": 1.0, "score_mae": 0.0,
                   "rank_displacement": 0.0}


def test_retrieval_quality_respects_n():
    approx = (np.array([[0.9, 0.1]]), np.array([[1, 99]]))
    exact = (np.array([[0.9, 0.8]]), np.array([[1, 2]]))
    out = retrieval_quality(approx, exact, n=1)
    assert out["n"] == 1 and out["recall"] == 1.0
    assert out["score_mae"] == 0.0 and out["rank_displacement"] == 0.0
