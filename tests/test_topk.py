"""Equivalence tests for the abs-top-k family (paper eq. 1).

``abs_topk_sparse`` is the oracle; the grouped two-stage form and the
shard_map'd distributed form must select the same (value, index) sets.
The distributed form runs in a subprocess (the device count must be set
before jax initializes — same harness as test_distributed_equiv).
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topk import abs_topk, abs_topk_sparse, abs_topk_sparse_grouped


@pytest.mark.parametrize("b,h,k,groups", [(8, 256, 8, 4), (33, 512, 16, 8),
                                          (4, 128, 1, 2), (16, 256, 32, 8)])
def test_grouped_matches_single_stage(b, h, k, groups):
    x = jax.random.normal(jax.random.PRNGKey(b + h + k), (b, h))
    want_v, want_i = abs_topk_sparse(x, k)
    got_v, got_i = abs_topk_sparse_grouped(x, k, groups)
    # identical selection: random input has no |value| ties, so the sorted
    # (desc |value|) output order is also identical
    np.testing.assert_allclose(got_v, want_v, rtol=1e-6)
    np.testing.assert_array_equal(got_i, want_i)


def test_grouped_dense_activation_matches():
    x = jax.random.normal(jax.random.PRNGKey(0), (12, 256))
    np.testing.assert_allclose(abs_topk(x, 8, groups=4), abs_topk(x, 8), rtol=1e-6)


def test_grouped_leading_dims():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 128))
    want_v, want_i = abs_topk_sparse(x, 4)
    got_v, got_i = abs_topk_sparse_grouped(x, 4, 4)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-6)
    np.testing.assert_array_equal(got_i, want_i)


@pytest.mark.timeout(300)
def test_distributed_matches_single_device():
    script = pathlib.Path(__file__).with_name("_topk_distributed_impl.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, str(script)], env=env,
        capture_output=True, text=True, timeout=270,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "DISTRIBUTED TOPK OK" in proc.stdout


# ------------------------------------------- sharded_top_n ragged shards
# Regression (ISSUE 9 satellite): a local slice narrower than n — a tiny
# delta segment next to a huge base, or an uneven final shard — must pad
# out with the (-inf, -1) contract before the local top-k.  Previously
# lax.top_k rejected k > width outright.
def _run_sharded(scores, ids, n):
    from repro.core.retrieval import sharded_top_n

    f = jax.vmap(lambda s, i: sharded_top_n(s, i, n, axis_name="shards"),
                 axis_name="shards")
    return f(scores, ids)


def test_sharded_top_n_ragged_width_matches_global():
    n_shards, width, n = 4, 16, 32         # width < n: the ragged case
    scores = jax.random.normal(jax.random.PRNGKey(7), (n_shards, width))
    ids = jnp.arange(n_shards * width).reshape(n_shards, width)
    fv, fi = _run_sharded(scores, ids, n)
    want_v, want_i = jax.lax.top_k(scores.reshape(-1), n)
    for shard in range(n_shards):          # merged list replicated
        np.testing.assert_array_equal(np.asarray(fv[shard]),
                                      np.asarray(want_v))
        np.testing.assert_array_equal(np.asarray(fi[shard]),
                                      np.asarray(want_i))


def test_sharded_top_n_ragged_underfull_pads_neg_inf():
    # total candidates < n: the padding itself must surface as (-inf, -1)
    n_shards, width, n = 4, 5, 32
    scores = jax.random.normal(jax.random.PRNGKey(8), (n_shards, width))
    ids = jnp.arange(n_shards * width).reshape(n_shards, width)
    fv, fi = _run_sharded(scores, ids, n)
    total = n_shards * width
    want_v = np.sort(np.asarray(scores).ravel())[::-1]
    for shard in range(n_shards):
        v, i = np.asarray(fv[shard]), np.asarray(fi[shard])
        np.testing.assert_array_equal(v[:total], want_v)
        assert np.all(v[total:] == -np.inf) and np.all(i[total:] == -1)
        assert set(i[:total]) == set(range(total))


def test_sharded_top_n_ragged_lookup_table_variant():
    # the 1-D (N_loc,) id-table calling convention must pad identically
    n_shards, width, n = 2, 3, 8
    scores = jax.random.normal(jax.random.PRNGKey(9), (n_shards, width))
    ids = (jnp.arange(width)[None, :]
           + width * jnp.arange(n_shards)[:, None])
    fv, fi = _run_sharded(scores, ids, n)
    flat = np.asarray(scores).ravel()
    order = np.argsort(-flat, kind="stable")
    np.testing.assert_array_equal(np.asarray(fi[0])[: flat.size],
                                  order.astype(np.int32))
    assert np.all(np.asarray(fv[0])[flat.size:] == -np.inf)
