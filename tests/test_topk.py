"""Equivalence tests for the abs-top-k family (paper eq. 1).

``abs_topk_sparse`` is the oracle; the grouped two-stage form and the
shard_map'd distributed form must select the same (value, index) sets.
The distributed form runs in a subprocess (the device count must be set
before jax initializes — same harness as test_distributed_equiv).
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topk import abs_topk, abs_topk_sparse, abs_topk_sparse_grouped


@pytest.mark.parametrize("b,h,k,groups", [(8, 256, 8, 4), (33, 512, 16, 8),
                                          (4, 128, 1, 2), (16, 256, 32, 8)])
def test_grouped_matches_single_stage(b, h, k, groups):
    x = jax.random.normal(jax.random.PRNGKey(b + h + k), (b, h))
    want_v, want_i = abs_topk_sparse(x, k)
    got_v, got_i = abs_topk_sparse_grouped(x, k, groups)
    # identical selection: random input has no |value| ties, so the sorted
    # (desc |value|) output order is also identical
    np.testing.assert_allclose(got_v, want_v, rtol=1e-6)
    np.testing.assert_array_equal(got_i, want_i)


def test_grouped_dense_activation_matches():
    x = jax.random.normal(jax.random.PRNGKey(0), (12, 256))
    np.testing.assert_allclose(abs_topk(x, 8, groups=4), abs_topk(x, 8), rtol=1e-6)


def test_grouped_leading_dims():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 128))
    want_v, want_i = abs_topk_sparse(x, 4)
    got_v, got_i = abs_topk_sparse_grouped(x, 4, 4)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-6)
    np.testing.assert_array_equal(got_i, want_i)


@pytest.mark.timeout(300)
def test_distributed_matches_single_device():
    script = pathlib.Path(__file__).with_name("_topk_distributed_impl.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, str(script)], env=env,
        capture_output=True, text=True, timeout=270,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "DISTRIBUTED TOPK OK" in proc.stdout
