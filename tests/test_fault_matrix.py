"""Fault-matrix acceptance suite (ISSUE 6): every injected fault through
the full guarded serving stack must either recover BIT-identically to the
exact path or return a visibly degraded answer (``ServingStatus.degraded``
with a measured quality bound) — never crash, never silently serve wrong
results.

Covers the issue's acceptance criteria directly:
  * the startup self-check detects a single flipped byte in a quantized
    index (checksum mismatch -> typed error);
  * a 4-way sharded retrieve with one dead shard returns merged results
    from the 3 survivors, with the degradation (and its recall bound, the
    coverage) reported;
  * the full fault matrix never crashes and never quietly degrades.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    QuantizedIndex, SAEConfig, build_index, dequantize_index, encode,
    index_checksum, init_params, verify_index,
)
from repro.distributed.retrieve import (
    mesh_shard_count, partial_retrieve_prepped, shard_slices,
)
from repro.errors import IndexIntegrityError, ShardFailureError
from repro.launch.mesh import make_candidate_mesh
from repro.core.segments import SegmentedIndex
from repro.serving import (
    FAULTS, FaultInjector, GuardedEngine, RetrievalEngine, corrupt_postings,
    flip_delta_byte, flip_index_byte, poison_queries,
)

CFG = SAEConfig(d=32, h=128, k=8)
N, Q, TOPN = 327, 9, 16


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (N, CFG.d))
    queries = jax.random.normal(jax.random.PRNGKey(2), (Q, CFG.d))
    codes = encode(params, corpus, CFG.k)
    index = build_index(codes, params)
    qindex = build_index(codes, params, quantize=True)
    assert isinstance(qindex, QuantizedIndex)
    return params, index, qindex, queries


def recall_vs(ids, ref_ids):
    a, b = np.asarray(ids), np.asarray(ref_ids)
    return float(np.mean([
        len(set(r) & set(w)) / len(w) for r, w in zip(a, b)
    ]))


# -------------------------------------------------------- index integrity
def test_build_index_stores_verifiable_checksum(setup):
    _, index, qindex, _ = setup
    for idx in (index, qindex):
        assert idx.checksum is not None
        assert verify_index(idx)
        assert index_checksum(idx) == idx.checksum
    # dequantization mints a fresh digest over the new fp32 bytes
    d = dequantize_index(qindex)
    assert d.checksum is not None and d.checksum != qindex.checksum
    assert verify_index(d)


@pytest.mark.parametrize("byte,bit", [(0, 0), (17, 2), (1001, 7)])
def test_single_flipped_byte_is_detected(setup, byte, bit):
    """Acceptance criterion: ONE flipped bit anywhere in the stored code
    bytes -> typed IndexIntegrityError, before any request is served."""
    params, index, qindex, _ = setup
    for idx in (index, qindex):
        corrupt = flip_index_byte(idx, byte=byte, bit=bit)
        with pytest.raises(IndexIntegrityError, match="checksum mismatch"):
            verify_index(corrupt)
        with pytest.raises(IndexIntegrityError):
            GuardedEngine(RetrievalEngine(params, corrupt, use_kernel=False),
                          run_self_check=True)


def test_norm_corruption_is_detected_too(setup):
    """The checksum covers the norm arrays, not just the codes — poisoned
    norms would silently rerank everything."""
    _, index, _, _ = setup
    bad = index._replace(
        sparse_norms=index.sparse_norms.at[3].multiply(2.0)
    )
    with pytest.raises(IndexIntegrityError, match="checksum mismatch"):
        verify_index(bad)


# ------------------------------------------------- dead shard: merge path
@pytest.mark.distributed
def test_dead_shard_partial_merge_matches_survivor_oracle(
        setup, forced_device_count):
    """Acceptance criterion: 4-way sharded retrieve, shard 1 permanently
    dead -> merged results from the 3 survivors, bit-identical to an
    exact retrieve over exactly the surviving rows, degradation and
    coverage reported."""
    if forced_device_count < 4:
        pytest.skip("needs 4 devices")
    params, index, _, queries = setup
    mesh = make_candidate_mesh(4)
    assert mesh_shard_count(mesh) == 4
    g = GuardedEngine(
        RetrievalEngine(params, index, use_kernel=False, mesh=mesh),
        injector=FaultInjector("dead-shard", shard=1),
        retries=1, backoff_s=1e-4,
    )
    scores, ids, status, *_ = g.retrieve_dense(queries, TOPN)
    assert status.degraded and status.path == "fp32-ref-sharded"
    assert status.shards_total == 4 and status.shards_used == 3
    assert "partial merge over 3/4 shards" in status.fault

    # survivor oracle: mask shard 1's global rows out of the full-catalog
    # exact answer and re-rank — the merge must equal it bit-for-bit
    slices = shard_slices(N, 4)
    dead_rows = np.arange(*slices[1])
    oracle = RetrievalEngine(params, index, use_kernel=False)
    codes = oracle.encode_queries(queries)
    pq = oracle.prep_query(codes)
    ws, wi, cov = partial_retrieve_prepped(
        index, pq, TOPN, n_shards=4, dead_shards={1}, use_fused=False,
    )
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(ws))
    assert status.coverage == pytest.approx(cov)
    assert status.coverage == pytest.approx(1.0 - len(dead_rows) / N)
    # no survivor id comes from the dead shard's row range
    assert not np.isin(np.asarray(ids), dead_rows).any()


@pytest.mark.distributed
def test_flaky_shard_recovers_bit_identically(setup, forced_device_count):
    if forced_device_count < 4:
        pytest.skip("needs 4 devices")
    params, index, _, queries = setup
    mesh = make_candidate_mesh(4)
    g = GuardedEngine(
        RetrievalEngine(params, index, use_kernel=False, mesh=mesh),
        injector=FaultInjector("dead-shard", shard=2, recover_after=1),
        retries=2, backoff_s=1e-4,
    )
    scores, ids, status, *_ = g.retrieve_dense(queries, TOPN)
    # recovered on retry: full-coverage answer, annotated but NOT degraded
    assert not status.degraded and status.retries == 1
    assert status.coverage == 1.0
    assert "recovered after 1 retry" in status.fault
    wv, wi, *_ = RetrievalEngine(params, index,
                             use_kernel=False).retrieve_dense(queries, TOPN)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(wv))


@pytest.mark.distributed
def test_slow_shard_deadline_annotates_not_drops(setup, forced_device_count):
    """The deadline abandons slow retry paths, never the answer: an
    expired budget yields the correct full-coverage result tagged
    deadline_exceeded."""
    if forced_device_count < 2:
        pytest.skip("needs 2 devices")
    params, index, _, queries = setup
    mesh = make_candidate_mesh(2)
    g = GuardedEngine(
        RetrievalEngine(params, index, use_kernel=False, mesh=mesh),
        injector=FaultInjector("slow-shard", delay_s=0.02),
        deadline_ms=1.0,
    )
    scores, ids, status, *_ = g.retrieve_dense(queries, TOPN)
    assert status.deadline_exceeded
    assert not status.degraded and status.coverage == 1.0
    wv, wi, *_ = RetrievalEngine(params, index,
                             use_kernel=False).retrieve_dense(queries, TOPN)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(wv))


def test_all_shards_dead_is_typed(setup):
    params, index, _, queries = setup
    oracle = RetrievalEngine(params, index, use_kernel=False)
    pq = oracle.prep_query(oracle.encode_queries(queries))
    with pytest.raises(ShardFailureError, match="all 4 candidate shards"):
        partial_retrieve_prepped(index, pq, TOPN, n_shards=4,
                                 dead_shards={0, 1, 2, 3}, use_fused=False)


# ------------------------------------------------------- the full matrix
def test_fault_matrix_never_crashes(setup, forced_device_count):
    """Every fault in serving.faults.FAULTS, end to end: the guarded
    engine returns (scores, ids, status) where the answer is either
    bit-identical to the healthy exact path or explicitly degraded with
    recall@16 vs exact still clearing a floor.  No fault crashes."""
    params, index, qindex, queries = setup
    exact = RetrievalEngine(params, qindex, use_kernel=False)
    ev, ei, *_ = exact.retrieve_dense(queries, TOPN)
    mesh = (make_candidate_mesh(min(4, forced_device_count))
            if forced_device_count > 1 else None)
    fp_index = dequantize_index(qindex)

    def int8_engine():
        return RetrievalEngine(params, qindex, use_kernel=False,
                               precision="int8")

    def corrupted_segments():
        # flipped bit in the delta segment: the per-segment CRC catches
        # it at startup and serving sheds to base-only (coverage < 1.0)
        ecodes = encode(
            params, jax.random.normal(jax.random.PRNGKey(9), (8, CFG.d)),
            CFG.k)
        seg = SegmentedIndex.from_index(qindex)
        seg = seg.add_items(ecodes, ids=range(N, N + 8))
        return GuardedEngine(
            RetrievalEngine(params, flip_delta_byte(seg),
                            use_kernel=False, precision="int8"),
            run_self_check=True,
        )

    def corrupted_two_stage():
        # planted out-of-range posting id: stage 1's integrity check
        # fires, the ladder sheds candidate generation and serves the
        # exact single-stage scan
        eng = RetrievalEngine(params, qindex, use_kernel=False,
                              stage="two_stage", candidate_fraction=0.5)
        eng.inverted = corrupt_postings(eng.inverted)
        return eng

    matrix = {
        "corrupt-index": lambda: GuardedEngine(
            RetrievalEngine(params, flip_index_byte(qindex, byte=11, bit=5),
                            use_kernel=False, precision="int8"),
            run_self_check=True, fallback_index=fp_index,
        ),
        "nonfinite-query": lambda: GuardedEngine(
            int8_engine(), on_invalid="sanitize"
        ),
        "dead-shard": lambda: GuardedEngine(
            RetrievalEngine(params, qindex, use_kernel=False, mesh=mesh),
            injector=FaultInjector("dead-shard", shard=1),
            retries=1, backoff_s=1e-4,
        ),
        "slow-shard": lambda: GuardedEngine(
            RetrievalEngine(params, qindex, use_kernel=False, mesh=mesh),
            injector=FaultInjector("slow-shard", delay_s=0.005),
            deadline_ms=1.0,
        ),
        "kernel-exception": lambda: GuardedEngine(
            int8_engine(), injector=FaultInjector("kernel-exception")
        ),
        "corrupt-postings": lambda: GuardedEngine(corrupted_two_stage()),
        "corrupt-delta": corrupted_segments,
    }
    assert set(matrix) == set(FAULTS)

    for fault, build in matrix.items():
        if fault in ("dead-shard", "slow-shard") and mesh is None:
            continue
        guard = build()
        x = (poison_queries(queries, kind="nan", position=(1, 3))
             if fault == "nonfinite-query" else queries)
        scores, ids, status, *_ = guard.retrieve_dense(x, TOPN)  # never raises
        assert np.asarray(ids).shape == (Q, TOPN), fault
        identical = (np.array_equal(np.asarray(ids), np.asarray(ei))
                     and np.array_equal(np.asarray(scores), np.asarray(ev)))
        assert identical or status.degraded, (fault, status)
        r = recall_vs(ids, ei)
        if status.coverage == 1.0:
            # full-coverage recoveries: int8 vs exact quality floor on
            # this tiny corpus (see test_serving_engine's 0.85 bound)
            assert r >= 0.85, (fault, r, status)
        else:
            # partial merge: coverage itself is the recall bound
            assert r >= status.coverage - 0.25, (fault, r, status)
        assert np.all(np.isfinite(np.asarray(scores))), fault


def test_fault_matrix_specific_outcomes(setup):
    """Pin the recovery PATH per fault (not just 'did not crash'):
    corrupt-index serves the fallback exactly; kernel-exception lands on
    the exact rung bit-identically; sanitize reports the plant."""
    params, _, qindex, queries = setup
    exact = RetrievalEngine(params, qindex, use_kernel=False)
    ev, ei, *_ = exact.retrieve_dense(queries, TOPN)
    fp_index = dequantize_index(qindex)

    g = GuardedEngine(
        RetrievalEngine(params, flip_index_byte(qindex, byte=11, bit=5),
                        use_kernel=False, precision="int8"),
        run_self_check=True, fallback_index=fp_index,
    )
    scores, ids, status, *_ = g.retrieve_dense(queries, TOPN)
    assert status.degraded and "fallback" in status.fault
    # fallback = dequantized twin served exactly == the exact oracle
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ei))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(ev))

    g = GuardedEngine(
        RetrievalEngine(params, qindex, use_kernel=False, precision="int8"),
        injector=FaultInjector("kernel-exception"),
    )
    scores, ids, status, *_ = g.retrieve_dense(queries, TOPN)
    assert status.degraded and status.path == "quantized-ref"
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ei))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(ev))

    g = GuardedEngine(
        RetrievalEngine(params, qindex, use_kernel=False, precision="int8"),
        on_invalid="sanitize",
    )
    x = poison_queries(queries, kind="inf", position=(1, 3))
    scores, ids, status, *_ = g.retrieve_dense(x, TOPN)
    assert status.degraded and status.sanitized == 1
    # only the poisoned row's answer may differ from the healthy int8 one
    hv, hi, *_ = RetrievalEngine(
        params, qindex, use_kernel=False, precision="int8"
    ).retrieve_dense(queries, TOPN)
    keep = [r for r in range(Q) if r != 1]
    np.testing.assert_array_equal(np.asarray(ids)[keep],
                                  np.asarray(hi)[keep])


def test_corrupt_delta_sheds_to_base_only(setup):
    """Pin the corrupt-delta recovery PATH: the per-segment CRC catches
    the flipped bit at startup, serving sheds to base-only (the base IS
    the stale-but-verified replica — no fallback_index needed), base
    deletions stay masked, delta-only items become unservable, and
    ``ServingStatus.coverage`` reports the surviving fraction."""
    params, _, qindex, queries = setup
    ecodes = encode(
        params, jax.random.normal(jax.random.PRNGKey(9), (8, CFG.d)),
        CFG.k)
    seg = SegmentedIndex.from_index(qindex)
    seg = seg.add_items(ecodes, ids=range(N, N + 8))
    seg = seg.delete_items([5])

    g = GuardedEngine(
        RetrievalEngine(params, flip_delta_byte(seg),
                        use_kernel=False, precision="int8"),
        run_self_check=True,
    )
    assert "base-only" in g.degraded_from_start
    assert g.engine.segments.delta is None

    scores, ids, status, *_ = g.retrieve_dense(queries, TOPN)
    assert status.degraded and "base-only" in status.fault
    assert status.coverage == pytest.approx(seg.base_coverage)
    assert status.coverage == pytest.approx((N - 1) / (N - 1 + 8))
    returned = set(np.asarray(ids).ravel().tolist())
    assert not any(v >= N for v in returned)     # delta items are shed
    assert 5 not in returned                     # deletions persist

    # the answer is the healthy base-only engine's, bit for bit
    wv, wi, *_ = RetrievalEngine(
        params, seg.base_only(), use_kernel=False, precision="int8"
    ).retrieve_dense(queries, TOPN)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(wv))

    # a flipped BASE byte cannot shed (no verified segment left): with no
    # fallback index the integrity error surfaces typed
    base_bad = SegmentedIndex(
        flip_index_byte(seg.base, byte=3, bit=1), seg.base_ids,
        seg.base_alive, delta=seg.delta, delta_codes=seg.delta_codes,
        delta_ids=seg.delta_ids, delta_alive=seg.delta_alive,
    )
    with pytest.raises(IndexIntegrityError):
        GuardedEngine(RetrievalEngine(params, base_bad, use_kernel=False,
                                      precision="int8"),
                      run_self_check=True)
