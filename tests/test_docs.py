"""The docs are part of the deliverable (ISSUE 4): README + docs/ must
exist, cross-link, contain no broken internal links, and show only
commands that resolve to real modules/scripts.  The CI docs job
additionally EXECUTES the canonical commands (tools/check_docs.py --run);
here we gate the static half in-process so tier-1 catches doc rot fast.
"""
import pathlib
import sys

REPO = pathlib.Path(__file__).parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_docs_exist_and_are_cross_linked():
    for doc in ("README.md", "docs/ARCHITECTURE.md", "docs/BENCHMARKS.md"):
        assert (REPO / doc).exists(), f"{doc} missing"
    errors = check_docs.static_checks()
    assert not errors, "\n".join(errors)


def test_readme_shows_canonical_commands():
    readme = (REPO / "README.md").read_text()
    assert check_docs.TIER1_CMD in readme
    assert check_docs.SMOKE_CMD in readme
