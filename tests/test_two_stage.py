"""Two-stage retrieval (ISSUE 7 tentpole): inverted-index candidate
generation + fused re-rank over the gathered rows.

Covers the acceptance points that belong in tier-1 rather than the
benchmark harness: recall@32 vs the brute-force scan on a
trained-briefly corpus, tie/duplicate-id handling across the gather
boundary, bit-identity at candidate_fraction=1.0 (fp32 and int8), the
engine-config guard rails, and the degradation-ladder fallback under an
injected posting-corruption fault.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    SAEConfig, SparseCodes, build_index, encode, init_train_state, retrieve,
    train_step,
)
from repro.core.eval import recall_at_n
from repro.core.inverted_index import build_inverted_index
from repro.core.retrieval import two_stage_budget, two_stage_retrieve
from repro.data import clustered_embeddings
from repro.errors import EngineConfigError
from repro.optim import AdamConfig
from repro.serving import GuardedEngine, RetrievalEngine, corrupt_postings

CFG = SAEConfig(d=32, h=128, k=4)
N, NQ = 512, 8


@pytest.fixture(scope="module")
def trained():
    """A briefly trained SAE + encoded corpus/queries (module-scoped:
    training dominates this file's runtime)."""
    corpus = clustered_embeddings(jax.random.PRNGKey(0), N, d=CFG.d)
    queries = clustered_embeddings(jax.random.PRNGKey(1), NQ, d=CFG.d)
    state = init_train_state(CFG, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, CFG, AdamConfig(lr=3e-3)))
    for i in range(60):
        idx = jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(3), i), (256,), 0, N)
        state, _ = step(state, corpus[idx])
    params = state.params
    codes = encode(params, corpus, CFG.k)
    q = encode(params, queries, CFG.k)
    return params, build_index(codes, params), q, queries


def test_recall_at_32_vs_brute_force(trained):
    """Scanning half the catalog must keep recall@32 vs the exact
    brute-force scan above the serving floor (the full-size bench gates
    the same bound at candidate_fraction=0.3 via check_bench)."""
    _, index, q, _ = trained
    inv = build_inverted_index(index.codes, cap=N)
    _, ids = two_stage_retrieve(index, inv, q, 32, use_fused=False,
                                candidate_fraction=0.5)
    _, ref = retrieve(index, q, 32, use_kernel=False)
    assert recall_at_n(ids, ref) >= 0.95


def test_fraction_one_is_bit_identical_to_single_stage(trained):
    _, index, q, _ = trained
    inv = build_inverted_index(index.codes, cap=N)
    v2, i2 = two_stage_retrieve(index, inv, q, 10, use_fused=False,
                                candidate_fraction=1.0)
    v1, i1 = retrieve(index, q, 10, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i1))


def test_quantized_int8_two_stage_matches_single_stage(trained):
    """The gathered sub-index stays quantized: at candidate_fraction=1.0
    the int8-scored two-stage answer is bit-identical to the int8-scored
    single-stage engine."""
    params, index, q, _ = trained
    qindex = build_index(index.codes, params, quantize=True)
    two = RetrievalEngine(params, qindex, precision="int8",
                          stage="two_stage", candidate_fraction=1.0)
    one = RetrievalEngine(params, qindex, precision="int8")
    v2, i2 = two.retrieve_codes(q, 10)
    v1, i1 = one.retrieve_codes(q, 10)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i1))


def test_duplicate_rows_tie_break_across_gather_boundary():
    """Exact-duplicate catalog rows score identically; ``lax.top_k``
    breaks the tie toward the lowest id.  Because candidate rows are
    sorted ascending before the gather, the two-stage sub-index position
    order equals global-id order, so the tie resolves to the same ids as
    the single-stage scan even when the budget < N re-rank only sees a
    subset of the catalog."""
    n, h, k = 300, 8, 2
    idx = np.zeros((n, k), dtype=np.int32)
    val = np.zeros((n, k), dtype=np.float32)
    idx[:20] = [0, 1]            # 20 exact duplicates, all tied at the top
    val[:20] = [1.0, 1.0]
    idx[20:] = [6, 7]            # disjoint latents: score exactly 0
    val[20:] = [0.3, 0.2]
    codes = SparseCodes(values=jnp.asarray(val), indices=jnp.asarray(idx),
                        dim=h)
    index = build_index(codes)
    q = SparseCodes(values=jnp.asarray([[1.0, 1.0]], dtype=jnp.float32),
                    indices=jnp.asarray([[0, 1]], dtype=jnp.int32), dim=h)
    inv = build_inverted_index(codes, cap=n)
    # BLOCK_N rounding makes the budget 256 < N=300: a genuine sub-scan
    assert two_stage_budget(n, 10, 0.1) < n
    v2, i2 = two_stage_retrieve(index, inv, q, 10, use_fused=False,
                                candidate_fraction=0.1)
    v1, i1 = retrieve(index, q, 10, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i1))
    assert np.asarray(i2)[0, :10].tolist() == list(range(10))


def test_engine_config_guard_rails(trained):
    params, index, _, _ = trained
    with pytest.raises(EngineConfigError, match="stage"):
        RetrievalEngine(params, index, stage="three_stage")
    with pytest.raises(EngineConfigError, match="mode='sparse'"):
        RetrievalEngine(params, index, mode="reconstructed",
                        stage="two_stage")
    with pytest.raises(EngineConfigError, match="candidate_fraction"):
        RetrievalEngine(params, index, stage="two_stage",
                        candidate_fraction=0.0)


def test_engine_two_stage_matches_core_function(trained):
    params, index, q, queries = trained
    eng = RetrievalEngine(params, index, stage="two_stage",
                          candidate_fraction=0.5)
    v_e, i_e = eng.retrieve_codes(q, 10)
    v_c, i_c = two_stage_retrieve(index, eng.inverted, q, 10,
                                  use_fused=eng.use_fused,
                                  candidate_fraction=0.5)
    np.testing.assert_array_equal(np.asarray(v_e), np.asarray(v_c))
    np.testing.assert_array_equal(np.asarray(i_e), np.asarray(i_c))
    # dense entry point: encode folded in front of the same path
    v_d, i_d, *_ = eng.retrieve_dense(queries, 10)
    assert v_d.shape == (NQ, 10) and i_d.shape == (NQ, 10)


def test_guard_falls_back_on_corrupt_postings(trained):
    """Posting corruption trips the stage-1 integrity check; the ladder
    steps down to the single-stage rung and the answer is bit-identical
    to a healthy single-stage engine."""
    params, index, _, queries = trained
    eng = RetrievalEngine(params, index, stage="two_stage",
                          candidate_fraction=0.5, use_kernel=False)
    guard = GuardedEngine(eng)
    assert guard.ladder[0].startswith("two-stage-")
    # healthy: served by the primary two-stage rung
    _, _, status, *_ = guard.retrieve_dense(queries, 8)
    assert status.step == 0 and not status.degraded
    eng.inverted = corrupt_postings(eng.inverted)
    v, ids, status, *_ = guard.retrieve_dense(queries, 8)
    assert status.step >= 1 and status.degraded
    assert "postings corrupted" in status.fault
    single = RetrievalEngine(params, index, use_kernel=False)
    v1, i1, *_ = single.retrieve_dense(queries, 8)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(i1))
