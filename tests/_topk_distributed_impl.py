"""Runs under 4 fake devices (spawned by test_topk.py; the forcing flag is
inherited from the tier-1 conftest environment, with a flag-append so the
script stays standalone-runnable).

distributed_abs_topk_sparse inside shard_map (h sharded over a 'model'
axis) must match the single-device abs_topk_sparse oracle.  Goes through
the repro.compat shim so it runs on jax 0.4.x and >= 0.6.
"""
import os

_FORCE = "xla_force_host_platform_device_count"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"{os.environ.get('XLA_FLAGS', '')} --{_FORCE}=4"
    ).strip()

import numpy as np
import jax

from repro import compat
from repro.compat import P
from repro.core.topk import abs_topk_sparse, distributed_abs_topk_sparse


def main():
    assert jax.device_count() >= 4, jax.devices()
    mesh = compat.make_mesh((4,), ("model",))
    for b, h, k in [(8, 256, 8), (17, 128, 4), (4, 512, 32)]:
        x = jax.random.normal(jax.random.PRNGKey(b + h), (b, h))
        h_local = h // 4

        def local_fn(xl):
            off = jax.lax.axis_index("model") * h_local
            return distributed_abs_topk_sparse(
                xl, k, axis_name="model", shard_offset=off
            )

        got_v, got_i = jax.jit(
            compat.shard_map(
                local_fn, mesh=mesh,
                in_specs=P(None, "model"),
                out_specs=(P(None, None), P(None, None)),
                check=False,  # replicated via all_gather; not inferred
            )
        )(x)
        want_v, want_i = abs_topk_sparse(x, k)
        np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
        print(f"ok b={b} h={h} k={k}")
    print("DISTRIBUTED TOPK OK")


if __name__ == "__main__":
    main()
