"""Runs under 4 fake devices (spawned by test_topk.py).

distributed_abs_topk_sparse inside shard_map (h sharded over a 'model'
axis) must match the single-device abs_topk_sparse oracle.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.topk import abs_topk_sparse, distributed_abs_topk_sparse


def main():
    assert jax.device_count() == 4, jax.devices()
    mesh = Mesh(np.array(jax.devices()), ("model",))
    for b, h, k in [(8, 256, 8), (17, 128, 4), (4, 512, 32)]:
        x = jax.random.normal(jax.random.PRNGKey(b + h), (b, h))
        h_local = h // 4

        def local_fn(xl):
            off = jax.lax.axis_index("model") * h_local
            return distributed_abs_topk_sparse(
                xl, k, axis_name="model", shard_offset=off
            )

        got_v, got_i = jax.jit(
            shard_map(
                local_fn, mesh=mesh,
                in_specs=P(None, "model"),
                out_specs=(P(None, None), P(None, None)),
                check_rep=False,  # replicated via all_gather; not inferred
            )
        )(x)
        want_v, want_i = abs_topk_sparse(x, k)
        np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
        print(f"ok b={b} h={h} k={k}")
    print("DISTRIBUTED TOPK OK")


if __name__ == "__main__":
    main()
