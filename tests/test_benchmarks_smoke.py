"""Non-gating perf-trajectory step: runs the benchmark harness in --smoke
mode (tiny sizes) so every tier-1 run refreshes BENCH_retrieval.json.

Non-gating by design: a perf-harness *failure* SKIPs (with the log
attached) instead of failing the build — correctness is covered by the
real tests.  The BENCH_retrieval.json record *schema* (backend path,
shard count) IS gated once a run succeeds, so the perf trajectory stays
comparable across PRs and backends.  The subprocess inherits the
conftest-forced multi-device CPU topology, so the candidate-sharded mode
runs on a real multi-way mesh.
"""
import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).parents[1]


@pytest.mark.timeout(600)
def test_benchmarks_smoke_writes_perf_record(forced_device_count):
    env = {"PYTHONPATH": str(REPO / "src")}
    import os

    env = {**os.environ, **env}
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--smoke"],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=540,
        )
    except subprocess.TimeoutExpired:
        pytest.skip("benchmark smoke timed out (non-gating)")
    if proc.returncode != 0:
        pytest.skip(
            "benchmark smoke failed (non-gating):\n"
            f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"
        )
    bench = REPO / "BENCH_retrieval.json"
    assert bench.exists(), "smoke run succeeded but wrote no perf record"
    records = json.loads(bench.read_text())
    by_name = {r["name"]: r for r in records}
    assert "retrieval_sparse" in by_name
    # ISSUE 3: the serving-engine whole-request row (dense embeddings in,
    # encode folded into the kernel chain) is part of the record schema
    assert "retrieval_e2e_dense" in by_name
    # record schema: every row carries the backend path and shard count
    for r in records:
        assert {"name", "us_per_call", "recall", "path", "shards"} <= set(r), r
        assert r["path"] in ("fused-kernel", "jnp-chunked"), r
        assert r["shards"] >= 1, r
    # the sharded mode ran on the conftest-forced multi-device topology
    sharded = by_name["retrieval_sparse_sharded"]
    assert sharded["shards"] == min(4, forced_device_count), sharded
    # ISSUE 4: the quantized serving row is part of the record schema and
    # must report its index-HBM bytes (computed from the live arrays) at
    # <= 40% of the fp32 SparseIndex at the paper's k=32, h < 65536
    quant = by_name["retrieval_sparse_quantized"]
    assert quant["k"] == 32, quant
    assert quant["index_bytes"] <= 0.40 * quant["index_bytes_fp32"], quant
    # ISSUE 5: the approximate int8-scoring row must carry the harness
    # metrics measured against the exact quantized path (recall@32 — the
    # 0.95 bound at full benchmark size is gated by
    # tests/test_retrieval_quality.py and the full-size harness run; the
    # smoke record only has to be present, well-formed, and sane)
    mxu = by_name["retrieval_sparse_quantized_mxu"]
    assert mxu["k"] == 32 and mxu["precision"] == "int8", mxu
    assert mxu["quality_n"] == 32, mxu
    assert 0.0 <= mxu["recall_vs_exact"] <= 1.0, mxu
    assert mxu["score_mae"] >= 0.0, mxu
    assert mxu["rank_displacement"] >= 0.0, mxu
    # int8-vs-exact quality is seeded and deterministic on CPU: even the
    # tiny smoke corpus clears a comfortable floor
    assert mxu["recall_vs_exact"] >= 0.8, mxu
    # ISSUE 6: the fault-matrix row records the recovery-path outcome of
    # every injected fault — all entries are either bit-identical
    # recoveries or visibly degraded answers, and the worst full-coverage
    # recall vs exact clears the smoke floor
    # ISSUE 7: the two-stage serving row carries its quality-vs-exact and
    # scanned-work metrics (the 0.95 floor at full size is gated by
    # tools/check_bench.py; the smoke record has to be present and sane)
    ts = by_name["retrieval_two_stage"]
    assert 0.0 <= ts["recall_vs_exact"] <= 1.0, ts
    assert 0.0 < ts["scanned_fraction"] <= 0.5, ts
    assert 0.0 < ts["candidate_fraction"] <= 1.0, ts
    assert ts["quality_n"] == 32, ts
    # ISSUE 8: the device-stage-1 two-stage row mirrors the host row's
    # quality fields and must agree with it EXACTLY — the device union
    # is bit-identical to the host oracle, so the whole request is
    tsd = by_name["retrieval_two_stage_device"]
    assert tsd["recall_vs_exact"] == ts["recall_vs_exact"], (tsd, ts)
    assert tsd["scanned_fraction"] == ts["scanned_fraction"], (tsd, ts)
    assert tsd["candidate_fraction"] == ts["candidate_fraction"], (tsd, ts)
    assert tsd["quality_n"] == 32, tsd
    # ISSUE 9: the segmented mutable-index row serves the mutated catalog
    # (base + delta + deletion masks).  Its recall_vs_exact is measured
    # against a fresh build_index over the surviving rows — 1.0 by the
    # bit-identity contract at ANY size — and compaction_parity is the
    # checksum equality of compact() vs that rebuilt index, also
    # size-independent, so both gate exactly even on the smoke record
    sg = by_name["retrieval_segmented"]
    assert sg["recall_vs_exact"] == 1.0, sg
    assert sg["compaction_parity"] == 1, sg
    assert sg["quality_n"] == 32, sg
    assert sg["adds"] >= 1 and sg["deletes"] >= 1, sg
    assert sg["n_alive"] == sg["n"] + sg["adds"] - sg["deletes"], sg
    assert 0.0 < sg["base_coverage"] <= 1.0, sg
    # ISSUE 7: the candidate-generator row (inverted-index bench) appends
    # after retrieval_modes' wholesale rewrite — presence proves ordering
    inv = by_name["retrieval_inverted_index"]
    assert inv["cap"] >= 1 and 0.0 < inv["scan_frac"] <= 1.0, inv
    fm = by_name["retrieval_fault_matrix"]
    assert set(fm["faults"]) >= {"corrupt-index", "nonfinite-query",
                                 "kernel-exception"}, fm
    if fm["shards"] > 1:  # shard faults need the forced multi-device mesh
        assert {"dead-shard-flaky", "dead-shard-permanent",
                "slow-shard"} <= set(fm["faults"]), fm
    assert fm["recovered_exact"] + fm["degraded"] >= len(fm["faults"]), fm
    assert fm["recall_vs_exact_min"] >= 0.8, fm
    assert 0.0 < fm["coverage_min"] <= 1.0, fm
