"""Non-gating perf-trajectory step: runs the benchmark harness in --smoke
mode (tiny sizes) so every tier-1 run refreshes BENCH_retrieval.json.

Non-gating by design: a perf-harness failure SKIPs (with the log attached)
instead of failing the build — correctness is covered by the real tests.
"""
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).parents[1]


@pytest.mark.timeout(600)
def test_benchmarks_smoke_writes_perf_record():
    env = {"PYTHONPATH": str(REPO / "src")}
    import os

    env = {**os.environ, **env}
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--smoke"],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=540,
        )
    except subprocess.TimeoutExpired:
        pytest.skip("benchmark smoke timed out (non-gating)")
    if proc.returncode != 0:
        pytest.skip(
            "benchmark smoke failed (non-gating):\n"
            f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"
        )
    bench = REPO / "BENCH_retrieval.json"
    assert bench.exists(), "smoke run succeeded but wrote no perf record"
    assert "retrieval_sparse" in bench.read_text()
