"""Candidate-sharded ``distributed_retrieve`` == single-device
``core.retrieve()`` — bit-identical scores AND ids, ties included.

Runs in-process on the forced multi-device CPU topology (tests/conftest.py).
A deterministic grid always gates the equivalence; when the optional
``hypothesis`` dev dependency is installed, a property-based sweep widens
the shape coverage (random N/Q/k/h/n/shard-count, including ragged N and
n larger than a shard's slice).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import SAEConfig, build_index, encode, init_params, retrieve
from repro.core.types import SparseCodes
from repro.launch.mesh import make_candidate_mesh

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile(
        "repro_dist", deadline=None, max_examples=20, derandomize=True
    )
    hypothesis.settings.load_profile("repro_dist")
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.distributed

CFG = SAEConfig(d=32, h=128, k=4)


def _index_and_queries(n_cand, nq, seed=0, dup_rows=0):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    corpus = jax.random.normal(jax.random.PRNGKey(seed + 1), (n_cand, CFG.d))
    if dup_rows:
        # duplicate a prefix onto the tail -> exactly tied scores whose ids
        # straddle shard boundaries
        corpus = jnp.concatenate([corpus, corpus[:dup_rows]])
    queries = jax.random.normal(jax.random.PRNGKey(seed + 2), (nq, CFG.d))
    codes = encode(params, corpus, CFG.k)
    q = encode(params, queries, CFG.k)
    return params, build_index(codes, params), q


def _assert_bit_identical(index, q, n, shards, params=None, mode="sparse"):
    mesh = make_candidate_mesh(shards)
    v0, i0 = retrieve(index, q, n, mode=mode, params=params, use_kernel=False)
    v1, i1 = retrieve(index, q, n, mode=mode, params=params, use_kernel=False,
                      mesh=mesh)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v0))


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize(
    "n_cand,nq,n",
    [
        (512, 8, 10),   # even split
        (37, 5, 10),    # ragged: N % shards != 0 for every multi-way mesh
        (16, 3, 10),    # n > per-shard candidate count (4-way: 4/shard)
        (100, 2, 100),  # n == N (every shard returns its whole slice)
    ],
)
def test_matches_single_device(n_cand, nq, n, shards, forced_device_count):
    if shards > forced_device_count:
        pytest.skip(f"needs {shards} devices")
    params, index, q = _index_and_queries(n_cand, nq)
    _assert_bit_identical(index, q, n, shards)


@pytest.mark.parametrize("shards", [2, 4])
def test_tied_scores_resolve_to_same_ids(shards, forced_device_count):
    if shards > forced_device_count:
        pytest.skip(f"needs {shards} devices")
    # 13 duplicated rows: ties between ids 0..12 and 40..52 across shards
    params, index, q = _index_and_queries(40, 6, seed=3, dup_rows=13)
    _assert_bit_identical(index, q, 20, shards)


def test_reconstructed_mode_and_single_query(forced_device_count):
    if forced_device_count < 4:
        pytest.skip("needs 4 devices")
    params, index, q = _index_and_queries(203, 7, seed=5)
    _assert_bit_identical(index, q, 15, 4, params=params, mode="reconstructed")
    single = SparseCodes(values=q.values[0], indices=q.indices[0], dim=q.dim)
    _assert_bit_identical(index, single, 5, 4)


def test_top_n_exceeding_catalog_raises(forced_device_count):
    if forced_device_count < 2:
        pytest.skip("needs 2 devices")
    params, index, q = _index_and_queries(32, 2)
    with pytest.raises(ValueError, match="exceeds candidate count"):
        retrieve(index, q, 33, use_kernel=False, mesh=make_candidate_mesh(2))


def test_jitted_serving_pattern(forced_device_count):
    if forced_device_count < 4:
        pytest.skip("needs 4 devices")
    params, index, q = _index_and_queries(200, 1, seed=7)
    mesh = make_candidate_mesh(4)
    qd = jax.random.normal(jax.random.PRNGKey(9), (8, CFG.d))
    f = jax.jit(lambda x: retrieve(index, encode(params, x, CFG.k), 10,
                                   use_kernel=False, mesh=mesh))
    g = jax.jit(lambda x: retrieve(index, encode(params, x, CFG.k), 10,
                                   use_kernel=False))
    np.testing.assert_array_equal(np.asarray(f(qd)[1]), np.asarray(g(qd)[1]))
    np.testing.assert_array_equal(np.asarray(f(qd)[0]), np.asarray(g(qd)[0]))


if HAVE_HYPOTHESIS:

    @given(
        n_cand=st.integers(min_value=8, max_value=300),
        nq=st.integers(min_value=1, max_value=8),
        n=st.integers(min_value=1, max_value=40),
        shards=st.sampled_from([1, 2, 4]),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_property_matches_single_device(n_cand, nq, n, shards, seed):
        if shards > jax.device_count():
            return
        n = min(n, n_cand)
        params, index, q = _index_and_queries(n_cand, nq, seed=seed)
        _assert_bit_identical(index, q, n, shards)
