"""Hypothesis property tests for the segmented mutable index (ISSUE 9).

Random mutation traces — interleaved add/delete/compact with drawn
sizes, drawn victims, and drawn top-n (including n > survivors) — must
preserve the bit-identity contract against the rebuilt-index oracle,
and ids that were EVER deleted (and not re-added) must never appear in
any result, padded slots included.

Ref path only (use_fused=False): the deterministic grid in
tests/test_segments.py pins the fused kernels on the same contract;
here Hypothesis explores trace space, where interpret-mode kernel
recompiles per drawn shape would dominate the run time.
"""
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
import hypothesis
import hypothesis.strategies as st
import jax
import numpy as np
from hypothesis import given

from repro.core import SAEConfig, build_index, encode, init_params
from repro.core.segments import SegmentedIndex

from test_segments import (
    _ledger_codes,
    _ledger_from,
    _rows,
    oracle_retrieve,
)

hypothesis.settings.register_profile(
    "repro_segments", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("repro_segments")

CFG = SAEConfig(d=16, h=64, k=4)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (40, CFG.d))
    codes = encode(params, corpus, CFG.k)
    queries = jax.random.normal(jax.random.PRNGKey(2), (5, CFG.d))
    qcodes = encode(params, queries, CFG.k)
    pool = encode(params,
                  jax.random.normal(jax.random.PRNGKey(3), (24, CFG.d)),
                  CFG.k)
    return codes, qcodes, pool


@given(st.data())
def test_random_trace_matches_rebuilt_oracle(setup, data):
    codes, qcodes, pool = setup
    quantize = data.draw(st.booleans(), label="quantize")
    precision = ("int8" if quantize and data.draw(st.booleans(),
                                                  label="int8")
                 else "exact")
    # test_segments helpers key the ledger codes dim off their module's
    # CFG.h; rebuild with OUR dim
    ledger = {k: v for k, v in _ledger_from(codes, range(40)).items()}
    seg = SegmentedIndex.from_index(build_index(codes, quantize=quantize))

    deleted_now: set[int] = set()
    next_id, pool_pos = 1000, 0
    n_ops = data.draw(st.integers(1, 6), label="n_ops")
    for _ in range(n_ops):
        op = data.draw(st.sampled_from(["add", "delete", "compact"]),
                       label="op")
        if op == "add" and pool_pos < 24:
            m = min(data.draw(st.integers(1, 4), label="m"),
                    24 - pool_pos)
            rows = list(range(pool_pos, pool_pos + m))
            # sometimes resurrect a previously deleted id instead of a
            # fresh one — the delete-then-readd path
            ids = []
            for _ in range(m):
                if deleted_now and data.draw(st.booleans(),
                                             label="readd"):
                    rid = sorted(deleted_now)[0]
                    deleted_now.discard(rid)
                    ids.append(rid)
                else:
                    ids.append(next_id)
                    next_id += 1
            chunk = _rows(pool, rows)
            ledger.update(_ledger_from(chunk, ids))
            seg = seg.add_items(chunk, ids=ids)
            pool_pos += m
        elif op == "delete":
            alive = [int(v) for v in seg.alive_ids()]
            if len(alive) <= 4:
                continue
            k = data.draw(st.integers(1, min(4, len(alive) - 4)),
                          label="k")
            picks = data.draw(
                st.lists(st.integers(0, len(alive) - 1),
                         min_size=k, max_size=k, unique=True),
                label="victims")
            victims = [alive[j] for j in picks]
            deleted_now.update(victims)
            seg = seg.delete_items(victims)
        elif op == "compact":
            seg = seg.compact()
            assert seg.delta is None and seg.base_alive.all()

    n = data.draw(st.integers(1, seg.n_alive + 10), label="n")
    surv = np.asarray(seg.alive_ids())
    rebuilt = build_index(_ledger_codes_dim(ledger, surv, CFG.h),
                          quantize=quantize)
    want_s, want_i = oracle_retrieve(rebuilt, surv, qcodes, n,
                                     use_fused=False, precision=precision)
    got_s, got_i = seg.retrieve(qcodes, n, use_fused=False,
                                precision=precision)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))

    # deleted ids NEVER appear — padded slots are -1, nothing else leaks
    returned = {int(v) for v in np.asarray(got_i).ravel()}
    assert not (returned & deleted_now)
    assert returned <= {int(v) for v in surv} | {-1}


def _ledger_codes_dim(ledger, ids, dim):
    out = _ledger_codes(ledger, ids)
    return out._replace(dim=dim)
