"""RetrievalEngine (ISSUE 3) == the composed ``encode()`` + ``retrieve()``
pipeline — BIT-identical scores, ids, and tie resolution, for both modes,
both backends (fused kernels in interpret mode / chunked jnp), and 1/2/4-way
candidate-sharded meshes (on the conftest-forced multi-device CPU topology).

Since ISSUE 4 the same contract covers the quantized serving format: an
engine over a ``QuantizedIndex`` must be bit-identical to an engine over
the dequantized index across the whole modes × backends × meshes matrix.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    QuantizedIndex, SAEConfig, build_index, dequantize_index, encode,
    init_params, retrieve,
)
from repro.core.types import SparseCodes
from repro.launch.mesh import make_candidate_mesh
from repro.serving import EngineConfig, RetrievalEngine

CFG = SAEConfig(d=32, h=128, k=8)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (310, CFG.d))
    # duplicate a prefix onto the tail -> exactly tied scores, so the
    # engine's tie resolution is exercised against the composed path's
    corpus = jnp.concatenate([corpus, corpus[:17]])
    queries = jax.random.normal(jax.random.PRNGKey(2), (9, CFG.d))
    index = build_index(encode(params, corpus, CFG.k), params)
    return params, index, queries


def _assert_engine_matches_composed(params, index, x, n, mode, use_kernel,
                                    mesh=None):
    engine = RetrievalEngine(index, params,
                    config=EngineConfig(mode=mode, use_kernel=use_kernel, mesh=mesh))
    got_v, got_i, *_ = engine.retrieve_dense(x, n)
    want_v, want_i = retrieve(
        index, encode(params, x, CFG.k), n,
        mode=mode, params=params, use_kernel=use_kernel, mesh=mesh,
    )
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    return engine


@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_engine_matches_composed_path(setup, mode, use_kernel):
    params, index, queries = setup
    _assert_engine_matches_composed(params, index, queries, 25, mode,
                                    use_kernel)


@pytest.mark.distributed
@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_engine_matches_composed_sharded(setup, mode, shards,
                                         forced_device_count):
    if shards > forced_device_count:
        pytest.skip(f"needs {shards} devices")
    params, index, queries = setup
    mesh = make_candidate_mesh(shards)
    engine = _assert_engine_matches_composed(
        params, index, queries, 20, mode, False, mesh=mesh
    )
    # and the sharded engine must equal the UNsharded engine bit-for-bit
    single = RetrievalEngine(index, params,
                    config=EngineConfig(mode=mode, use_kernel=False))
    sv, si, *_ = single.retrieve_dense(queries, 20)
    gv, gi, *_ = engine.retrieve_dense(queries, 20)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(si))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(sv))


@pytest.fixture(scope="module")
def qsetup(setup):
    """Quantized index over the SAME corpus codes (ties included) + its
    dequantized twin — the exactness oracle for quantized serving."""
    params, index, queries = setup
    qindex = build_index(index.codes, params, quantize=True)
    assert isinstance(qindex, QuantizedIndex)
    return params, qindex, dequantize_index(qindex), queries


@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_quantized_engine_matches_dequantized(qsetup, mode, use_kernel):
    """Serving straight from the quantized index must be BIT-identical —
    scores, ids, ties — to serving the dequantized index, on both
    backends and both modes.  Quantization error is a build-time choice,
    never a serving-path one."""
    params, qindex, dindex, queries = qsetup
    eq = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=use_kernel))
    ed = RetrievalEngine(dindex, params,
                    config=EngineConfig(mode=mode, use_kernel=use_kernel))
    qv, qi, *_ = eq.retrieve_dense(queries, 25)
    dv, di, *_ = ed.retrieve_dense(queries, 25)
    np.testing.assert_array_equal(np.asarray(qi), np.asarray(di))
    np.testing.assert_array_equal(np.asarray(qv), np.asarray(dv))
    # and the codes-in entry point agrees too
    q_codes = encode(params, queries, CFG.k)
    qv2, qi2 = eq.retrieve_codes(q_codes, 12)
    dv2, di2 = ed.retrieve_codes(q_codes, 12)
    np.testing.assert_array_equal(np.asarray(qi2), np.asarray(di2))
    np.testing.assert_array_equal(np.asarray(qv2), np.asarray(dv2))


@pytest.mark.distributed
@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_quantized_engine_sharded(qsetup, mode, shards, forced_device_count):
    """Candidate-sharding the quantized index (the int8/int16 arrays are
    what the mesh shards) must stay bit-identical to both the unsharded
    quantized engine and the sharded dequantized engine."""
    if shards > forced_device_count:
        pytest.skip(f"needs {shards} devices")
    params, qindex, dindex, queries = qsetup
    mesh = make_candidate_mesh(shards)
    em = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=False, mesh=mesh))
    e1 = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=False))
    ed = RetrievalEngine(dindex, params,
                    config=EngineConfig(mode=mode, use_kernel=False, mesh=mesh))
    mv, mi, *_ = em.retrieve_dense(queries, 20)
    sv, si, *_ = e1.retrieve_dense(queries, 20)
    dv, di, *_ = ed.retrieve_dense(queries, 20)
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(si))
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(sv))
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(di))
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(dv))


@pytest.mark.distributed
@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
def test_quantized_engine_sharded_fused_kernel(qsetup, mode,
                                               forced_device_count):
    """The distributed dispatch must also serve the quantized index
    through the FUSED kernels (interpret mode here): sharded cand-spec
    plumbing for the extra scales operand × the Pallas path is otherwise
    untested.  Small 2-way mesh — the kernels are slow in interpret mode
    inside shard_map."""
    if forced_device_count < 2:
        pytest.skip("needs 2 devices")
    params, qindex, dindex, queries = qsetup
    mesh = make_candidate_mesh(2)
    em = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=True, mesh=mesh))
    ed = RetrievalEngine(dindex, params,
                    config=EngineConfig(mode=mode, use_kernel=True, mesh=mesh))
    q = queries[:3]
    mv, mi, *_ = em.retrieve_dense(q, 10)
    dv, di, *_ = ed.retrieve_dense(q, 10)
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(di))
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(dv))


def test_quantized_index_via_core_retrieve(qsetup):
    """The functional ``core.retrieve`` wrapper accepts a QuantizedIndex
    and its n-validation still fires (QuantizedCodes carries n/k)."""
    params, qindex, dindex, queries = qsetup
    q_codes = encode(params, queries, CFG.k)
    gv, gi = retrieve(qindex, q_codes, 9, use_kernel=False)
    wv, wi = retrieve(dindex, q_codes, 9, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    assert qindex.codes.n == dindex.codes.n
    assert qindex.codes.k == dindex.codes.k
    with pytest.raises(ValueError, match="exceeds candidate count"):
        retrieve(qindex, q_codes, qindex.codes.n + 1, use_kernel=False)


# ------------------------------------------------- precision="int8" (ISSUE 5)
@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
def test_int8_engine_kernel_ref_bit_identical(qsetup, mode):
    """The approximate path keeps the OTHER bit-identity: engine over the
    fused kernels (interpret mode) == engine over the jnp refs, exactly —
    int32 accumulation plus the shared panel quantizer leave no rounding
    slack between the two backends."""
    params, qindex, _, queries = qsetup
    ek = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=True, precision="int8"))
    er = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=False, precision="int8"))
    kv, ki, *_ = ek.retrieve_dense(queries, 25)
    rv, ri, *_ = er.retrieve_dense(queries, 25)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))


@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
def test_int8_engine_quality_vs_exact(qsetup, mode):
    """int8 vs exact on the same QuantizedIndex is approximate by design;
    the harness-measured quality must clear a comfortable floor even on
    this tiny corpus (and the score curves must be close)."""
    from repro.core.eval import retrieval_quality

    params, qindex, _, queries = qsetup
    exact = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=False))
    approx = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=False, precision="int8"))
    e = exact.retrieve_dense(queries, 25)
    a = approx.retrieve_dense(queries, 25)
    quality = retrieval_quality(a, e)
    assert quality["recall"] >= 0.85, quality
    assert quality["score_mae"] < 2e-2, quality


@pytest.mark.distributed
@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_int8_engine_sharded_bit_identical(qsetup, mode, shards,
                                           forced_device_count):
    """Sharding stays exactly transparent on the approximate path: the
    replicated query quantizes identically on every shard and candidate
    scores are shard-local, so sharded int8 == unsharded int8 bit-for-bit
    (only int8-vs-exact is approximate)."""
    if shards > forced_device_count:
        pytest.skip(f"needs {shards} devices")
    params, qindex, _, queries = qsetup
    mesh = make_candidate_mesh(shards)
    em = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=False, mesh=mesh, precision="int8"))
    e1 = RetrievalEngine(qindex, params,
                    config=EngineConfig(mode=mode, use_kernel=False, precision="int8"))
    mv, mi, *_ = em.retrieve_dense(queries, 20)
    sv, si, *_ = e1.retrieve_dense(queries, 20)
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(si))
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(sv))


@pytest.mark.distributed
def test_int8_engine_sharded_fused_kernel(qsetup, forced_device_count):
    """The distributed dispatch must route the int8 generation through the
    FUSED kernels too (scales operand + int8 scratch × shard_map plumbing
    is otherwise untested).  2-way mesh, tiny batch — interpret mode."""
    if forced_device_count < 2:
        pytest.skip("needs 2 devices")
    params, qindex, _, queries = qsetup
    mesh = make_candidate_mesh(2)
    em = RetrievalEngine(qindex, params,
                    config=EngineConfig(use_kernel=True, mesh=mesh, precision="int8"))
    er = RetrievalEngine(qindex, params,
                    config=EngineConfig(use_kernel=False, precision="int8"))
    q = queries[:3]
    mv, mi, *_ = em.retrieve_dense(q, 10)
    rv, ri, *_ = er.retrieve_dense(q, 10)
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(rv))


def test_precision_validation(setup, qsetup):
    """int8 needs a QuantizedIndex; unknown precisions are rejected —
    at construction AND at the functional retrieve() entry point."""
    params, index, queries = setup
    _, qindex, _, _ = qsetup
    with pytest.raises(ValueError, match="requires a QuantizedIndex"):
        RetrievalEngine(index, params,
                    config=EngineConfig(precision="int8"))
    with pytest.raises(ValueError, match="unknown precision"):
        RetrievalEngine(qindex, params,
                    config=EngineConfig(precision="fp8"))
    q_codes = encode(params, queries, CFG.k)
    with pytest.raises(ValueError, match="requires a QuantizedIndex"):
        retrieve(index, q_codes, 5, use_kernel=False, precision="int8")
    # and the exact default keeps serving the fp32 index unchanged
    gv, gi = retrieve(index, q_codes, 5, use_kernel=False, precision="exact")
    wv, wi = retrieve(index, q_codes, 5, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))


def test_engine_single_dense_query(setup):
    params, index, queries = setup
    engine = RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False))
    v, i, *_ = engine.retrieve_dense(queries[0], 5)
    assert v.shape == (5,) and i.shape == (5,)
    bv, bi, *_ = engine.retrieve_dense(queries[:1], 5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(bi[0]))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(bv[0]))


def test_engine_retrieve_codes_matches_retrieve(setup):
    params, index, queries = setup
    q_codes = encode(params, queries, CFG.k)
    for mode in ("sparse", "reconstructed"):
        engine = RetrievalEngine(index, params,
                    config=EngineConfig(mode=mode, use_kernel=False))
        gv, gi = engine.retrieve_codes(q_codes, 12)
        wv, wi = retrieve(index, q_codes, 12, mode=mode, params=params,
                          use_kernel=False)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))


def test_engine_jit_cache_reuse(setup):
    params, index, queries = setup
    engine = RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False))
    engine.retrieve_dense(queries, 7)
    fn = engine._serve_cache[7]
    engine.retrieve_dense(queries, 7)
    assert engine._serve_cache[7] is fn          # same executable reused
    engine.retrieve_dense(queries, 8)
    assert set(engine._serve_cache) == {7, 8}    # one entry per distinct n


def test_engine_validations(setup):
    params, index, queries = setup
    with pytest.raises(ValueError, match="unknown retrieval mode"):
        RetrievalEngine(index, params,
                    config=EngineConfig(mode="bogus"))
    with pytest.raises(ValueError, match="requires SAE params"):
        RetrievalEngine(index, None,
                    config=EngineConfig(mode="reconstructed"))
    index_no_params = build_index(index.codes)   # no decoder norms
    with pytest.raises(ValueError, match="recon norms missing"):
        RetrievalEngine(index_no_params, params,
                    config=EngineConfig(mode="reconstructed"))
    engine = RetrievalEngine(index, params,
                    config=EngineConfig(use_kernel=False))
    with pytest.raises(ValueError, match="exceeds candidate count"):
        engine.retrieve_dense(queries, index.codes.n + 1)
    with pytest.raises(ValueError, match="requires SAE params"):
        RetrievalEngine(index, None,
                    config=EngineConfig(use_kernel=False)).retrieve_dense(
            queries, 3
        )


def test_engine_codes_only_without_params(setup):
    """Sparse-mode retrieval over pre-encoded codes needs no params at all."""
    params, index, queries = setup
    q_codes = encode(params, queries, CFG.k)
    engine = RetrievalEngine(index, None,
                    config=EngineConfig(use_kernel=False))
    gv, gi = engine.retrieve_codes(q_codes, 6)
    wv, wi = retrieve(index, q_codes, 6, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
