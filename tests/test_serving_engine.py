"""RetrievalEngine (ISSUE 3) == the composed ``encode()`` + ``retrieve()``
pipeline — BIT-identical scores, ids, and tie resolution, for both modes,
both backends (fused kernels in interpret mode / chunked jnp), and 1/2/4-way
candidate-sharded meshes (on the conftest-forced multi-device CPU topology).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import SAEConfig, build_index, encode, init_params, retrieve
from repro.core.types import SparseCodes
from repro.launch.mesh import make_candidate_mesh
from repro.serving import RetrievalEngine

CFG = SAEConfig(d=32, h=128, k=8)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (310, CFG.d))
    # duplicate a prefix onto the tail -> exactly tied scores, so the
    # engine's tie resolution is exercised against the composed path's
    corpus = jnp.concatenate([corpus, corpus[:17]])
    queries = jax.random.normal(jax.random.PRNGKey(2), (9, CFG.d))
    index = build_index(encode(params, corpus, CFG.k), params)
    return params, index, queries


def _assert_engine_matches_composed(params, index, x, n, mode, use_kernel,
                                    mesh=None):
    engine = RetrievalEngine(params, index, mode=mode, use_kernel=use_kernel,
                             mesh=mesh)
    got_v, got_i = engine.retrieve_dense(x, n)
    want_v, want_i = retrieve(
        index, encode(params, x, CFG.k), n,
        mode=mode, params=params, use_kernel=use_kernel, mesh=mesh,
    )
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    return engine


@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_engine_matches_composed_path(setup, mode, use_kernel):
    params, index, queries = setup
    _assert_engine_matches_composed(params, index, queries, 25, mode,
                                    use_kernel)


@pytest.mark.distributed
@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_engine_matches_composed_sharded(setup, mode, shards,
                                         forced_device_count):
    if shards > forced_device_count:
        pytest.skip(f"needs {shards} devices")
    params, index, queries = setup
    mesh = make_candidate_mesh(shards)
    engine = _assert_engine_matches_composed(
        params, index, queries, 20, mode, False, mesh=mesh
    )
    # and the sharded engine must equal the UNsharded engine bit-for-bit
    single = RetrievalEngine(params, index, mode=mode, use_kernel=False)
    sv, si = single.retrieve_dense(queries, 20)
    gv, gi = engine.retrieve_dense(queries, 20)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(si))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(sv))


def test_engine_single_dense_query(setup):
    params, index, queries = setup
    engine = RetrievalEngine(params, index, use_kernel=False)
    v, i = engine.retrieve_dense(queries[0], 5)
    assert v.shape == (5,) and i.shape == (5,)
    bv, bi = engine.retrieve_dense(queries[:1], 5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(bi[0]))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(bv[0]))


def test_engine_retrieve_codes_matches_retrieve(setup):
    params, index, queries = setup
    q_codes = encode(params, queries, CFG.k)
    for mode in ("sparse", "reconstructed"):
        engine = RetrievalEngine(params, index, mode=mode, use_kernel=False)
        gv, gi = engine.retrieve_codes(q_codes, 12)
        wv, wi = retrieve(index, q_codes, 12, mode=mode, params=params,
                          use_kernel=False)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))


def test_engine_jit_cache_reuse(setup):
    params, index, queries = setup
    engine = RetrievalEngine(params, index, use_kernel=False)
    engine.retrieve_dense(queries, 7)
    fn = engine._serve_cache[7]
    engine.retrieve_dense(queries, 7)
    assert engine._serve_cache[7] is fn          # same executable reused
    engine.retrieve_dense(queries, 8)
    assert set(engine._serve_cache) == {7, 8}    # one entry per distinct n


def test_engine_validations(setup):
    params, index, queries = setup
    with pytest.raises(ValueError, match="unknown retrieval mode"):
        RetrievalEngine(params, index, mode="bogus")
    with pytest.raises(ValueError, match="requires SAE params"):
        RetrievalEngine(None, index, mode="reconstructed")
    index_no_params = build_index(index.codes)   # no decoder norms
    with pytest.raises(ValueError, match="recon norms missing"):
        RetrievalEngine(params, index_no_params, mode="reconstructed")
    engine = RetrievalEngine(params, index, use_kernel=False)
    with pytest.raises(ValueError, match="exceeds candidate count"):
        engine.retrieve_dense(queries, index.codes.n + 1)
    with pytest.raises(ValueError, match="requires SAE params"):
        RetrievalEngine(None, index, use_kernel=False).retrieve_dense(
            queries, 3
        )


def test_engine_codes_only_without_params(setup):
    """Sparse-mode retrieval over pre-encoded codes needs no params at all."""
    params, index, queries = setup
    q_codes = encode(params, queries, CFG.k)
    engine = RetrievalEngine(None, index, use_kernel=False)
    gv, gi = engine.retrieve_codes(q_codes, 6)
    wv, wi = retrieve(index, q_codes, 6, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
