"""Inverted-file sparse retrieval (beyond-paper serving structure)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    SAEConfig, SparseCodes, build_index, encode, init_params, retrieve,
    score_sparse, top_n,
)
from repro.core.inverted_index import (
    _search_inverted_fullsort, build_inverted_index, candidate_union,
    expected_scan_fraction, search_inverted,
)
from repro.errors import IndexIntegrityError, InvalidCodesError

CFG = SAEConfig(d=32, h=128, k=4)


def _setup(n=512, nq=8, seed=0):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    corpus = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, CFG.d))
    queries = jax.random.normal(jax.random.PRNGKey(seed + 2), (nq, CFG.d))
    codes = encode(params, corpus, CFG.k)
    q = encode(params, queries, CFG.k)
    return codes, q


def test_uncapped_matches_exact_scan():
    codes, q = _setup()
    truth = top_n(score_sparse(build_index(codes), q), 5)[1]
    inv = build_inverted_index(codes, cap=codes.n)
    _, ids = search_inverted(inv, q, 5)
    # same candidate sets (scores can tie)
    for a, b in zip(np.asarray(ids), np.asarray(truth)):
        assert set(a.tolist()) == set(b.tolist())


def test_postings_contain_exactly_the_activating_rows():
    codes, _ = _setup(n=64)
    inv = build_inverted_index(codes, cap=64)
    post = np.asarray(inv.postings)
    idx = np.asarray(codes.indices)
    for lat in range(CFG.h):
        want = {r for r in range(64) if lat in set(idx[r].tolist())}
        got = {int(x) for x in post[lat] if x >= 0}
        assert got == want, lat


def test_single_query_shape_and_padding_excluded():
    codes, q = _setup()
    inv = build_inverted_index(codes, cap=32)
    v, ids = search_inverted(
        inv,
        type(codes)(values=q.values[0], indices=q.indices[0], dim=q.dim),
        5,
    )
    assert v.shape == (5,) and ids.shape == (5,)
    assert (np.asarray(ids) >= 0).all()   # never returns padding


def test_streaming_epilogue_matches_fullsort_selection():
    """The streaming top-n epilogue (blockwise scan, running best buffer)
    must reproduce the pre-streaming full ``lax.top_k``-over-the-union
    selection exactly — scores bitwise, ids included, across block sizes
    that split the k·cap union raggedly and the single-block case."""
    codes, q = _setup(n=600, nq=8, seed=4)
    inv = build_inverted_index(codes, cap=64)     # union = k·cap = 256
    for n in (1, 5, 20):
        want_v, want_i = _search_inverted_fullsort(inv, q, n)
        for block in (7, 64, 256, 4096):
            got_v, got_i = search_inverted(inv, q, n, block=block)
            np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
            finite = np.isfinite(np.asarray(want_v))
            np.testing.assert_array_equal(
                np.asarray(got_i)[finite], np.asarray(want_i)[finite]
            )


def test_scan_fraction_decreases_with_cap():
    codes, _ = _setup(n=1024)
    f_small = expected_scan_fraction(codes, cap=8)
    f_big = expected_scan_fraction(codes, cap=1024)
    assert 0 < f_small <= f_big <= codes.k * codes.k / codes.dim * 4 + 1


def test_scan_fraction_is_a_probability_on_dense_latent_corpus():
    """ISSUE 7 bugfix: every item lighting the same few latents used to
    drive the k·p union-bound estimate above 1.0 (a fraction of 2.0 for
    this corpus).  The inclusion–exclusion form stays in [0, 1]."""
    n = 100
    codes = SparseCodes(
        values=jnp.ones((n, 4), dtype=jnp.float32),
        indices=jnp.tile(jnp.arange(4, dtype=jnp.int32), (n, 1)),
        dim=8,
    )
    frac = expected_scan_fraction(codes, cap=n)
    assert 0.0 <= frac <= 1.0
    # 4 of 8 latents hold all n items: p = 0.5, union = 1 - (1-p)^k
    assert frac == pytest.approx(1.0 - 0.5 ** 4)


def test_padding_contract_when_n_exceeds_the_union():
    """ISSUE 7 bugfix: with n > |valid union| the padded tail must follow
    the fused path's n>matches contract — score −inf, id −1, padded
    entries last — and the real prefix must match the exact scan
    (``core.retrieve``) bitwise.  Ids are compared EVERYWHERE, including
    the padded tail, for the streaming and fullsort paths alike."""
    h, k = 8, 2
    # items 0-2 share latents {0,1} with the query (positive scores);
    # items 3-5 live on disjoint latents {6,7} (score exactly 0, outside
    # every queried posting list)
    idx = np.array([[0, 1], [0, 1], [1, 0], [6, 7], [6, 7], [7, 6]],
                   dtype=np.int32)
    val = np.array([[1.0, .5], [.9, .4], [.8, .3], [1., 1.], [.5, .5],
                    [.2, .1]], dtype=np.float32)
    codes = SparseCodes(values=jnp.asarray(val), indices=jnp.asarray(idx),
                        dim=h)
    q = SparseCodes(values=jnp.asarray([[1.0, 1.0]], dtype=jnp.float32),
                    indices=jnp.asarray([[0, 1]], dtype=jnp.int32), dim=h)
    inv = build_inverted_index(codes, cap=6)
    n = 5                                      # union is only 3 items
    want_v, want_i = _search_inverted_fullsort(inv, q, n)
    for block in (2, 3, 4096):
        got_v, got_i = search_inverted(inv, q, n, block=block)
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    v, ids = np.asarray(want_v)[0], np.asarray(want_i)[0]
    # padded tail: (-inf, -1) pairs, strictly after every real entry
    assert np.isneginf(v[3:]).all() and (ids[3:] == -1).all()
    assert np.isfinite(v[:3]).all() and (ids[:3] >= 0).all()
    # real prefix matches the exact scan: same ids in the same order (all
    # union scores are positive, all non-union scores are exactly 0), and
    # scores to float tolerance (the two paths order the reductions
    # differently, so last-ulp differences are expected)
    ref_v, ref_i = retrieve(build_index(codes), q, n, use_kernel=False)
    np.testing.assert_array_equal(ids[:3], np.asarray(ref_i)[0, :3])
    np.testing.assert_allclose(v[:3], np.asarray(ref_v)[0, :3], rtol=1e-5)


def test_build_rejects_out_of_range_latents():
    """ISSUE 7 bugfix: an out-of-range latent index used to be silently
    bucketed modulo-ish by one-hot masking; now the build raises a typed
    error naming the offending row/slot/value."""
    codes, _ = _setup(n=16)
    for bad_val in (CFG.h + 5, -2):
        idx = np.asarray(codes.indices).copy()
        idx[3, 2] = bad_val
        bad = SparseCodes(values=codes.values, indices=jnp.asarray(idx),
                          dim=codes.dim)
        with pytest.raises(InvalidCodesError, match=r"codes\.indices\[3, 2\]"):
            build_inverted_index(bad, cap=16)
        with pytest.raises(ValueError):        # typed error IS a ValueError
            build_inverted_index(bad, cap=16)


def test_candidate_union_covers_dedups_sorts_and_pads():
    codes, q = _setup(n=400)
    inv = build_inverted_index(codes, cap=64)
    qi = np.asarray(q.indices)
    rows = candidate_union(inv, qi, budget=128)
    post = np.asarray(inv.postings)
    assert rows.shape == (qi.shape[0], 128) and rows.dtype == np.int32
    for r in range(qi.shape[0]):
        row = rows[r]
        assert (np.diff(row) > 0).all()          # sorted, duplicate-free
        assert row.min() >= 0 and row.max() < 400  # real catalog rows only
        union = {int(x) for x in post[qi[r]].ravel() if x >= 0}
        if len(union) <= 128:                    # exactness precondition
            assert union <= set(row.tolist())


def test_candidate_union_rejects_corrupt_postings():
    from repro.serving import corrupt_postings

    codes, q = _setup(n=64)
    inv = corrupt_postings(build_inverted_index(codes, cap=64))
    with pytest.raises(IndexIntegrityError, match="postings corrupted"):
        candidate_union(inv, np.asarray(q.indices), budget=32)


def test_candidate_union_budget_cannot_exceed_catalog():
    codes, q = _setup(n=64)
    inv = build_inverted_index(codes, cap=64)
    with pytest.raises(ValueError):
        candidate_union(inv, np.asarray(q.indices), budget=65)
