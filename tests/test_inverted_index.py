"""Inverted-file sparse retrieval (beyond-paper serving structure)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SAEConfig, build_index, encode, init_params, score_sparse, top_n
from repro.core.inverted_index import (
    _search_inverted_fullsort, build_inverted_index, expected_scan_fraction,
    search_inverted,
)

CFG = SAEConfig(d=32, h=128, k=4)


def _setup(n=512, nq=8, seed=0):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    corpus = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, CFG.d))
    queries = jax.random.normal(jax.random.PRNGKey(seed + 2), (nq, CFG.d))
    codes = encode(params, corpus, CFG.k)
    q = encode(params, queries, CFG.k)
    return codes, q


def test_uncapped_matches_exact_scan():
    codes, q = _setup()
    truth = top_n(score_sparse(build_index(codes), q), 5)[1]
    inv = build_inverted_index(codes, cap=codes.n)
    _, ids = search_inverted(inv, q, 5)
    # same candidate sets (scores can tie)
    for a, b in zip(np.asarray(ids), np.asarray(truth)):
        assert set(a.tolist()) == set(b.tolist())


def test_postings_contain_exactly_the_activating_rows():
    codes, _ = _setup(n=64)
    inv = build_inverted_index(codes, cap=64)
    post = np.asarray(inv.postings)
    idx = np.asarray(codes.indices)
    for lat in range(CFG.h):
        want = {r for r in range(64) if lat in set(idx[r].tolist())}
        got = {int(x) for x in post[lat] if x >= 0}
        assert got == want, lat


def test_single_query_shape_and_padding_excluded():
    codes, q = _setup()
    inv = build_inverted_index(codes, cap=32)
    v, ids = search_inverted(
        inv,
        type(codes)(values=q.values[0], indices=q.indices[0], dim=q.dim),
        5,
    )
    assert v.shape == (5,) and ids.shape == (5,)
    assert (np.asarray(ids) >= 0).all()   # never returns padding


def test_streaming_epilogue_matches_fullsort_selection():
    """The streaming top-n epilogue (blockwise scan, running best buffer)
    must reproduce the pre-streaming full ``lax.top_k``-over-the-union
    selection exactly — scores bitwise, ids included, across block sizes
    that split the k·cap union raggedly and the single-block case."""
    codes, q = _setup(n=600, nq=8, seed=4)
    inv = build_inverted_index(codes, cap=64)     # union = k·cap = 256
    for n in (1, 5, 20):
        want_v, want_i = _search_inverted_fullsort(inv, q, n)
        for block in (7, 64, 256, 4096):
            got_v, got_i = search_inverted(inv, q, n, block=block)
            np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
            finite = np.isfinite(np.asarray(want_v))
            np.testing.assert_array_equal(
                np.asarray(got_i)[finite], np.asarray(want_i)[finite]
            )


def test_scan_fraction_decreases_with_cap():
    codes, _ = _setup(n=1024)
    f_small = expected_scan_fraction(codes, cap=8)
    f_big = expected_scan_fraction(codes, cap=1024)
    assert 0 < f_small <= f_big <= codes.k * codes.k / codes.dim * 4 + 1
