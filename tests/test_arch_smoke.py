"""Per-architecture smoke tests (deliverable (f)).

Each assigned arch instantiates its REDUCED config and runs one real
forward/train step on CPU, asserting output shapes and finiteness.  The
full configs are exercised only by the dry-run (no allocation).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import registry
from repro.optim import AdamConfig, adam_init, adam_update

LM_ARCHS = [
    "command-r-35b", "gemma2-27b", "qwen3-1.7b",
    "qwen3-moe-30b-a3b", "llama4-scout-17b-a16e",
]
RECSYS_ARCHS = ["dlrm-mlperf", "din", "deepfm", "bert4rec"]


def _finite(tree) -> bool:
    return all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


# ------------------------------------------------------------------ LM archs
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models import transformer as T

    cfg = registry.arch_module(arch).smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 4, 64
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab, dtype=jnp.int32),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab, dtype=jnp.int32),
    }
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: T.lm_loss(p, batch, cfg), has_aux=True
    )(params)
    assert jnp.isfinite(loss), arch
    # init loss ~ ln(vocab): untrained uniform prediction
    assert abs(float(metrics["xent"]) - np.log(cfg.vocab)) < 1.5, (
        arch, float(metrics["xent"]), np.log(cfg.vocab))
    assert _finite(grads), arch
    new_params, _ = adam_update(grads, adam_init(params), params, AdamConfig(lr=1e-3))
    assert _finite(new_params), arch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode(arch):
    from repro.models import transformer as T

    cfg = registry.arch_module(arch).smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0, cfg.vocab,
                              dtype=jnp.int32)
    logits, caches = T.prefill(params, toks[:, :s], cfg)
    assert logits.shape == (b, cfg.vocab) and bool(jnp.isfinite(logits).all())
    # decode one token; must match a fresh prefill of s+1 tokens
    full = T.init_cache(cfg, b, s + 16)
    full = [
        (c0.at[:, :, :s].set(k), c1.at[:, :, :s].set(v))
        for (c0, c1), (k, v) in zip(full, caches)
    ]
    dec, _ = T.decode_step(params, toks[:, s : s + 1], full, jnp.int32(s), cfg)
    ref, _ = T.prefill(params, toks, cfg)
    np.testing.assert_allclose(dec, ref, rtol=5e-3, atol=5e-3)


# ------------------------------------------------------------------- nequip
def test_nequip_smoke_train_step():
    from repro.data import random_graph
    from repro.models.nequip import nequip_init, nequip_loss

    cfg = registry.arch_module("nequip").smoke()
    params = nequip_init(cfg, jax.random.PRNGKey(0))
    g = random_graph(0, n_nodes=40, n_edges=160, d_feat=cfg.d_feat)
    batch = {
        "node_feat": jnp.asarray(g["node_feat"]),
        "edge_index": jnp.asarray(g["edge_index"]),
        "positions": jnp.asarray(g["positions"]),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (40,), -1, cfg.n_out),
    }
    (loss, _), grads = jax.value_and_grad(
        lambda p: nequip_loss(p, batch, cfg), has_aux=True
    )(params)
    assert jnp.isfinite(loss) and _finite(grads)


def test_nequip_smoke_edge_mask_zeroes_padding():
    """Padded edges (mask 0) must not change outputs."""
    from repro.data import random_graph
    from repro.models.nequip import nequip_forward, nequip_init

    cfg = registry.arch_module("nequip").smoke()
    params = nequip_init(cfg, jax.random.PRNGKey(0))
    g = random_graph(3, n_nodes=20, n_edges=50, d_feat=cfg.d_feat)
    nf, ei, pos = (jnp.asarray(g[k]) for k in ("node_feat", "edge_index", "positions"))
    out = nequip_forward(params, nf, ei, pos, cfg)
    # append 14 garbage edges with mask 0
    pad = jnp.zeros((2, 14), jnp.int32)
    ei2 = jnp.concatenate([ei, pad], axis=1)
    mask = jnp.concatenate([jnp.ones(50), jnp.zeros(14)])
    out2 = nequip_forward(params, nf, ei2, pos, cfg, edge_mask=mask)
    np.testing.assert_allclose(out, out2, rtol=1e-5, atol=1e-6)


def test_nequip_smoke_molecule_regression():
    from repro.models.nequip import NequIPConfig, nequip_init, nequip_loss

    cfg = NequIPConfig(n_layers=2, d_hidden=8, l_max=2, n_rbf=4, d_feat=8,
                       n_out=1, task="graph_regress", radial_hidden=16)
    params = nequip_init(cfg, jax.random.PRNGKey(0))
    n_graphs, nodes_per, edges_per = 4, 6, 10
    n, e = n_graphs * nodes_per, n_graphs * edges_per
    rng = np.random.default_rng(0)
    # block-diagonal batched graphs
    src = np.concatenate([rng.integers(0, nodes_per, edges_per) + i * nodes_per
                          for i in range(n_graphs)])
    dst = np.concatenate([rng.integers(0, nodes_per, edges_per) + i * nodes_per
                          for i in range(n_graphs)])
    batch = {
        "node_feat": jnp.asarray(rng.standard_normal((n, cfg.d_feat)), jnp.float32),
        "edge_index": jnp.asarray(np.stack([src, dst]), jnp.int32),
        "positions": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        "graph_ids": jnp.repeat(jnp.arange(n_graphs), nodes_per),
        "energies": jnp.asarray(rng.standard_normal(n_graphs), jnp.float32),
    }
    (loss, _), grads = jax.value_and_grad(
        lambda p: nequip_loss(p, batch, cfg), has_aux=True
    )(params)
    assert jnp.isfinite(loss) and _finite(grads)


# ------------------------------------------------------------------- recsys
@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_step(arch):
    from repro.data.synthetic import bert4rec_batch, criteo_like_batch, din_batch
    from repro.models import recsys as R

    cfg = registry.arch_module(arch).smoke()
    init_fn, loss_fn, serve_fn, uvec_fn = registry._recsys_fns(arch)
    params = init_fn(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    b = 16
    if arch == "dlrm-mlperf":
        batch = criteo_like_batch(key, b, cfg.n_dense, list(cfg.vocab_sizes))
    elif arch == "deepfm":
        batch = criteo_like_batch(key, b, 1, list(cfg.vocab_sizes))
    elif arch == "din":
        batch = din_batch(key, b, cfg.seq_len, cfg.n_items)
    else:
        batch = bert4rec_batch(key, b, cfg.seq_len, cfg.n_items, cfg.mask_id,
                               cfg.n_negatives)
    (loss, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True
    )(params)
    assert jnp.isfinite(loss), arch
    assert _finite(grads), arch
    # user vector for the retrieval head
    uv = uvec_fn(params, batch, cfg)
    assert uv.ndim == 2 and uv.shape[0] == b and bool(jnp.isfinite(uv).all())


@pytest.mark.parametrize("arch", ["dlrm-mlperf", "deepfm", "bert4rec"])
def test_recsys_compressed_retrieval_smoke(arch):
    """End-to-end paper path: train SAE on item embeddings, compress the
    catalog, retrieve; compressed top-n must overlap dense top-n."""
    from repro.core import SAEConfig, build_index, encode, init_train_state, train_step
    from repro.data.synthetic import bert4rec_batch, criteo_like_batch
    from repro.models import recsys as R
    from repro.models.retrieval_head import compressed_retrieval, dense_retrieval
    from repro.optim import AdamConfig

    cfg = registry.arch_module(arch).smoke()
    init_fn, _, _, uvec_fn = registry._recsys_fns(arch)
    params = init_fn(cfg, jax.random.PRNGKey(0))

    # catalog = an embedding table of the model
    if arch == "dlrm-mlperf":
        table = params["tables"]["table_0"]
        batch = criteo_like_batch(jax.random.PRNGKey(1), 2, cfg.n_dense,
                                  list(cfg.vocab_sizes))
        d = cfg.embed_dim
    elif arch == "deepfm":
        table = params["tables"]["table_1"]
        batch = criteo_like_batch(jax.random.PRNGKey(1), 2, 1, list(cfg.vocab_sizes))
        d = cfg.embed_dim
    else:
        table = params["items"][: cfg.n_items]
        batch = bert4rec_batch(jax.random.PRNGKey(1), 2, cfg.seq_len, cfg.n_items,
                               cfg.mask_id, cfg.n_negatives)
        d = cfg.embed_dim
    sae_cfg = SAEConfig(d=d, h=max(4 * d, 64), k=max(d // 4, 2))
    state = init_train_state(sae_cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, sae_cfg, AdamConfig(lr=3e-3)))
    for _ in range(40):
        state, _ = step(state, table)
    codes = encode(state.params, table, sae_cfg.k)
    norms = jnp.linalg.norm(codes.values, axis=-1)
    uv = uvec_fn(params, batch, cfg)
    n = 10
    sv, si = compressed_retrieval(uv, state.params, codes, norms, n, sae_cfg.k)
    dv, di = dense_retrieval(uv, table, n)
    assert si.shape == (2, n) and bool(jnp.isfinite(sv).all())
    overlap = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / n
        for a, b in zip(np.asarray(si), np.asarray(di))
    ])
    assert overlap > 0.2, f"{arch}: compressed retrieval overlap {overlap}"
