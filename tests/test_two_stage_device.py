"""Device-side two-stage candidate generation (ISSUE 8 tentpole).

Pins the two bit-equality contracts the tentpole rests on:

* ``device_candidate_union`` is BIT-IDENTICAL to the host
  ``candidate_union`` oracle — rows, ascending order, and the filler
  tail — across duplicate latents, overflowing caps, budget < |union|
  truncation, the budget > |union| filler path, and tie-heavy corpora
  (a property suite when Hypothesis is installed, plus seeded
  deterministic twins of the same properties that always run);
* the batched stage 2 (one gathered re-rank over the whole (Q, budget)
  panel, generation-6 kernels) is BIT-IDENTICAL to the PR-7 per-query
  loop — scores, ids, ties, and the (−inf, −1) padding — across
  {fp32, quantized} × {exact, int8} × {fused, ref}.

Also covers the inverted-index content checksum (build-time stamp,
``verify_inverted_index``, and the startup ``self_check`` catching
``corrupt-postings`` before the first request) and the filler-rule
regression test referenced from ``candidate_union``'s docstring.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    SAEConfig, SparseCodes, build_index, encode, init_params, retrieve,
)
from repro.core.inverted_index import (
    build_inverted_index,
    candidate_union,
    device_candidate_union,
    inverted_index_checksum,
    verify_inverted_index,
)
from repro.core.retrieval import two_stage_retrieve
from repro.errors import IndexIntegrityError
from repro.serving import GuardedEngine, RetrievalEngine, corrupt_postings

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # the container has no hypothesis wheel:
    HAVE_HYPOTHESIS = False  # the seeded twins below cover the properties

CFG = SAEConfig(d=32, h=128, k=4)


def _random_codes(n, h, k, seed, duplicate_latents=False):
    """Random sparse codes straight from NumPy (no SAE training): values
    positive so posting impact-ordering is exercised, indices optionally
    WITH duplicate latents inside a row (the union must dedup them)."""
    rng = np.random.default_rng(seed)
    if duplicate_latents:
        idx = rng.integers(0, h, size=(n, k), dtype=np.int32)
    else:
        idx = np.stack([
            rng.choice(h, size=k, replace=False) for _ in range(n)
        ]).astype(np.int32)
    val = rng.uniform(0.1, 1.0, size=(n, k)).astype(np.float32)
    return SparseCodes(values=jnp.asarray(val), indices=jnp.asarray(idx),
                       dim=h)


def _q_indices(nq, h, k, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, h, size=(nq, k), dtype=np.int32)


# --------------------------------------------------------- union parity
@pytest.mark.parametrize("n,h,k,cap,budget,dup", [
    (512, 128, 4, 64, 128, False),   # ordinary truncation race
    (512, 128, 4, 8, 200, False),    # tiny cap -> filler path dominates
    (64, 16, 4, 64, 64, True),       # budget == catalog, duplicate latents
    (300, 8, 2, 16, 17, True),       # dense latents -> heavy ties/overlap
    (512, 128, 4, 512, 512, False),  # uncapped postings, full budget
    (96, 4, 3, 96, 40, True),        # h < k·q overlap: every list collides
])
def test_device_union_matches_host_oracle(n, h, k, cap, budget, dup):
    """The seeded grid: every config class the property suite samples,
    pinned deterministically so the contract gates without Hypothesis."""
    codes = _random_codes(n, h, k, seed=n + cap, duplicate_latents=dup)
    inv = build_inverted_index(codes, cap=cap)
    qi = _q_indices(7, h, k, seed=budget)
    host = candidate_union(inv, qi, budget)
    dev = np.asarray(device_candidate_union(inv, jnp.asarray(qi), budget))
    np.testing.assert_array_equal(dev, host)
    assert dev.dtype == np.int32 and dev.shape == (7, budget)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=40, derandomize=True)
    @given(
        n=st.integers(8, 200),
        h=st.integers(2, 48),
        k=st.integers(1, 4),
        cap_frac=st.floats(0.05, 1.0),
        budget_frac=st.floats(0.05, 1.0),
        dup=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_device_union_property(n, h, k, cap_frac, budget_frac, dup,
                                   seed):
        """Property form of the grid above: any (corpus, cap, budget)
        the strategy can draw — duplicate latents, overflowing caps,
        budget under/over the union size — device == host, bitwise."""
        k = min(k, h)
        cap = max(1, int(cap_frac * n))
        budget = max(1, int(budget_frac * n))
        codes = _random_codes(n, h, k, seed, duplicate_latents=dup)
        inv = build_inverted_index(codes, cap=cap)
        qi = _q_indices(3, h, k, seed + 1)
        host = candidate_union(inv, qi, budget)
        dev = np.asarray(
            device_candidate_union(inv, jnp.asarray(qi), budget))
        np.testing.assert_array_equal(dev, host)


def test_filler_rule_is_first_non_members_over_full_catalog():
    """Regression pin for the documented filler contract (referenced from
    ``candidate_union``'s docstring): when budget > |union|, the filler
    tail is the FIRST ``need`` non-member catalog ids ascending over the
    full [0, N) range — NOT over a biased sub-range — and the device
    union reproduces it bit for bit.  The corpus is built so the union
    is a scattered high-id set, which a [0, budget)-only filler draw
    would have answered differently before the rule was pinned."""
    n, h, k = 200, 8, 2
    # every item lights latents {6, 7}; the query hits latent 0, whose
    # posting list holds only the 5 hand-planted high-id rows
    idx = np.tile(np.array([6, 7], dtype=np.int32), (n, 1))
    val = np.full((n, k), 0.5, dtype=np.float32)
    planted = [150, 160, 170, 180, 190]
    for r in planted:
        idx[r] = [0, 7]
    codes = SparseCodes(values=jnp.asarray(val), indices=jnp.asarray(idx),
                        dim=h)
    inv = build_inverted_index(codes, cap=n)
    qi = np.array([[0, 0]], dtype=np.int32)
    budget = 12
    host = candidate_union(inv, qi, budget)
    dev = np.asarray(
        device_candidate_union(inv, jnp.asarray(qi), budget))
    np.testing.assert_array_equal(dev, host)
    # brute-force statement of the rule over the FULL catalog range
    union = np.unique(np.asarray(inv.postings)[qi[0]].ravel())
    union = union[union >= 0]
    need = budget - union.size
    expect = np.sort(np.concatenate(
        [union, np.setdiff1d(np.arange(n), union)[:need]]))
    np.testing.assert_array_equal(host[0], expect)
    assert set(planted) <= set(host[0].tolist())


def test_device_union_raises_the_host_oracle_errors():
    """Same typed errors, same messages, from both implementations."""
    codes = _random_codes(64, 16, 4, seed=0)
    inv = build_inverted_index(codes, cap=64)
    qi = _q_indices(4, 16, 4, seed=1)
    with pytest.raises(ValueError) as host_err:
        candidate_union(inv, qi, 65)
    with pytest.raises(ValueError) as dev_err:
        device_candidate_union(inv, jnp.asarray(qi), 65)
    assert str(host_err.value) == str(dev_err.value)
    bad = corrupt_postings(inv)
    with pytest.raises(IndexIntegrityError) as host_bad:
        candidate_union(bad, qi, 32)
    with pytest.raises(IndexIntegrityError) as dev_bad:
        device_candidate_union(bad, jnp.asarray(qi), 32)
    assert str(host_bad.value) == str(dev_bad.value)
    assert "postings corrupted" in str(dev_bad.value)


# --------------------------------------------------- batched stage 2
@pytest.fixture(scope="module")
def corpus_setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (512, CFG.d))
    queries = jax.random.normal(jax.random.PRNGKey(2), (9, CFG.d))
    codes = encode(params, corpus, CFG.k)
    q = encode(params, queries, CFG.k)
    return params, codes, q


@pytest.mark.parametrize("quantized,precision", [
    (False, "exact"), (True, "exact"), (True, "int8"),
])
@pytest.mark.parametrize("use_fused", [False, True])
def test_batched_stage2_bit_identical_to_per_query(corpus_setup,
                                                   quantized, precision,
                                                   use_fused):
    """ONE gathered re-rank over the (Q, budget) panel == the PR-7
    per-query loop, bit for bit — scores, ids, tie resolution — across
    every mode × precision × backend the engine serves."""
    params, codes, q = corpus_setup
    index = build_index(codes, params, quantize=quantized)
    inv = build_inverted_index(codes, cap=64)
    kw = dict(candidate_fraction=0.3, precision=precision)
    v_b, i_b = two_stage_retrieve(index, inv, q, 10, use_fused=use_fused,
                                  stage1="host", stage2="batched", **kw)
    v_p, i_p = two_stage_retrieve(index, inv, q, 10, use_fused=use_fused,
                                  stage1="host", stage2="per_query", **kw)
    np.testing.assert_array_equal(np.asarray(v_b), np.asarray(v_p))
    np.testing.assert_array_equal(np.asarray(i_b), np.asarray(i_p))


def test_device_stage1_end_to_end_bit_identical(corpus_setup):
    """stage1='device' swaps only the union implementation: the whole
    request (device union + batched gathered re-rank) must equal the
    all-host PR-7 composition bitwise, through the engine too."""
    params, codes, q = corpus_setup
    index = build_index(codes, params)
    inv = build_inverted_index(codes, cap=64)
    v_d, i_d = two_stage_retrieve(index, inv, q, 10, use_fused=False,
                                  candidate_fraction=0.3,
                                  stage1="device", stage2="batched")
    v_h, i_h = two_stage_retrieve(index, inv, q, 10, use_fused=False,
                                  candidate_fraction=0.3,
                                  stage1="host", stage2="per_query")
    np.testing.assert_array_equal(np.asarray(v_d), np.asarray(v_h))
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_h))
    dev = RetrievalEngine(params, index, stage="two_stage",
                          candidate_fraction=0.3, stage1="device")
    host = RetrievalEngine(params, index, stage="two_stage",
                           candidate_fraction=0.3, stage1="host")
    ve, ie = dev.retrieve_codes(q, 10)
    vh, ih = host.retrieve_codes(q, 10)
    np.testing.assert_array_equal(np.asarray(ve), np.asarray(vh))
    np.testing.assert_array_equal(np.asarray(ie), np.asarray(ih))


def test_batched_padding_contract_when_budget_exceeds_union():
    """budget > |union| engages the filler path in stage 1 AND the
    ascending-id tie contract in stage 2: batched == per-query down to
    the padded tail."""
    n, h, k = 300, 8, 2
    idx = np.zeros((n, k), dtype=np.int32)
    val = np.zeros((n, k), dtype=np.float32)
    idx[:20] = [0, 1]
    val[:20] = [1.0, 1.0]            # 20 exact duplicates tied on top
    idx[20:] = [6, 7]
    val[20:] = [0.3, 0.2]
    codes = SparseCodes(values=jnp.asarray(val), indices=jnp.asarray(idx),
                        dim=h)
    index = build_index(codes)
    inv = build_inverted_index(codes, cap=n)
    q = SparseCodes(values=jnp.asarray([[1.0, 1.0]], dtype=jnp.float32),
                    indices=jnp.asarray([[0, 1]], dtype=jnp.int32), dim=h)
    for stage1 in ("device", "host"):
        v_b, i_b = two_stage_retrieve(index, inv, q, 10, use_fused=False,
                                      candidate_fraction=0.1,
                                      stage1=stage1, stage2="batched")
        v_1, i_1 = retrieve(index, q, 10, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(v_b), np.asarray(v_1))
        np.testing.assert_array_equal(np.asarray(i_b), np.asarray(i_1))


# ----------------------------------------------------------- checksums
def test_inverted_index_checksum_stamped_and_verified():
    codes = _random_codes(128, 16, 4, seed=7)
    inv = build_inverted_index(codes, cap=32)
    assert inv.checksum is not None
    assert inv.checksum == inverted_index_checksum(inv)
    verify_inverted_index(inv)                      # clean: no raise
    bad = corrupt_postings(inv)                     # stale stored checksum
    with pytest.raises(IndexIntegrityError, match="postings corrupted"):
        verify_inverted_index(bad)


def test_self_check_catches_corrupt_postings_at_startup():
    """Satellite: the startup self-check must fail on a corrupted
    inverted index BEFORE any request is served — the fault used to
    surface only on the first stage-1 call."""
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (256, CFG.d))
    codes = encode(params, corpus, CFG.k)
    index = build_index(codes, params)
    eng = RetrievalEngine(params, index, stage="two_stage",
                          candidate_fraction=0.5, use_kernel=False)
    GuardedEngine(eng, run_self_check=True)         # healthy: accepted
    eng2 = RetrievalEngine(params, index, stage="two_stage",
                           candidate_fraction=0.5, use_kernel=False)
    eng2.inverted = corrupt_postings(eng2.inverted)
    with pytest.raises(IndexIntegrityError, match="postings corrupted"):
        GuardedEngine(eng2, run_self_check=True)


def test_guard_ladder_sheds_device_then_host_then_single():
    """The two-stage ladder has a device rung above a host rung; genuine
    postings corruption fails both (they share the one inverted index)
    and lands on the exact single-stage rung."""
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (256, CFG.d))
    queries = jax.random.normal(jax.random.PRNGKey(2), (4, CFG.d))
    codes = encode(params, corpus, CFG.k)
    index = build_index(codes, params)
    eng = RetrievalEngine(params, index, stage="two_stage",
                          candidate_fraction=0.5, use_kernel=False)
    guard = GuardedEngine(eng)
    assert guard.ladder[0].startswith("two-stage-device-")
    assert guard.ladder[1].startswith("two-stage-host-")
    eng.inverted = corrupt_postings(eng.inverted)
    v, ids, status, *_ = guard.retrieve_dense(queries, 8)
    assert status.step == 2 and status.degraded
    assert status.fault.count("postings corrupted") == 2  # both rungs tried
    single = RetrievalEngine(params, index, use_kernel=False)
    v1, i1, *_ = single.retrieve_dense(queries, 8)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(i1))
