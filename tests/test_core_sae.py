"""Unit tests: CompresSAE core — activation, model, losses, training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SAEConfig,
    abs_topk,
    abs_topk_sparse,
    compressae_loss,
    decode,
    decode_dense,
    encode,
    encode_dense,
    init_params,
    init_train_state,
    kernel_matrix,
    normalize_decoder,
    normalize_input,
    reconstruct,
    train_step,
)
from repro.core import sparse as sp
from repro.data import clustered_embeddings
from repro.optim import AdamConfig

CFG = SAEConfig(d=64, h=256, k=8)


def test_abs_topk_keeps_largest_abs_signed():
    x = jnp.array([3.0, -5.0, 1.0, 0.5, -2.0, 4.0])
    out = abs_topk(x, 3)
    np.testing.assert_allclose(out, [3.0, -5.0, 0.0, 0.0, 0.0, 4.0])


def test_abs_topk_sparse_roundtrip():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (17, 64))
    vals, idx = abs_topk_sparse(x, 5)
    assert vals.shape == (17, 5) and idx.shape == (17, 5)
    dense = abs_topk(x, 5)
    # every (val, idx) pair appears in the dense masked version
    rows = jnp.arange(17)[:, None]
    np.testing.assert_allclose(dense[rows, idx], vals, rtol=1e-6)
    # exactly k nonzeros per row
    assert int((dense != 0).sum()) == 17 * 5


def test_encoder_normalizes_input_scale_invariant():
    params = init_params(CFG, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, CFG.d))
    c1 = encode(params, x, CFG.k)
    c2 = encode(params, 3.7 * x, CFG.k)
    np.testing.assert_allclose(c1.values, c2.values, rtol=1e-5)
    np.testing.assert_array_equal(c1.indices, c2.indices)


def test_decoder_rows_unit_norm_after_projection():
    params = init_params(CFG, jax.random.PRNGKey(1))
    params = {**params, "w_dec": params["w_dec"] * 3.0}
    params = normalize_decoder(params)
    norms = jnp.linalg.norm(params["w_dec"], axis=-1)
    np.testing.assert_allclose(norms, jnp.ones(CFG.h), rtol=1e-6)


def test_sparse_decode_matches_dense_decode():
    params = init_params(CFG, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(3), (9, CFG.d))
    codes = encode(params, x, CFG.k)
    dense_lat = encode_dense(params, x, CFG.k)
    np.testing.assert_allclose(
        decode(params, codes), decode_dense(params, dense_lat), rtol=1e-5, atol=1e-6
    )


def test_densify_from_dense_roundtrip():
    params = init_params(CFG, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(4), (6, CFG.d))
    codes = encode(params, x, CFG.k)
    dense = sp.densify(codes)
    assert dense.shape == (6, CFG.h)
    codes2 = sp.from_dense(dense, CFG.k)
    np.testing.assert_allclose(sp.densify(codes2), dense, rtol=1e-6)


def test_csr_roundtrip():
    params = init_params(CFG, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(5), (8, CFG.d))
    codes = encode(params, x, CFG.k)
    data, indices, indptr = sp.to_csr(codes)
    assert indptr[-1] == 8 * CFG.k
    back = sp.from_csr(data, indices, indptr, CFG.h)
    np.testing.assert_allclose(sp.densify(back), sp.densify(codes), rtol=1e-6)


def test_compression_ratio_paper_arithmetic():
    # Paper: 768-d fp32 -> 4096-dim k=32 sparse = 12x
    cfg = SAEConfig(d=768, h=4096, k=32)
    assert cfg.compression_ratio == pytest.approx(12.0)


def test_loss_and_train_step_reduce_loss():
    key = jax.random.PRNGKey(7)
    x = clustered_embeddings(key, 512, d=CFG.d, n_clusters=8)
    state = init_train_state(CFG, jax.random.PRNGKey(8))
    opt_cfg = AdamConfig(lr=3e-3)
    loss0, m0 = compressae_loss(state.params, x, CFG)
    step = jax.jit(
        lambda s, b: train_step(s, b, CFG, opt_cfg), donate_argnums=(0,)
    )
    for _ in range(30):
        state, metrics = step(state, x)
    assert float(metrics["loss"]) < float(loss0) * 0.7
    assert jnp.isfinite(metrics["loss"])
    # decoder stays row-normalized through training
    norms = jnp.linalg.norm(state.params["w_dec"], axis=-1)
    np.testing.assert_allclose(norms, jnp.ones(CFG.h), rtol=1e-5)


def test_multi_k_aux_loss_components():
    key = jax.random.PRNGKey(9)
    x = clustered_embeddings(key, 128, d=CFG.d, n_clusters=8)
    params = init_params(CFG, jax.random.PRNGKey(10))
    loss, m = compressae_loss(params, x, CFG)
    # total = k-loss + aux-loss (aux_weight=1)
    np.testing.assert_allclose(
        float(loss), float(m["cos_loss_k"] + m["cos_loss_aux"]), rtol=1e-6
    )
    # 4k reconstruction must be at least as good as k (more capacity)
    assert float(m["cos_loss_aux"]) <= float(m["cos_loss_k"]) + 1e-6


def test_kernel_matrix_symmetry():
    params = init_params(CFG, jax.random.PRNGKey(11))
    K = kernel_matrix(params)
    assert K.shape == (CFG.h, CFG.h)
    np.testing.assert_allclose(K, K.T, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(jnp.diag(K), jnp.ones(CFG.h), rtol=1e-5)
