"""Distributed-correctness: shard_map implementations must match their
single-device oracles bit-for-bit (up to float reassociation).

Runs in a subprocess because the device count must be set before jax
initializes (the main pytest process is single-device)."""
import os
import pathlib
import subprocess
import sys

import jax
import pytest

if not hasattr(jax, "shard_map"):
    # the impl (and the sharded fns it exercises: moe_ffn_sharded, nequip
    # sharded, encode_sharded) target jax>=0.6 APIs — jax.shard_map,
    # jax.set_mesh, jax.sharding.AxisType, get_abstract_mesh — absent from
    # older jax; see ROADMAP open items
    pytest.skip("requires jax.shard_map (jax >= 0.6)", allow_module_level=True)


@pytest.mark.timeout(600)
def test_shard_map_implementations_match_oracles():
    script = pathlib.Path(__file__).with_name("_distributed_equiv_impl.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, str(script)], env=env,
        capture_output=True, text=True, timeout=570,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL DISTRIBUTED EQUIV OK" in proc.stdout
