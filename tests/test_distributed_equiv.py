"""Distributed-correctness: shard_map implementations must match their
single-device oracles bit-for-bit (up to float reassociation).

Runs in a subprocess so the forced multi-device CPU topology (XLA_FLAGS,
set process-wide by tests/conftest.py and inherited here) is guaranteed to
be in effect before jax initializes in the child — the parent pytest
process may or may not have it, depending on import order.

The implementations under test (moe_ffn_sharded, nequip sharded,
encode_sharded, the registry retrieval cells, distributed_retrieve) all go
through the repro.compat jax-version shim, so this suite runs — unskipped —
on jax 0.4.x as well as >= 0.6.
"""
import os
import pathlib
import subprocess
import sys

import pytest


@pytest.mark.distributed
@pytest.mark.timeout(600)
def test_shard_map_implementations_match_oracles():
    script = pathlib.Path(__file__).with_name("_distributed_equiv_impl.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, str(script)], env=env,
        capture_output=True, text=True, timeout=570,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL DISTRIBUTED EQUIV OK" in proc.stdout
