"""Fault-tolerance substrate tests: checkpoint atomicity, resume,
elastic restore, deterministic data replay."""
import os
import pathlib
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.core import SAEConfig, init_train_state, train_step
from repro.data import LoaderState, ShardedLoader, clustered_embeddings
from repro.optim import AdamConfig

CFG = SAEConfig(d=32, h=128, k=4)


def _state():
    return init_train_state(CFG, jax.random.PRNGKey(0))


def test_save_load_roundtrip(tmp_path):
    state = _state()
    save_pytree(tmp_path / "x.ckpt", state, {"step": 7})
    loaded, meta = load_pytree(tmp_path / "x.ckpt", like=state)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_partial_files(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _state()
    mgr.save(1, state)
    # simulate a crashed writer: stray tmp file must not be visible as a step
    (tmp_path / "step_0000000002.ckpt.tmp-999-1").write_bytes(b"garbage")
    assert mgr.steps() == [1]
    restored, meta = mgr.restore(state)
    assert meta["step"] == 1


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.steps() == [3, 4]


def test_resume_training_bitexact(tmp_path):
    """Kill/restart at step 5 of 10 == uninterrupted 10 steps (checkpoint +
    deterministic loader replay)."""
    opt = AdamConfig(lr=1e-3)
    loader = ShardedLoader(
        generate=lambda k, s, n: {"x": clustered_embeddings(k, 64, d=CFG.d)}, seed=3
    )
    step_fn = jax.jit(lambda s, b: train_step(s, b, CFG, opt))

    def run(state, lo, hi):
        for t in range(lo, hi):
            state, _ = step_fn(state, loader.batch_at(t)["x"])
        return state

    straight = run(_state(), 0, 10)

    mgr = CheckpointManager(tmp_path)
    half = run(_state(), 0, 5)
    mgr.save(5, half)
    restored, meta = mgr.restore(_state())
    resumed = run(restored, int(meta["step"]), 10)

    for a, b in zip(jax.tree.leaves(straight.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_elastic_restore_shape_agnostic(tmp_path):
    """Checkpoints store full logical arrays — restoring onto a different
    'device count' (here simulated by restructuring) works unchanged."""
    state = _state()
    save_pytree(tmp_path / "e.ckpt", {"w": jnp.arange(64.0).reshape(8, 8)})
    # a 'resharded' consumer just asks for the same logical array
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    loaded, _ = load_pytree(tmp_path / "e.ckpt", like=like)
    np.testing.assert_array_equal(np.asarray(loaded["w"]),
                                  np.arange(64.0).reshape(8, 8))


def test_shape_mismatch_rejected(tmp_path):
    save_pytree(tmp_path / "m.ckpt", {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        load_pytree(tmp_path / "m.ckpt",
                    like={"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)})


def test_async_save_completes(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(3, {"w": jnp.ones((16,))})
    mgr.wait()
    assert mgr.steps() == [3]


def test_train_launcher_end_to_end(tmp_path, capsys):
    """Tiny end-to-end run of the production launcher incl. resume."""
    from repro.launch.train import main

    ckpt = str(tmp_path / "ck")
    rc = main(["--steps", "30", "--batch", "128", "--d", "32", "--h", "128",
               "--k", "4", "--ckpt-dir", ckpt, "--ckpt-every", "10",
               "--log-every", "10"])
    assert rc == 0
    # resume: second invocation starts from the final checkpoint
    rc = main(["--steps", "35", "--batch", "128", "--d", "32", "--h", "128",
               "--k", "4", "--ckpt-dir", ckpt, "--ckpt-every", "10",
               "--log-every", "10"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "resumed from step 30" in out
