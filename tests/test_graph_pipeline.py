"""Graph data-pipeline tests: dst-partitioning contract + sampler."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data import random_graph
from repro.data.sampler import (
    CSRGraph, partition_edges_by_dst, sample_subgraph, subgraph_shapes,
)


def test_partition_edges_by_dst_contract():
    g = random_graph(0, n_nodes=64, n_edges=200, d_feat=4)
    out = partition_edges_by_dst(g["edge_index"], 64, n_node_shards=4,
                                 n_splits=2)
    ei, mask = out["edge_index"], out["edge_mask"]
    e = ei.shape[1]
    assert e % (4 * 2) == 0
    per = e // 4
    # every edge in block i has dst in node shard i (incl. padding)
    for i in range(4):
        dsts = ei[1, i * per:(i + 1) * per]
        assert ((dsts // 16) == i).all(), i
    # masked-in edge multiset preserved
    real = mask > 0
    got = set(map(tuple, ei[:, real].T.tolist()))
    want = set(map(tuple, g["edge_index"].T.tolist()))
    assert got == want


def test_partition_preserves_forward_result():
    """Dense nequip forward is invariant to the reordering+padding."""
    from repro.models.nequip import NequIPConfig, nequip_forward, nequip_init

    cfg = NequIPConfig(n_layers=2, d_hidden=8, l_max=1, n_rbf=4, d_feat=6,
                       n_out=3, radial_hidden=8)
    params = nequip_init(cfg, jax.random.PRNGKey(0))
    g = random_graph(1, n_nodes=32, n_edges=100, d_feat=6)
    nf = jnp.asarray(g["node_feat"])
    pos = jnp.asarray(g["positions"])
    ref = nequip_forward(params, nf, jnp.asarray(g["edge_index"]), pos, cfg)
    out = partition_edges_by_dst(g["edge_index"], 32, 4, 2)
    got = nequip_forward(params, nf, jnp.asarray(out["edge_index"]), pos, cfg,
                         edge_mask=jnp.asarray(out["edge_mask"]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_sampler_deterministic_and_masked():
    g = random_graph(2, n_nodes=500, n_edges=3000, d_feat=4)
    csr = CSRGraph.from_edge_index(g["edge_index"], 500)
    seeds = np.arange(16)
    a = sample_subgraph(csr, seeds, [4, 3], np.random.default_rng(7))
    b = sample_subgraph(csr, seeds, [4, 3], np.random.default_rng(7))
    np.testing.assert_array_equal(a["nodes"], b["nodes"])
    np.testing.assert_array_equal(a["edge_index"], b["edge_index"])
    ns, es = subgraph_shapes(16, [4, 3])
    assert a["nodes"].shape == (ns,) and a["edge_mask"].shape == (es,)
    # every real edge's endpoints are real nodes
    real = a["edge_mask"] > 0
    assert (a["nodes"][a["edge_index"][0, real]] >= 0).all()
