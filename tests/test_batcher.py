"""Microbatching serving front (ISSUE 10 tentpole): coalescing, padding
bit-identity, the max-wait bound, per-bucket jit reuse, typed overload
shedding, and the deterministic loadtest smoke run.

The load-bearing contract is bit-identity: a request's (scores, ids) —
ties included — must be exactly what a per-request ``retrieve_dense``
call returns, at every bucket size, because the panel padding rows are
scored and discarded before any slice can see them.
"""
import json
import os
import pathlib
import subprocess
import sys
import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import SAEConfig, build_index, encode, init_params
from repro.errors import (
    EngineConfigError,
    InvalidQueryError,
    QueueFullError,
)
from repro.kernels.sparse_dot.kernel import BLOCK_Q
from repro.serving import (
    EngineConfig,
    GuardedEngine,
    MicrobatchServer,
    RetrievalEngine,
    RetrievalResponse,
)

REPO = pathlib.Path(__file__).parents[1]
CFG = SAEConfig(d=32, h=128, k=8)
B = BLOCK_Q  # 8: the panel-size quantum


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (310, CFG.d))
    queries = jax.random.normal(jax.random.PRNGKey(2), (64, CFG.d))
    codes = encode(params, corpus, CFG.k)
    index = build_index(codes, params)
    return params, index, queries


def make_engine(setup):
    params, index, _ = setup
    return RetrievalEngine(index, params,
                           config=EngineConfig(use_kernel=False))


# ------------------------------------------------- coalescing + identity
def test_burst_coalesces_into_one_full_panel_bit_identical(setup):
    """A burst whose rows fill the largest bucket dispatches as ONE
    panel, and every request's slice is bit-identical to its own
    per-request retrieve_dense call — including the 1-D (squeezed)
    submission."""
    params, index, queries = setup
    engine = make_engine(setup)
    # 3 + 1 (1-D) + 4 + 8 = 16 rows = largest bucket -> fires on the
    # last submit, no deadline involved
    reqs = [queries[0:3], queries[3], queries[4:8], queries[8:16]]
    with MicrobatchServer(engine, buckets=(B, 2 * B),
                          max_wait_us=30_000_000) as server:
        futures = [server.submit(x, 5) for x in reqs]
        resps = [f.result(timeout=60) for f in futures]
    for x, resp in zip(reqs, resps):
        want_s, want_i, *_ = engine.retrieve_dense(x, 5)
        assert isinstance(resp, RetrievalResponse)
        assert resp.scores.shape == want_s.shape  # squeeze preserved
        np.testing.assert_array_equal(np.asarray(resp.ids),
                                      np.asarray(want_i))
        np.testing.assert_array_equal(np.asarray(resp.scores),
                                      np.asarray(want_s))
        assert resp.queue_us >= 0.0 and resp.compute_us > 0.0
    s = server.stats()
    assert s["panels"] == 1 and s["panels_by_bucket"][2 * B] == 1
    assert s["padded_rows"] == 0 and s["occupancy_mean"] == 1.0


@pytest.mark.parametrize("rows", [1, 3, B, B + 1, 2 * B - 1, 2 * B])
def test_padding_never_leaks_at_any_bucket_fill(setup, rows):
    """A lone request of every fill level pads to the smallest bucket
    that fits; the sliced response is bit-identical to the unpadded
    per-request call, so the zero padding rows are unobservable."""
    params, index, queries = setup
    engine = make_engine(setup)
    x = queries[:rows]
    with MicrobatchServer(engine, buckets=(B, 2 * B),
                          max_wait_us=1000) as server:
        resp = server.serve(x, 7, timeout=60)
    want_s, want_i, *_ = engine.retrieve_dense(x, 7)
    np.testing.assert_array_equal(np.asarray(resp.ids), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(resp.scores),
                                  np.asarray(want_s))
    s = server.stats()
    bucket = B if rows <= B else 2 * B
    assert s["panels"] == 1 and s["panels_by_bucket"][bucket] == 1
    assert s["padded_rows"] == bucket - rows


def test_trickle_fires_partial_panels_on_max_wait(setup):
    """Requests arriving slower than max_wait never coalesce: each fires
    as its own padded panel once the oldest-request deadline passes — a
    trickle is never starved waiting for a batch that isn't coming."""
    params, index, queries = setup
    engine = make_engine(setup)
    with MicrobatchServer(engine, buckets=(B,),
                          max_wait_us=1000, max_queue_rows=B) as server:
        for r in range(3):
            resp = server.serve(queries[r], 5, timeout=60)
            want_s, want_i, *_ = engine.retrieve_dense(queries[r], 5)
            np.testing.assert_array_equal(np.asarray(resp.ids),
                                          np.asarray(want_i))
            time.sleep(0.02)  # > max_wait: the next request is alone too
    s = server.stats()
    assert s["panels"] == 3 and s["padded_rows"] == 3 * (B - 1)
    assert s["occupancy_mean"] == pytest.approx(1 / B)


def test_mixed_topn_requests_never_share_a_panel(setup):
    """top-n is a compile-time constant of the serve jit, so requests
    with different n ride separate panels but all resolve correctly."""
    params, index, queries = setup
    engine = make_engine(setup)
    with MicrobatchServer(engine, buckets=(B,),
                          max_wait_us=1000) as server:
        f5 = [server.submit(queries[i], 5) for i in range(2)]
        f9 = [server.submit(queries[i + 2], 9) for i in range(2)]
        r5 = [f.result(timeout=60) for f in f5]
        r9 = [f.result(timeout=60) for f in f9]
    assert all(r.ids.shape == (5,) for r in r5)
    assert all(r.ids.shape == (9,) for r in r9)
    for i, resp in enumerate(r5):
        _, want_i, *_ = engine.retrieve_dense(queries[i], 5)
        np.testing.assert_array_equal(np.asarray(resp.ids),
                                      np.asarray(want_i))
    for i, resp in enumerate(r9):
        _, want_i, *_ = engine.retrieve_dense(queries[i + 2], 9)
        np.testing.assert_array_equal(np.asarray(resp.ids),
                                      np.asarray(want_i))
    assert server.stats()["panels"] >= 2  # n=5 and n=9 panels are disjoint


# ------------------------------------------------------- jit reuse
def test_one_trace_per_bucket_then_cache_hits(setup):
    """The engine only ever sees bucket-shaped panels, so the serve jit
    traces exactly once per (bucket, n) — warmup pre-pays all of them and
    steady-state traffic adds zero retraces.  ``encode_queries``'s Python
    body runs once per trace, making it the compile counter."""
    params, index, queries = setup
    engine = make_engine(setup)
    traces = []
    orig = engine.encode_queries
    engine.encode_queries = lambda xb: (traces.append(tuple(xb.shape)),
                                        orig(xb))[1]
    with MicrobatchServer(engine, buckets=(B, 2 * B),
                          max_wait_us=1000) as server:
        server.warmup(5)
        assert sorted(t[0] for t in traces) == [B, 2 * B]
        # traffic at both fill levels: partial (pads to B) and full 2B
        server.serve(queries[:3], 5, timeout=60)
        fs = [server.submit(queries[i * B:(i + 1) * B], 5)
              for i in range(2)]
        for f in fs:
            f.result(timeout=60)
    assert sorted(t[0] for t in traces) == [B, 2 * B]  # zero retraces


# ------------------------------------------------------ overload shedding
class _GatedEngine:
    """Blocks the dispatcher inside retrieve_dense until released, so the
    queue state during an in-flight panel is deterministic."""

    def __init__(self, inner):
        self.engine = inner  # warmup unwraps via .engine
        self.entered = threading.Event()
        self.release = threading.Event()

    def retrieve_dense(self, x, n):
        self.entered.set()
        assert self.release.wait(timeout=60)
        return self.engine.retrieve_dense(x, n)


def test_queue_full_sheds_typed_then_retry_succeeds(setup):
    params, index, queries = setup
    gated = _GatedEngine(make_engine(setup))
    server = MicrobatchServer(gated, buckets=(B,), max_queue_rows=B,
                              max_wait_us=1000)
    try:
        # panel A fills the only bucket -> dispatcher drains it and
        # blocks inside the gated engine; the queue is empty again
        fa = server.submit(queries[:B], 5)
        assert gated.entered.wait(timeout=60)
        # panel B refills the queue to max_queue_rows
        fb = server.submit(queries[B:2 * B], 5)
        # request C finds 8 + 1 > max_queue_rows -> typed shed, and the
        # error carries the admission numbers
        with pytest.raises(QueueFullError) as exc:
            server.submit(queries[0], 5)
        assert exc.value.queued_rows == B
        assert exc.value.max_queue_rows == B
        assert server.stats()["shed"] == 1
        gated.release.set()
        ra, rb = fa.result(timeout=60), fb.result(timeout=60)
        # the retried request flows through the normal path and carries
        # the same ServingStatus surface as every response
        rc = server.serve(queries[0], 5, timeout=60)
        assert rc.status.path == ra.status.path
        assert not rc.status.degraded
        _, want_i, *_ = gated.engine.retrieve_dense(queries[0], 5)
        np.testing.assert_array_equal(np.asarray(rc.ids),
                                      np.asarray(want_i))
    finally:
        gated.release.set()
        server.close()


# ----------------------------------------------------- guard + validation
def test_batcher_over_guard_passes_status_through(setup):
    """GuardedEngine under the batcher: responses carry the guard's
    ServingStatus and stay bit-identical to the guard's own answers."""
    params, index, queries = setup
    guard = GuardedEngine(make_engine(setup))
    with MicrobatchServer(guard, buckets=(B,),
                          max_wait_us=1000) as server:
        server.warmup(5)
        resp = server.serve(queries[:3], 5, timeout=60)
    want_s, want_i, status, *_ = guard.retrieve_dense(queries[:3], 5)
    assert resp.status.path == status.path
    np.testing.assert_array_equal(np.asarray(resp.ids), np.asarray(want_i))


def test_submit_validation_and_lifecycle(setup):
    params, index, queries = setup
    engine = make_engine(setup)
    server = MicrobatchServer(engine, buckets=(B,), max_wait_us=1000)
    with pytest.raises(InvalidQueryError, match="rank"):
        server.submit(jnp.zeros((2, 2, CFG.d)), 5)
    with pytest.raises(InvalidQueryError, match="empty"):
        server.submit(queries[:0], 5)
    with pytest.raises(InvalidQueryError, match="largest panel bucket"):
        server.submit(jnp.asarray(np.zeros((B + 1, CFG.d))), 5)
    assert server.stats()["requests"] == 0  # none of those were admitted
    server.close()
    server.close()  # idempotent
    with pytest.raises(EngineConfigError, match="closed"):
        server.submit(queries[0], 5)


def test_bucket_configuration_is_validated(setup):
    engine = make_engine(setup)
    with pytest.raises(EngineConfigError, match="ascending"):
        MicrobatchServer(engine, buckets=(2 * B, B))
    with pytest.raises(EngineConfigError, match="multiples"):
        MicrobatchServer(engine, buckets=(B, B + 1))
    with pytest.raises(EngineConfigError, match="max_queue_rows"):
        MicrobatchServer(engine, buckets=(B, 4 * B), max_queue_rows=B)


# ------------------------------------------------------- loadtest smoke
@pytest.mark.timeout(600)
def test_loadtest_smoke_writes_schema_valid_record(tmp_path):
    """The traffic-shaped loadtest driver end to end at smoke size: the
    run must emit a BENCH_serving.json that the serving-schema gate
    (tools/check_bench.py --schema serving) accepts against itself.
    Timing is machine noise, so a slow/failed run SKIPs (non-gating, like
    the benchmark smoke) — but a SUCCEEDED run's record schema gates."""
    out = tmp_path / "BENCH_serving.json"
    env = {**os.environ, "PYTHONPATH": str(REPO / "src")}
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.loadtest", "--smoke",
             "--catalog", "1200", "--train-steps", "8", "--requests", "48",
             "--users", "64", "--out", str(out)],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=540,
        )
    except subprocess.TimeoutExpired:
        pytest.skip("loadtest smoke timed out (non-gating)")
    if proc.returncode != 0:
        pytest.skip(
            "loadtest smoke failed (non-gating):\n"
            f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"
        )
    records = json.loads(out.read_text())
    by_name = {r["name"]: r for r in records}
    assert {"serving_closed_loop", "serving_open_loop"} <= set(by_name)
    for r in records:
        assert 0.0 <= r["shed_rate"] <= 1.0, r
        assert 0.0 <= r["occupancy_mean"] <= 1.0, r
        assert r["p50_ms"] <= r["p95_ms"] <= r["p99_ms"], r
        assert r["requests"] == 48 and r["smoke"] is True, r
    # the serving-schema gate accepts the fresh record against itself
    gate = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_bench.py"),
         str(out), str(out), "--schema", "serving"],
        capture_output=True, text=True, timeout=60,
    )
    assert gate.returncode == 0, gate.stdout + gate.stderr
