"""The examples are part of the public API surface — run them."""
import runpy
import sys
import pathlib

import pytest

EXAMPLES = pathlib.Path(__file__).parents[1] / "examples"


@pytest.mark.parametrize("name", [
    "quickstart", "recsys_catalog_compression", "llm_embedding_compression",
])
@pytest.mark.timeout(900)
def test_example_runs(name, capsys):
    runpy.run_path(str(EXAMPLES / f"{name}.py"), run_name="__main__")
    out = capsys.readouterr().out
    assert "MiB" in out  # every example prints a compression line
