"""Hypothesis property-based tests on the system's invariants."""
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import (
    SAEConfig,
    abs_topk,
    abs_topk_sparse,
    cosine_distance,
    encode,
    init_params,
    normalize_decoder,
)
from repro.core import sparse as sp
from repro.core.types import SparseCodes

hypothesis.settings.register_profile(
    "repro", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("repro")


@st.composite
def arrays_2d(draw, max_rows=16, max_cols=128, min_cols=4):
    rows = draw(st.integers(1, max_rows))
    cols = draw(st.integers(min_cols, max_cols))
    seed = draw(st.integers(0, 2**31 - 1))
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols))
    return x


@given(arrays_2d(), st.integers(1, 16))
def test_abs_topk_invariants(x, k):
    k = min(k, x.shape[-1])
    out = abs_topk(x, k)
    # I1: exactly k nonzeros per row (generic continuous inputs)
    assert (np.asarray((out != 0).sum(-1)) == k).all()
    # I2: kept entries equal the input where kept
    mask = np.asarray(out != 0)
    np.testing.assert_allclose(np.asarray(out)[mask], np.asarray(x)[mask], rtol=1e-6)
    # I3: every dropped |entry| <= every kept |entry| (per row)
    xa = np.abs(np.asarray(x))
    for r in range(x.shape[0]):
        kept = xa[r][mask[r]]
        dropped = xa[r][~mask[r]]
        if dropped.size and kept.size:
            assert dropped.max() <= kept.min() + 1e-6
    # I4: idempotence — φ(φ(x,k),k) = φ(x,k)
    np.testing.assert_allclose(abs_topk(out, k), out, rtol=1e-6)


@given(arrays_2d(), st.integers(1, 8))
def test_sparse_densify_roundtrip(x, k):
    k = min(k, x.shape[-1])
    vals, idx = abs_topk_sparse(x, k)
    codes = SparseCodes(values=vals, indices=idx, dim=x.shape[-1])
    dense = sp.densify(codes)
    np.testing.assert_allclose(dense, abs_topk(x, k), rtol=1e-6)
    # storage arithmetic: 2 * k * 4 bytes per row
    assert codes.nbytes_logical == x.shape[0] * 2 * k * 4


@given(st.integers(0, 2**31 - 1))
def test_cosine_distance_bounds_and_self(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 32))
    y = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 32))
    d = np.asarray(cosine_distance(x, y))
    assert (d >= -1e-6).all() and (d <= 2 + 1e-6).all()
    np.testing.assert_allclose(cosine_distance(x, x), np.zeros(8), atol=1e-6)
    # scale invariance
    np.testing.assert_allclose(
        cosine_distance(3.0 * x, 0.5 * y), d, rtol=1e-5, atol=1e-6
    )


@given(st.integers(0, 2**31 - 1))
def test_encode_is_scale_invariant_and_normalization_idempotent(seed):
    cfg = SAEConfig(d=32, h=128, k=4)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    params = normalize_decoder(normalize_decoder(params))  # idempotent
    norms = np.asarray(jnp.linalg.norm(params["w_dec"], axis=-1))
    np.testing.assert_allclose(norms, np.ones(cfg.h), rtol=1e-6)
    x = jax.random.normal(jax.random.PRNGKey(seed + 7), (4, cfg.d))
    c1 = encode(params, x, cfg.k)
    c2 = encode(params, 100.0 * x, cfg.k)
    np.testing.assert_array_equal(np.asarray(c1.indices), np.asarray(c2.indices))


@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
def test_sparse_dot_linearity(seed, k):
    """sparse_dot is linear in the query: f(a·q1 + q2) = a·f(q1) + f(q2)."""
    from repro.kernels.sparse_dot.ops import sparse_dot

    h = 64
    kv, ki, kq = jax.random.split(jax.random.PRNGKey(seed), 3)
    vals = jax.random.normal(kv, (24, k))
    idx = jax.random.randint(ki, (24, k), 0, h, dtype=jnp.int32)
    q1 = jax.random.normal(kq, (1, h))
    q2 = jnp.roll(q1, 3, axis=-1)
    lhs = sparse_dot(vals, idx, 2.5 * q1 + q2)
    rhs = 2.5 * sparse_dot(vals, idx, q1) + sparse_dot(vals, idx, q2)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2**31 - 1))
def test_loader_determinism_and_shard_disjointness(seed):
    """Resumable loader: batch at step t is reproducible and shard-dependent."""
    from repro.data import ShardedLoader, clustered_embeddings

    def gen(key, shard, nshards):
        return {"x": clustered_embeddings(key, 8, d=16, n_clusters=2)}

    l0 = ShardedLoader(generate=gen, seed=seed, shard_id=0, num_shards=2)
    l0b = ShardedLoader(generate=gen, seed=seed, shard_id=0, num_shards=2)
    l1 = ShardedLoader(generate=gen, seed=seed, shard_id=1, num_shards=2)
    b0 = l0.batch_at(5)["x"]
    np.testing.assert_array_equal(b0, l0b.batch_at(5)["x"])  # deterministic
    assert not np.allclose(b0, l1.batch_at(5)["x"])          # shard-distinct
    assert not np.allclose(b0, l0.batch_at(6)["x"])          # step-distinct
