"""Retrieval-mode tests (paper §3.2) + the kernel-trick exactness property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SAEConfig,
    build_index,
    decode,
    encode,
    init_params,
    init_train_state,
    retrieve,
    score_dense,
    score_reconstructed,
    score_sparse,
    top_n,
    train_step,
)
from repro.data import clustered_embeddings
from repro.optim import AdamConfig

CFG = SAEConfig(d=64, h=512, k=16)


@pytest.fixture(scope="module")
def trained():
    """A briefly-trained SAE + corpus (module-scoped: train once)."""
    key = jax.random.PRNGKey(0)
    corpus = clustered_embeddings(key, 2048, d=CFG.d, n_clusters=16)
    state = init_train_state(CFG, jax.random.PRNGKey(1))
    step = jax.jit(lambda s, b: train_step(s, b, CFG, AdamConfig(lr=3e-3)))
    for i in range(60):
        state, _ = step(state, corpus)
    return state.params, corpus


def test_kernel_trick_is_exact(trained):
    """cos in reconstructed space via sparse codes == cos of decoded vectors.

    This is the paper's §3.2 identity; our z = W_dec^T(W_dec s_q)
    factorization must be EXACT (associativity), not approximate.
    """
    params, corpus = trained
    db = corpus[:256]
    queries = corpus[256:260]
    codes_db = encode(params, db, CFG.k)
    codes_q = encode(params, queries, CFG.k)
    index = build_index(codes_db, params)

    got = score_reconstructed(index, codes_q, params)

    x_hat_db = decode(params, codes_db)
    x_hat_q = decode(params, codes_q)
    want = score_dense(x_hat_db, x_hat_q)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_sparse_scores_match_dense_latent_cosine(trained):
    params, corpus = trained
    db = corpus[:128]
    q = corpus[200:203]
    codes_db = encode(params, db, CFG.k)
    codes_q = encode(params, q, CFG.k)
    index = build_index(codes_db)
    got = score_sparse(index, codes_q)

    from repro.core import sparse as sp

    want = score_dense(sp.densify(codes_db), sp.densify(codes_q))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_retrieval_recall_beats_random(trained):
    """Compressed retrieval must agree with exact dense retrieval far above
    chance — the paper's core claim, scaled down."""
    params, corpus = trained
    db = corpus[:1024]
    queries = corpus[1024:1088]
    n = 10

    truth = score_dense(db, queries)
    _, true_ids = top_n(truth, n)

    codes_db = encode(params, db, CFG.k)
    codes_q = encode(params, queries, CFG.k)
    index = build_index(codes_db, params)

    def recall(ids):
        hits = 0
        for r, t in zip(np.asarray(ids), np.asarray(true_ids)):
            hits += len(set(r.tolist()) & set(t.tolist()))
        return hits / true_ids.size

    _, ids_sparse = top_n(score_sparse(index, codes_q), n)
    _, ids_recon = top_n(score_reconstructed(index, codes_q, params), n)
    r_sparse, r_recon = recall(ids_sparse), recall(ids_recon)
    chance = n / db.shape[0]
    assert r_sparse > 10 * chance, f"sparse recall {r_sparse} ~ chance"
    assert r_recon > 10 * chance, f"recon recall {r_recon} ~ chance"
    # Paper Fig 3 center: reconstructed-space >= sparse-space fidelity.
    assert r_recon >= r_sparse - 0.05


@pytest.mark.parametrize("mode", ["sparse", "reconstructed"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_retrieve_matches_score_then_select(trained, mode, use_kernel):
    """retrieve() (fused score+select, both backends) must return the same
    top-n as materializing the full score matrix and running lax.top_k —
    values to f32 rounding, ids exactly (inputs are untied)."""
    params, corpus = trained
    codes_db = encode(params, corpus[:512], CFG.k)
    codes_q = encode(params, corpus[512:530], CFG.k)
    index = build_index(codes_db, params)
    full = (score_sparse(index, codes_q) if mode == "sparse"
            else score_reconstructed(index, codes_q, params))
    want_v, want_i = top_n(full, 9)
    got_v, got_i = retrieve(index, codes_q, 9, mode=mode, params=params,
                            use_kernel=use_kernel)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(got_i, want_i)


def test_retrieve_single_query(trained):
    params, corpus = trained
    codes_db = encode(params, corpus[:256], CFG.k)
    index = build_index(codes_db)
    q = encode(params, corpus[300:301], CFG.k)
    q1 = type(q)(values=q.values[0], indices=q.indices[0], dim=q.dim)
    v, i = retrieve(index, q1, 5, use_kernel=False)
    assert v.shape == (5,) and i.shape == (5,)
    v2, i2 = retrieve(index, q1, 5, use_kernel=True)
    np.testing.assert_array_equal(i, i2)
    want_v, want_i = top_n(score_sparse(index, q1), 5)
    np.testing.assert_array_equal(i, want_i)


def test_retrieve_requires_params_for_recon(trained):
    params, corpus = trained
    index = build_index(encode(params, corpus[:64], CFG.k))  # no params
    q = encode(params, corpus[64:66], CFG.k)
    with pytest.raises(ValueError):
        retrieve(index, q, 3, mode="reconstructed", params=params)
    with pytest.raises(ValueError):
        retrieve(index, q, 3, mode="reconstructed")  # params missing
    with pytest.raises(ValueError):
        retrieve(index, q, 3, mode="bogus")


def test_retrieve_jit_compatible(trained):
    # the whole serve step (encode + fused retrieve) under one jit, the way
    # launch/serve.py uses it
    params, corpus = trained
    codes_db = encode(params, corpus[:256], CFG.k)
    index = build_index(codes_db, params)
    fn = jax.jit(
        lambda x: retrieve(index, encode(params, x, CFG.k), 7, use_kernel=False)
    )
    v, i = fn(corpus[300:310])
    q = encode(params, corpus[300:310], CFG.k)
    want_v, want_i = top_n(score_sparse(index, q), 7)
    np.testing.assert_array_equal(i, want_i)


def test_top_n_shapes(trained):
    params, corpus = trained
    codes_db = encode(params, corpus[:100], CFG.k)
    index = build_index(codes_db)
    q = encode(params, corpus[100:102], CFG.k)
    scores = score_sparse(index, q)
    v, i = top_n(scores, 7)
    assert v.shape == (2, 7) and i.shape == (2, 7)
    assert (jnp.diff(v, axis=-1) <= 1e-6).all()  # sorted descending
