"""Retrieval-mode tests (paper §3.2) + the kernel-trick exactness property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SAEConfig,
    build_index,
    decode,
    encode,
    init_params,
    init_train_state,
    score_dense,
    score_reconstructed,
    score_sparse,
    top_n,
    train_step,
)
from repro.data import clustered_embeddings
from repro.optim import AdamConfig

CFG = SAEConfig(d=64, h=512, k=16)


@pytest.fixture(scope="module")
def trained():
    """A briefly-trained SAE + corpus (module-scoped: train once)."""
    key = jax.random.PRNGKey(0)
    corpus = clustered_embeddings(key, 2048, d=CFG.d, n_clusters=16)
    state = init_train_state(CFG, jax.random.PRNGKey(1))
    step = jax.jit(lambda s, b: train_step(s, b, CFG, AdamConfig(lr=3e-3)))
    for i in range(60):
        state, _ = step(state, corpus)
    return state.params, corpus


def test_kernel_trick_is_exact(trained):
    """cos in reconstructed space via sparse codes == cos of decoded vectors.

    This is the paper's §3.2 identity; our z = W_dec^T(W_dec s_q)
    factorization must be EXACT (associativity), not approximate.
    """
    params, corpus = trained
    db = corpus[:256]
    queries = corpus[256:260]
    codes_db = encode(params, db, CFG.k)
    codes_q = encode(params, queries, CFG.k)
    index = build_index(codes_db, params)

    got = score_reconstructed(index, codes_q, params)

    x_hat_db = decode(params, codes_db)
    x_hat_q = decode(params, codes_q)
    want = score_dense(x_hat_db, x_hat_q)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_sparse_scores_match_dense_latent_cosine(trained):
    params, corpus = trained
    db = corpus[:128]
    q = corpus[200:203]
    codes_db = encode(params, db, CFG.k)
    codes_q = encode(params, q, CFG.k)
    index = build_index(codes_db)
    got = score_sparse(index, codes_q)

    from repro.core import sparse as sp

    want = score_dense(sp.densify(codes_db), sp.densify(codes_q))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_retrieval_recall_beats_random(trained):
    """Compressed retrieval must agree with exact dense retrieval far above
    chance — the paper's core claim, scaled down."""
    params, corpus = trained
    db = corpus[:1024]
    queries = corpus[1024:1088]
    n = 10

    truth = score_dense(db, queries)
    _, true_ids = top_n(truth, n)

    codes_db = encode(params, db, CFG.k)
    codes_q = encode(params, queries, CFG.k)
    index = build_index(codes_db, params)

    def recall(ids):
        hits = 0
        for r, t in zip(np.asarray(ids), np.asarray(true_ids)):
            hits += len(set(r.tolist()) & set(t.tolist()))
        return hits / true_ids.size

    _, ids_sparse = top_n(score_sparse(index, codes_q), n)
    _, ids_recon = top_n(score_reconstructed(index, codes_q, params), n)
    r_sparse, r_recon = recall(ids_sparse), recall(ids_recon)
    chance = n / db.shape[0]
    assert r_sparse > 10 * chance, f"sparse recall {r_sparse} ~ chance"
    assert r_recon > 10 * chance, f"recon recall {r_recon} ~ chance"
    # Paper Fig 3 center: reconstructed-space >= sparse-space fidelity.
    assert r_recon >= r_sparse - 0.05


def test_top_n_shapes(trained):
    params, corpus = trained
    codes_db = encode(params, corpus[:100], CFG.k)
    index = build_index(codes_db)
    q = encode(params, corpus[100:102], CFG.k)
    scores = score_sparse(index, q)
    v, i = top_n(scores, 7)
    assert v.shape == (2, 7) and i.shape == (2, 7)
    assert (jnp.diff(v, axis=-1) <= 1e-6).all()  # sorted descending
