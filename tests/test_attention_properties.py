"""Property tests: chunked flash attention vs the naive softmax oracle."""
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
import hypothesis
import hypothesis.strategies as st
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings

from repro.layers.attention import (
    decode_attention, decode_attention_grouped, flash_attention,
)

hypothesis.settings.register_profile("attn", deadline=None, max_examples=10,
                                     derandomize=True)
hypothesis.settings.load_profile("attn")


def naive(q, k, v, causal=True, window=None, cap=None):
    b, s, hq, d = q.shape
    g = hq // k.shape[2]
    kx = jnp.repeat(k, g, axis=2)
    vx = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q * d ** -0.5, kx)
    if cap:
        scores = cap * jnp.tanh(scores / cap)
    row = jnp.arange(s)[:, None]
    col = jnp.arange(s)[None, :]
    m = jnp.ones((s, s), bool)
    if causal:
        m &= col <= row
    if window is not None:
        m &= col > row - window
    scores = jnp.where(m, scores, -1e30)
    p = jax.nn.softmax(scores, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vx)


@st.composite
def attn_case(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    s = draw(st.integers(3, 48))
    hkv = draw(st.sampled_from([1, 2, 4]))
    g = draw(st.sampled_from([1, 2, 4]))
    d = draw(st.sampled_from([4, 8, 16]))
    qc = draw(st.sampled_from([4, 8, 16]))
    kc = draw(st.sampled_from([4, 8, 16]))
    causal = draw(st.booleans())
    window = draw(st.one_of(st.none(), st.integers(1, s)))
    cap = draw(st.one_of(st.none(), st.just(5.0)))
    return seed, s, hkv, g, d, qc, kc, causal, window, cap


@given(attn_case())
def test_flash_matches_naive(case):
    seed, s, hkv, g, d, qc, kc, causal, window, cap = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, hq = 2, hkv * g
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    got = flash_attention(q, k, v, causal=causal, window=window,
                          logit_softcap=cap, q_chunk=qc, kv_chunk=kc)
    want = naive(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-5)


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]),
       st.sampled_from([2, 4]))
def test_decode_variants_agree(seed, hkv, g):
    """Grouped and expand decode paths must produce identical outputs."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, s, d, hq = 2, 24, 8, hkv * g
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    a = decode_attention(q, kc, vc, length=17)
    bb = decode_attention_grouped(q, kc, vc, length=17)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                               rtol=2e-5, atol=2e-6)


@given(st.integers(0, 2**31 - 1))
def test_flash_is_permutation_equivariant_over_batch(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, s, h, d = 4, 16, 2, 8
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), b)
    a = flash_attention(q, k, v, q_chunk=8, kv_chunk=8)[perm]
    bb = flash_attention(q[perm], k[perm], v[perm], q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-6)
