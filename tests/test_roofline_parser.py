"""Unit tests for the loop-adjusted HLO cost model and the analytic
retrieval traffic model (benchmarks/roofline.py)."""
import math
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "benchmarks"))
from roofline import (  # noqa: E402
    HBM_BW, PEAK_FLOPS, PEAK_INT8_OPS,
    _trip_count, collective_bytes, hlo_cost, quantized_row_bytes,
    retrieval_traffic, retrieval_traffic_report, split_computations,
)

HLO = """\
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,16]{1,0} all-reduce(%x), to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (w: f32[16,32], x: f32[8,16]) -> f32[8,16] {
  %w = f32[16,32]{1,0} parameter(0)
  %x = f32[8,16]{1,0} parameter(1)
  %d = f32[8,32]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %z = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%z, %x)
  %wl = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wl), index=1
}
"""


def test_split_computations_finds_all():
    comps = split_computations(HLO)
    assert set(comps) == {"add", "body.1", "cond.1", "main"}


def test_trip_count_from_condition():
    comps = split_computations(HLO)
    assert _trip_count(comps["cond.1"]) == 5


def test_collective_bytes_loop_adjusted():
    total, kinds = collective_bytes(HLO)
    # all-reduce of f32[8,16] = 512 B, wire factor 2, trip count 5
    assert total == pytest.approx(512 * 2 * 5)
    assert kinds == {"all-reduce": pytest.approx(512 * 2 * 5)}


def test_hlo_cost_dot_flops_and_loop_bytes():
    cost = hlo_cost(HLO)
    # dot: 2 * |result 8x32| * contraction 16 = 8192 flops
    assert cost["flops"] == pytest.approx(2 * 8 * 32 * 16)
    assert cost["coll"] == pytest.approx(512 * 2 * 5)
    # bytes include the dot (in+out) and 5x the loop body's AR traffic
    assert cost["bytes"] >= (8 * 16 + 16 * 32 + 8 * 32) * 4


# ------------------------------------------- retrieval traffic model (g5)
def test_quantized_row_bytes_formula():
    # k·(1 + idx_bytes) + 4-byte scale; int16 indices below 65536, int32 at
    assert quantized_row_bytes(32, 4096) == 32 * 3 + 4
    assert quantized_row_bytes(32, 65535) == 32 * 3 + 4
    assert quantized_row_bytes(32, 65536) == 32 * 5 + 4
    assert quantized_row_bytes(16, 70000) == 16 * 5 + 4


def test_retrieval_traffic_quantized_bytes():
    n, k, q, topn, bq, h = 1000, 32, 64, 20, 8, 4096
    rows = retrieval_traffic(n, k, q, topn, bq, h)
    panels = -(-q // bq)
    out = q * topn * 8
    # fp32 fused: 8k B/row streamed once per panel + norms + results
    assert rows["fused"]["bytes"] == n * k * 8 * panels + n * 4 + out
    # quantized fused: the compound storage format is what streams
    assert rows["fused_quantized"]["bytes"] == (
        n * quantized_row_bytes(k, h) * panels + n * 4 + out
    )
    # per-row accounting includes the 4 B reciprocal norm on both formats
    assert rows["fused"]["bytes_per_row"] == 8 * k + 4
    assert rows["fused_quantized"]["bytes_per_row"] == (
        quantized_row_bytes(k, h) + 4
    )
    # t_mem is bytes over HBM bandwidth
    assert rows["fused"]["t_mem_ms"] == pytest.approx(
        rows["fused"]["bytes"] / HBM_BW * 1e3
    )


def test_retrieval_traffic_int8_mxu_terms():
    rows = retrieval_traffic(100_000, 32, 64, 20, 8, 4096)
    g4, g5 = rows["fused_quantized"], rows["fused_quantized_mxu"]
    # int8 scoring adds NO HBM traffic: the query panel quantizes in VMEM
    # and the candidate stream is the same int8/int16 storage either way
    assert g5["bytes"] == g4["bytes"]
    assert g5["speedup_vs_per_query"] == g4["speedup_vs_per_query"]
    # ...but the scoring contraction runs at the int8 MXU rate (2x)
    assert g5["t_comp_ms"] == pytest.approx(
        g4["t_comp_ms"] * PEAK_FLOPS / PEAK_INT8_OPS
    )
    assert g5["t_comp_ms"] < g4["t_comp_ms"]
    # generation ordering on HBM traffic (the roofline bound here)
    b = {name: r["bytes"] for name, r in rows.items()}
    assert (b["fused_quantized"] < b["fused"] < b["blocked"]
            < b["per_query"])
    # at k=32, h<65536 the quantized stream is ~2.5x lighter per row
    assert g4["bytes_per_row"] / rows["fused"]["bytes_per_row"] < 0.41


def test_retrieval_traffic_report_lists_all_generations():
    report = retrieval_traffic_report(1000, 32, 16, 5, 8, 4096)
    for row in ("per_query", "blocked", "fused", "fused_quantized",
                "fused_quantized_mxu"):
        assert f"| {row} |" in report
    assert "int16 indices" in report
    assert "int32 indices" in retrieval_traffic_report(1000, 32, 16, 5, 8,
                                                       70000)


def test_real_artifact_parses():
    art = pathlib.Path(__file__).parents[1] / "artifacts" / "dryrun"
    hlos = sorted(art.glob("qwen3-1.7b__train_4k__singlepod.hlo.txt"))
    if not hlos:
        pytest.skip("dry-run artifacts not generated")
    cost = hlo_cost(hlos[0].read_text())
    # loop-adjusted flops must exceed raw cost_analysis by ~the layer count
    assert cost["flops"] > 1e13
    assert cost["coll"] > 0
