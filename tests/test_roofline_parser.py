"""Unit tests for the loop-adjusted HLO cost model (benchmarks/roofline.py)."""
import math
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "benchmarks"))
from roofline import (  # noqa: E402
    _trip_count, collective_bytes, hlo_cost, split_computations,
)

HLO = """\
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,16]{1,0} all-reduce(%x), to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (w: f32[16,32], x: f32[8,16]) -> f32[8,16] {
  %w = f32[16,32]{1,0} parameter(0)
  %x = f32[8,16]{1,0} parameter(1)
  %d = f32[8,32]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %z = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%z, %x)
  %wl = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wl), index=1
}
"""


def test_split_computations_finds_all():
    comps = split_computations(HLO)
    assert set(comps) == {"add", "body.1", "cond.1", "main"}


def test_trip_count_from_condition():
    comps = split_computations(HLO)
    assert _trip_count(comps["cond.1"]) == 5


def test_collective_bytes_loop_adjusted():
    total, kinds = collective_bytes(HLO)
    # all-reduce of f32[8,16] = 512 B, wire factor 2, trip count 5
    assert total == pytest.approx(512 * 2 * 5)
    assert kinds == {"all-reduce": pytest.approx(512 * 2 * 5)}


def test_hlo_cost_dot_flops_and_loop_bytes():
    cost = hlo_cost(HLO)
    # dot: 2 * |result 8x32| * contraction 16 = 8192 flops
    assert cost["flops"] == pytest.approx(2 * 8 * 32 * 16)
    assert cost["coll"] == pytest.approx(512 * 2 * 5)
    # bytes include the dot (in+out) and 5x the loop body's AR traffic
    assert cost["bytes"] >= (8 * 16 + 16 * 32 + 8 * 32) * 4


def test_real_artifact_parses():
    art = pathlib.Path(__file__).parents[1] / "artifacts" / "dryrun"
    hlos = sorted(art.glob("qwen3-1.7b__train_4k__singlepod.hlo.txt"))
    if not hlos:
        pytest.skip("dry-run artifacts not generated")
    cost = hlo_cost(hlos[0].read_text())
    # loop-adjusted flops must exceed raw cost_analysis by ~the layer count
    assert cost["flops"] > 1e13
    assert cost["coll"] > 0
