"""Beyond-paper compound compression: quantized sparse codes."""
import pytest

try:  # optional dev dep (requirements-dev.txt); only the property test needs it
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    st = None
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SAEConfig, encode, init_params
from repro.core.quantized_codes import (
    compression_ratio, dequantize_codes, quantize_codes,
)
from repro.core.types import SparseCodes


def _codes(seed, n=32, k=8, h=256):
    kv, ki = jax.random.split(jax.random.PRNGKey(seed))
    vals = jax.random.normal(kv, (n, k))
    idx = jax.random.randint(ki, (n, k), 0, h, dtype=jnp.int32)
    return SparseCodes(values=vals, indices=idx, dim=h)


def test_roundtrip_error_bounded():
    codes = _codes(0)
    q = quantize_codes(codes)
    back = dequantize_codes(q)
    # int8 symmetric: error <= scale/2 per element
    err = np.abs(np.asarray(back.values) - np.asarray(codes.values))
    bound = np.asarray(q.scales)[:, None] * 0.5 + 1e-7
    assert (err <= bound).all()
    np.testing.assert_array_equal(np.asarray(back.indices),
                                  np.asarray(codes.indices))


def test_index_dtype_follows_dim():
    assert quantize_codes(_codes(1, h=4096)).indices.dtype == jnp.int16
    assert quantize_codes(_codes(2, h=70000)).indices.dtype == jnp.int32


def test_int16_wraparound_region_roundtrips():
    """h in [32768, 65536): indices overflow SIGNED int16 and are stored
    as wrapped two's-complement bit patterns — dequantize must recover
    them exactly via the low-16-bit widen (regression: a plain astype
    round-trip returned negative indices here)."""
    kv, ki = jax.random.split(jax.random.PRNGKey(9))
    vals = jax.random.normal(kv, (64, 8))
    idx = jax.random.randint(ki, (64, 8), 32768, 65536, dtype=jnp.int32)
    codes = SparseCodes(values=vals, indices=idx, dim=65535)
    q = quantize_codes(codes)
    assert q.indices.dtype == jnp.int16
    assert (np.asarray(q.indices) < 0).any()          # really wrapped
    back = dequantize_codes(q)
    np.testing.assert_array_equal(np.asarray(back.indices), np.asarray(idx))
    assert back.indices.dtype == jnp.int32


def test_bytes_and_ratio():
    codes = _codes(3, n=100, k=8, h=256)
    q = quantize_codes(codes)
    assert q.nbytes_logical == 100 * (8 * (1 + 2) + 4)
    # the paper's point at compound compression: 768d k=32 h=4096 -> ~31x
    assert 30 < compression_ratio(768, 32, 4096) < 32


@pytest.mark.skipif(st is None, reason="hypothesis not installed")
def test_quantization_preserves_row_max():
    """The largest-|value| entry per row maps to ±127 — it remains A
    maximizer after dequantization (ties with near-max entries allowed)."""

    @given(st.integers(0, 2**31 - 1))
    @settings(deadline=None, max_examples=15)
    def check(seed):
        codes = _codes(seed % 1000)
        back = np.abs(np.asarray(dequantize_codes(quantize_codes(codes)).values))
        orig_argmax = np.abs(np.asarray(codes.values)).argmax(-1)
        rows = np.arange(back.shape[0])
        np.testing.assert_allclose(back[rows, orig_argmax], back.max(-1),
                                   rtol=1e-6)

    check()


def test_sae_pipeline_with_quantized_codes():
    cfg = SAEConfig(d=32, h=128, k=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d))
    codes = encode(params, x, cfg.k)
    back = dequantize_codes(quantize_codes(codes))
    # cosine between fp and dequantized sparse vectors stays high
    from repro.core import sparse as sp

    a = np.asarray(sp.densify(codes))
    b = np.asarray(sp.densify(back))
    cos = (a * b).sum(-1) / (np.linalg.norm(a, axis=-1)
                             * np.linalg.norm(b, axis=-1) + 1e-9)
    assert (cos > 0.999).all()
