"""Segmented mutable index — the ISSUE 9 tier-1 parity gate.

The binding contract (core/segments.py module doc): after ANY
interleaving of add_items / delete_items / compact, ``retrieve`` over
(base + delta + deletion masks) is BIT-identical — scores, ids, ties —
to a fresh ``build_index`` over the surviving fp32 rows (base survivors
then delta survivors, original order), across {exact, quantized, int8}
x {ref, fused}; and ``compact()`` output is bit-identical, checksum
included, to that rebuilt index.

Every assertion here is ``assert_array_equal`` on purpose: the contract
is bit-identity, not allclose.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import SAEConfig, build_index, encode, init_params
from repro.core.retrieval import NORM_EPS, verify_index
from repro.core.segments import SegmentedIndex, concat_indexes
from repro.core.types import SparseCodes
from repro.errors import IndexIntegrityError, SegmentMutationError
from repro.serving.engine import select_retrieve_fn

CFG = SAEConfig(d=32, h=128, k=8)

# (precision, quantize, use_fused): every serving generation segments
# compose with — ref and fused must BOTH hold the oracle parity
GRID = [
    ("exact", False, False),
    ("exact", False, True),
    ("exact", True, False),
    ("exact", True, True),
    ("int8", True, False),
    ("int8", True, True),
]


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (300, CFG.d))
    codes = encode(params, corpus, CFG.k)
    queries = jax.random.normal(jax.random.PRNGKey(2), (7, CFG.d))
    qcodes = encode(params, queries, CFG.k)
    extra = jax.random.normal(jax.random.PRNGKey(3), (16, CFG.d))
    ecodes = encode(params, extra, CFG.k)
    return params, codes, qcodes, ecodes


def _rows(codes: SparseCodes, rows) -> SparseCodes:
    rows = np.asarray(rows)
    return SparseCodes(
        values=jnp.asarray(np.asarray(codes.values)[rows]),
        indices=jnp.asarray(np.asarray(codes.indices)[rows]),
        dim=codes.dim,
    )


def _ledger_codes(ledger: dict, ids) -> SparseCodes:
    vals = np.stack([ledger[int(i)][0] for i in ids])
    idx = np.stack([ledger[int(i)][1] for i in ids])
    return SparseCodes(
        values=jnp.asarray(vals), indices=jnp.asarray(idx), dim=CFG.h
    )


def _ledger_from(codes: SparseCodes, ids) -> dict:
    vals, idx = np.asarray(codes.values), np.asarray(codes.indices)
    return {int(i): (vals[p], idx[p]) for p, i in enumerate(ids)}


def oracle_retrieve(index, item_ids, q, n, *, use_fused, precision):
    """The independent oracle: the SAME serving generation run over an
    immutable index rebuilt from the surviving fp32 rows, with the same
    (-inf, -1) padding and post-merge query-norm division."""
    squeeze = q.values.ndim == 1
    qv = q.values[None] if squeeze else q.values
    qi = q.indices[None] if squeeze else q.indices
    quantized = hasattr(index.codes, "q_values")
    fn = select_retrieve_fn(
        sparse_query=True, quantized=quantized,
        int8_scoring=precision == "int8", use_fused=use_fused,
    )
    if quantized:
        cand = (index.codes.q_values, index.codes.indices,
                index.codes.scales)
    else:
        cand = (index.codes.values, index.codes.indices)
    inv = index.inv_sparse_norms
    if inv is None:
        inv = 1.0 / jnp.maximum(index.sparse_norms, NORM_EPS)
    n_eff = min(n, index.codes.n)
    vals, ids = fn(*cand, inv, qv, qi, index.codes.dim, n=n_eff)
    ids = jnp.where(vals == -jnp.inf, -1, ids)
    table = jnp.asarray(np.asarray(item_ids))
    ids = jnp.where(ids >= 0, table[jnp.maximum(ids, 0)], -1)
    if n_eff < n:
        pad = [(0, 0)] * (vals.ndim - 1) + [(0, n - n_eff)]
        vals = jnp.pad(vals, pad, constant_values=-jnp.inf)
        ids = jnp.pad(ids, pad, constant_values=-1)
    norm = jnp.linalg.norm(qv, axis=-1)
    scores = vals / jnp.maximum(norm[..., None], NORM_EPS)
    return (scores[0], ids[0]) if squeeze else (scores, ids)


def assert_parity(seg, ledger, qcodes, n, *, use_fused, precision):
    """seg.retrieve must be bit-identical to the rebuilt-index oracle."""
    surv = np.asarray(seg.alive_ids())
    rebuilt = build_index(_ledger_codes(ledger, surv),
                          quantize=seg.quantized)
    want_s, want_i = oracle_retrieve(
        rebuilt, surv, qcodes, n, use_fused=use_fused, precision=precision
    )
    got_s, got_i = seg.retrieve(
        qcodes, n, use_fused=use_fused, precision=precision
    )
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    # deleted ids never appear — not even in padded slots
    alive = set(int(v) for v in surv)
    for v in np.asarray(got_i).ravel():
        assert int(v) in alive or int(v) == -1


# --------------------------------------------------- lifecycle parity grid
@pytest.mark.parametrize("precision,quantize,use_fused", GRID)
def test_lifecycle_parity(setup, precision, quantize, use_fused):
    _, codes, qcodes, ecodes = setup
    ledger = _ledger_from(codes, range(300))
    seg = SegmentedIndex.from_index(build_index(codes, quantize=quantize))
    check = lambda s: assert_parity(s, ledger, qcodes, 16,
                                    use_fused=use_fused,
                                    precision=precision)
    check(seg)

    seg = seg.delete_items([3, 7, 250])                  # base deletes
    check(seg)

    ledger.update(_ledger_from(_rows(ecodes, range(10)),
                               range(1000, 1010)))
    seg = seg.add_items(_rows(ecodes, range(10)),
                        ids=range(1000, 1010))           # delta adds
    check(seg)

    seg = seg.delete_items([1004, 12])                   # delta + base
    check(seg)

    # delete-then-readd of the same item id: the dead base row stays
    # masked, the NEW delta row serves under the old id
    ledger[3] = (np.asarray(ecodes.values)[10],
                 np.asarray(ecodes.indices)[10])
    seg = seg.add_items(_rows(ecodes, [10]), ids=[3])
    check(seg)

    # compact: bit-identical (arrays AND checksum) to the rebuilt index
    surv = np.asarray(seg.alive_ids())
    rebuilt = build_index(_ledger_codes(ledger, surv), quantize=quantize)
    comp = seg.compact()
    assert comp.base.checksum == rebuilt.checksum
    if quantize:
        np.testing.assert_array_equal(
            np.asarray(comp.base.codes.q_values),
            np.asarray(rebuilt.codes.q_values))
        np.testing.assert_array_equal(
            np.asarray(comp.base.codes.scales),
            np.asarray(rebuilt.codes.scales))
    else:
        np.testing.assert_array_equal(
            np.asarray(comp.base.codes.values),
            np.asarray(rebuilt.codes.values))
    np.testing.assert_array_equal(np.asarray(comp.base_ids), surv)
    assert comp.delta is None and comp.base_alive.all()
    check(comp)

    # mutation continues across the compaction boundary
    ledger.update(_ledger_from(_rows(ecodes, range(11, 14)),
                               range(2000, 2003)))
    seg2 = comp.add_items(_rows(ecodes, range(11, 14)),
                          ids=range(2000, 2003))
    seg2 = seg2.delete_items([2001, 30])
    check(seg2)


# --------------------------------------------------- underfull top-n (n > N)
@pytest.mark.parametrize("precision,quantize,use_fused",
                         [("exact", False, False), ("exact", True, True),
                          ("int8", True, True)])
def test_n_exceeds_surviving_rows(setup, precision, quantize, use_fused):
    _, codes, qcodes, ecodes = setup
    small = _rows(codes, range(12))
    ledger = _ledger_from(small, range(12))
    seg = SegmentedIndex.from_index(build_index(small, quantize=quantize))
    seg = seg.delete_items([0, 4, 5, 9, 11])
    ledger.update(_ledger_from(_rows(ecodes, [0, 1]), [100, 101]))
    seg = seg.add_items(_rows(ecodes, [0, 1]), ids=[100, 101])
    assert seg.n_alive == 9
    assert_parity(seg, ledger, qcodes, 32,
                  use_fused=use_fused, precision=precision)
    s, i = seg.retrieve(qcodes, 32, use_fused=use_fused,
                        precision=precision)
    # exactly n_alive filled slots, the rest the (-inf, -1) contract
    np.testing.assert_array_equal(np.asarray(i)[:, 9:], -1)
    assert np.all(np.asarray(s)[:, 9:] == -np.inf)


# ------------------------------------ whole-tile deletion + boundary ties
@pytest.mark.parametrize("quantize", [False, True])
def test_whole_tile_deleted_and_tie_across_boundary(setup, quantize):
    """Deleting item ids 0..255 kills the fused path's entire first
    candidate tile (BLOCK_N=256) — the kernels' whole-tile skip must not
    drop survivors.  A delta row with codes IDENTICAL to an alive base
    row then ties across the segment boundary; the merge must resolve it
    exactly like the rebuilt oracle (base survivor first)."""
    _, codes, qcodes, _ = setup
    ledger = _ledger_from(codes, range(300))
    seg = SegmentedIndex.from_index(build_index(codes, quantize=quantize))
    seg = seg.delete_items(list(range(256)))             # tile 0, entirely
    dup = _rows(codes, [260])                            # == alive base row
    ledger.update(_ledger_from(dup, [5000]))
    seg = seg.add_items(dup, ids=[5000])
    for use_fused in (False, True):
        assert_parity(seg, ledger, qcodes, 16, use_fused=use_fused,
                      precision="int8" if quantize else "exact")
        s, i = seg.retrieve(qcodes, seg.n_alive, use_fused=use_fused,
                            precision="exact")
        i = np.asarray(i)
        # the tied pair surfaces base-id-first in every row's list
        for row in range(i.shape[0]):
            pos = {int(v): p for p, v in enumerate(i[row])}
            assert pos[260] < pos[5000]


# ----------------------------------------------------------- typed errors
def test_lifecycle_typed_errors(setup):
    _, codes, _, ecodes = setup
    seg = SegmentedIndex.from_index(build_index(_rows(codes, range(20))))
    one = _rows(ecodes, [0])
    with pytest.raises(SegmentMutationError, match="already alive"):
        seg.add_items(one, ids=[5])
    with pytest.raises(SegmentMutationError, match="unique within one add"):
        seg.add_items(_rows(ecodes, [0, 1]), ids=[100, 100])
    with pytest.raises(SegmentMutationError, match="rows for"):
        seg.add_items(one, ids=[100, 101])
    with pytest.raises(SegmentMutationError, match="dim"):
        seg.add_items(one._replace(dim=CFG.h * 2), ids=[100])
    with pytest.raises(SegmentMutationError, match="not alive"):
        seg.delete_items([999])
    with pytest.raises(SegmentMutationError, match="listed twice"):
        seg.delete_items([5, 5])
    gone = seg.delete_items([5])
    with pytest.raises(SegmentMutationError, match="not alive"):
        gone.delete_items([5])
    with pytest.raises(SegmentMutationError, match="unique"):
        SegmentedIndex.from_index(build_index(_rows(codes, range(4))),
                                  ids=[0, 1, 1, 2])


# ---------------------------------------------- shed + per-segment verify
def test_base_only_coverage_and_per_segment_verify(setup):
    from repro.serving import flip_delta_byte

    _, codes, _, ecodes = setup
    seg = SegmentedIndex.from_index(
        build_index(_rows(codes, range(30)), quantize=True))
    with pytest.raises(ValueError, match="no delta"):
        flip_delta_byte(seg)
    seg = seg.add_items(_rows(ecodes, range(10)), ids=range(100, 110))
    seg = seg.delete_items([2, 103])
    assert seg.n_alive == 38 and seg.n_rows == 40
    assert seg.base_coverage == pytest.approx(29 / 38)

    shed = seg.base_only()
    assert shed.delta is None
    assert set(shed.alive_ids()) == set(range(30)) - {2}

    bad = flip_delta_byte(seg)
    with pytest.raises(IndexIntegrityError):
        bad.verify()
    verify_index(bad.base)               # the base is still pristine
    assert seg.verify()                  # and the original untouched


def test_concat_indexes_rejects_mixed_formats(setup):
    _, codes, _, _ = setup
    a = build_index(_rows(codes, range(8)))
    b = build_index(_rows(codes, range(8, 16)), quantize=True)
    with pytest.raises(SegmentMutationError, match="concatenate"):
        concat_indexes(a, b)


# ------------------------------------------------------- engine lifecycle
def test_engine_apply_update_serves_current_segments(setup):
    from repro.serving import RetrievalEngine

    params, codes, qcodes, ecodes = setup
    ledger = _ledger_from(codes, range(300))
    seg = SegmentedIndex.from_index(build_index(codes, quantize=True))
    eng = RetrievalEngine(params, seg, use_kernel=True, precision="int8")

    eng.apply_update("delete", ids=[1, 2, 3])
    ledger.update(_ledger_from(_rows(ecodes, range(4)), range(400, 404)))
    eng.apply_update("add", codes=_rows(ecodes, range(4)),
                     ids=range(400, 404))
    want = eng.segments.retrieve(qcodes, 10, use_fused=True,
                                 precision="int8")
    got = eng.retrieve_codes(qcodes, 10)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    assert_parity(eng.segments, ledger, qcodes, 10,
                  use_fused=True, precision="int8")

    eng.apply_update("compact")
    assert eng.segments.delta is None
    assert eng.index is eng.segments.base     # base swap went through
    assert_parity(eng.segments, ledger, qcodes, 10,
                  use_fused=True, precision="int8")
