"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode on CPU).

Sweeps shapes/dtypes per the deliverable spec; hypothesis property tests on
the invariants live in test_properties.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantized_codes import dequantize_codes, quantize_codes
from repro.core.sae import normalize_input
from repro.core.types import SparseCodes
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.fused_encode.ops import fused_encode
from repro.kernels.fused_encode.ref import fused_encode_ref
from repro.kernels.sparse_dot.ops import (
    fused_retrieve,
    fused_retrieve_quantized,
    fused_retrieve_quantized_mxu,
    fused_retrieve_quantized_mxu_sparse_q,
    fused_retrieve_quantized_sparse_q,
    fused_retrieve_sparse_q,
    sparse_dot,
)
from repro.kernels.sparse_dot.ref import (
    _quantize_panel,
    retrieve_quantized_mxu_ref,
    retrieve_quantized_mxu_sparse_q_ref,
    retrieve_quantized_ref,
    retrieve_quantized_sparse_q_ref,
    retrieve_ref,
    retrieve_sparse_q_ref,
    sparse_dot_ref,
)
from repro.kernels.topk_mask.ops import topk_mask
from repro.kernels.topk_mask.ref import topk_mask_ref


# ----------------------------------------------------------------- sparse_dot
@pytest.mark.parametrize("n", [64, 256, 1000, 4097])
@pytest.mark.parametrize("k,h", [(8, 256), (32, 4096)])
def test_sparse_dot_shapes(n, k, h):
    key = jax.random.PRNGKey(n * k)
    k1, k2, k3 = jax.random.split(key, 3)
    vals = jax.random.normal(k1, (n, k), jnp.float32)
    idx = jax.random.randint(k2, (n, k), 0, h, dtype=jnp.int32)
    q = jax.random.normal(k3, (2, h), jnp.float32)
    np.testing.assert_allclose(
        sparse_dot(vals, idx, q), sparse_dot_ref(vals, idx, q), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("qdtype", [jnp.float32, jnp.bfloat16])
def test_sparse_dot_dtypes(qdtype):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    vals = jax.random.normal(k1, (128, 16), jnp.float32)
    idx = jax.random.randint(k2, (128, 16), 0, 512, dtype=jnp.int32)
    q = jax.random.normal(k3, (1, 512)).astype(qdtype)
    got = sparse_dot(vals, idx, q)
    want = sparse_dot_ref(vals, idx, q)
    rtol = 1e-5 if qdtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=rtol, atol=rtol
    )


def test_sparse_dot_duplicate_indices_sum():
    # duplicate column indices in one row must contribute additively
    vals = jnp.array([[1.0, 2.0, 3.0]])
    idx = jnp.array([[5, 5, 7]], dtype=jnp.int32)
    q = jnp.zeros((1, 16)).at[0, 5].set(10.0).at[0, 7].set(1.0)
    np.testing.assert_allclose(sparse_dot(vals, idx, q), [[33.0]], rtol=1e-6)


def test_sparse_dot_ragged_query_panel():
    # Q not a multiple of BLOCK_Q exercises the query-padding path of the
    # blocked multi-query kernel.
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    vals = jax.random.normal(k1, (300, 8), jnp.float32)
    idx = jax.random.randint(k2, (300, 8), 0, 128, dtype=jnp.int32)
    q = jax.random.normal(k3, (13, 128), jnp.float32)
    np.testing.assert_allclose(
        sparse_dot(vals, idx, q), sparse_dot_ref(vals, idx, q), rtol=1e-5, atol=1e-5
    )


# ------------------------------------------------------------- fused_retrieve
def _retrieve_case(n, q, k, h, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    vals = jax.random.normal(k1, (n, k), jnp.float32)
    idx = jax.random.randint(k2, (n, k), 0, h, dtype=jnp.int32)
    qq = jax.random.normal(k3, (q, h), jnp.float32)
    inv = 1.0 / jnp.maximum(jnp.linalg.norm(vals, axis=-1), 1e-8)
    return vals, idx, qq, inv


# ragged N (pads candidate tiles) and ragged Q (pads the query panel)
@pytest.mark.parametrize("n,q,topn", [(64, 9, 64), (256, 1, 5), (1000, 3, 10), (4097, 5, 20)])
def test_fused_retrieve_matches_bruteforce(n, q, topn):
    vals, idx, qq, inv = _retrieve_case(n, q, 8, 256, seed=n + q)
    want_v, want_i = jax.lax.top_k(sparse_dot_ref(vals, idx, qq) * inv[None], topn)
    got_v, got_i = fused_retrieve(vals, idx, inv, qq, n=topn)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(got_i, want_i)
    ref_v, ref_i = retrieve_ref(vals, idx, inv, qq, n=topn, block_n=300)
    np.testing.assert_allclose(ref_v, want_v, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(ref_i, want_i)


def test_fused_retrieve_tied_scores_match_lax_topk():
    # Duplicated candidate rows give exactly-tied scores across tile
    # boundaries; both the streaming kernel epilogue and the chunked jnp
    # reference must resolve them like lax.top_k (lowest candidate id wins).
    base_v, base_i, qq, _ = _retrieve_case(40, 3, 4, 64, seed=7)
    vals = jnp.tile(base_v, (8, 1))
    idx = jnp.tile(base_i, (8, 1))
    inv = 1.0 / jnp.maximum(jnp.linalg.norm(vals, axis=-1), 1e-8)
    want_v, want_i = jax.lax.top_k(sparse_dot_ref(vals, idx, qq) * inv[None], 17)
    got_v, got_i = fused_retrieve(vals, idx, inv, qq, n=17, block_n=64, block_q=2)
    np.testing.assert_array_equal(got_i, want_i)
    ref_v, ref_i = retrieve_ref(vals, idx, inv, qq, n=17, block_n=96)
    np.testing.assert_array_equal(ref_i, want_i)


def test_fused_retrieve_single_query_and_n_equals_N():
    vals, idx, qq, inv = _retrieve_case(96, 1, 8, 128, seed=11)
    v, i = fused_retrieve(vals, idx, inv, qq[0], n=96)
    assert v.shape == (96,) and i.shape == (96,)
    # exhaustive n == N: every candidate id must surface exactly once
    assert sorted(np.asarray(i).tolist()) == list(range(96))
    with pytest.raises(ValueError):
        fused_retrieve(vals, idx, inv, qq, n=97)


def test_fused_retrieve_all_negative_scores_exclude_padding():
    # all-negative scores: padded rows (masked to -inf, not 0) must never
    # win even though 0 would outrank every real candidate
    vals = -jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (130, 4)))
    idx = jax.random.randint(jax.random.PRNGKey(1), (130, 4), 0, 64, dtype=jnp.int32)
    q = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (2, 64)))
    inv = jnp.ones((130,), jnp.float32)
    _, ids = fused_retrieve(vals, idx, inv, q, n=20)
    assert (np.asarray(ids) < 130).all()


# ---------------------------------------------------- fused_retrieve_sparse_q
def _sparse_q_case(n, q, kq, h, seed, idx_hi=None):
    """Candidate codes + SPARSE query codes.  ``idx_hi`` < h concentrates
    query indices to force duplicate indices within code rows."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    vals = jax.random.normal(ks[0], (n, kq), jnp.float32)
    idx = jax.random.randint(ks[1], (n, kq), 0, h, dtype=jnp.int32)
    inv = 1.0 / jnp.maximum(jnp.linalg.norm(vals, axis=-1), 1e-8)
    qv = jax.random.normal(ks[2], (q, kq), jnp.float32)
    qi = jax.random.randint(ks[3], (q, kq), 0, idx_hi or h, dtype=jnp.int32)
    return vals, idx, inv, qv, qi


def _densify(qv, qi, h):
    def one(v, i):
        return jnp.zeros((h,), v.dtype).at[i].add(v)

    return jax.vmap(one)(qv, qi)


# ragged N (candidate-tile padding), ragged Q (query-panel padding), and
# Q > the ref path's q_chunk (exercises its chunked densify)
@pytest.mark.parametrize("n,q,topn", [(64, 9, 64), (256, 1, 5),
                                      (1000, 13, 10), (4097, 5, 20),
                                      (300, 150, 7)])
def test_sparse_q_bit_identical_to_densify_composed(n, q, topn):
    """The sparse-query generation (kernel AND ref) must be BIT-identical —
    scores, ids, ties — to densify + the dense-query path it replaces."""
    vals, idx, inv, qv, qi = _sparse_q_case(n, q, 8, 256, seed=n + q)
    qd = _densify(qv, qi, 256)
    want_v, want_i = fused_retrieve(vals, idx, inv, qd, n=topn)
    got_v, got_i = fused_retrieve_sparse_q(vals, idx, inv, qv, qi, 256, n=topn)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    rwant_v, rwant_i = retrieve_ref(vals, idx, inv, qd, n=topn)
    rgot_v, rgot_i = retrieve_sparse_q_ref(vals, idx, inv, qv, qi, 256, n=topn)
    np.testing.assert_array_equal(np.asarray(rgot_v), np.asarray(rwant_v))
    np.testing.assert_array_equal(np.asarray(rgot_i), np.asarray(rwant_i))


def test_sparse_q_tied_scores_match_lax_topk():
    # duplicated candidate rows -> exactly-tied scores across tile
    # boundaries; the sparse-query paths must resolve them like lax.top_k
    # (lowest candidate id wins), byte-for-byte with the dense-query paths
    base_v, base_i, _, qv, qi = _sparse_q_case(40, 3, 4, 64, seed=7)
    vals = jnp.tile(base_v, (8, 1))
    idx = jnp.tile(base_i, (8, 1))
    inv = 1.0 / jnp.maximum(jnp.linalg.norm(vals, axis=-1), 1e-8)
    qd = _densify(qv, qi, 64)
    want_v, want_i = jax.lax.top_k(sparse_dot_ref(vals, idx, qd) * inv[None], 17)
    got_v, got_i = fused_retrieve_sparse_q(vals, idx, inv, qv, qi, 64, n=17,
                                           block_n=64, block_q=2)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(got_v, want_v, rtol=1e-6, atol=1e-7)
    ref_v, ref_i = retrieve_sparse_q_ref(vals, idx, inv, qv, qi, 64, n=17,
                                         block_n=96)
    np.testing.assert_array_equal(np.asarray(ref_i), np.asarray(want_i))


def test_sparse_q_duplicate_indices_within_code_row():
    # duplicate indices inside one query code row must contribute
    # additively — and in the same accumulation order as sparse.densify's
    # scatter-add, so results stay bit-identical to the composed path
    vals = jnp.array([[1.0, 2.0], [3.0, 0.5], [0.25, 4.0]])
    idx = jnp.array([[5, 7], [5, 5], [7, 2]], dtype=jnp.int32)
    inv = jnp.ones((3,), jnp.float32)
    qv = jnp.array([[0.3, 0.7, 0.11]])          # all three hit column 5
    qi = jnp.array([[5, 5, 5]], dtype=jnp.int32)
    qd = _densify(qv, qi, 16)
    want_v, want_i = fused_retrieve(vals, idx, inv, qd, n=3)
    got_v, got_i = fused_retrieve_sparse_q(vals, idx, inv, qv, qi, 16, n=3)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    rv, ri = retrieve_sparse_q_ref(vals, idx, inv, qv, qi, 16, n=3)
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(want_i))
    # heavy random duplication across many rows
    vals, idx, inv, qv, qi = _sparse_q_case(200, 11, 6, 128, seed=3, idx_hi=9)
    qd = _densify(qv, qi, 128)
    want = fused_retrieve(vals, idx, inv, qd, n=9)
    got = fused_retrieve_sparse_q(vals, idx, inv, qv, qi, 128, n=9)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


def test_sparse_q_single_query_and_validation():
    vals, idx, inv, qv, qi = _sparse_q_case(96, 1, 8, 128, seed=11)
    v, i = fused_retrieve_sparse_q(vals, idx, inv, qv[0], qi[0], 128, n=96)
    assert v.shape == (96,) and i.shape == (96,)
    assert sorted(np.asarray(i).tolist()) == list(range(96))
    with pytest.raises(ValueError):
        fused_retrieve_sparse_q(vals, idx, inv, qv, qi, 128, n=97)


# ------------------------------------------------ fused_retrieve_quantized
def _quantized_case(n, q, k, h, seed):
    """Quantized candidate codes + their dequantized fp32 oracle twin.

    The norms come from the DEQUANTIZED values (exactly what build_index
    does with quantize=True), so the quantized path and the
    dequantize-then-retrieve oracle score the same space.
    """
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    vals = jax.random.normal(ks[0], (n, k), jnp.float32)
    idx = jax.random.randint(ks[1], (n, k), 0, h, dtype=jnp.int32)
    qc = quantize_codes(SparseCodes(values=vals, indices=idx, dim=h))
    deq = dequantize_codes(qc)
    inv = 1.0 / jnp.maximum(jnp.linalg.norm(deq.values, axis=-1), 1e-8)
    qq = jax.random.normal(ks[2], (q, h), jnp.float32)
    return qc, deq, inv, qq


# ragged N (candidate-tile padding) and ragged Q (query-panel padding)
@pytest.mark.parametrize("n,q,topn", [(64, 9, 64), (256, 1, 5),
                                      (1000, 3, 10), (4097, 5, 20)])
def test_quantized_bit_identical_to_dequantized(n, q, topn):
    """The quantized generation (kernel AND ref) must be BIT-identical —
    scores, ids, ties — to dequantize + the fp32 path it replaces."""
    qc, deq, inv, qq = _quantized_case(n, q, 8, 256, seed=n + q)
    want_v, want_i = fused_retrieve(deq.values, deq.indices, inv, qq, n=topn)
    got_v, got_i = fused_retrieve_quantized(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=topn
    )
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    rwant_v, rwant_i = retrieve_ref(deq.values, deq.indices, inv, qq, n=topn)
    rgot_v, rgot_i = retrieve_quantized_ref(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=topn
    )
    np.testing.assert_array_equal(np.asarray(rgot_v), np.asarray(rwant_v))
    np.testing.assert_array_equal(np.asarray(rgot_i), np.asarray(rwant_i))


def test_quantized_tied_scores_match_lax_topk():
    # duplicated candidate rows share one quantization scale, so their
    # dequantized scores tie EXACTLY across tile boundaries; the quantized
    # epilogue must resolve them like lax.top_k (lowest candidate id wins)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    vals = jnp.tile(jax.random.normal(ks[0], (40, 4), jnp.float32), (8, 1))
    idx = jnp.tile(jax.random.randint(ks[1], (40, 4), 0, 64, jnp.int32), (8, 1))
    qq = jax.random.normal(ks[2], (3, 64), jnp.float32)
    qc = quantize_codes(SparseCodes(values=vals, indices=idx, dim=64))
    deq = dequantize_codes(qc)
    inv = 1.0 / jnp.maximum(jnp.linalg.norm(deq.values, axis=-1), 1e-8)
    want_v, want_i = jax.lax.top_k(
        sparse_dot_ref(deq.values, deq.indices, qq) * inv[None], 17
    )
    got_v, got_i = fused_retrieve_quantized(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=17,
        block_n=64, block_q=2,
    )
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(got_v, want_v, rtol=1e-6, atol=1e-7)
    ref_v, ref_i = retrieve_quantized_ref(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=17, block_n=96
    )
    np.testing.assert_array_equal(np.asarray(ref_i), np.asarray(want_i))


@pytest.mark.parametrize("h,want_dtype", [(256, jnp.int16),
                                          (40000, jnp.int16),
                                          (70000, jnp.int32)])
def test_quantized_index_dtype_and_wraparound(h, want_dtype):
    """int16 indices cover all of h < 65536 via the low-16-bit widen
    (h=40000 puts indices in the two's-complement wrap region); h >= 65536
    falls back to int32.  All must stay bit-identical to the fp32 path."""
    qc, deq, inv, qq = _quantized_case(300, 2, 8, h, seed=h)
    assert qc.indices.dtype == want_dtype
    want_v, want_i = fused_retrieve(deq.values, deq.indices, inv, qq, n=7)
    got_v, got_i = fused_retrieve_quantized(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=7
    )
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    # the ref pair has its own (jnp-sum) accumulation order: bit-identity
    # holds quantized-vs-dequantized WITHIN each path, so the ref's oracle
    # is retrieve_ref, not the kernel
    rwant = retrieve_ref(deq.values, deq.indices, inv, qq, n=7)
    rgot = retrieve_quantized_ref(qc.q_values, qc.indices, qc.scales, inv,
                                  qq, n=7)
    np.testing.assert_array_equal(np.asarray(rgot[0]), np.asarray(rwant[0]))
    np.testing.assert_array_equal(np.asarray(rgot[1]), np.asarray(rwant[1]))


# ragged N/Q, Q > the ref q_chunk (chunked densify), duplicate query indices
@pytest.mark.parametrize("n,q,topn,idx_hi", [(64, 9, 64, None),
                                             (1000, 13, 10, None),
                                             (300, 150, 7, None),
                                             (200, 11, 9, 9)])
def test_quantized_sparse_q_bit_identical(n, q, topn, idx_hi):
    """Quantized candidates × sparse query codes (kernel AND ref) must be
    bit-identical to the fp32 sparse-query generation over the dequantized
    index — including duplicate indices inside query code rows."""
    kq = 8
    qc, deq, inv, _ = _quantized_case(n, q, kq, 256, seed=n + q)
    ks = jax.random.split(jax.random.PRNGKey(n * q + 1), 2)
    qv = jax.random.normal(ks[0], (q, kq), jnp.float32)
    qi = jax.random.randint(ks[1], (q, kq), 0, idx_hi or 256, dtype=jnp.int32)
    want_v, want_i = fused_retrieve_sparse_q(
        deq.values, deq.indices, inv, qv, qi, 256, n=topn
    )
    got_v, got_i = fused_retrieve_quantized_sparse_q(
        qc.q_values, qc.indices, qc.scales, inv, qv, qi, 256, n=topn
    )
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    rwant = retrieve_sparse_q_ref(deq.values, deq.indices, inv, qv, qi, 256,
                                  n=topn)
    rgot = retrieve_quantized_sparse_q_ref(
        qc.q_values, qc.indices, qc.scales, inv, qv, qi, 256, n=topn
    )
    np.testing.assert_array_equal(np.asarray(rgot[0]), np.asarray(rwant[0]))
    np.testing.assert_array_equal(np.asarray(rgot[1]), np.asarray(rwant[1]))


def test_quantized_single_query_and_validation():
    qc, deq, inv, qq = _quantized_case(96, 1, 8, 128, seed=11)
    v, i = fused_retrieve_quantized(qc.q_values, qc.indices, qc.scales, inv,
                                    qq[0], n=96)
    assert v.shape == (96,) and i.shape == (96,)
    assert sorted(np.asarray(i).tolist()) == list(range(96))
    with pytest.raises(ValueError):
        fused_retrieve_quantized(qc.q_values, qc.indices, qc.scales, inv,
                                 qq, n=97)
    qv = jnp.zeros((1, 8)); qi = jnp.zeros((1, 8), jnp.int32)
    with pytest.raises(ValueError):
        fused_retrieve_quantized_sparse_q(
            qc.q_values, qc.indices, qc.scales, inv, qv, qi, 128, n=97
        )


# -------------------------------------------- fused_retrieve_quantized_mxu
# Generation 5 is APPROXIMATE vs the exact quantized path, but its kernel
# and chunked jnp ref must be BIT-identical to each other: int32
# accumulation is exact/order-invariant and the query-panel quantization
# is one shared function — the only generation where kernel↔ref equality
# is array_equal rather than allclose.
@pytest.mark.parametrize("n,q,topn", [(64, 9, 64), (256, 1, 5),
                                      (1000, 3, 10), (4097, 5, 20),
                                      (300, 150, 7)])
def test_quantized_mxu_kernel_ref_bit_identical(n, q, topn):
    qc, deq, inv, qq = _quantized_case(n, q, 8, 256, seed=n + q)
    got_v, got_i = fused_retrieve_quantized_mxu(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=topn
    )
    ref_v, ref_i = retrieve_quantized_mxu_ref(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=topn
    )
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(ref_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ref_i))
    # and the ref's candidate blocking cannot change the result either
    blk_v, blk_i = retrieve_quantized_mxu_ref(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=topn, block_n=96
    )
    np.testing.assert_array_equal(np.asarray(blk_v), np.asarray(ref_v))
    np.testing.assert_array_equal(np.asarray(blk_i), np.asarray(ref_i))


def test_quantized_mxu_close_to_exact_scores():
    """The approximate path's contract vs the exact quantized path is a
    quality bound, not equality: per-element error of the int8 scoring is
    bounded by the two symmetric-quantization steps (≲1% of each side's
    amax), so norm-folded cosine scores must agree to ~1e-2."""
    qc, deq, inv, qq = _quantized_case(512, 6, 8, 256, seed=99)
    ex_v, _ = retrieve_quantized_ref(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=16
    )
    ap_v, _ = retrieve_quantized_mxu_ref(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=16
    )
    np.testing.assert_allclose(np.asarray(ap_v), np.asarray(ex_v), atol=5e-2)
    assert float(np.abs(np.asarray(ap_v) - np.asarray(ex_v)).mean()) < 2e-2


def test_quantized_mxu_tied_scores_match_lax_topk():
    # duplicated candidate rows share a quantization scale AND quantize to
    # identical int8 codes, so int8 scores tie EXACTLY across tile
    # boundaries; the merge must resolve them like lax.top_k (lowest id)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    vals = jnp.tile(jax.random.normal(ks[0], (40, 4), jnp.float32), (8, 1))
    idx = jnp.tile(jax.random.randint(ks[1], (40, 4), 0, 64, jnp.int32), (8, 1))
    qq = jax.random.normal(ks[2], (3, 64), jnp.float32)
    qc = quantize_codes(SparseCodes(values=vals, indices=idx, dim=64))
    deq = dequantize_codes(qc)
    inv = 1.0 / jnp.maximum(jnp.linalg.norm(deq.values, axis=-1), 1e-8)
    got_v, got_i = fused_retrieve_quantized_mxu(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=17,
        block_n=64, block_q=2,
    )
    ref_v, ref_i = retrieve_quantized_mxu_ref(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=17, block_n=96
    )
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(ref_v))
    # within a tied run, ids must come out ascending (lowest id wins)
    gi = np.asarray(got_i)
    gv = np.asarray(got_v)
    for row_v, row_i in zip(gv, gi):
        for a in range(16):
            if row_v[a] == row_v[a + 1]:
                assert row_i[a] < row_i[a + 1]


@pytest.mark.parametrize("h,want_dtype", [(256, jnp.int16),
                                          (40000, jnp.int16),
                                          (70000, jnp.int32)])
def test_quantized_mxu_int16_wraparound(h, want_dtype):
    """The int8-scoring path shares the low-16-bit index widen: indices in
    the two's-complement wrap region (h=40000) and the int32 fallback
    (h >= 65536) must stay kernel↔ref bit-identical."""
    qc, deq, inv, qq = _quantized_case(300, 2, 8, h, seed=h)
    assert qc.indices.dtype == want_dtype
    got = fused_retrieve_quantized_mxu(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=7
    )
    ref = retrieve_quantized_mxu_ref(
        qc.q_values, qc.indices, qc.scales, inv, qq, n=7
    )
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


# ragged N/Q, Q > the ref q_chunk (chunked densify+quantize), duplicate
# query indices (densify-then-quantize must share the scatter-add order)
@pytest.mark.parametrize("n,q,topn,idx_hi", [(64, 9, 64, None),
                                             (1000, 13, 10, None),
                                             (300, 150, 7, None),
                                             (200, 11, 9, 9)])
def test_quantized_mxu_sparse_q_bit_identical(n, q, topn, idx_hi):
    kq = 8
    qc, deq, inv, _ = _quantized_case(n, q, kq, 256, seed=n + q)
    ks = jax.random.split(jax.random.PRNGKey(n * q + 1), 2)
    qv = jax.random.normal(ks[0], (q, kq), jnp.float32)
    qi = jax.random.randint(ks[1], (q, kq), 0, idx_hi or 256, dtype=jnp.int32)
    got_v, got_i = fused_retrieve_quantized_mxu_sparse_q(
        qc.q_values, qc.indices, qc.scales, inv, qv, qi, 256, n=topn
    )
    ref_v, ref_i = retrieve_quantized_mxu_sparse_q_ref(
        qc.q_values, qc.indices, qc.scales, inv, qv, qi, 256, n=topn
    )
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(ref_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ref_i))
    # the sparse-q path must equal densify + the dense-query mxu path:
    # same panel values -> same quantized panel -> same int8 scores
    qd = _densify(qv, qi, 256)
    dn_v, dn_i = fused_retrieve_quantized_mxu(
        qc.q_values, qc.indices, qc.scales, inv, qd, n=topn
    )
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(dn_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(dn_i))


def test_quantize_panel_matches_quantize_codes_arithmetic():
    """The shared panel quantizer must reproduce quantize_codes' value
    arithmetic exactly (same scale floor, rounding, clip) — it is the
    reason the offline and online int8 representations agree."""
    vals = jax.random.normal(jax.random.PRNGKey(0), (5, 16), jnp.float32)
    idx = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (5, 16))
    qc = quantize_codes(SparseCodes(values=vals, indices=idx, dim=16))
    qi8, qs = _quantize_panel(vals)
    np.testing.assert_array_equal(np.asarray(qi8), np.asarray(qc.q_values))
    np.testing.assert_array_equal(np.asarray(qs[:, 0]), np.asarray(qc.scales))
    # zero rows (query padding) quantize to zeros with the floored scale
    zi8, zs = _quantize_panel(jnp.zeros((2, 8), jnp.float32))
    assert (np.asarray(zi8) == 0).all() and (np.asarray(zs) == 1e-12).all()


def test_quantized_mxu_single_query_and_validation():
    qc, deq, inv, qq = _quantized_case(96, 1, 8, 128, seed=11)
    v, i = fused_retrieve_quantized_mxu(
        qc.q_values, qc.indices, qc.scales, inv, qq[0], n=96
    )
    assert v.shape == (96,) and i.shape == (96,)
    assert sorted(np.asarray(i).tolist()) == list(range(96))
    with pytest.raises(ValueError):
        fused_retrieve_quantized_mxu(
            qc.q_values, qc.indices, qc.scales, inv, qq, n=97
        )
    qv = jnp.zeros((1, 8)); qi = jnp.zeros((1, 8), jnp.int32)
    with pytest.raises(ValueError):
        fused_retrieve_quantized_mxu_sparse_q(
            qc.q_values, qc.indices, qc.scales, inv, qv, qi, 128, n=97
        )


# ------------------------------------------------------------------ topk_mask
@pytest.mark.parametrize("b,h,k", [(8, 128, 4), (300, 512, 16), (64, 4096, 32), (257, 640, 1)])
def test_topk_mask_shapes(b, h, k):
    x = jax.random.normal(jax.random.PRNGKey(b + h + k), (b, h))
    np.testing.assert_allclose(topk_mask(x, k), topk_mask_ref(x, k), rtol=1e-6)


def test_topk_mask_leading_dims():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 6, 256))
    np.testing.assert_allclose(topk_mask(x, 8), topk_mask_ref(x, 8), rtol=1e-6)


def test_topk_mask_ties_match_lax_topk():
    # Repeated |values|: kernel must break ties toward the lowest index,
    # exactly like jax.lax.top_k on |x|.
    x = jnp.array([[2.0, -2.0, 2.0, 1.0, -2.0, 0.5]] * 8)
    np.testing.assert_allclose(topk_mask(x, 3), topk_mask_ref(x, 3), rtol=0)


# --------------------------------------------------------------- fused_encode
@pytest.mark.parametrize("b,d,h,k", [(64, 96, 512, 8), (200, 64, 256, 4), (128, 768, 1024, 32)])
def test_fused_encode_matches_ref(b, d, h, k):
    key = jax.random.PRNGKey(b + d)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (b, d))
    w = jax.random.normal(k2, (d, h)) / np.sqrt(d)
    bias = 0.01 * jax.random.normal(k3, (h,))
    codes = fused_encode(x, w, bias, k)
    rv, ri = fused_encode_ref(normalize_input(x), w, bias, k)
    # same selected index SET per row, and same (index -> value) mapping
    got = {}
    for r in range(b):
        gi = np.asarray(codes.indices[r])
        ri_r = np.asarray(ri[r])
        assert set(gi.tolist()) == set(ri_r.tolist()), f"row {r} index set differs"
    # values agree after aligning by index
    dense_got = np.zeros((b, h), np.float32)
    dense_want = np.zeros((b, h), np.float32)
    bidx = np.arange(b)[:, None]
    dense_got[bidx, np.asarray(codes.indices)] = np.asarray(codes.values)
    dense_want[bidx, np.asarray(ri)] = np.asarray(rv)
    np.testing.assert_allclose(dense_got, dense_want, rtol=1e-4, atol=1e-5)


def test_fused_encode_agrees_with_core_encode():
    from repro.core import SAEConfig, encode, init_params
    from repro.core import sparse as sp

    cfg = SAEConfig(d=64, h=256, k=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d))
    a = encode(params, x, cfg.k)
    b = fused_encode(x, params["w_enc"], params["b_enc"], cfg.k)
    np.testing.assert_allclose(sp.densify(a), sp.densify(b), rtol=1e-4, atol=1e-5)


# -------------------------------------------------------------- embedding_bag
@pytest.mark.parametrize("v,dim,b,l", [(100, 32, 16, 1), (1000, 64, 37, 5), (5000, 128, 8, 20)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_shapes(v, dim, b, l, mode):
    kt, ki = jax.random.split(jax.random.PRNGKey(v + b), 2)
    table = jax.random.normal(kt, (v, dim))
    ids = jax.random.randint(ki, (b, l), -1, v, dtype=jnp.int32)  # -1 = pad
    np.testing.assert_allclose(
        embedding_bag(table, ids, mode),
        embedding_bag_ref(table, ids, mode),
        rtol=1e-5,
        atol=1e-5,
    )


def test_embedding_bag_all_padding_row():
    table = jax.random.normal(jax.random.PRNGKey(0), (10, 16))
    ids = jnp.full((3, 4), -1, jnp.int32)
    out = embedding_bag(table, ids, "mean")
    np.testing.assert_allclose(out, np.zeros((3, 16)), atol=1e-7)


def test_embedding_bag_bf16_table():
    table = jax.random.normal(jax.random.PRNGKey(0), (50, 32)).astype(jnp.bfloat16)
    ids = jax.random.randint(jax.random.PRNGKey(1), (7, 3), 0, 50, dtype=jnp.int32)
    got = embedding_bag(table, ids, "sum").astype(jnp.float32)
    want = embedding_bag_ref(table, ids, "sum").astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
