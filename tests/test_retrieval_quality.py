"""Tier-1 quality gate for the approximate int8 serving path (ISSUE 5).

Generation 5 (``precision="int8"``) trades bit-identity against the exact
quantized path for int8-MXU scoring; its acceptance contract is a
MEASURED bound at the benchmark configuration — N=16384, Q=64, k=32,
recall@32 ≥ 0.95 vs the exact quantized path — enforced here through the
shared harness (``repro.core.eval``), on the jnp refs (the kernel is
gated bit-identical to the ref in test_kernels.py, so the ref's quality
IS the kernel's quality)."""
import jax
import numpy as np
import pytest

from repro.core import SAEConfig, build_index, encode, init_params
from repro.core.eval import retrieval_quality
from repro.data import clustered_embeddings
from repro.serving import RetrievalEngine

# the benchmark operating point (benchmarks/retrieval_modes.py: D, H at the
# harness defaults, k at the paper's 32, full-size catalog/batch)
D, H, K = 256, 1024, 32
N, Q, TOPN = 16384, 64, 32


@pytest.fixture(scope="module")
def setup():
    """One quantized index at benchmark shape, retrieved once at TOPN by
    the exact and the int8 engine — the full-size retrievals are the
    expensive part, so the module-scoped fixture computes each exactly
    once and the tests share the outputs.

    The encoder is untrained (random projection + abs-top-k): the
    int8-vs-exact relationship depends on the quantization arithmetic,
    not on SAE training, and skipping training keeps the gate fast.
    """
    cfg = SAEConfig(d=D, h=H, k=K)
    params = init_params(cfg, jax.random.PRNGKey(0))
    corpus = clustered_embeddings(jax.random.PRNGKey(1), N, d=D)
    queries = clustered_embeddings(jax.random.PRNGKey(2), Q, d=D)
    qindex = build_index(encode(params, corpus, K), params, quantize=True)
    exact = RetrievalEngine(params, qindex, use_kernel=False)
    approx = RetrievalEngine(params, qindex, use_kernel=False,
                             precision="int8")
    e = exact.retrieve_dense(queries, TOPN)
    a = approx.retrieve_dense(queries, TOPN)
    return params, qindex, queries, e, a


@pytest.mark.timeout(300)
def test_int8_recall_at_32_meets_bound(setup):
    """THE acceptance gate: recall@32 vs the exact quantized path ≥ 0.95
    at N=16384, Q=64, k=32."""
    *_, e, a = setup
    quality = retrieval_quality(a, e)
    assert quality["n"] == TOPN
    assert quality["recall"] >= 0.95, quality


def test_int8_score_error_and_rank_damage_bounded(setup):
    """Beyond recall: the score curve must sit within int8-quantization
    error of the exact one (two ≲1%-of-amax quantizers on unit-cosine
    scores) and ranks must barely move on average."""
    *_, e, a = setup
    quality = retrieval_quality(a, e)
    assert quality["score_mae"] < 5e-3, quality
    assert quality["rank_displacement"] < 2.0, quality


def test_exact_path_is_self_identical_through_harness(setup):
    """Sanity for the harness-as-gate: the exact path measured against
    itself must report the perfect triple (recall 1, MAE 0, displacement
    0) — if this fails, the gate above is meaningless."""
    *_, e, _ = setup
    quality = retrieval_quality(e, e)
    assert quality == {"n": TOPN, "recall": 1.0, "score_mae": 0.0,
                       "rank_displacement": 0.0}


def test_int8_mode_reconstructed_also_meets_bound(setup):
    """The dense-query (reconstructed-mode) int8 generation sits under the
    same quality bound — smaller query batch to keep the runtime down,
    same quantization arithmetic."""
    params, qindex, queries, *_ = setup
    er = RetrievalEngine(params, qindex, mode="reconstructed",
                         use_kernel=False)
    ar = RetrievalEngine(params, qindex, mode="reconstructed",
                         use_kernel=False, precision="int8")
    e = er.retrieve_dense(queries[:16], TOPN)
    a = ar.retrieve_dense(queries[:16], TOPN)
    assert retrieval_quality(a, e)["recall"] >= 0.95
