"""EngineConfig (ISSUE 10 satellite): the one frozen knob namespace.

Gates the api_redesign contract: field-space validation at construction,
index-dependent validation in ``validate``, the ``from_flags`` CLI
mapping every entry point shares, and the legacy constructor shim —
both the (params, index) argument order and the keyword-knob spelling —
warning ``DeprecationWarning`` while building engines whose responses
are bit-identical to the config-first spelling.
"""
import argparse
import dataclasses

import numpy as np
import pytest
import jax

from repro.core import SAEConfig, build_index, encode, init_params
from repro.core.segments import SegmentedIndex
from repro.errors import EngineConfigError
from repro.serving import (
    EngineConfig,
    RetrievalEngine,
    RetrievalResponse,
    ServingStatus,
)

CFG = SAEConfig(d=32, h=128, k=8)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (310, CFG.d))
    queries = jax.random.normal(jax.random.PRNGKey(2), (9, CFG.d))
    codes = encode(params, corpus, CFG.k)
    index = build_index(codes, params)
    qindex = build_index(codes, params, quantize=True)
    return params, index, qindex, queries


def _bit_equal(a: RetrievalResponse, b: RetrievalResponse):
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


# ------------------------------------------------------ the frozen value
def test_config_is_frozen_and_replace_copies():
    cfg = EngineConfig(precision="exact")
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.precision = "int8"
    cfg2 = cfg.replace(precision="int8", stage="single")
    assert cfg2.precision == "int8" and cfg.precision == "exact"
    assert cfg2 is not cfg


@pytest.mark.parametrize("bad", [
    dict(mode="dense"),
    dict(stage="three_stage"),
    dict(stage1="gpu"),
    dict(precision="fp64"),
    dict(stage="two_stage", mode="reconstructed"),
    dict(stage="two_stage", candidate_fraction=0.0),
    dict(stage="two_stage", candidate_fraction=1.5),
    dict(stage="two_stage", mesh=object()),
])
def test_field_space_validation_rejects_at_construction(bad):
    """Invalid combinations die the moment the config exists — before
    any index or params are in sight."""
    with pytest.raises(EngineConfigError):
        EngineConfig(**bad)


def test_index_dependent_validation(setup):
    params, index, qindex, _ = setup
    EngineConfig(precision="int8").validate(qindex)          # ok
    with pytest.raises(EngineConfigError, match="QuantizedIndex"):
        EngineConfig(precision="int8").validate(index)       # fp32 codes
    with pytest.raises(EngineConfigError, match="requires SAE params"):
        EngineConfig(mode="reconstructed").validate(index, params=None)
    wrong = {**params, "w_enc": params["w_enc"][:, : CFG.h // 2]}
    with pytest.raises(EngineConfigError, match="latent-dim mismatch"):
        EngineConfig().validate(index, wrong)
    seg = SegmentedIndex.from_index(index)
    with pytest.raises(EngineConfigError, match="single"):
        EngineConfig(stage="two_stage").validate(seg)
    with pytest.raises(EngineConfigError, match="sparse"):
        EngineConfig(mode="reconstructed").validate(seg, params)


# ---------------------------------------------------------- CLI plumbing
def _flags(argv):
    ap = argparse.ArgumentParser()
    EngineConfig.add_flags(ap)
    return ap.parse_args(argv)


def test_from_flags_default_namespace_is_default_config():
    assert EngineConfig.from_flags(_flags([])) == EngineConfig()


def test_from_flags_maps_every_knob():
    cfg = EngineConfig.from_flags(_flags([
        "--use-kernel", "0", "--quantized", "--precision", "int8",
        "--two-stage", "--candidate-fraction", "0.5",
        "--inverted-cap", "512", "--stage1", "host",
    ]))
    assert cfg.use_kernel is False and cfg.precision == "int8"
    assert cfg.stage == "two_stage" and cfg.stage1 == "host"
    assert cfg.candidate_fraction == 0.5 and cfg.inverted_cap == 512
    assert cfg.mesh is None
    assert EngineConfig.from_flags(
        _flags(["--use-kernel", "1"])).use_kernel is True


def test_from_flags_cross_checks():
    """The checks that used to be duplicated per entry point as
    ``ap.error(...)`` now live in ONE place and raise typed."""
    with pytest.raises(EngineConfigError, match="requires --quantized"):
        EngineConfig.from_flags(_flags(["--precision", "int8"]))
    with pytest.raises(EngineConfigError, match="--shards"):
        EngineConfig.from_flags(_flags(["--two-stage", "--shards", "2"]))
    with pytest.raises(EngineConfigError, match="requires --two-stage"):
        EngineConfig.from_flags(_flags(["--stage1", "device"]))


def test_from_flags_builds_shard_mesh():
    n = min(2, jax.device_count())
    if n < 2:
        pytest.skip("single-device process")
    cfg = EngineConfig.from_flags(_flags(["--shards", str(n)]))
    assert cfg.mesh is not None and "cand" in cfg.mesh.axis_names


# ------------------------------------------------------ the legacy shim
def test_legacy_argument_order_warns_and_is_equivalent(setup):
    params, index, _, queries = setup
    new = RetrievalEngine(index, params)
    with pytest.warns(DeprecationWarning, match="argument order"):
        old = RetrievalEngine(params, index)
    assert old.index is new.index and old.params is new.params
    assert old.config == new.config
    _bit_equal(old.retrieve_dense(queries, 7),
               new.retrieve_dense(queries, 7))


def test_legacy_paramless_order_warns_and_is_equivalent(setup):
    _, index, _, _ = setup
    new = RetrievalEngine(index, None)
    with pytest.warns(DeprecationWarning, match="argument order"):
        old = RetrievalEngine(None, index)
    assert old.index is new.index and old.params is None


def test_legacy_keyword_knobs_warn_and_match_config(setup):
    params, _, qindex, queries = setup
    new = RetrievalEngine(qindex, params, config=EngineConfig(
        use_kernel=False, precision="int8", k=4))
    with pytest.warns(DeprecationWarning, match="config=EngineConfig"):
        old = RetrievalEngine(qindex, params,
                              use_kernel=False, precision="int8", k=4)
    assert old.config == new.config
    _bit_equal(old.retrieve_dense(queries, 7),
               new.retrieve_dense(queries, 7))


def test_legacy_both_orders_and_knobs_together(setup):
    """The fully-legacy spelling — old order AND keyword knobs — still
    lands on the same engine as the config-first spelling."""
    params, index, _, queries = setup
    new = RetrievalEngine(index, params,
                          config=EngineConfig(use_kernel=False))
    with pytest.warns(DeprecationWarning):
        old = RetrievalEngine(params, index, use_kernel=False)
    assert old.config == new.config
    _bit_equal(old.retrieve_dense(queries, 5),
               new.retrieve_dense(queries, 5))


def test_config_and_legacy_knobs_conflict(setup):
    params, index, _, _ = setup
    with pytest.raises(EngineConfigError, match="not both"):
        RetrievalEngine(index, params, config=EngineConfig(),
                        use_kernel=False)


def test_unknown_keyword_is_a_type_error(setup):
    params, index, _, _ = setup
    with pytest.raises(TypeError, match="unexpected keyword"):
        RetrievalEngine(index, params, use_kernle=False)


def test_unidentifiable_arguments_raise_typed(setup):
    with pytest.raises(EngineConfigError, match="could not identify"):
        RetrievalEngine({"not": "params"}, 42)


# ----------------------------------------------------- response surface
def test_response_surface_is_unified(setup):
    params, index, _, queries = setup
    engine = RetrievalEngine(index, params,
                             config=EngineConfig(use_kernel=False))
    resp = engine.retrieve_dense(queries, 7)
    assert isinstance(resp, RetrievalResponse)
    assert isinstance(resp.status, ServingStatus)
    assert resp.status.path and not resp.status.degraded
    assert resp.queue_us == 0.0 and resp.compute_us > 0.0
    # the tuple-era contract survives: positional access + .pair
    scores, ids, *_ = resp
    assert scores is resp.scores and ids is resp.ids
    assert resp.pair == (resp.scores, resp.ids)
    assert resp[0] is resp.scores and resp[1] is resp.ids
