"""Hypothesis property tests for the guard layer (ISSUE 6 satellite).

Properties:
  * a dense query with non-finite values at ANY positions is never served
    raw — "reject" raises a typed error naming the count, "sanitize"
    serves the zeroed batch and reports it as degraded;
  * ragged shapes / wrong dtypes / bad top-n never reach the kernel — the
    engine's jit cache stays cold across every rejection;
  * valid (finite, well-shaped) inputs are never rejected and never
    flagged degraded.
"""
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from repro.core import SAEConfig, build_index, encode, init_params
from repro.errors import InvalidQueryError
from repro.serving import GuardedEngine, RetrievalEngine

hypothesis.settings.register_profile(
    "repro_guard", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("repro_guard")

CFG = SAEConfig(d=16, h=64, k=4)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    corpus = jax.random.normal(jax.random.PRNGKey(1), (96, CFG.d))
    index = build_index(encode(params, corpus, CFG.k), params)
    return params, index


def fresh_guard(setup, **kw):
    params, index = setup
    return GuardedEngine(RetrievalEngine(params, index, use_kernel=False),
                         **kw)


@st.composite
def poisoned_batches(draw, d=CFG.d, max_rows=6):
    """A finite query batch + 1..4 distinct non-finite plants."""
    rows = draw(st.integers(1, max_rows))
    seed = draw(st.integers(0, 2**31 - 1))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (rows, d)))
    n_bad = draw(st.integers(1, 4))
    positions = draw(
        st.lists(
            st.tuples(st.integers(0, rows - 1), st.integers(0, d - 1)),
            min_size=n_bad, max_size=n_bad, unique=True,
        )
    )
    for k, (r, c) in enumerate(positions):
        x[r, c] = [np.nan, np.inf, -np.inf][k % 3]
    return x, len(positions)


@given(poisoned_batches())
def test_nonfinite_always_rejected(setup, batch):
    x, n_bad = batch
    g = fresh_guard(setup)
    with pytest.raises(InvalidQueryError, match=f"{n_bad} non-finite"):
        g.retrieve_dense(x, 5)
    assert g.counters["rejected"] == 1


@given(poisoned_batches())
def test_nonfinite_always_sanitized(setup, batch):
    x, n_bad = batch
    g = fresh_guard(setup, on_invalid="sanitize")
    scores, ids, status, *_ = g.retrieve_dense(x, 5)
    assert status.degraded and status.sanitized == n_bad
    assert np.all(np.isfinite(np.asarray(scores)))
    # serving the pre-zeroed batch is the same request
    clean = np.where(np.isfinite(x), x, 0.0)
    wv, wi, *_ = g.engine.retrieve_dense(jnp.asarray(clean), 5)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi))


@st.composite
def malformed_requests(draw, d=CFG.d):
    """(x, n) pairs that must ALL fail admission before any kernel."""
    kind = draw(st.sampled_from(
        ["rank3", "rank0", "wrong_d", "int_dtype", "not_array",
         "bad_topn_type", "bad_topn_range"]
    ))
    x = jnp.zeros((2, d))
    n = 5
    if kind == "rank3":
        x = jnp.zeros((2, 3, d))
    elif kind == "rank0":
        x = jnp.zeros(())
    elif kind == "wrong_d":
        x = jnp.zeros((2, d + draw(st.integers(1, 7))))
    elif kind == "int_dtype":
        x = jnp.zeros((2, d), dtype=jnp.int32)
    elif kind == "not_array":
        x = [[0.0] * d]
    elif kind == "bad_topn_type":
        n = draw(st.sampled_from([5.0, "5", None, True]))
    elif kind == "bad_topn_range":
        n = draw(st.sampled_from([0, -3, 10**6]))
    return x, n


@given(malformed_requests())
def test_malformed_never_reaches_the_kernel(setup, req):
    x, n = req
    g = fresh_guard(setup)
    with pytest.raises(InvalidQueryError):
        g.retrieve_dense(x, n)
    # cold jit cache == no serving computation was ever traced/compiled
    assert g.engine._serve_cache == {}
    assert g.counters["rejected"] == 1 and g.counters["degraded"] == 0


@st.composite
def valid_batches(draw, d=CFG.d):
    rows = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.sampled_from([1e-3, 1.0, 1e3]))
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d)) * scale
    n = draw(st.integers(1, 12))
    return x, n


@given(valid_batches())
def test_valid_inputs_never_rejected(setup, req):
    x, n = req
    g = fresh_guard(setup)
    scores, ids, status, *_ = g.retrieve_dense(x, n)
    assert not status.degraded and status.step == 0
    assert status.fault is None and status.sanitized == 0
    assert scores.shape == (x.shape[0], n)
    assert g.counters["rejected"] == 0 and g.counters["degraded"] == 0
