"""Tier-1 test-process topology: force a multi-device CPU platform.

The distributed tests (candidate-sharded retrieval, shard_map equivalence,
the sharded benchmark mode) need several devices.  XLA only honours
``--xla_force_host_platform_device_count`` if it is set before jax
initializes its backends, so this must happen at conftest import time —
before any test module (or plugin) imports jax — rather than in a
per-test fixture or per-test env hack.  Subprocess-based tests
(test_distributed_equiv, test_benchmarks_smoke, test_topk) inherit the
value through the environment.

An existing forcing flag in the environment is respected, so
``XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest …`` still
works for manual runs at other device counts.
"""
import os

import pytest

FORCED_HOST_DEVICES = 4
_FORCE_FLAG = "--xla_force_host_platform_device_count"

if _FORCE_FLAG.lstrip("-") not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"{os.environ.get('XLA_FLAGS', '')} "
        f"{_FORCE_FLAG}={FORCED_HOST_DEVICES}"
    ).strip()


@pytest.fixture(scope="session")
def forced_device_count() -> int:
    """The CPU device count tier-1 runs under (sanity-checked live).

    The expected count is read back from XLA_FLAGS so manual runs that
    pre-force a different value (see module docstring) are honoured —
    tests then skip, not error, on the mesh widths that don't fit.
    """
    import re

    import jax

    m = re.search(rf"{_FORCE_FLAG}=(\d+)", os.environ.get("XLA_FLAGS", ""))
    expected = int(m.group(1)) if m else FORCED_HOST_DEVICES
    n = jax.device_count()
    assert n >= expected or jax.default_backend() != "cpu", (
        f"expected >= {expected} forced host devices, got "
        f"{jax.devices()} — was jax imported before tests/conftest.py?"
    )
    return n
