"""Normalization layers (functional: params are plain arrays)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm (Zhang & Sennrich) — LLaMA/Gemma/Qwen default."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: jax.Array | None = None, eps: float = 1e-5
) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)
