"""Embedding substrate.

JAX has no native ``nn.EmbeddingBag`` and no CSR/CSC sparse — the lookup
substrate here is gather (``jnp.take``) + ``jax.ops.segment_sum``, with the
Pallas kernel (repro.kernels.embedding_bag) as the TPU hot-path variant for
fixed-width bags.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain row gather: (…,) int32 -> (…, dim)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag_segment(
    table: jax.Array,
    flat_ids: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    mode: str = "sum",
) -> jax.Array:
    """Ragged EmbeddingBag: gather + segment-reduce.

    flat_ids (nnz,) int32 rows of ``table``; segment_ids (nnz,) int32
    monotone bag assignment; -> (num_segments, dim).
    """
    rows = jnp.take(table, flat_ids, axis=0)            # (nnz, dim)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if mode == "mean":
        ones = jnp.ones((flat_ids.shape[0],), table.dtype)
        cnt = jax.ops.segment_sum(ones, segment_ids, num_segments=num_segments)
        out = out / jnp.maximum(cnt[:, None], 1.0)
    return out


def embedding_bag_fixed(
    table: jax.Array, ids: jax.Array, mode: str = "sum"
) -> jax.Array:
    """Fixed-width bags: ids (B, L), negative = padding. -> (B, dim).

    Pure-jnp path (matches the Pallas kernel's oracle exactly).
    """
    v = table.shape[0]
    rows = jnp.take(table, jnp.clip(ids, 0, v - 1), axis=0)   # (B, L, dim)
    valid = (ids >= 0)[..., None].astype(table.dtype)
    out = jnp.sum(rows * valid, axis=1)
    if mode == "mean":
        out = out / jnp.maximum(jnp.sum(valid, axis=1), 1.0)
    return out
