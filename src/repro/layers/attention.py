"""Attention substrate: RoPE, chunked (flash-style) training/prefill
attention, and single-token decode attention.  All functions are pure and
GQA-aware (n_q_heads = G · n_kv_heads).

Memory discipline: ``flash_attention`` never materializes the (Sq, Skv)
score matrix — it scans q-chunks and, inside, kv-chunks with the running
(max, denom, acc) online-softmax state.  This is what lets 32k-token
prefill fit the dry-run memory budget (DESIGN.md §5).

Sharding discipline: GQA is computed by expanding K/V to the full query
head count via a static head-map gather (``kv_map``).  Every attention
tensor then carries the full n_heads axis, which shards evenly over the
16-way 'model' axis even when n_kv_heads < 16 (DESIGN.md §5).  The
expansion is per-kv-chunk inside the scan, so the 8× blow-up is transient
(a VMEM-scale tile), not a resident tensor.

Supported variants (driven by the arch configs): causal / bidirectional,
sliding-window (Gemma-2 local layers), attention-logit soft-capping
(Gemma-2), GQA with any group size.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding, split-halves convention.  x (..., S, H, D),
    positions (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    sin = jnp.sin(angles)[..., None, :]                            # (..., S, 1, half)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def _kv_map(hq: int, hkv: int) -> jax.Array:
    """Static q-head -> kv-head index map for GQA expansion."""
    g = hq // hkv
    return jnp.repeat(jnp.arange(hkv, dtype=jnp.int32), g)


# --------------------------------------------------- chunked flash attention
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[jax.Array | int] = None,
    logit_softcap: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jax.Array:
    """q (B, Sq, Hq, D); k, v (B, Skv, Hkv, D); Hq % Hkv == 0.

    Returns (B, Sq, Hq, D).  ``window`` masks keys with
    col <= row - window (sliding-window attention); may be a traced scalar
    so alternating-window stacks can share one jaxpr.
    """
    b, sq0, hq, d = q.shape
    _, skv0, hkv, _ = k.shape
    kvm = _kv_map(hq, hkv)
    q_chunk = min(q_chunk, sq0)
    kv_chunk = min(kv_chunk, skv0)
    # pad ragged sequence lengths up to the chunk grid (masked out below)
    pad_q = (-sq0) % q_chunk
    pad_kv = (-skv0) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    sq, skv = sq0 + pad_q, skv0 + pad_kv
    nq, nk = sq // q_chunk, skv // kv_chunk

    qs = jnp.moveaxis(
        (q * (d ** -0.5)).reshape(b, nq, q_chunk, hq, d), 1, 0
    )                                                  # (nq, B, qc, Hq, D)
    ks = jnp.moveaxis(k.reshape(b, nk, kv_chunk, hkv, d), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kv_chunk, hkv, d), 1, 0)

    q_iota = jnp.arange(q_chunk)
    k_iota = jnp.arange(kv_chunk)

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk                             # (B, qc, Hq, D)
        row = qi * q_chunk + q_iota                    # (qc,)

        # remat: without this the scan-of-scan AD stacks every (qc, kc)
        # probability block as a residual — the full S² attention matrix
        # flash exists to avoid.  Recomputing p per block in the backward
        # is the standard FlashAttention trade (one extra QK^T per block).
        @jax.checkpoint
        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            col = ki * kv_chunk + k_iota               # (kc,)
            kx = kblk[:, :, kvm, :]                    # GQA expand (B,kc,Hq,D)
            vx = vblk[:, :, kvm, :]
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qblk, kx,
                preferred_element_type=jnp.float32,
            )                                           # (B, Hq, qc, kc)
            if logit_softcap is not None:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            mask = (col < skv0)[None, :] & jnp.ones((q_chunk, 1), dtype=bool)
            if causal:
                mask &= col[None, :] <= row[:, None]
            if window is not None:
                mask &= col[None, :] > row[:, None] - window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vx.dtype), vx,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hq, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]    # (B, Hq, qc, D)
        return None, jnp.moveaxis(out, 2, 1).astype(q.dtype)  # (B, qc, Hq, D)

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1)                      # (B, nq, qc, Hq, D)
    return out.reshape(b, sq, hq, d)[:, :sq0]


# ------------------------------------------------------------ decode step
def decode_attention_grouped(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    length: jax.Array | int,
    window: Optional[jax.Array | int] = None,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    """Grouped-GQA decode: no KV expansion — used when n_kv_heads divides
    the model axis, so the (hkv, G) head split shards cleanly and each
    device's q-head group reads exactly its local kv head.  (The expand
    path would all-gather the whole cache over heads: +2 GiB/layer at
    gemma2 decode_32k shapes.)"""
    b, _, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    qr = (q * (d ** -0.5)).reshape(b, hkv, g, d)
    scores = jnp.einsum(
        "bhgd,bkhd->bhgk", qr, k_cache, preferred_element_type=jnp.float32
    )                                                   # (B, Hkv, G, S)
    if logit_softcap is not None:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    col = jnp.arange(s)
    length = jnp.asarray(length)
    lb = length if length.ndim else length[None]
    valid = col[None, :] < lb[:, None]
    if window is not None:
        valid &= col[None, :] > lb[:, None] - 1 - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    length: jax.Array | int,
    window: Optional[jax.Array | int] = None,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    """One new token against a KV cache.

    q (B, 1, Hq, D); caches (B, S, Hkv, D); length = number of valid cache
    entries (scalar or (B,)).  Returns (B, 1, Hq, D).
    """
    b, _, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    kvm = _kv_map(hq, hkv)
    kx = k_cache[:, :, kvm, :]                          # (B, S, Hq, D)
    vx = v_cache[:, :, kvm, :]
    qr = (q * (d ** -0.5)).reshape(b, hq, d)
    scores = jnp.einsum(
        "bhd,bkhd->bhk", qr, kx, preferred_element_type=jnp.float32
    )                                                   # (B, Hq, S)
    if logit_softcap is not None:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    col = jnp.arange(s)
    length = jnp.asarray(length)
    lb = length if length.ndim else length[None]
    valid = col[None, :] < lb[:, None]                  # (B|1, S)
    if window is not None:
        valid &= col[None, :] > lb[:, None] - 1 - window
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhk,bkhd->bhd", p.astype(vx.dtype), vx,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, d).astype(q.dtype)
