"""Dense FFN variants (functional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """LLaMA-family gated FFN: (silu(x·Wg) ⊙ x·Wu) · Wd."""
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def gelu_mlp(x: jax.Array, w_in: jax.Array, w_out: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w_in, approximate=True) @ w_out


def mlp_stack(x: jax.Array, weights: list[jax.Array], biases: list[jax.Array],
              final_activation: bool = False) -> jax.Array:
    """Plain ReLU MLP tower (recsys models: DLRM/DIN/DeepFM)."""
    n = len(weights)
    for i, (w, b) in enumerate(zip(weights, biases)):
        x = x @ w + b
        if i < n - 1 or final_activation:
            x = jax.nn.relu(x)
    return x
