from repro.layers import attention, embedding, mlp, moe, norms

__all__ = ["attention", "embedding", "mlp", "moe", "norms"]
