"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Design (DESIGN.md §5): the classic one-hot dispatch einsum builds an
(N, E, C) mask — at N=65k tokens/device, E=128 that is unlowerable.  We use
the sort-based (MegaBlocks-style) dispatch instead:

    route → stable-sort slots by expert → positions via searchsorted →
    drop beyond capacity → scatter tokens into an (E·C, d) buffer →
    batched per-expert SwiGLU einsum (the grouped GEMM) → gather back →
    weighted scatter-add to tokens.

Everything is static-shaped (capacity C is a compile-time function of
N, E, top_k, capacity_factor), so it lowers under pjit with experts sharded
over the 'model' axis (expert parallelism).  Aux load-balance loss is the
Switch formulation.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.compat import P


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array
    dropped_frac: jax.Array


def capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = math.ceil(n_tokens * top_k / n_experts * factor)
    return max(8, int(math.ceil(c / 8) * 8))  # sublane-align


def moe_ffn_sharded(
    x: jax.Array,
    router_w: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    norm_topk: bool = True,
    batch_axes: tuple = ("data",),
    model_axis: str = "model",
) -> MoEOut:
    """Expert-parallel MoE via shard_map (DESIGN.md §5).

    Mesh contract: tokens are sharded over ``batch_axes`` and replicated
    over ``model_axis``; experts are sharded over ``model_axis`` and
    replicated over ``batch_axes``.  Every device therefore already holds
    (its token shard) × (its expert shard): dispatch is a *local*
    sort/scatter — no all-to-all — and the only collective is the psum of
    partial expert outputs over ``model_axis``.  Under plain GSPMD the
    sort-based dispatch is unpartitionable (it replicated the (N·K, d)
    gather on every device — 104 GiB/device at qwen3-moe train shapes,
    EXPERIMENTS.md §Perf); shard_map makes the locality explicit.
    """
    mesh = compat.current_mesh()
    if mesh is None:
        raise ValueError("moe_ffn_sharded needs an ambient mesh "
                         "(repro.compat.set_mesh)")
    sizes = dict(mesh.shape)
    n_model = sizes[model_axis]
    n_bshards = 1
    for a in batch_axes:
        n_bshards *= sizes[a]
    n, d = x.shape
    e = router_w.shape[1]
    e_loc = e // n_model
    n_loc = n // n_bshards
    c_loc = capacity(n_loc, e, top_k, capacity_factor)
    bspec = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]

    def local_fn(x_l, rw, wg, wu, wd):
        nl = x_l.shape[0]
        logits = x_l.astype(jnp.float32) @ rw.astype(jnp.float32)   # (nl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
        if norm_topk:
            gate_vals = gate_vals / jnp.maximum(
                jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
            )
        j = jax.lax.axis_index(model_axis)
        e_lo = j.astype(jnp.int32) * e_loc

        slot_expert = gate_idx.reshape(-1).astype(jnp.int32)
        slot_token = jnp.broadcast_to(
            jnp.arange(nl, dtype=jnp.int32)[:, None], (nl, top_k)
        ).reshape(-1)
        slot_gate = gate_vals.reshape(-1)
        # map to local expert ids; non-local slots -> drop bucket e_loc
        se_rel = slot_expert - e_lo
        local = (se_rel >= 0) & (se_rel < e_loc)
        se_l = jnp.where(local, se_rel, e_loc)
        order = jnp.argsort(se_l, stable=True)
        se = se_l[order]
        st = slot_token[order]
        sg = slot_gate[order]
        first = jnp.searchsorted(se, jnp.arange(e_loc, dtype=se.dtype))
        pos = jnp.arange(nl * top_k, dtype=jnp.int32) - first[se].astype(jnp.int32)
        keep = (se < e_loc) & (pos < c_loc)
        dest = jnp.where(keep, se * c_loc + pos, e_loc * c_loc)

        buf = jnp.zeros((e_loc * c_loc + 1, d), x_l.dtype).at[dest].set(x_l[st])
        h = buf[: e_loc * c_loc].reshape(e_loc, c_loc, d)
        act = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", h, wg, preferred_element_type=jnp.float32)
        ) * jnp.einsum("ecd,edf->ecf", h, wu, preferred_element_type=jnp.float32)
        out = jnp.einsum(
            "ecf,efd->ecd", act.astype(x_l.dtype), wd,
            preferred_element_type=jnp.float32,
        ).astype(x_l.dtype)

        out_flat = out.reshape(e_loc * c_loc, d)
        slot_out = out_flat[jnp.minimum(dest, e_loc * c_loc - 1)]
        slot_out = jnp.where(keep[:, None], slot_out, 0.0) * sg[:, None].astype(x_l.dtype)
        y = jnp.zeros((nl, d), x_l.dtype).at[st].add(slot_out)
        y = jax.lax.psum(y, model_axis)

        # telemetry — Switch aux needs the GLOBAL f_e·p_e product: sync the
        # per-shard stats over the batch axes before multiplying (the mean
        # of per-shard products is a different, biased quantity)
        top1 = gate_idx[:, 0]
        f_e = jnp.zeros((e,), jnp.float32).at[top1].add(1.0) / nl
        p_e = jnp.mean(probs, axis=0)
        for a in batch_axes:
            f_e = jax.lax.pmean(f_e, a)
            p_e = jax.lax.pmean(p_e, a)
        aux = e * jnp.sum(f_e * p_e)
        kept = jax.lax.psum(jnp.sum(keep.astype(jnp.float32)), model_axis)
        dropped = 1.0 - kept / (nl * top_k)
        for a in batch_axes:
            dropped = jax.lax.pmean(dropped, a)
        return y, aux, dropped

    y, aux, dropped = compat.shard_map(
        local_fn,
        in_specs=(
            P(bspec, None),
            P(None, None),
            P(model_axis, None, None),
            P(model_axis, None, None),
            P(model_axis, None, None),
        ),
        out_specs=(P(bspec, None), P(), P()),
    )(x, router_w, w_gate, w_up, w_down)
    return MoEOut(y=y, aux_loss=aux, dropped_frac=dropped)


def moe_ffn(
    x: jax.Array,
    router_w: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    norm_topk: bool = True,
) -> MoEOut:
    """x (N, d); router_w (d, E); w_gate/w_up (E, d, f); w_down (E, f, d)."""
    n, d = x.shape
    e = router_w.shape[1]
    c = capacity(n, e, top_k, capacity_factor)

    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)   # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)               # (N, K)
    if norm_topk:
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
        )

    # ---- slot flattening & stable sort by expert
    slot_expert = gate_idx.reshape(-1)                               # (N·K,)
    slot_token = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[:, None], (n, top_k)
    ).reshape(-1)
    slot_gate = gate_vals.reshape(-1)
    order = jnp.argsort(slot_expert, stable=True)
    se = slot_expert[order]
    st = slot_token[order]
    sg = slot_gate[order]

    # ---- position within expert group; drop beyond capacity
    first = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))      # (E,)
    pos = jnp.arange(n * top_k, dtype=jnp.int32) - first[se].astype(jnp.int32)
    keep = pos < c
    dest = jnp.where(keep, se.astype(jnp.int32) * c + pos, e * c)    # sink row

    # ---- dispatch: scatter tokens into the expert-major buffer
    from repro.distributed.sharding import shard_hint

    buf = jnp.zeros((e * c + 1, d), x.dtype).at[dest].set(x[st])
    h = shard_hint(buf[: e * c].reshape(e, c, d), "moe_experts")

    # ---- grouped GEMM (per-expert SwiGLU), expert-parallel over 'model'
    act = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", h, w_gate, preferred_element_type=jnp.float32)
    ) * jnp.einsum("ecd,edf->ecf", h, w_up, preferred_element_type=jnp.float32)
    out = jnp.einsum(
        "ecf,efd->ecd", act.astype(x.dtype), w_down,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out = shard_hint(out, "moe_experts")

    # ---- combine: gather expert outputs back to slots, weighted scatter-add
    out_flat = out.reshape(e * c, d)
    slot_out = out_flat[jnp.minimum(dest, e * c - 1)]
    slot_out = jnp.where(keep[:, None], slot_out, 0.0) * sg[:, None].astype(x.dtype)
    y = jnp.zeros((n, d), x.dtype).at[st].add(slot_out)

    # ---- aux losses / telemetry
    # Switch load balance: E * Σ_e (frac tokens routed to e) · (mean prob e)
    top1 = gate_idx[:, 0]
    f_e = jnp.zeros((e,), jnp.float32).at[top1].add(1.0) / n
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (n * top_k)
    return MoEOut(y=y, aux_loss=aux, dropped_frac=dropped)
