"""Fault-tolerant checkpointing (DESIGN.md §5).

Design points for thousand-node fleets:

* **Atomicity** — a checkpoint is written to ``step_N.tmp-<nonce>`` and
  ``os.replace``d into place; a crash mid-write can never leave a readable
  half checkpoint, and restore_latest only ever sees complete ones.
* **Mesh-agnostic restore (elastic scaling)** — arrays are saved as full
  (unsharded) logical arrays plus a separately-stored PartitionSpec tree.
  Restore reshards onto the *current* mesh: a 512-chip run restores onto
  256 chips and vice versa.  Nothing in the file depends on device count.
* **Async save** — ``save_async`` snapshots to host memory synchronously
  (cheap) and writes to disk on a worker thread, so the train loop only
  blocks for the device→host copy, not the filesystem.
* **Keep-last-N GC** with never-deleting the newest complete checkpoint.
* **Resumable data** — the loader state is an integer step (see
  repro.data.loader), stored in the same file: restart = restore + regen.

Format: a single msgpack-framed binary per checkpoint (stdlib-only:
header json + raw little-endian array blobs), no pickle.
"""
from __future__ import annotations

import io
import json
import os
import pathlib
import struct
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax

_MAGIC = b"RPRCKPT1"


# ------------------------------------------------------------- serialization
def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        keyed[key] = leaf
    return keyed, treedef


def save_pytree(path: str | os.PathLike, tree: Any, meta: Optional[Dict] = None):
    """Atomic single-file pytree save (host-gathers sharded arrays)."""
    keyed, _ = _flatten_with_paths(tree)
    header = {"meta": meta or {}, "arrays": {}}
    blobs = []
    offset = 0
    for key, leaf in keyed.items():
        arr = np.asarray(jax.device_get(leaf))
        blob = arr.tobytes()
        header["arrays"][key] = {
            "dtype": arr.dtype.str, "shape": list(arr.shape),
            "offset": offset, "nbytes": len(blob),
        }
        blobs.append(blob)
        offset += len(blob)
    hdr = json.dumps(header).encode()
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + f".tmp-{os.getpid()}-{threading.get_ident()}")
    with open(tmp, "wb") as f:
        f.write(_MAGIC)
        f.write(struct.pack("<Q", len(hdr)))
        f.write(hdr)
        for blob in blobs:
            f.write(blob)
    os.replace(tmp, path)  # atomic publish


def load_pytree(path: str | os.PathLike, like: Any = None) -> Tuple[Any, Dict]:
    """Load a checkpoint.  If ``like`` (a pytree of arrays/SDS) is given the
    stored arrays are restructured to its treedef; else a flat dict is
    returned."""
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != _MAGIC:
            raise ValueError(f"{path}: not a repro checkpoint")
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        base = f.tell()
        arrays = {}
        for key, spec in header["arrays"].items():
            f.seek(base + spec["offset"])
            buf = f.read(spec["nbytes"])
            arrays[key] = np.frombuffer(buf, dtype=np.dtype(spec["dtype"])).reshape(
                spec["shape"]
            )
    if like is None:
        return arrays, header["meta"]
    keyed, treedef = _flatten_with_paths(like)
    leaves = []
    for key, leaf in keyed.items():
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        got = arrays[key]
        want_shape = tuple(leaf.shape)
        if tuple(got.shape) != want_shape:
            raise ValueError(f"{key}: shape {got.shape} != expected {want_shape}")
        leaves.append(got.astype(leaf.dtype) if hasattr(leaf, "dtype") else got)
    flat, treedef2 = jax.tree_util.tree_flatten(like)
    tree = jax.tree_util.tree_unflatten(treedef2, leaves)
    return tree, header["meta"]


# ----------------------------------------------------------------- manager
class CheckpointManager:
    """Step-indexed checkpoint directory with keep-N GC and async save."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._worker: Optional[threading.Thread] = None

    def _path(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:010d}.ckpt"

    def steps(self):
        out = []
        for p in self.dir.glob("step_*.ckpt"):
            try:
                out.append(int(p.stem.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None):
        meta = dict(meta or {})
        meta["step"] = step
        meta["time"] = time.time()
        save_pytree(self._path(step), tree, meta)
        self._gc()

    def save_async(self, step: int, tree: Any, meta: Optional[Dict] = None):
        """Snapshot to host now; write on a background thread."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._worker = threading.Thread(
            target=self.save, args=(step, host, meta), daemon=True
        )
        self._worker.start()

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def restore(self, like: Any, step: Optional[int] = None):
        steps = self.steps()
        if not steps:
            return None, None
        step = steps[-1] if step is None else step
        return load_pytree(self._path(step), like)

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            try:
                self._path(s).unlink()
            except FileNotFoundError:
                pass


def restore_latest(directory, like):
    return CheckpointManager(directory).restore(like)
