from repro.checkpoint.manager import CheckpointManager, restore_latest, save_pytree, load_pytree

__all__ = ["CheckpointManager", "restore_latest", "save_pytree", "load_pytree"]
