"""Candidate-sharded distributed retrieval (ISSUE 2 tentpole, engine-aware
since ISSUE 3).

Once the catalog exceeds one chip's HBM, the fused retrieve has to run
over a candidate-sharded mesh: each shard holds only its slice of the
(k-sparse) codes + norms — the compression is exactly what makes the
shards cheap — scores it with the streaming score+select primitive, and
the per-shard top-n sets are merged with one small all-gather
(``core.retrieval.sharded_top_n``).  A ``QuantizedIndex`` (ISSUE 4)
shards exactly the same way, except the arrays living on each shard are
the int8/int16 compound-compressed ones (+ fp32 scales) — the per-shard
HBM footprint keeps the full compression ratio, and the per-shard
retrieve runs the quantized kernel generation (VMEM dequant).

The serving engine (``repro.serving.engine.RetrievalEngine``) enters
through ``distributed_retrieve_prepped``: the query is encoded and
prepped ONCE per request outside the shard_map, then replicated into it.
For sparse mode the replicated payload is the (Q, k) **codes** — each
shard runs the sparse-query retrieve (``fused_retrieve_sparse_q`` /
``retrieve_sparse_q_ref``) over its slice, so the dense (Q, h) query
panel never exists outside VMEM, and the replication traffic drops by
h/(2k)×.  Reconstructed mode replicates the dense z = W_decᵀ(W_dec s_q)
computed in the engine's query-prep (dense by construction).

Equivalence contract (gated by tests/test_distributed_retrieval.py and
tests/test_serving_engine.py): ``distributed_retrieve`` is *bit-identical*
to single-device ``core.retrieve()`` — scores AND ids, ties included:

  * per-candidate scores are row-local f32 ops on the same inputs, so
    sharding the candidate axis cannot reassociate them;
  * any candidate cut from its shard's local top-n is preceded (in the
    global score-then-lowest-id order) by n candidates of the same shard,
    so it can never be in the global top-n — local top-n loses nothing;
  * the all-gather concatenates shards in ascending shard order and each
    shard's list is score-desc / ties-id-asc, so the final ``lax.top_k``
    resolves ties to the lowest global id — exactly the single-device rule.

Ragged catalogs (N not divisible by the shard count) are zero-padded on
the candidate axis; padding rows are masked to -inf *by global id* inside
the shard-local epilogue.  ``n`` larger than a shard's slice is handled by
returning the whole slice and padding the local result to n with -inf.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.compat import P
from repro.core import sae
from repro.core.quantized_codes import QuantizedCodes
from repro.core.types import SparseCodes
from repro.errors import ShardFailureError

CAND_AXIS = "cand"


def mesh_shard_count(mesh, axis_name: str = CAND_AXIS) -> int:
    sizes = dict(mesh.shape)
    if axis_name not in sizes:
        raise ValueError(
            f"mesh has no {axis_name!r} axis (axes: {tuple(sizes)})"
        )
    return int(sizes[axis_name])


def distributed_retrieve_prepped(
    index,
    pq,
    n: int,
    *,
    mesh,
    axis_name: str = CAND_AXIS,
    use_fused: bool,
    inv_norms: Optional[jax.Array] = None,
    precision: str = "exact",
) -> tuple[jax.Array, jax.Array]:
    """Serve one prepped query batch (``serving.engine.PreppedQuery``) over
    a candidate-sharded mesh.  The prepped representation — sparse codes or
    dense z — is replicated; the index shards along ``mesh[axis_name]``.
    Per shard, the matching streaming retrieve produces a local top-n in
    the norm-folded space; the merge is one all-gather of n·n_shards
    (score, id) pairs per query.

    ``precision="int8"`` runs generation 5's approximate int8 scoring per
    shard (QuantizedIndex only).  Sharding stays exactly transparent even
    on the approximate path: the query quantizes per ROW over the full h
    (replicated, so every shard derives the identical int8 panel) and
    per-candidate scores are shard-local int32/f32 ops on the same
    inputs — sharded int8 serving is bit-identical to unsharded int8
    serving, it is only int8-vs-exact that is approximate.
    """
    from repro.core.retrieval import NORM_EPS, sharded_top_n
    from repro.serving.engine import (
        check_precision, mode_inv_norms, select_retrieve_fn,
    )

    check_precision(index, precision)
    int8_scoring = precision == "int8"

    N = index.codes.n
    if n > N:
        raise ValueError(f"top-n {n} exceeds candidate count {N}")
    if inv_norms is None:
        inv_norms = mode_inv_norms(index, "sparse" if pq.is_sparse
                                   else "reconstructed")
    n_shards = mesh_shard_count(mesh, axis_name)

    squeeze = pq.norm.ndim == 0
    h = index.codes.dim

    # a QuantizedIndex shards its quantized arrays AS-IS along the 'cand'
    # axis — each shard holds int8 values + int16/int32 indices + scales,
    # so the per-shard HBM cost keeps the compound-compression ratio
    quantized = isinstance(index.codes, QuantizedCodes)
    if quantized:
        values, indices = index.codes.q_values, index.codes.indices
        scales = index.codes.scales
    else:
        values, indices = index.codes.values, index.codes.indices
        scales = None
    pad = (-N) % n_shards
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        inv_norms = jnp.pad(inv_norms, (0, pad))
        if quantized:
            scales = jnp.pad(scales, (0, pad))
    n_loc_cand = (N + pad) // n_shards
    # widen the local selection by `pad`: the zero rows padded onto the last
    # shard score exactly 0 (0-values · anything, times inv_norm 0) and may
    # occupy up to `pad` local top slots ahead of real negative-score
    # candidates; selecting n+pad locally and masking them out afterwards
    # (by global id) keeps every real local top-n candidate
    n_loc = min(n + pad, n_loc_cand)

    def _finish_local(lv, li):
        shard = jax.lax.axis_index(axis_name).astype(jnp.int32)
        gid = li + shard * n_loc_cand
        # global-padding rows live at the tail of the last shard: mask by id
        lv = jnp.where(gid < N, lv, -jnp.inf)
        if n_loc < n:  # n exceeds this shard's slice: pad the local result
            lv = jnp.pad(lv, ((0, 0), (0, n - n_loc)),
                         constant_values=-jnp.inf)
            gid = jnp.pad(gid, ((0, 0), (0, n - n_loc)), constant_values=N)
        return sharded_top_n(lv, gid, n, axis_name=axis_name)

    # candidate-side shard_map operands: the index arrays in their serving
    # dtypes (quantized: + per-row scales between indices and inv norms,
    # matching the quantized kernel signatures), all sharded along 'cand'
    cand_args = (values, indices) + ((scales,) if quantized else ())
    cand_args += (inv_norms,)
    cand_specs = (P(axis_name, None),) * 2
    cand_specs += (P(axis_name),) * (2 if quantized else 1)

    fn = select_retrieve_fn(
        sparse_query=pq.is_sparse, quantized=quantized,
        int8_scoring=int8_scoring, use_fused=use_fused,
    )
    if pq.is_sparse:
        qv = pq.values[None] if squeeze else pq.values
        qi = pq.indices[None] if squeeze else pq.indices

        def local(*args):
            *cand_l, qv_r, qi_r = args
            lv, li = fn(*cand_l, qv_r, qi_r, h, n=n_loc)
            return _finish_local(lv, li)

        q_args = (qv, qi)
        q_specs = (P(None, None), P(None, None))
    else:
        qd = pq.dense[None] if squeeze else pq.dense

        def local(*args):
            *cand_l, qd_r = args
            lv, li = fn(*cand_l, qd_r, n=n_loc)
            return _finish_local(lv, li)

        q_args = (qd,)
        q_specs = (P(None, None),)

    with compat.set_mesh(mesh):
        vals, ids = compat.shard_map(
            local,
            mesh=mesh,
            in_specs=cand_specs + q_specs,
            out_specs=(P(None, None), P(None, None)),
            # outputs are replicated via the all_gather merge, which the
            # static replication checker cannot infer
            check=False,
        )(*cand_args, *q_args)
    norm = pq.norm[None] if squeeze else pq.norm
    scores = vals / jnp.maximum(norm[..., None], NORM_EPS)
    if squeeze:
        scores, ids = scores[0], ids[0]
    return scores, ids


def shard_slices(N: int, n_shards: int) -> list[tuple[int, int]]:
    """Global candidate-row range ``[start, stop)`` owned by each shard.

    Matches ``distributed_retrieve_prepped``'s padded layout exactly:
    rows are zero-padded to a multiple of ``n_shards`` and dealt out in
    equal contiguous slices, so the last shard's slice may be short (the
    padding rows belong to no shard).  The recovery path uses this to
    know which global ids died with a shard.
    """
    pad = (-N) % n_shards
    n_loc_cand = (N + pad) // n_shards
    return [
        (s * n_loc_cand, min((s + 1) * n_loc_cand, N))
        for s in range(n_shards)
    ]


def partial_retrieve_prepped(
    index,
    pq,
    n: int,
    *,
    n_shards: int,
    dead_shards,
    use_fused: bool,
    inv_norms: Optional[jax.Array] = None,
    precision: str = "exact",
) -> tuple[jax.Array, jax.Array, float]:
    """Degraded-mode retrieve over the shards that survived (ISSUE 6).

    When retries exhaust and ``dead_shards`` still won't answer, serving
    a partial result beats serving nothing: gather the surviving shards'
    candidate rows (per ``shard_slices``' layout), run the ordinary
    single-device streaming retrieve over them, and remap local ids back
    to global candidate ids.  Returns ``(scores, ids, coverage)`` where
    ``coverage`` = surviving candidates / N — the caller's bound on
    achieved recall: results are bit-identical to an exact retrieve over
    the survivor rows, so recall@n vs the full index is lower-bounded by
    the fraction of the true top-n that lived on surviving shards (in
    expectation ≈ coverage under a uniform catalog).

    If ``n`` exceeds the surviving candidate count the result is padded
    with ``(-inf, N)`` rows, mirroring the sharded path's
    n-exceeds-slice convention.  All shards dead raises
    ``ShardFailureError`` — there is nothing left to serve from.
    """
    from repro.core.retrieval import take_index_rows
    from repro.serving.engine import mode_inv_norms, retrieve_prepped

    N = index.codes.n
    dead = frozenset(dead_shards)
    survivors = [s for s in range(n_shards) if s not in dead]
    if not survivors:
        raise ShardFailureError(
            f"all {n_shards} candidate shards failed; no rows left to "
            "serve a partial result from"
        )
    if inv_norms is None:
        inv_norms = mode_inv_norms(index, "sparse" if pq.is_sparse
                                   else "reconstructed")

    slices = shard_slices(N, n_shards)
    rows = jnp.concatenate([
        jnp.arange(start, stop, dtype=jnp.int32)
        for start, stop in (slices[s] for s in survivors)
    ])
    n_live = int(rows.shape[0])

    # sub-index over the survivor rows (checksum-less: integrity was
    # verified on the full index) — same gather as two-stage's stage 2
    live_index = take_index_rows(index, rows)

    n_local = min(n, n_live)
    scores, ids = retrieve_prepped(
        live_index, pq, n_local,
        use_fused=use_fused, inv_norms=jnp.take(inv_norms, rows, axis=0),
        precision=precision,
    )
    gids = rows[ids]
    if n_local < n:
        pad_width = [(0, 0)] * (scores.ndim - 1) + [(0, n - n_local)]
        scores = jnp.pad(scores, pad_width, constant_values=-jnp.inf)
        gids = jnp.pad(gids, pad_width, constant_values=N)
    return scores, gids, n_live / N


def distributed_retrieve(
    index,
    q: SparseCodes,
    n: int,
    mode: str = "sparse",
    params: Optional[sae.Params] = None,
    *,
    mesh,
    axis_name: str = CAND_AXIS,
    use_kernel=None,
    precision: str = "exact",
) -> tuple[jax.Array, jax.Array]:
    """Top-n (cosine scores, global candidate ids) over a candidate-sharded
    mesh.  Same signature/semantics as ``core.retrieve`` plus ``mesh``;
    normally reached via ``core.retrieve(..., mesh=...)`` or a
    ``RetrievalEngine`` constructed with a mesh.  Preps the query once
    (engine query-prep) and serves through
    ``distributed_retrieve_prepped``.
    """
    from repro.core.retrieval import kernel_path
    from repro.serving.engine import mode_inv_norms, prep_query

    use_fused = kernel_path("auto" if use_kernel is None else use_kernel)
    pq = prep_query(index, q, mode, params)
    return distributed_retrieve_prepped(
        index, pq, n,
        mesh=mesh, axis_name=axis_name, use_fused=use_fused,
        inv_norms=mode_inv_norms(index, mode), precision=precision,
    )
