"""Sharding rule tables (DESIGN.md §5).

Axis semantics:
  pod   — outermost data parallelism (crosses DCI; gradient all-reduce only)
  data  — data parallelism + FSDP (params/opt-state sharded over it)
  model — tensor / expert / vocab parallelism

``shard_hint(x, kind)`` lets pure model code request activation shardings
without importing mesh machinery: inside ``axis_rules(...)`` context it
applies ``with_sharding_constraint``; outside (CPU unit tests) it is a
no-op.  GSPMD propagation handles everything else; explicit hints exist for
the places propagation picks badly (found during §Perf iteration).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_RULES: contextvars.ContextVar[Optional["AxisRules"]] = contextvars.ContextVar(
    "axis_rules", default=None
)


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Maps logical activation kinds -> PartitionSpec."""

    batch: tuple = ("pod", "data")    # logical batch axes
    model: str = "model"
    # per-kind specs; None entries mean "leave to propagation"
    kinds: Optional[Dict[str, P]] = None

    def _all_axes(self) -> tuple:
        b = self.batch if isinstance(self.batch, tuple) else (self.batch,)
        return (*b, self.model)

    def spec(self, kind: str) -> Optional[P]:
        defaults = {
            # LM activations: the residual carried between layer groups is
            # SEQUENCE-sharded over the model axis (Megatron-style sequence
            # parallelism).  The layer-scan AD saves this carry per group —
            # and XLA's loop-invariant convert hoisting materializes it
            # twice (bf16 + f32) — so its footprint drives train-step HBM:
            # seq-sharding cut command-r train temps 31.9 -> 5.0 GiB
            # (EXPERIMENTS.md §Perf).  Only training touches this kind;
            # decode's seq dim is 1 and never gets the hint.
            "residual": P(self.batch, self.model, None),
            "residual_batchsharded": P(self.batch, None, None),
            "logits": P(self.batch, self.model),
            # attention internals: full-head tensors shard heads over model;
            # small-kv (hkv < 16) tensors replicate heads (DESIGN.md §5)
            "attn_q": P(self.batch, None, self.model, None),
            "attn_kv_small": P(self.batch, None, None, None),
            "attn_kv_decode": P(self.batch, None, None, self.model),
            # MoE: expert-major buffers shard experts over model
            "moe_experts": P(self.model, None, None),
            "tokens_2d": P(self.batch, None),
            # GNN: per-node tensors shard nodes over (pod, data)
            "gnn_feat": P(self.batch, None, None),
            "gnn_out": P(self.batch, None),
            # retrieval: candidate-major tensors shard over every axis
            "cand_rows": P(self._all_axes(), None),
            "cand_scores": P(None, self._all_axes()),
            # generic
            "batch_only": P(self.batch),
            "tokens": P(self.batch, None),
        }
        if self.kinds and kind in self.kinds:
            return self.kinds[kind]
        return defaults.get(kind)


@contextlib.contextmanager
def axis_rules(rules: AxisRules):
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def current_rules() -> Optional[AxisRules]:
    return _RULES.get()


def shard_hint(x: jax.Array, kind: str) -> jax.Array:
    rules = _RULES.get()
    if rules is None:
        return x
    spec = rules.spec(kind)
    if spec is None:
        return x
    # bare-PartitionSpec constraints need an ambient mesh to resolve against
    # (jax.set_mesh on new jax, the Mesh context manager on 0.4.x — both via
    # repro.compat.set_mesh); outside one the hint is a no-op, same as
    # outside axis_rules
    from repro import compat

    if compat.current_mesh() is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ------------------------------------------------------------------ helpers
def tree_replicated(tree: Any) -> Any:
    return jax.tree.map(lambda _: P(), tree)


def opt_state_pspecs(param_specs: Any, opt_state: Any) -> Any:
    """AdamState(step, mu, nu) with moments sharded like their params."""
    from repro.optim import AdamState

    return AdamState(step=P(), mu=param_specs, nu=jax.tree.map(lambda s: s, param_specs))


# ------------------------------------------------------------- LM transformer
def _lm_block_pspecs(block: Dict[str, Any]) -> Dict[str, Any]:
    """Per-sub-layer stacked params (leading n_groups axis = None)."""
    table = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "wq": P(None, "data", "model"),
        "wk": P(None, "data", "model"),
        "wv": P(None, "data", "model"),
        "wo": P(None, "model", "data"),
        "q_norm": P(None, None),
        "k_norm": P(None, None),
        "w_gate": P(None, "data", "model"),
        "w_up": P(None, "data", "model"),
        "w_down": P(None, "model", "data"),
        "router": P(None, "data", None),
        "moe_gate": P(None, "model", "data", None),
        "moe_up": P(None, "model", "data", None),
        "moe_down": P(None, "model", None, "data"),
    }
    return {k: table[k] for k in block}


def lm_param_pspecs(params: Dict[str, Any]) -> Dict[str, Any]:
    tied = "unembed" not in params
    specs: Dict[str, Any] = {
        # untied: embed d_model-sharded (local token gathers), unembed
        # vocab-sharded (TP logits).  Tied: embed must be VOCAB-sharded so
        # its transpose yields vocab-sharded logits — otherwise the loss
        # matmul contracts over a sharded d and replicates (B, V) logits.
        "embed": P("model", None) if tied else P(None, "model"),
        "ln_f": P(None),
        "blocks": [_lm_block_pspecs(b) for b in params["blocks"]],
    }
    if not tied:
        specs["unembed"] = P(None, "model")
    return specs


def lm_batch_pspecs(batch: Dict[str, Any]) -> Dict[str, Any]:
    return {k: P(("pod", "data"), None) for k in batch}


def cache_pspec(n_kv_heads: int, model_size: int = 16) -> P:
    """KV cache (n_groups, B, S, Hkv, hd): shard kv-heads over model when
    divisible, else shard head_dim (DESIGN.md §5, decode path)."""
    if n_kv_heads % model_size == 0:
        return P(None, ("pod", "data"), None, "model", None)
    return P(None, ("pod", "data"), None, None, "model")


# ------------------------------------------------------------------ SAE
def sae_param_pspecs(params: Dict[str, Any]) -> Dict[str, Any]:
    """CompresSAE: h is the sharded axis on both matrices (DESIGN.md §5)."""
    return {
        "w_enc": P(None, "model"),
        "b_enc": P("model"),
        "w_dec": P("model", None),
    }


# ------------------------------------------------------------------ recsys
MESH_DIV = 16  # production axis size both meshes share (data=model=16)


def recsys_param_pspecs(params: Any) -> Any:
    """Embedding tables: column-shard (embed_dim over model) when the dim
    divides the axis, else row-shard over model (vocab padded to ×16 in the
    configs).  MLP towers: FSDP over data on whichever dim divides.
    Small/odd tensors replicate."""

    def spec_for(path: tuple, leaf: Any) -> P:
        keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        name = ".".join(str(k) for k in keys if k is not None)
        is_table = (
            "tables" in name or name.startswith("items")
            or name.startswith("pos") or "lin" in name
        )
        if leaf.ndim == 2 and is_table:
            if leaf.shape[-1] % MESH_DIV == 0:
                if leaf.shape[0] % MESH_DIV == 0:
                    return P("data", "model")  # 2-D sharded (padded vocab)
                return P(None, "model")      # (V, dim): column-sharded
            if leaf.shape[0] % MESH_DIV == 0:
                return P("model", None)      # row-sharded (padded vocab)
            return P()
        if leaf.ndim == 2:                   # MLP / attention weights
            if leaf.shape[0] % MESH_DIV == 0:
                return P("data", None)
            if leaf.shape[1] % MESH_DIV == 0:
                return P(None, "data")
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params)


# ------------------------------------------------------------------ GNN
def gnn_batch_pspecs(batch: Dict[str, Any]) -> Dict[str, Any]:
    """Edges sharded over the full (pod·data·model) device set; node arrays
    sharded over (pod, data) where the leading dim is nodes."""
    specs = {}
    for k, v in batch.items():
        if k == "edge_index":
            specs[k] = P(None, ("pod", "data", "model"))
        elif k == "edge_mask":
            specs[k] = P(("pod", "data", "model"))
        elif k in ("node_feat", "positions"):
            specs[k] = P(("pod", "data"), None)
        elif k in ("labels", "graph_ids", "nodes", "seed_mask"):
            specs[k] = P(("pod", "data"))
        else:
            specs[k] = P()
    return specs
