from repro.distributed.sharding import (
    AxisRules,
    axis_rules,
    current_rules,
    shard_hint,
    lm_param_pspecs,
    lm_batch_pspecs,
    cache_pspec,
    sae_param_pspecs,
    recsys_param_pspecs,
    tree_replicated,
    opt_state_pspecs,
)

from repro.distributed.retrieve import distributed_retrieve

__all__ = [
    "AxisRules", "axis_rules", "current_rules", "shard_hint",
    "lm_param_pspecs", "lm_batch_pspecs", "cache_pspec", "sae_param_pspecs",
    "recsys_param_pspecs", "tree_replicated", "opt_state_pspecs",
    "distributed_retrieve",
]
