"""Deterministic, resumable, sharded data loader.

Fault-tolerance contract: the batch served at global step t is a pure
function of (seed, t, shard_id, num_shards).  A job restarted from a step-t
checkpoint — possibly on a *different* number of hosts — regenerates exactly
the batches it would have seen, because nothing is consumed statefully.
This is the standard deterministic-input-pipeline design for large fleets
(cf. MaxText/grain): state is O(1) (an integer), not a stream position.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, NamedTuple

import jax
import numpy as np


class LoaderState(NamedTuple):
    step: int


@dataclasses.dataclass
class ShardedLoader:
    """Wraps a (key, shard_id, num_shards) -> batch generator function."""

    generate: Callable[[jax.Array, int, int], Dict[str, jax.Array]]
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), self.shard_id
        )
        return self.generate(key, self.shard_id, self.num_shards)

    def iterate(self, state: LoaderState) -> Iterator[tuple[LoaderState, Dict]]:
        step = state.step
        while True:
            yield LoaderState(step + 1), self.batch_at(step)
            step += 1


def host_shard_info() -> tuple[int, int]:
    """(shard_id, num_shards) for the current process (1 process on CPU)."""
    return jax.process_index(), jax.process_count()
