"""Synthetic data generators.

The paper's corpus (O(10^8) media items embedded with Nomic) is proprietary.
``clustered_embeddings`` generates a documented stand-in with the three
properties that make embedding compression non-trivial and retrieval
measurable:

  1. cluster structure (items concentrate around topic centroids — what
     retrieval must preserve),
  2. decaying spectrum (energy concentrated in leading dims, matching text
     embeddings and making prefix-truncation a *fair* Matryoshka analogue),
  3. heavy-tailed cluster sizes (long-tail catalogs, paper §1).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def clustered_embeddings(
    key: jax.Array,
    n: int,
    d: int = 768,
    n_clusters: int = 64,
    spectrum_decay: float = 0.65,
    noise: float = 0.35,
    zipf_a: float = 1.2,
) -> jax.Array:
    """(n, d) unit-norm embeddings with clustered, spectrally-decaying structure."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # per-dim scale ~ decaying spectrum: var_i = decay^(i / (d/8))
    spectrum = spectrum_decay ** (jnp.arange(d) / (d / 8.0))
    centroids = jax.random.normal(k1, (n_clusters, d)) * spectrum
    # heavy-tailed cluster assignment (approximate Zipf via exponentiated uniforms)
    u = jax.random.uniform(k2, (n,), minval=1e-6, maxval=1.0)
    assign = jnp.clip((u ** (-1.0 / zipf_a) - 1.0), 0, n_clusters - 1).astype(jnp.int32)
    base = centroids[assign]
    x = base + noise * jax.random.normal(k3, (n, d)) * spectrum
    # small per-item scale jitter so ‖x‖ is informative (paper normalizes it away)
    scale = jnp.exp(0.1 * jax.random.normal(k4, (n, 1)))
    x = x * scale
    return x


def token_batch(key: jax.Array, batch: int, seq: int, vocab: int):
    """LM training batch: tokens + next-token labels."""
    toks = jax.random.randint(key, (batch, seq + 1), 0, vocab, dtype=jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def criteo_like_batch(
    key: jax.Array, batch: int, n_dense: int, vocab_sizes: list[int]
):
    """DLRM-style batch: dense features, one categorical id per table, label."""
    kd, kc, kl = jax.random.split(key, 3)
    dense = jax.random.normal(kd, (batch, n_dense))
    maxv = np.array(vocab_sizes, dtype=np.int64)
    u = jax.random.uniform(kc, (batch, len(vocab_sizes)))
    cat = (u * jnp.asarray(maxv, dtype=jnp.float32)).astype(jnp.int32)
    label = jax.random.bernoulli(kl, 0.25, (batch,)).astype(jnp.float32)
    return {"dense": dense, "cat": cat, "label": label}


def din_batch(key: jax.Array, batch: int, seq_len: int, n_items: int):
    """DIN batch: behavior history (padded), target item, click label."""
    kh, kt, kl, kp = jax.random.split(key, 4)
    hist = jax.random.randint(kh, (batch, seq_len), 0, n_items, dtype=jnp.int32)
    # random history lengths: pad tail with -1
    lens = jax.random.randint(kp, (batch, 1), seq_len // 4, seq_len + 1)
    pos = jnp.arange(seq_len)[None, :]
    hist = jnp.where(pos < lens, hist, -1)
    target = jax.random.randint(kt, (batch,), 0, n_items, dtype=jnp.int32)
    label = jax.random.bernoulli(kl, 0.3, (batch,)).astype(jnp.float32)
    return {"hist": hist, "target": target, "label": label}


def bert4rec_batch(
    key: jax.Array, batch: int, seq_len: int, n_items: int,
    mask_id: int, n_negatives: int, mask_prob: float = 0.2,
):
    """Masked-item-prediction batch: exactly M = ceil(S·mask_prob) masked
    positions per row (static shapes), shared sampled negatives."""
    kh, km, kn = jax.random.split(key, 3)
    m = max(1, int(seq_len * mask_prob))
    hist = jax.random.randint(kh, (batch, seq_len), 0, n_items, dtype=jnp.int32)
    # choose M distinct positions per row
    scores = jax.random.uniform(km, (batch, seq_len))
    _, pos_idx = jax.lax.top_k(scores, m)                    # (B, M)
    pos_idx = pos_idx.astype(jnp.int32)
    labels = jnp.take_along_axis(hist, pos_idx, axis=1)      # (B, M)
    hist = jnp.asarray(hist).at[
        jnp.arange(batch)[:, None], pos_idx
    ].set(mask_id)
    negatives = jax.random.randint(kn, (n_negatives,), 0, n_items, dtype=jnp.int32)
    return {"hist": hist, "masked_positions": pos_idx, "labels": labels,
            "negatives": negatives}


def random_graph(
    seed: int, n_nodes: int, n_edges: int, d_feat: int, with_positions: bool = True
):
    """Host-side random graph: edge_index (2, E) int32, features, positions.

    numpy (not jax) — graph construction is a data-pipeline step.
    Guarantees no self-loops; degree distribution ~ uniform.
    """
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    off = rng.integers(1, max(n_nodes, 2), size=n_edges, dtype=np.int64)
    dst = ((src.astype(np.int64) + off) % n_nodes).astype(np.int32)
    feats = rng.standard_normal((n_nodes, d_feat), dtype=np.float32)
    out = {
        "edge_index": np.stack([src, dst]),
        "node_feat": feats,
    }
    if with_positions:
        out["positions"] = rng.standard_normal((n_nodes, 3), dtype=np.float32) * 3.0
    return out
