from repro.data.synthetic import (
    clustered_embeddings,
    criteo_like_batch,
    random_graph,
    token_batch,
)
from repro.data.loader import ShardedLoader, LoaderState

__all__ = [
    "clustered_embeddings",
    "criteo_like_batch",
    "random_graph",
    "token_batch",
    "ShardedLoader",
    "LoaderState",
]
