from repro.data.synthetic import (
    clustered_embeddings,
    criteo_like_batch,
    random_graph,
    token_batch,
)
from repro.data.loader import ShardedLoader, LoaderState
from repro.data.sampler import ZipfianQueryStream

__all__ = [
    "clustered_embeddings",
    "criteo_like_batch",
    "random_graph",
    "token_batch",
    "ShardedLoader",
    "ZipfianQueryStream",
    "LoaderState",
]
