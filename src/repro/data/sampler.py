"""Host-side numpy samplers: graph fanout sampling for GNN training and
traffic-shaped query sampling for serving loadtests.

* Fanout neighbor sampler (GraphSAGE-style): builds a CSR adjacency
  once, then samples fixed-fanout k-hop neighborhoods producing
  *static-shaped* padded arrays (seed nodes → hop-1 fanout f1 → hop-2
  fanout f2 …), which is what the jitted train step consumes.  Padding
  uses node -1 / edge mask conventions.
* ``ZipfianQueryStream`` (ISSUE 10): replays a Zipf-popular user
  population as retrieval queries — the arrival-content model the
  microbatching loadtest (``repro.launch.loadtest``) drives offered
  load with.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np


@dataclasses.dataclass
class ZipfianQueryStream:
    """Deterministic traffic-shaped query replay over a user population.

    ``users`` is a (U, d) matrix of per-user preference embeddings (the
    loadtest builds it with ``data.synthetic.clustered_embeddings`` so
    queries share the catalog's cluster structure).  Request frequencies
    follow the same bounded-Zipf construction ``clustered_embeddings``
    uses for cluster sizes — rank r is drawn with the exponentiated
    -uniform trick ``clip(u^(-1/a) - 1, 0, U-1)`` — so a few head users
    dominate the stream and the long tail trickles, which is exactly the
    arrival pattern that makes microbatch coalescing measurable.  Each
    request is its user's embedding plus per-request Gaussian jitter
    (session context), so repeated head-user hits are near-duplicate but
    not identical queries.

    Host-side numpy and fully seeded: two streams with the same
    ``(users, zipf_a, jitter, seed)`` emit identical request sequences —
    the loadtest's determinism contract.
    """

    users: np.ndarray            # (U, d) preference embeddings
    zipf_a: float = 1.1
    jitter: float = 0.05
    seed: int = 0

    def __post_init__(self):
        self.users = np.asarray(self.users, dtype=np.float32)
        if self.users.ndim != 2 or self.users.shape[0] < 1:
            raise ValueError(
                f"users: expected a (U, d) matrix, got {self.users.shape}"
            )
        if self.zipf_a <= 0:
            raise ValueError(f"zipf_a must be > 0, got {self.zipf_a}")
        self._rng = np.random.default_rng(self.seed)

    def sample(self, count: int) -> tuple[np.ndarray, np.ndarray]:
        """The next ``count`` requests: (user_ids (count,), queries
        (count, d) float32), advancing the stream."""
        n_users = self.users.shape[0]
        u = self._rng.uniform(1e-6, 1.0, size=count)
        ranks = np.clip(
            u ** (-1.0 / self.zipf_a) - 1.0, 0, n_users - 1
        ).astype(np.int64)
        q = self.users[ranks]
        if self.jitter > 0:
            q = q + self.jitter * self._rng.standard_normal(
                q.shape
            ).astype(np.float32)
        return ranks, q.astype(np.float32)


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,) neighbor ids
    n_nodes: int

    @staticmethod
    def from_edge_index(edge_index: np.ndarray, n_nodes: int) -> "CSRGraph":
        src, dst = edge_index
        order = np.argsort(dst, kind="stable")   # incoming-neighbor CSR
        s = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr=indptr, indices=s.astype(np.int32), n_nodes=n_nodes)


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    rng: np.random.Generator,
) -> Dict[str, np.ndarray]:
    """Sample a fixed-fanout neighborhood.

    Returns a padded, static-shaped subgraph:
      nodes      (n_sub,)  original node ids (-1 = padding)
      edge_index (2, e_sub) edges in *subgraph-local* indices; padded edges
                 point at node 0 with mask 0
      edge_mask  (e_sub,) 1.0 for real edges
      seed_mask  (n_sub,) 1 for seed nodes (positions 0..len(seeds)-1)
    where n_sub = B·(1 + f1 + f1·f2 + …) and e_sub = B·(f1 + f1·f2 + …).
    """
    layers = [seeds.astype(np.int32)]
    edges_src: list[np.ndarray] = []
    edges_dst: list[np.ndarray] = []
    # subgraph-local ids are assigned positionally: seeds first, then each
    # hop's sampled neighbors in order
    offset = len(seeds)
    frontier_local = np.arange(len(seeds), dtype=np.int32)
    for f in fanouts:
        frontier = layers[-1]
        nbrs = np.full((len(frontier), f), -1, dtype=np.int32)
        for i, node in enumerate(frontier):
            if node < 0:
                continue
            lo, hi = graph.indptr[node], graph.indptr[node + 1]
            deg = hi - lo
            if deg == 0:
                continue
            pick = rng.integers(0, deg, size=f)
            nbrs[i] = graph.indices[lo + pick]
        flat = nbrs.reshape(-1)
        local_ids = offset + np.arange(flat.size, dtype=np.int32)
        # edge: sampled neighbor (src) -> frontier node (dst)
        edges_src.append(local_ids)
        edges_dst.append(np.repeat(frontier_local, f))
        layers.append(flat)
        frontier_local = local_ids
        offset += flat.size

    nodes = np.concatenate(layers)
    src = np.concatenate(edges_src)
    dst = np.concatenate(edges_dst)
    mask = (nodes[src] >= 0).astype(np.float32)
    src = np.where(nodes[src] >= 0, src, 0)
    seed_mask = np.zeros(nodes.size, dtype=np.int32)
    seed_mask[: len(seeds)] = 1
    return {
        "nodes": nodes,
        "edge_index": np.stack([src, dst]).astype(np.int32),
        "edge_mask": mask,
        "seed_mask": seed_mask,
    }


def partition_edges_by_dst(
    edge_index: np.ndarray,
    n_nodes: int,
    n_node_shards: int,
    n_splits: int,
    edge_mask: np.ndarray | None = None,
) -> Dict[str, np.ndarray]:
    """Reorder + pad edges for nequip_forward_sharded's contract.

    Device (i, j) of a (node_shards × splits) edge grid must only hold
    edges whose dst lies in node shard i.  This groups edges by dst shard,
    pads every group to the max group size (rounded so the total divides
    n_node_shards · n_splits), and emits the matching edge_mask.

    Returns {"edge_index" (2, E'), "edge_mask" (E',)} with
    E' = n_node_shards · per_shard, per_shard % n_splits == 0.
    """
    assert n_nodes % n_node_shards == 0
    n_loc = n_nodes // n_node_shards
    src, dst = edge_index
    if edge_mask is None:
        edge_mask = np.ones(src.shape[0], dtype=np.float32)
    shard_of = dst // n_loc
    groups_s, groups_d, groups_m = [], [], []
    max_len = 0
    for i in range(n_node_shards):
        sel = (shard_of == i) & (edge_mask > 0)
        groups_s.append(src[sel])
        groups_d.append(dst[sel])
        groups_m.append(edge_mask[sel])
        max_len = max(max_len, int(sel.sum()))
    per_shard = ((max_len + n_splits - 1) // n_splits) * n_splits
    out_s, out_d, out_m = [], [], []
    for i in range(n_node_shards):
        pad = per_shard - groups_s[i].shape[0]
        out_s.append(np.concatenate([groups_s[i],
                                     np.zeros(pad, dtype=src.dtype)]))
        # padded edges still point INSIDE shard i so dst-locality holds
        out_d.append(np.concatenate([groups_d[i],
                                     np.full(pad, i * n_loc, dtype=dst.dtype)]))
        out_m.append(np.concatenate([groups_m[i],
                                     np.zeros(pad, dtype=np.float32)]))
    return {
        "edge_index": np.stack([np.concatenate(out_s), np.concatenate(out_d)])
        .astype(np.int32),
        "edge_mask": np.concatenate(out_m),
    }


def subgraph_shapes(batch_nodes: int, fanouts: Sequence[int]) -> tuple[int, int]:
    """(n_sub, e_sub) static shapes for a given sampling config."""
    n = batch_nodes
    n_sub = batch_nodes
    e_sub = 0
    for f in fanouts:
        e_sub += n * f
        n = n * f
        n_sub += n
    return n_sub, e_sub
