"""Learning-rate schedules as step -> scale multipliers."""
from __future__ import annotations

import jax.numpy as jnp


def constant(step):
    return jnp.ones_like(jnp.asarray(step, jnp.float32))


def linear_warmup(step, warmup_steps: int):
    s = jnp.asarray(step, jnp.float32)
    return jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1))


def cosine_decay(step, total_steps: int, warmup_steps: int = 0, floor: float = 0.0):
    s = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, warmup_steps) if warmup_steps else 1.0
    frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos
