from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update
from repro.optim.schedules import constant, cosine_decay, linear_warmup

__all__ = [
    "AdamConfig",
    "AdamState",
    "adam_init",
    "adam_update",
    "constant",
    "cosine_decay",
    "linear_warmup",
]
