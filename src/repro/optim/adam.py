"""Adam (+ optional decoupled weight decay and global-norm clipping).

Pure-pytree implementation (no optax dependency).  Moments live in fp32
regardless of the param dtype, sharded identically to their params — under
pjit this keeps optimizer state FSDP-sharded for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = None


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any      # pytree like params, fp32
    nu: Any      # pytree like params, fp32


def adam_init(params: Any) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adam_update(
    grads: Any, state: AdamState, params: Any, cfg: AdamConfig, lr_scale: jax.Array | float = 1.0
) -> tuple[Any, AdamState]:
    """One Adam step.  Returns (new_params, new_state)."""
    step = state.step + 1
    if cfg.grad_clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gn, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * lr_scale * delta
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_m, nu=new_v)
