"""Arch registry: builds every (architecture × input-shape) cell.

A *cell* is a lowering unit for the dry-run and the roofline pass:

    Cell(fn, abstract_args, in_specs, out_specs, kind, skip)

``abstract_args`` are ShapeDtypeStructs — nothing is allocated; the dry-run
does ``jax.jit(fn, in_shardings=…, out_shardings=…).lower(*abstract_args)``.
Smoke tests build the same cells from the *smoke* configs with real
(tiny) arrays via ``materialize_args``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import sae as sae_lib
from repro.core.types import SAEConfig
from repro.distributed import sharding as shd
from repro.optim import AdamConfig, AdamState, adam_init, adam_update

# ---------------------------------------------------------------- plumbing
_CONFIG_MODULES = {
    "command-r-35b": "repro.configs.command_r_35b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "nequip": "repro.configs.nequip_cfg",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
    "din": "repro.configs.din_cfg",
    "deepfm": "repro.configs.deepfm_cfg",
    "bert4rec": "repro.configs.bert4rec_cfg",
}

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
# the paper's own production workloads (beyond the assigned 40 cells):
# SAE training at the paper's batch size, offline bulk compression of a
# catalog shard, and sparse retrieval over an O(10^8) catalog (paper §1)
SAE_SHAPES = ("train_100k", "compress_1m", "retrieval_100m")

TOP_N = 100              # retrieval result size
SERVE_SLATE = 100        # bert4rec rerank slate

# CompresSAE config per recsys arch for the retrieval_cand cell: k chosen so
# the compressed code (2k·4 B) is ~8x smaller than the fp32 embedding row,
# mirroring the paper's 12x point at d=768 (DESIGN.md §4).
RETRIEVAL_SAE: Dict[str, SAEConfig] = {
    "dlrm-mlperf": SAEConfig(d=128, h=2048, k=8),
    "deepfm": SAEConfig(d=10, h=128, k=2, aux_k_mult=4),
    "bert4rec": SAEConfig(d=64, h=1024, k=4),
    "din": SAEConfig(d=18, h=256, k=2),
}

OPT = AdamConfig(lr=1e-4, grad_clip_norm=1.0)


def arch_module(arch: str):
    return importlib.import_module(_CONFIG_MODULES[arch])


def all_arch_ids() -> Tuple[str, ...]:
    return tuple(_CONFIG_MODULES) + ("compressae",)


def shapes_for(arch: str) -> Tuple[str, ...]:
    if arch == "compressae":
        return SAE_SHAPES
    fam = arch_module(arch).FAMILY
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}[fam]


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                         # train | prefill | decode | serve | retrieval
    fn: Optional[Callable] = None
    abstract_args: Optional[tuple] = None
    in_specs: Any = None
    out_specs: Any = None
    skip: Optional[str] = None
    # metadata for the roofline (model-flops accounting)
    meta: Optional[Dict[str, Any]] = None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _abstract(tree):
    return jax.tree.map(lambda x: _sds(x.shape, x.dtype), tree)


# =================================================================== LM cells
LM_SHAPE_DEFS = {
    "train_4k": dict(seq=4096, batch=256),
    "prefill_32k": dict(seq=32768, batch=32),
    "decode_32k": dict(seq=32768, batch=128),
    "long_500k": dict(seq=524288, batch=1),
}


def _lm_train_step(cfg, grad_accum: int):
    from repro.models.transformer import lm_loss

    def step(params, opt, batch):
        def loss_fn(p, mb):
            return lm_loss(p, mb, cfg)

        mbs = jax.tree.map(
            lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]),
            batch,
        )

        def acc(carry, mb):
            g_acc, l_acc = carry
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (grads, loss), _ = jax.lax.scan(acc, (zeros, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: g / grad_accum, grads)
        new_params, new_opt = adam_update(grads, opt, params, OPT)
        return new_params, new_opt, {"loss": loss / grad_accum}

    return step


def _lm_cell(arch: str, shape: str, full: bool) -> Cell:
    mod = arch_module(arch)
    if shape in mod.SKIP:
        return Cell(arch=arch, shape=shape, kind="skip", skip=mod.SKIP[shape])
    cfg = mod.full() if full else mod.smoke()
    sdef = LM_SHAPE_DEFS[shape]
    seq, batch = (sdef["seq"], sdef["batch"]) if full else (64, 8)
    from repro.models import transformer as T

    params_a = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = shd.lm_param_pspecs(params_a)
    meta = dict(cfg=cfg, seq=seq, batch=batch)

    if shape == "train_4k":
        ga = mod.GRAD_ACCUM.get(shape, 1) if full else 1
        opt_a = jax.eval_shape(lambda: adam_init(params_a))
        ospecs = AdamState(step=P(), mu=pspecs, nu=pspecs)
        batch_a = {
            "tokens": _sds((batch, seq), jnp.int32),
            "labels": _sds((batch, seq), jnp.int32),
        }
        return Cell(
            arch=arch, shape=shape, kind="train",
            fn=_lm_train_step(cfg, ga),
            abstract_args=(params_a, opt_a, batch_a),
            in_specs=(pspecs, ospecs, shd.lm_batch_pspecs(batch_a)),
            out_specs=(pspecs, ospecs, P()),
            meta={**meta, "grad_accum": ga},
        )

    if shape in ("prefill_32k",):
        tokens_a = _sds((batch, seq), jnp.int32)
        cspec = shd.cache_pspec(cfg.n_kv_heads)
        cache_specs = [(cspec, cspec) for _ in range(cfg.group_size)]
        fn = lambda p, t: T.prefill(p, t, cfg)
        return Cell(
            arch=arch, shape=shape, kind="prefill",
            fn=fn,
            abstract_args=(params_a, tokens_a),
            in_specs=(pspecs, P(("pod", "data"), None)),
            out_specs=(P(("pod", "data"), "model"), cache_specs),
            meta=meta,
        )

    # decode shapes: one new token, cache of length seq
    caches_a = [
        (
            _sds((cfg.n_groups, batch, seq, cfg.n_kv_heads, cfg.hd), cfg.compute_dtype),
            _sds((cfg.n_groups, batch, seq, cfg.n_kv_heads, cfg.hd), cfg.compute_dtype),
        )
        for _ in range(cfg.group_size)
    ]
    token_a = _sds((batch, 1), jnp.int32)
    pos_a = _sds((), jnp.int32)
    cspec = shd.cache_pspec(cfg.n_kv_heads)
    cache_specs = [(cspec, cspec) for _ in range(cfg.group_size)]
    fn = lambda p, t, c, pos: T.decode_step(p, t, c, pos, cfg)
    return Cell(
        arch=arch, shape=shape, kind="decode",
        fn=fn,
        abstract_args=(params_a, token_a, caches_a, pos_a),
        in_specs=(pspecs, P(("pod", "data"), None), cache_specs, P()),
        out_specs=(P(("pod", "data"), "model"), cache_specs),
        meta=meta,
    )


# ================================================================== GNN cells
GNN_SHAPE_DEFS = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556),
    "minibatch_lg": dict(batch_nodes=1024, fanouts=(15, 10)),
    "ogb_products": dict(n_nodes=2449029, n_edges=61859140),
    "molecule": dict(n_graphs=128, nodes_per=30, edges_per=64),
}


def _gnn_train_step(cfg):
    from repro.models.nequip import nequip_loss

    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: nequip_loss(p, batch, cfg), has_aux=True
        )(params)
        new_params, new_opt = adam_update(grads, opt, params, OPT)
        return new_params, new_opt, {"loss": loss}

    return step


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _gnn_cell(arch: str, shape: str, full: bool) -> Cell:
    mod = arch_module(arch)
    from repro.models import nequip as N

    cfg = mod.full(shape) if full else mod.smoke()
    sdef = GNN_SHAPE_DEFS[shape]
    if shape == "minibatch_lg":
        from repro.data.sampler import subgraph_shapes

        bn, fo = (sdef["batch_nodes"], sdef["fanouts"]) if full else (8, (3, 2))
        n, e = subgraph_shapes(bn, fo)
    elif shape == "molecule":
        ng, npn, epn = (
            (sdef["n_graphs"], sdef["nodes_per"], sdef["edges_per"])
            if full else (4, 6, 10)
        )
        n, e = ng * npn, ng * epn
    else:
        n, e = (sdef["n_nodes"], sdef["n_edges"]) if full else (64, 256)

    # pad node arrays to ×64 (shardable over pod·data on both meshes) and
    # edge arrays to ×512 (shardable over the full device set); padded
    # edges are masked via edge_mask, padded nodes carry label -1
    if full:
        n, e = _pad_to(n, 64), _pad_to(e, 512)

    batch_a: Dict[str, Any] = {
        "node_feat": _sds((n, cfg.d_feat), jnp.float32),
        "edge_index": _sds((2, e), jnp.int32),
        "edge_mask": _sds((e,), jnp.float32),
        "positions": _sds((n, 3), jnp.float32),
    }
    if cfg.task == "node_classify":
        batch_a["labels"] = _sds((n,), jnp.int32)
    else:
        ng = sdef["n_graphs"] if full else 4
        batch_a["graph_ids"] = _sds((n,), jnp.int32)
        batch_a["energies"] = _sds((ng,), jnp.float32)

    params_a = jax.eval_shape(lambda: N.nequip_init(cfg, jax.random.PRNGKey(0)))
    opt_a = jax.eval_shape(lambda: adam_init(params_a))
    pspecs = shd.tree_replicated(params_a)     # tiny model: replicate params
    ospecs = AdamState(step=P(), mu=pspecs, nu=pspecs)
    bspecs = shd.gnn_batch_pspecs(batch_a)
    return Cell(
        arch=arch, shape=shape, kind="train",
        fn=_gnn_train_step(cfg),
        abstract_args=(params_a, opt_a, batch_a),
        in_specs=(pspecs, ospecs, bspecs),
        out_specs=(pspecs, ospecs, P()),
        meta=dict(cfg=cfg, n_nodes=n, n_edges=e),
    )


# =============================================================== recsys cells
RECSYS_SHAPE_DEFS = {
    "train_batch": dict(batch=65536),
    "serve_p99": dict(batch=512),
    "serve_bulk": dict(batch=262144),
    # 1M candidates padded to ×512 so the candidate axis shards over the
    # full 512-chip multi-pod device set (padding masked at score time)
    "retrieval_cand": dict(batch=1, n_candidates=1_000_448),
}


def _recsys_batch_specs(arch: str, cfg, batch: int, with_label: bool):
    if arch == "dlrm-mlperf":
        b = {
            "dense": _sds((batch, cfg.n_dense), jnp.float32),
            "cat": _sds((batch, cfg.n_sparse), jnp.int32),
        }
    elif arch == "deepfm":
        b = {"cat": _sds((batch, cfg.n_sparse), jnp.int32)}
    elif arch == "din":
        b = {
            "hist": _sds((batch, cfg.seq_len), jnp.int32),
            "target": _sds((batch,), jnp.int32),
        }
    else:  # bert4rec
        b = {"hist": _sds((batch, cfg.seq_len), jnp.int32)}
    if with_label:
        if arch == "bert4rec":
            m = max(1, cfg.seq_len // 5)    # 20% mask rate, static M
            b["masked_positions"] = _sds((batch, m), jnp.int32)
            b["labels"] = _sds((batch, m), jnp.int32)
            b["negatives"] = _sds((cfg.n_negatives,), jnp.int32)
        else:
            b["label"] = _sds((batch,), jnp.float32)
    return b


def _recsys_fns(arch: str):
    from repro.models import recsys as R

    return {
        "dlrm-mlperf": (R.dlrm_init, R.dlrm_loss, R.dlrm_serve, R.dlrm_user_vector),
        "deepfm": (R.deepfm_init, R.deepfm_loss, R.deepfm_serve, R.deepfm_user_vector),
        "din": (R.din_init, R.din_loss, R.din_serve, R.din_user_vector),
        "bert4rec": (
            R.bert4rec_init, R.bert4rec_loss, R.bert4rec_serve,
            R.bert4rec_user_vector,
        ),
    }[arch]


def _recsys_cell(arch: str, shape: str, full: bool) -> Cell:
    mod = arch_module(arch)
    cfg = mod.full() if full else mod.smoke()
    init_fn, loss_fn, serve_fn, uvec_fn = _recsys_fns(arch)
    sdef = RECSYS_SHAPE_DEFS[shape]
    batch = sdef["batch"] if full else min(sdef["batch"], 16)
    params_a = jax.eval_shape(lambda: init_fn(cfg, jax.random.PRNGKey(0)))
    pspecs = shd.recsys_param_pspecs(params_a)
    bspec_batched = P(("pod", "data"))
    meta = dict(cfg=cfg, batch=batch)

    if shape == "train_batch":
        batch_a = _recsys_batch_specs(arch, cfg, batch, with_label=True)
        opt_a = jax.eval_shape(lambda: adam_init(params_a))
        ospecs = AdamState(step=P(), mu=pspecs, nu=pspecs)

        def step(params, opt, b):
            (loss, _), grads = jax.value_and_grad(
                lambda p: loss_fn(p, b, cfg), has_aux=True
            )(params)
            new_params, new_opt = adam_update(grads, opt, params, OPT)
            return new_params, new_opt, {"loss": loss}

        bspecs = {
            k: (P(("pod", "data"), *([None] * (v.ndim - 1))) if v.shape[0] == batch
                else P())
            for k, v in batch_a.items()
        }
        return Cell(
            arch=arch, shape=shape, kind="train",
            fn=step,
            abstract_args=(params_a, opt_a, batch_a),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, P()),
            meta=meta,
        )

    if shape in ("serve_p99", "serve_bulk"):
        batch_a = _recsys_batch_specs(arch, cfg, batch, with_label=False)
        if arch == "bert4rec":
            batch_a["candidates"] = _sds((batch, SERVE_SLATE), jnp.int32)

        def serve(params, b):
            return serve_fn(params, b, cfg)

        # Serving sharding (EXPERIMENTS.md §Perf, bert4rec hillclimb):
        # small-parameter models (bert4rec 64 MB, din 720 MB) REPLICATE
        # params and batch-shard over the FULL device set — model-sharding
        # a d=64 tower makes every layer a collective.  Big-table models
        # (dlrm, deepfm) keep table sharding and (pod, data) batch.
        small_model = arch in ("bert4rec", "din")
        batch_axes = ("pod", "data", "model") if small_model else ("pod", "data")
        serve_pspecs = shd.tree_replicated(params_a) if small_model else pspecs
        bspecs = {
            k: (P(batch_axes, *([None] * (v.ndim - 1))) if v.shape[0] == batch
                else P())
            for k, v in batch_a.items()
        }
        out = P(batch_axes) if arch != "bert4rec" else P(batch_axes, None)
        return Cell(
            arch=arch, shape=shape, kind="serve",
            fn=serve,
            abstract_args=(params_a, batch_a),
            in_specs=(serve_pspecs, bspecs),
            out_specs=out,
            meta=meta,
        )

    # ---- retrieval_cand
    n_cand = sdef["n_candidates"] if full else 512
    if arch == "din":
        # exact vectorized target-aware scoring (SAE inapplicable to DIN's
        # per-candidate attention; DESIGN.md §Arch-applicability).  The
        # candidate axis is shard_map'd over the whole device set: local
        # scoring + local top-n, merged with one small gather — GSPMD
        # replicates the (C, T, 4d) attention features otherwise.
        from repro.models.recsys import din_score_candidate_embs
        from repro.layers.embedding import embedding_lookup

        batch_a = _recsys_batch_specs(arch, cfg, 1, with_label=False)
        del batch_a["target"]
        cands_a = _sds((n_cand,), jnp.int32)
        all_axes = ("pod", "data", "model")

        def retrieve(params, b, cands):
            from repro.distributed.sharding import current_rules, shard_hint

            rules = current_rules()
            c_emb = shard_hint(
                embedding_lookup(params["items"], cands), "cand_rows"
            )
            if rules is None:
                from repro.core.retrieval import top_n

                scores = din_score_candidate_embs(params, b, c_emb, cfg)
                return top_n(scores, TOP_N)

            axes = rules._all_axes()
            small = {k: v for k, v in params.items() if k != "items"}
            hist_emb_params = {"items": params["items"]}

            def local(prm_small, hist_emb, bb, ce_l):
                prm = {**prm_small, "items": hist_emb}
                s = din_score_candidate_embs(prm, bb, ce_l, cfg)  # (1, C_loc)
                v, i = jax.lax.top_k(s, TOP_N)
                shard = jax.lax.axis_index(axes[0])
                for ax in axes[1:]:
                    shard = shard * compat.axis_size(ax) + jax.lax.axis_index(ax)
                return v, i + shard.astype(jnp.int32) * ce_l.shape[0]

            # only the hist rows of the items table are needed inside:
            # gather them up front (T rows) instead of replicating 10M rows
            hist_rows = embedding_lookup(
                params["items"], jnp.maximum(b["hist"], 0)
            )[0]                                            # (T, d)
            bb = {"hist": jnp.where(b["hist"] >= 0,
                                    jnp.arange(b["hist"].shape[1])[None], -1)}
            vs, ids = compat.shard_map(
                local,
                in_specs=(
                    jax.tree.map(lambda _: P(), small),
                    P(None, None), {"hist": P(None, None)},
                    P(axes, None),
                ),
                out_specs=(P(None, axes), P(None, axes)),
            )(small, hist_rows, bb, c_emb)
            v, sel = jax.lax.top_k(vs, TOP_N)
            return v, jnp.take_along_axis(ids, sel, axis=-1)

        return Cell(
            arch=arch, shape=shape, kind="retrieval",
            fn=retrieve,
            abstract_args=(params_a, batch_a, cands_a),
            in_specs=(pspecs, {"hist": P()}, P(("pod", "data", "model"))),
            out_specs=(P(), P()),
            meta={**meta, "n_candidates": n_cand, "variant": "exact-din"},
        )

    # paper path: catalog stored as fixed-k CompresSAE codes
    sae_cfg = RETRIEVAL_SAE[arch] if full else SAEConfig(d=_uvec_dim(arch, cfg), h=64, k=2)
    from repro.models.retrieval_head import compressed_retrieval

    batch_a = _recsys_batch_specs(arch, cfg, 1, with_label=False)
    codes_vals_a = _sds((n_cand, sae_cfg.k), jnp.float32)
    codes_idx_a = _sds((n_cand, sae_cfg.k), jnp.int32)
    norms_a = _sds((n_cand,), jnp.float32)
    sae_a = jax.eval_shape(lambda: sae_lib.init_params(sae_cfg, jax.random.PRNGKey(0)))

    def retrieve(params, sae_params, vals, idx, norms, b):
        from repro.core.types import SparseCodes

        uvec = uvec_fn(params, b, cfg)
        codes = SparseCodes(values=vals, indices=idx, dim=sae_cfg.h)
        return compressed_retrieval(uvec, sae_params, codes, norms, TOP_N, sae_cfg.k)

    cand_spec = P(("pod", "data", "model"))
    return Cell(
        arch=arch, shape=shape, kind="retrieval",
        fn=retrieve,
        abstract_args=(params_a, sae_a, codes_vals_a, codes_idx_a, norms_a, batch_a),
        in_specs=(
            pspecs, shd.tree_replicated(sae_a),
            P(("pod", "data", "model"), None),
            P(("pod", "data", "model"), None),
            cand_spec,
            {k: P() for k in batch_a},
        ),
        out_specs=(P(), P()),
        meta={**meta, "n_candidates": n_cand, "sae": sae_cfg, "variant": "compressed"},
    )


def _uvec_dim(arch: str, cfg) -> int:
    return {"dlrm-mlperf": cfg.bot_mlp[-1] if hasattr(cfg, "bot_mlp") else 16,
            "deepfm": cfg.embed_dim, "bert4rec": cfg.embed_dim,
            "din": cfg.embed_dim}[arch]


# ========================================================== CompresSAE cells
def _sae_cell(shape: str, full: bool) -> Cell:
    """The paper's production workloads on the production mesh."""
    from repro.core import sae as sae_lib2
    from repro.core.train import TrainState, init_train_state, train_step
    from repro.core.types import SAEConfig as SC

    # topk_groups=16 matches the model-axis size: the heavy top-k stage
    # runs on the h-shards locally (§Perf hillclimb 4)
    cfg = SC(d=768, h=4096, k=32, topk_groups=16) if full \
        else SC(d=32, h=128, k=4)
    sae_a = jax.eval_shape(lambda: sae_lib2.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = shd.sae_param_pspecs(sae_a)

    if shape == "train_100k":
        batch = 100_096 if full else 64       # paper: 100k rows/step (pad ×512)
        state_a = jax.eval_shape(lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
        sspecs = TrainState(
            params=pspecs,
            opt=AdamState(step=P(), mu=pspecs, nu=pspecs),
            steps_since_fired=P("model"),
        )
        x_a = _sds((batch, cfg.d), jnp.float32)

        def step(state, x):
            return train_step(state, x, cfg, OPT)

        return Cell(
            arch="compressae", shape=shape, kind="train",
            fn=step,
            abstract_args=(state_a, x_a),
            in_specs=(sspecs, P(("pod", "data"), None)),
            out_specs=(sspecs, P()),
            meta=dict(cfg=cfg, batch=batch),
        )

    if shape == "compress_1m":
        batch = 1_048_576 if full else 256    # offline catalog compression

        def compress(params, x):
            from repro.distributed.sharding import current_rules

            rules = current_rules()
            if rules is not None:
                codes = sae_lib2.encode_sharded(
                    params, x, cfg.k,
                    batch_axes=tuple(rules.batch) if isinstance(rules.batch, tuple)
                    else (rules.batch,),
                    model_axis=rules.model, chunk=8192,
                )
            else:
                codes = sae_lib2.encode_chunked(params, x, cfg.k, chunk=8192,
                                                groups=cfg.topk_groups)
            return codes.values, codes.indices

        x_a = _sds((batch, cfg.d), jnp.float32)
        return Cell(
            arch="compressae", shape=shape, kind="serve",
            fn=compress,
            abstract_args=(sae_a, x_a),
            in_specs=(pspecs, P(("pod", "data"), None)),
            out_specs=(P(("pod", "data"), None), P(("pod", "data"), None)),
            meta=dict(cfg=cfg, batch=batch),
        )

    # retrieval_100m: O(10^8)-item catalog (paper §1), 256 queries.
    # The catalog axis is shard_map'd over the whole device set (local
    # scatter-query SpMV + local top-n + one small merge): a global
    # lax.top_k over the sharded candidate axis would replicate the
    # (Q, 100M) score matrix (190 GiB/device measured).
    n_cand = 100_000_256 if full else 4096
    nq = 256 if full else 4
    from repro.core.retrieval import sparse_dot_dense_query
    from repro.core import sparse as sparse_lib2
    from repro.core.types import SparseCodes

    all_axes = ("pod", "data", "model")

    def retrieve(params, vals, idx, norms, queries):
        from repro.distributed.sharding import current_rules

        q_codes = sae_lib2.encode(params, queries, cfg.k)
        q_dense = sparse_lib2.densify(q_codes)
        q_norm = jnp.linalg.norm(q_codes.values, axis=-1)
        rules = current_rules()
        axes = rules._all_axes() if rules is not None else ()

        def local(vals_l, idx_l, norms_l, qd, qn):
            codes = SparseCodes(values=vals_l, indices=idx_l, dim=cfg.h)
            dots = sparse_dot_dense_query(codes, qd)
            scores = dots / jnp.maximum(qn[:, None] * norms_l[None, :], 1e-8)
            v, i = jax.lax.top_k(scores, TOP_N)
            if axes:
                shard = jax.lax.axis_index(axes[0])
                for ax in axes[1:]:
                    shard = shard * compat.axis_size(ax) + jax.lax.axis_index(ax)
                i = i + shard.astype(jnp.int32) * vals_l.shape[0]
            return v, i

        if not axes:
            v, i = local(vals, idx, norms, q_dense, q_norm)
            return v, i
        vs, ids = compat.shard_map(
            local,
            in_specs=(P(axes, None), P(axes, None), P(axes),
                      P(None, None), P(None)),
            out_specs=(P(None, axes), P(None, axes)),
        )(vals, idx, norms, q_dense, q_norm)
        v, sel = jax.lax.top_k(vs, TOP_N)
        return v, jnp.take_along_axis(ids, sel, axis=-1)

    return Cell(
        arch="compressae", shape=shape, kind="retrieval",
        fn=retrieve,
        abstract_args=(
            sae_a,
            _sds((n_cand, cfg.k), jnp.float32),
            _sds((n_cand, cfg.k), jnp.int32),
            _sds((n_cand,), jnp.float32),
            _sds((nq, cfg.d), jnp.float32),
        ),
        in_specs=(pspecs, P(("pod", "data", "model"), None),
                  P(("pod", "data", "model"), None), P(("pod", "data", "model")),
                  P()),
        out_specs=(P(), P()),
        meta=dict(cfg=cfg, n_candidates=n_cand, variant="compressed",
                  sae=cfg, batch=nq),
    )


# ------------------------------------------------------------------- public
def build_cell(arch: str, shape: str, full: bool = True) -> Cell:
    if arch == "compressae":
        return _sae_cell(shape, full)
    fam = arch_module(arch).FAMILY
    if fam == "lm":
        return _lm_cell(arch, shape, full)
    if fam == "gnn":
        return _gnn_cell(arch, shape, full)
    return _recsys_cell(arch, shape, full)


def all_cells(full: bool = True):
    for arch in all_arch_ids():
        for shape in shapes_for(arch):
            yield build_cell(arch, shape, full)


def count_cells(full: bool = True) -> Dict[str, int]:
    """Cell census: {live, skipped} across all archs × shapes."""
    live = skipped = 0
    for cell in all_cells(full):
        if cell.skip:
            skipped += 1
        else:
            live += 1
    return {"live": live, "skipped": skipped}
