"""Retrieval heads shared by the recsys archs (the paper's use case).

`retrieval_cand` cells score one query against ~10⁶ candidates.  Two paths:

  * dense   — exact cosine against the fp32 item table (baseline; what the
              paper's SBERT/Nomic rows do).
  * sparse  — the paper: the catalog is stored as fixed-k CompresSAE codes
              (12× smaller); the query embedding is encoded on the fly and
              scored with the scatter-query SpMV (sparse_dot kernel), then
              exact top-n.

Both are pure functions suitable for pjit with the candidate axis sharded
(embarrassingly parallel; top-n merges with lax.top_k after a gather).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import sae as sae_lib
from repro.core import sparse as sparse_lib
from repro.core.retrieval import sparse_dot_dense_query, top_n
from repro.core.types import SparseCodes


def dense_retrieval(
    user_vec: jax.Array, item_table: jax.Array, n: int
) -> Tuple[jax.Array, jax.Array]:
    """user_vec (Q, d); item_table (N, d).  Exact cosine top-n."""
    u = user_vec / jnp.maximum(jnp.linalg.norm(user_vec, axis=-1, keepdims=True), 1e-8)
    it = item_table / jnp.maximum(
        jnp.linalg.norm(item_table, axis=-1, keepdims=True), 1e-8
    )
    scores = u @ it.T
    return top_n(scores, n)


def compressed_retrieval(
    user_vec: jax.Array,
    sae_params: dict,
    codes: SparseCodes,
    code_norms: jax.Array,
    n: int,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """The paper's sparse-space retrieval: encode query, SpMV, top-n.

    user_vec (Q, d); codes (N, k) fixed-k catalog; code_norms (N,) ‖s_c‖.
    """
    q_codes = sae_lib.encode(sae_params, user_vec, k)
    q_dense = sparse_lib.densify(q_codes)                    # (Q, h)
    q_norm = jnp.linalg.norm(q_codes.values, axis=-1)        # (Q,)
    dots = sparse_dot_dense_query(codes, q_dense)            # (Q, N)
    scores = dots / jnp.maximum(q_norm[:, None] * code_norms[None, :], 1e-8)
    return top_n(scores, n)
