"""Configurable decoder-only LM transformer (the 5 assigned LM archs).

Features driven by config: GQA, explicit head_dim, qk-norm (Qwen3),
attention-logit + final-logit soft-capping (Gemma-2), sliding-window /
full alternation via ``window_pattern`` (Gemma-2 local+global), SwiGLU or
MoE FFN (Qwen3-MoE 128e top-8, Llama4-Scout 16e top-1 + shared expert),
RoPE, RMSNorm, optional tied embeddings, no biases anywhere (all five
assigned archs are bias-free).

Scaling discipline:
  * Layers are stacked into *groups* of ``len(window_pattern)`` sub-layers
    and scanned with ``jax.lax.scan`` — compile time is O(1) in depth and
    the HLO stays small enough to lower 40–48-layer models with 512
    placeholder devices.
  * Each group is wrapped in ``jax.checkpoint`` (remat) during training.
  * The loss never materializes (tokens, vocab) logits: cross-entropy is
    computed in token chunks (``loss_chunk``) inside a scan.
  * Forward activations in bf16; losses/softmax statistics in fp32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers.attention import decode_attention, flash_attention, rope
from repro.layers.moe import moe_ffn
from repro.layers.norms import rms_norm, softcap
from repro.layers.mlp import swiglu

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    # one entry per sub-layer in a repeating group; None = full attention
    window_pattern: Tuple[Optional[int], ...] = (None,)
    moe: Optional[MoESpec] = None
    tie_embeddings: bool = False
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    # sequence-shard the residual carried between layer groups (Megatron
    # SP).  Arch-dependent trade (EXPERIMENTS.md §Perf): big wins for
    # small-d archs (qwen3 11.5->3.2 GiB) and required by the shard_map
    # MoE token layout; for wide dense archs GSPMD propagation from the
    # attention hints alone is strictly better (command-r: 8.1->5.5 GiB
    # AND 10.2->7.1 TB collectives).
    residual_hint: bool = True
    q_chunk: int = 512
    kv_chunk: int = 512
    loss_chunk: int = 2048

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        return len(self.window_pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0
        return self.n_layers // self.group_size

    def param_count(self) -> int:
        leaves = jax.tree.leaves(
            jax.eval_shape(lambda: init_params(self, jax.random.PRNGKey(0)))
        )
        return sum(int(math.prod(l.shape)) for l in leaves)


# ------------------------------------------------------------------- init
def _layer_params(cfg: TransformerConfig, key: jax.Array) -> Params:
    """One sub-layer's params with a leading n_groups axis added by vmap."""
    d, hd = cfg.d_model, cfg.hd
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 12)
    std = 0.02
    p: Params = {
        "ln1": jnp.zeros((d,), cfg.param_dtype),
        "ln2": jnp.zeros((d,), cfg.param_dtype),
        "wq": std * jax.random.normal(ks[0], (d, hq * hd), cfg.param_dtype),
        "wk": std * jax.random.normal(ks[1], (d, hkv * hd), cfg.param_dtype),
        "wv": std * jax.random.normal(ks[2], (d, hkv * hd), cfg.param_dtype),
        "wo": std * jax.random.normal(ks[3], (hq * hd, d), cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.zeros((hd,), cfg.param_dtype)
    if cfg.moe is None:
        p["w_gate"] = std * jax.random.normal(ks[4], (d, cfg.d_ff), cfg.param_dtype)
        p["w_up"] = std * jax.random.normal(ks[5], (d, cfg.d_ff), cfg.param_dtype)
        p["w_down"] = std * jax.random.normal(ks[6], (cfg.d_ff, d), cfg.param_dtype)
    else:
        m = cfg.moe
        p["router"] = std * jax.random.normal(ks[7], (d, m.n_experts), jnp.float32)
        p["moe_gate"] = std * jax.random.normal(
            ks[8], (m.n_experts, d, m.d_ff_expert), cfg.param_dtype
        )
        p["moe_up"] = std * jax.random.normal(
            ks[9], (m.n_experts, d, m.d_ff_expert), cfg.param_dtype
        )
        p["moe_down"] = std * jax.random.normal(
            ks[10], (m.n_experts, m.d_ff_expert, d), cfg.param_dtype
        )
        if m.n_shared:
            f = m.d_ff_expert * m.n_shared
            p["w_gate"] = std * jax.random.normal(ks[4], (d, f), cfg.param_dtype)
            p["w_up"] = std * jax.random.normal(ks[5], (d, f), cfg.param_dtype)
            p["w_down"] = std * jax.random.normal(ks[6], (f, d), cfg.param_dtype)
    return p


def init_params(cfg: TransformerConfig, key: jax.Array) -> Params:
    k_emb, k_out, k_layers = jax.random.split(key, 3)
    # blocks[i] = params of sub-layer position i, stacked over n_groups
    blocks = []
    for i in range(cfg.group_size):
        keys = jax.random.split(jax.random.fold_in(k_layers, i), cfg.n_groups)
        blocks.append(jax.vmap(lambda k: _layer_params(cfg, k))(keys))
    params: Params = {
        "embed": 0.02 * jax.random.normal(
            k_emb, (cfg.vocab, cfg.d_model), cfg.param_dtype
        ),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = 0.02 * jax.random.normal(
            k_out, (cfg.d_model, cfg.vocab), cfg.param_dtype
        )
    return params


# ----------------------------------------------------------------- blocks
def _attn(
    x: jax.Array,
    p: Params,
    cfg: TransformerConfig,
    window: Optional[int],
    positions: jax.Array,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_len: Optional[jax.Array] = None,
):
    """Self-attention sub-block.  Returns (out, (k, v) for cache build)."""
    from repro.distributed.sharding import shard_hint

    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    cdt = cfg.compute_dtype
    h = rms_norm(x, p["ln1"])
    q = shard_hint((h @ p["wq"].astype(cdt)).reshape(b, s, hq, hd), "attn_q")
    k = shard_hint((h @ p["wk"].astype(cdt)).reshape(b, s, hkv, hd), "attn_kv_small")
    v = shard_hint((h @ p["wv"].astype(cdt)).reshape(b, s, hkv, hd), "attn_kv_small")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cache is None:
        # GQA expand to full query heads at the layer level (DESIGN.md §5):
        # every attention tensor then carries the shardable n_heads axis
        # (n_kv_heads < mesh model-size would force GSPMD replication).
        kvm = jnp.repeat(jnp.arange(hkv, dtype=jnp.int32), hq // hkv)
        kx = shard_hint(k[:, :, kvm, :], "attn_q")
        vx = shard_hint(v[:, :, kvm, :], "attn_q")
        o = flash_attention(
            q, kx, vx, causal=True, window=window,
            logit_softcap=cfg.attn_softcap,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        )
    else:
        kc, vc = cache
        # decode: all rows share the same write position (scalar index);
        # the new k/v slice adopts the cache's sharding so the dynamic
        # update stays shard-local
        k = shard_hint(k, "attn_kv_decode")
        v = shard_hint(v, "attn_kv_decode")
        pos = positions.reshape(-1)[0]
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
        from repro.distributed.sharding import MESH_DIV
        from repro.layers.attention import decode_attention_grouped

        decode_fn = (
            decode_attention_grouped if hkv % MESH_DIV == 0 else decode_attention
        )
        o = decode_fn(
            q, kc, vc, length=cache_len,
            window=window, logit_softcap=cfg.attn_softcap,
        )
        k, v = kc, vc
    out = o.reshape(b, s, hq * hd) @ p["wo"].astype(cdt)
    return out, (k, v)


def _ffn(x: jax.Array, p: Params, cfg: TransformerConfig):
    """FFN sub-block on normalized input.  Returns (out, aux_loss)."""
    cdt = cfg.compute_dtype
    b, s, d = x.shape
    h = rms_norm(x, p["ln2"])
    if cfg.moe is None:
        y = swiglu(h, p["w_gate"].astype(cdt), p["w_up"].astype(cdt),
                   p["w_down"].astype(cdt))
        return y, jnp.zeros((), jnp.float32)
    m = cfg.moe
    flat = h.reshape(b * s, d)
    from repro.distributed.sharding import current_rules

    rules = current_rules()
    if rules is not None:
        # distributed path: explicit expert-parallel shard_map dispatch
        from repro.layers.moe import moe_ffn_sharded

        out = moe_ffn_sharded(
            flat, p["router"],
            p["moe_gate"].astype(cdt), p["moe_up"].astype(cdt),
            p["moe_down"].astype(cdt),
            top_k=m.top_k, capacity_factor=m.capacity_factor,
            batch_axes=tuple(rules.batch) if isinstance(rules.batch, tuple)
            else (rules.batch,),
            model_axis=rules.model,
        )
    else:
        out = moe_ffn(
            flat, p["router"],
            p["moe_gate"].astype(cdt), p["moe_up"].astype(cdt),
            p["moe_down"].astype(cdt),
            top_k=m.top_k, capacity_factor=m.capacity_factor,
        )
    y = out.y.reshape(b, s, d)
    if m.n_shared:
        y = y + swiglu(h, p["w_gate"].astype(cdt), p["w_up"].astype(cdt),
                       p["w_down"].astype(cdt))
    return y, out.aux_loss


def _group_forward(
    x: jax.Array,
    gp: list[Params],
    cfg: TransformerConfig,
    positions: jax.Array,
    caches=None,
    cache_len=None,
):
    """Apply one group (len(window_pattern) sub-layers).  Returns
    (x, aux_loss_sum, new_caches)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, window in enumerate(cfg.window_pattern):
        cache_i = None if caches is None else caches[i]
        a, kv = _attn(x, gp[i], cfg, window, positions, cache_i, cache_len)
        x = x + a
        f, al = _ffn(x, gp[i], cfg)
        x = x + f
        aux = aux + al
        new_caches.append(kv)
    return x, aux, new_caches


# jax.lax.optimization_barrier carries no differentiation rule on this jax
# version; give it one (barrier the cotangent too — the backward while-loop
# is exactly where the LICM hoist it blocks would happen).
@jax.custom_vjp
def _residual_barrier(x: jax.Array) -> jax.Array:
    return jax.lax.optimization_barrier(x)


def _residual_barrier_fwd(x):
    return _residual_barrier(x), None


def _residual_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_residual_barrier.defvjp(_residual_barrier_fwd, _residual_barrier_bwd)


# ---------------------------------------------------------------- forward
def _unembed_weight(params: Params, cfg: TransformerConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def forward_hidden(
    params: Params, tokens: jax.Array, cfg: TransformerConfig
) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> final hidden states (B, S, d), aux_loss."""
    from repro.distributed.sharding import shard_hint

    b, s = tokens.shape
    cdt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cdt)
    if cfg.residual_hint:
        x = shard_hint(x, "residual")
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    def group_fn(x, gp):
        # barrier: blocks XLA's loop-invariant code motion from hoisting the
        # bf16->f32 upcast of the carry out of the backward while-loop —
        # without it the (n_groups, B, S, d) residual stack is materialized
        # TWICE (bf16 + converted f32), ~2.5x activation memory
        x = _residual_barrier(x)
        y, aux, _ = _group_forward(x, gp, cfg, positions)
        return y, aux

    if cfg.remat:
        group_fn = jax.checkpoint(group_fn)

    def scan_body(carry, gp):
        x, aux = carry
        y, a = group_fn(x, gp)
        return (y, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = rms_norm(x, params["ln_f"])
    return x, aux


def chunked_xent_loss(
    hidden: jax.Array, w_out: jax.Array, labels: jax.Array, cfg: TransformerConfig
) -> jax.Array:
    """Cross-entropy without materializing (tokens, vocab) logits.

    Chunks over the SEQUENCE axis (batch stays intact) so the scanned axis
    is replicated and the batch sharding survives into every chunk — a
    scan over a batch-sharded axis forces GSPMD to replicate the
    (chunk, vocab) logits per device.
    """
    b, s, d = hidden.shape
    s_chunk = max(1, min(cfg.loss_chunk // b, s))
    while s % s_chunk:
        s_chunk -= 1
    n_chunks = s // s_chunk
    hs = jnp.moveaxis(hidden.reshape(b, n_chunks, s_chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, n_chunks, s_chunk), 1, 0)
    cdt = cfg.compute_dtype

    # remat: without this, the scan's backward saves the (B, s_chunk, vocab)
    # logits of EVERY chunk (≈ tokens·vocab·4 bytes — hundreds of GB at
    # 151k vocab); recomputing logits in the backward costs one extra
    # matmul per chunk and keeps residuals at (B, s_chunk, d)
    @jax.checkpoint
    def chunk_loss(hc, lc):
        logits = (hc @ w_out.astype(cdt)).astype(jnp.float32)
        if cfg.final_softcap is not None:
            logits = softcap(logits, cfg.final_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - ll)

    def step(total, hl):
        hc, lc = hl
        return total + chunk_loss(hc, lc), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: TransformerConfig):
    hidden, aux = forward_hidden(params, batch["tokens"], cfg)
    xent = chunked_xent_loss(hidden, _unembed_weight(params, cfg),
                             batch["labels"], cfg)
    loss = xent + 0.01 * aux
    return loss, {"loss": loss, "xent": xent, "moe_aux": aux}


# ------------------------------------------------------------------ serve
def init_cache(cfg: TransformerConfig, batch: int, max_seq: int) -> list:
    """KV cache: per sub-layer position, stacked over groups:
    list[group_size] of (k, v) with shape (n_groups, B, S, Hkv, hd)."""
    shape = (cfg.n_groups, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return [
        (jnp.zeros(shape, cfg.compute_dtype), jnp.zeros(shape, cfg.compute_dtype))
        for _ in range(cfg.group_size)
    ]


def prefill(params: Params, tokens: jax.Array, cfg: TransformerConfig):
    """Full-sequence forward; returns (last-position logits (B, V), caches)."""
    b, s = tokens.shape
    cdt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cdt)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    def scan_body(x, gp):
        y, _, kvs = _group_forward(x, gp, cfg, positions)
        flat_kv = []
        for k, v in kvs:
            flat_kv.append(k)
            flat_kv.append(v)
        return y, tuple(flat_kv)

    x, stacked = jax.lax.scan(scan_body, x, params["blocks"])
    caches = [
        (stacked[2 * i], stacked[2 * i + 1]) for i in range(cfg.group_size)
    ]
    x = rms_norm(x, params["ln_f"])
    logits = (x[:, -1, :] @ _unembed_weight(params, cfg).astype(cdt)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = softcap(logits, cfg.final_softcap)
    return logits, caches


def decode_step(
    params: Params,
    token: jax.Array,
    caches: list,
    position: jax.Array,
    cfg: TransformerConfig,
):
    """One decode step.  token (B, 1) int32; position scalar int32 (current
    write index; cache entries < position+1 are valid).  Returns
    (logits (B, V), new caches)."""
    b = token.shape[0]
    cdt = cfg.compute_dtype
    x = jnp.take(params["embed"], token, axis=0).astype(cdt)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cdt)
    positions = jnp.broadcast_to(position[None, None], (b, 1)).astype(jnp.int32)
    cache_len = position + 1

    def scan_body(x, gp_and_cache):
        gp, caches_g = gp_and_cache
        y, _, kvs = _group_forward(
            x, gp, cfg, positions,
            caches=[(caches_g[2 * i], caches_g[2 * i + 1])
                    for i in range(cfg.group_size)],
            cache_len=cache_len,
        )
        flat = []
        for k, v in kvs:
            flat.extend((k, v))
        return y, tuple(flat)

    flat_caches = []
    for k, v in caches:
        flat_caches.extend((k, v))
    x, stacked = jax.lax.scan(scan_body, x, (params["blocks"], tuple(flat_caches)))
    new_caches = [(stacked[2 * i], stacked[2 * i + 1])
                  for i in range(cfg.group_size)]
    x = rms_norm(x, params["ln_f"])
    logits = (x[:, 0, :] @ _unembed_weight(params, cfg).astype(cdt)).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = softcap(logits, cfg.final_softcap)
    return logits, new_caches
