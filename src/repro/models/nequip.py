"""NequIP-style E(3)-equivariant interatomic GNN (arXiv:2101.03164).

Config (assigned): n_layers=5, d_hidden=32 channels per irrep, l_max=2,
n_rbf=8 Bessel radial basis, cutoff=5.0 Å.

Message passing is the irrep tensor-product regime (kernel_taxonomy §GNN):
per edge, sender features (l1) ⊗ spherical harmonics of the edge vector
(l2) → receiver irrep l3 through the real-CG intertwiners, with per-path,
per-channel weights produced by an MLP on the radial basis ('uvu'
channel-wise tensor product).  Aggregation is ``jax.ops.segment_sum`` over
the edge list (JAX-native scatter — the GNN message-passing primitive; no
sparse formats needed).

Features are a dict {l: (N, C, 2l+1)}.  CompresSAE is INAPPLICABLE to this
arch (DESIGN.md §Arch-applicability): there is no catalog-scale embedding
table, and compressing equivariant features would break E(3) symmetry.

Two task heads (driven by the shape cell):
  * node_classify — logits from invariant (l=0) features (cora/ogb cells),
  * graph_regress — per-graph energy = sum of per-node scalars (molecule).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.equivariant import real_cg, spherical_harmonics

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat: int = 16              # raw node-feature dim (shape-cell specific)
    n_out: int = 16               # classes (node_classify) / 1 (graph_regress)
    task: str = "node_classify"   # or "graph_regress"
    radial_hidden: int = 64
    avg_degree: float = 8.0
    param_dtype: Any = jnp.float32
    # feature/message dtype: bf16 for web-scale graphs (ogb_products:
    # 2.4M-node feature arrays + their AD cotangents dominate HBM; params
    # and the task head stay f32)
    feature_dtype: Any = jnp.float32

    @property
    def ls(self) -> Tuple[int, ...]:
        return tuple(range(self.l_max + 1))

    @property
    def paths(self) -> Tuple[Tuple[int, int, int], ...]:
        ps = []
        for l1 in self.ls:
            for l2 in self.ls:          # SH order
                for l3 in self.ls:
                    if abs(l1 - l2) <= l3 <= l1 + l2:
                        ps.append((l1, l2, l3))
        return tuple(ps)


# ------------------------------------------------------------------- init
def nequip_init(cfg: NequIPConfig, key: jax.Array) -> Params:
    c = cfg.d_hidden
    ks = jax.random.split(key, 4 + cfg.n_layers)
    params: Params = {
        "embed": jax.random.normal(ks[0], (cfg.d_feat, c), cfg.param_dtype)
        / math.sqrt(cfg.d_feat),
        "out_w": jax.random.normal(ks[1], (c, cfg.n_out), cfg.param_dtype)
        / math.sqrt(c),
        "out_b": jnp.zeros((cfg.n_out,), cfg.param_dtype),
        "layers": [],
    }
    n_paths = len(cfg.paths)
    for i in range(cfg.n_layers):
        k = ks[4 + i]
        kk = jax.random.split(k, 8)
        layer = {
            # radial MLP: rbf -> hidden -> per-(path, channel) weights
            "rad_w1": jax.random.normal(
                kk[0], (cfg.n_rbf, cfg.radial_hidden), cfg.param_dtype
            ) / math.sqrt(cfg.n_rbf),
            "rad_b1": jnp.zeros((cfg.radial_hidden,), cfg.param_dtype),
            "rad_w2": jax.random.normal(
                kk[1], (cfg.radial_hidden, n_paths * c), cfg.param_dtype
            ) / math.sqrt(cfg.radial_hidden),
            # per-l self-interaction (channel mix) before and after TP
            "self1": {
                str(l): jax.random.normal(kk[2 + l], (c, c), cfg.param_dtype)
                / math.sqrt(c)
                for l in cfg.ls
            },
            "self2": {
                str(l): jax.random.normal(kk[5 + (l % 3)], (c, c), cfg.param_dtype)
                / math.sqrt(c) * (0.5 if l else 1.0)
                for l in cfg.ls
            },
            # gates for l>0 from scalars
            "gate_w": jax.random.normal(kk[7], (c, c * cfg.l_max), cfg.param_dtype)
            / math.sqrt(c),
        }
        params["layers"].append(layer)
    return params


# ------------------------------------------------------------ radial basis
def bessel_rbf(r: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Bessel basis sin(nπr/rc)/r with smooth polynomial cutoff envelope."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(
        n * jnp.pi * r[..., None] / cutoff
    ) / r[..., None]
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5   # poly envelope p=3
    return basis * env[..., None]


# ---------------------------------------------------------------- forward
def _feature_dict(x0: jax.Array, cfg: NequIPConfig) -> Dict[int, jax.Array]:
    n, c = x0.shape
    feats = {0: x0[..., None]}                       # (N, C, 1)
    for l in cfg.ls[1:]:
        feats[l] = jnp.zeros((n, c, 2 * l + 1), x0.dtype)
    return feats


def nequip_forward(
    params: Params,
    node_feat: jax.Array,      # (N, d_feat)
    edge_index: jax.Array,     # (2, E) int32 [src, dst]
    positions: jax.Array,      # (N, 3)
    cfg: NequIPConfig,
    edge_mask: Optional[jax.Array] = None,   # (E,) 1.0 = real, 0.0 = padding
) -> jax.Array:
    """Returns per-node outputs (N, n_out)."""
    n = node_feat.shape[0]
    c = cfg.d_hidden
    src, dst = edge_index[0], edge_index[1]
    rel = positions[dst] - positions[src]             # (E, 3)
    r = jnp.linalg.norm(rel, axis=-1)
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)        # (E, n_rbf)
    sh = {l: spherical_harmonics(l, rel) for l in cfg.ls}   # (E, 2l+1)
    cg = {p: jnp.asarray(real_cg(*p)) for p in cfg.paths}

    feats = _feature_dict(node_feat @ params["embed"], cfg)
    inv_sqrt_deg = 1.0 / math.sqrt(cfg.avg_degree)

    for layer in params["layers"]:
        # radial weights per (path, channel)
        hidden = jax.nn.silu(rbf @ layer["rad_w1"] + layer["rad_b1"])
        rad = (hidden @ layer["rad_w2"]).reshape(-1, len(cfg.paths), c)  # (E,P,C)

        # self-interaction 1 (per-l channel mix)
        f1 = {l: jnp.einsum("ncm,cd->ndm", feats[l], layer["self1"][str(l)])
              for l in cfg.ls}

        # tensor-product messages + scatter aggregation
        agg = {l: jnp.zeros((n, c, 2 * l + 1), node_feat.dtype) for l in cfg.ls}
        for pi, (l1, l2, l3) in enumerate(cfg.paths):
            sender = f1[l1][src]                      # (E, C, 2l1+1)
            msg = jnp.einsum(
                "eca,eb,abz->ecz", sender, sh[l2], cg[(l1, l2, l3)]
            )                                          # (E, C, 2l3+1)
            msg = msg * rad[:, pi, :, None]
            if edge_mask is not None:
                msg = msg * edge_mask[:, None, None]
            agg[l3] = agg[l3] + jax.ops.segment_sum(
                msg, dst, num_segments=n
            )
        agg = {l: a * inv_sqrt_deg for l, a in agg.items()}

        # self-interaction 2 + gated nonlinearity + residual
        from repro.distributed.sharding import shard_hint

        agg = {l: shard_hint(a, "gnn_feat") for l, a in agg.items()}
        upd = {l: jnp.einsum("ncm,cd->ndm", agg[l], layer["self2"][str(l)])
               for l in cfg.ls}
        scalars = upd[0][..., 0]                      # (N, C)
        new0 = feats[0] + jax.nn.silu(scalars)[..., None]
        gates = jax.nn.sigmoid(scalars @ layer["gate_w"])   # (N, C·l_max)
        new = {0: new0}
        for li, l in enumerate(cfg.ls[1:]):
            g = gates[:, li * c : (li + 1) * c]
            new[l] = feats[l] + upd[l] * g[..., None]
        feats = new

    out = feats[0][..., 0] @ params["out_w"] + params["out_b"]
    return out


def nequip_forward_sharded(
    params: Params,
    node_feat: jax.Array,
    edge_index: jax.Array,
    positions: jax.Array,
    cfg: NequIPConfig,
    edge_mask: Optional[jax.Array],
    *,
    node_axes: tuple = ("data",),
    model_axis: str = "model",
) -> jax.Array:
    """Distributed NequIP via shard_map (DESIGN.md §5).

    Partitioning contract (the data pipeline enforces it — see
    repro.data.sampler.partition_edges_by_dst):
      * node features sharded over ``node_axes`` (contiguous blocks),
      * edges sharded over (node_axes…, model) with edges PRE-PARTITIONED
        by destination shard: device (i, j) only holds edges whose dst
        lies in node shard i (padded per shard with edge_mask=0).

    Per layer: all-gather node features over ``node_axes`` (so local edges
    can gather any *sender*), local tensor-product messages, local
    segment_sum directly into the (n_loc, C, 2l+1) destination shard, and
    a psum over ``model_axis`` only.  No (N, …)-sized aggregation buffer
    ever exists.  Plain GSPMD replicates every scatter operand instead
    (139 GiB/device at ogb_products scale, EXPERIMENTS.md §Perf).
    """
    n = node_feat.shape[0]
    c = cfg.d_hidden
    cg = {p: jnp.asarray(real_cg(*p)) for p in cfg.paths}
    inv_sqrt_deg = 1.0 / math.sqrt(cfg.avg_degree)
    nspec = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]
    espec = (*node_axes, model_axis)

    def local_fn(prm, nf_l, ei_l, pos_full, em_l):
        n_loc = nf_l.shape[0]
        # global -> shard-local destination ids
        shard_idx = jax.lax.axis_index(node_axes[0])
        for ax in node_axes[1:]:
            shard_idx = shard_idx * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        dst_off = shard_idx.astype(jnp.int32) * n_loc
        src, dst = ei_l[0], ei_l[1]
        dst_l = jnp.clip(dst - dst_off, 0, n_loc - 1)
        # contract check baked into the mask: out-of-shard dst contribute 0
        in_shard = (dst >= dst_off) & (dst < dst_off + n_loc)
        em = in_shard.astype(nf_l.dtype)
        if em_l is not None:
            em = em * em_l

        rel = pos_full[dst] - pos_full[src]
        r = jnp.linalg.norm(rel, axis=-1)
        rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)
        sh = {l: spherical_harmonics(l, rel) for l in cfg.ls}

        fdt = cfg.feature_dtype
        x0 = (nf_l.astype(fdt)) @ prm["embed"].astype(fdt)   # (n_loc, C)
        feats = _feature_dict(x0, cfg)

        def layer_fn(feats, layer):
            hidden = jax.nn.silu(rbf @ layer["rad_w1"] + layer["rad_b1"])
            rad = (hidden @ layer["rad_w2"]).reshape(-1, len(cfg.paths), c)
            rad = rad.astype(fdt)
            # Sender gather grouped by l1 so at most ONE all-gathered
            # (N, C, 2l+1) array is live at a time.
            e_loc = src.shape[0]
            msgs = {l: jnp.zeros((e_loc, c, 2 * l + 1), fdt) for l in cfg.ls}
            for l1 in cfg.ls:
                f1 = jnp.einsum(
                    "ncm,cd->ndm", feats[l1], layer["self1"][str(l1)].astype(fdt)
                )
                for ax in reversed(node_axes):
                    f1 = jax.lax.all_gather(f1, ax, axis=0, tiled=True)
                sender = f1[src]
                for pi, (p1, l2, l3) in enumerate(cfg.paths):
                    if p1 != l1:
                        continue
                    msg = jnp.einsum(
                        "eca,eb,abz->ecz", sender, sh[l2].astype(fdt),
                        cg[(l1, l2, l3)].astype(fdt),
                    )
                    msgs[l3] = msgs[l3] + msg * rad[:, pi, :, None]
            out = {}
            for l in cfg.ls:
                m = msgs[l] * em.astype(fdt)[:, None, None]
                a = jax.ops.segment_sum(m, dst_l, num_segments=n_loc)
                a = jax.lax.psum(a, model_axis)
                out[l] = a * jnp.asarray(inv_sqrt_deg, fdt)   # (n_loc, C, 2l+1)
            upd = {l: jnp.einsum("ncm,cd->ndm", out[l],
                                 layer["self2"][str(l)].astype(fdt))
                   for l in cfg.ls}
            scalars = upd[0][..., 0]
            new = {0: feats[0] + jax.nn.silu(scalars)[..., None]}
            gates = jax.nn.sigmoid(scalars @ layer["gate_w"].astype(fdt))
            for li, l in enumerate(cfg.ls[1:]):
                g = gates[:, li * c : (li + 1) * c]
                new[l] = feats[l] + upd[l] * g[..., None]
            return new

        for layer in prm["layers"]:
            feats = jax.checkpoint(layer_fn)(feats, layer)
        return (feats[0][..., 0].astype(jnp.float32) @ prm["out_w"]
                + prm["out_b"])

    from repro import compat
    from repro.compat import P

    return compat.shard_map(
        local_fn,
        in_specs=(
            jax.tree.map(lambda _: P(), params),
            P(nspec, None),
            P(None, espec),
            P(None, None),
            (P(espec) if edge_mask is not None else None),
        ),
        out_specs=P(nspec, None),
    )(params, node_feat, edge_index, positions, edge_mask)


def nequip_loss(params: Params, batch: Dict, cfg: NequIPConfig):
    from repro.distributed.sharding import current_rules

    rules = current_rules()
    if rules is not None:
        batch_axes = tuple(rules.batch) if isinstance(rules.batch, tuple) \
            else (rules.batch,)
        out = nequip_forward_sharded(
            params, batch["node_feat"], batch["edge_index"], batch["positions"],
            cfg, batch.get("edge_mask"),
            node_axes=batch_axes, model_axis=rules.model,
        )
        return _nequip_task_loss(out, batch, cfg)
    out = nequip_forward(
        params, batch["node_feat"], batch["edge_index"], batch["positions"], cfg,
        edge_mask=batch.get("edge_mask"),
    )
    return _nequip_task_loss(out, batch, cfg)


def _nequip_task_loss(out: jax.Array, batch: Dict, cfg: NequIPConfig):
    if cfg.task == "node_classify":
        labels = batch["labels"]                       # (N,) int32; -1 = unlabeled
        mask = labels >= 0
        logz = jax.nn.logsumexp(out, axis=-1)
        ll = jnp.take_along_axis(out, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
        loss = jnp.sum((logz - ll) * mask) / jnp.maximum(jnp.sum(mask), 1)
    else:  # graph_regress: segment-sum node scalars into per-graph energies
        energies = jax.ops.segment_sum(
            out[:, 0], batch["graph_ids"], num_segments=batch["energies"].shape[0]
        )
        loss = jnp.mean(jnp.square(energies - batch["energies"]))
    return loss, {"loss": loss}
