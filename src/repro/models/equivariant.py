"""E(3)-equivariant substrate built from scratch (no e3nn dependency).

Provides, for l ≤ 2 (NequIP config l_max=2):
  * real spherical harmonics ``sh_l(v)`` of unit vectors,
  * real-basis Clebsch-Gordan intertwiners C^{l1 l2 l3} computed at trace
    time in numpy (complex Racah CG + real↔complex change of basis; the
    1-D intertwiner space makes the real/imag selection exact),
  * Wigner-D matrices for the *real* basis recovered numerically from the
    identity  sh_l(R v) = D_l(R) sh_l(v)  (used by the equivariance tests).

Everything is returned as plain numpy constants folded into the jaxpr —
zero runtime cost.
"""
from __future__ import annotations

import functools
from math import factorial, sqrt

import numpy as np
import jax
import jax.numpy as jnp


# ------------------------------------------------- complex Clebsch-Gordan
def _cg_complex(j1: int, m1: int, j2: int, m2: int, j3: int, m3: int) -> float:
    """Condon-Shortley CG coefficient ⟨j1 m1 j2 m2 | j3 m3⟩ (Racah)."""
    if m3 != m1 + m2 or not abs(j1 - j2) <= j3 <= j1 + j2:
        return 0.0
    if abs(m1) > j1 or abs(m2) > j2 or abs(m3) > j3:
        return 0.0
    f = factorial
    pref = sqrt(
        (2 * j3 + 1)
        * f(j3 + j1 - j2) * f(j3 - j1 + j2) * f(j1 + j2 - j3)
        / f(j1 + j2 + j3 + 1)
    )
    pref *= sqrt(
        f(j3 + m3) * f(j3 - m3)
        * f(j1 - m1) * f(j1 + m1)
        * f(j2 - m2) * f(j2 + m2)
    )
    s = 0.0
    for k in range(0, j1 + j2 - j3 + 1):
        denoms = [
            k,
            j1 + j2 - j3 - k,
            j1 - m1 - k,
            j2 + m2 - k,
            j3 - j2 + m1 + k,
            j3 - j1 - m2 + k,
        ]
        if any(d < 0 for d in denoms):
            continue
        s += (-1) ** k / np.prod([float(f(d)) for d in denoms])
    return pref * s


def _real_to_complex_matrix(l: int) -> np.ndarray:
    """U with Y_l^m = Σ_mu U[m+l, mu+l] S_{l,mu} (standard real-SH bridge)."""
    u = np.zeros((2 * l + 1, 2 * l + 1), dtype=np.complex128)
    for m in range(-l, l + 1):
        i = m + l
        if m == 0:
            u[i, l] = 1.0
        elif m > 0:
            u[i, l + m] = (-1) ** m / sqrt(2)
            u[i, l - m] = 1j * (-1) ** m / sqrt(2)
        else:  # m < 0
            u[i, l - m] = 1 / sqrt(2)
            u[i, l + m] = -1j / sqrt(2)
    return u


@functools.lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis intertwiner C (2l1+1, 2l2+1, 2l3+1):
    (u ⊗ v)_c = Σ_ab C[a,b,c] u_a v_b transforms as l3."""
    u1 = _real_to_complex_matrix(l1)
    u2 = _real_to_complex_matrix(l2)
    u3 = _real_to_complex_matrix(l3)
    c = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1), dtype=np.complex128)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) > l3:
                continue
            coeff = _cg_complex(l1, m1, l2, m2, l3, m3)
            if coeff == 0.0:
                continue
            # C_real = U1^T diag-contract: C[a,b,c] += U1[m1,a] U2[m2,b] conj(U3[m3,c]) cg
            c += coeff * np.einsum(
                "a,b,c->abc",
                u1[m1 + l1],
                u2[m2 + l2],
                np.conj(u3[m3 + l3]),
            )
    re, im = np.real(c), np.imag(c)
    # the intertwiner space is 1-D: exactly one of re/im is (numerically) zero
    out = re if np.abs(re).sum() >= np.abs(im).sum() else im
    assert min(np.abs(re).sum(), np.abs(im).sum()) < 1e-10 * max(
        np.abs(out).sum(), 1e-30
    ), f"real CG not pure for ({l1},{l2},{l3})"
    # normalize so ||C||_F = 1 (path normalization, e3nn 'component'-like)
    n = np.linalg.norm(out)
    return (out / n if n > 0 else out).astype(np.float32)


# ----------------------------------------------- real spherical harmonics
SH_C0 = 0.28209479177387814      # 1 / (2 sqrt(pi))
SH_C1 = 0.4886025119029199
SH_C2 = np.array([
    1.0925484305920792,   # xy
    1.0925484305920792,   # yz
    0.31539156525252005,  # 3z^2 - 1
    1.0925484305920792,   # xz
    0.5462742152960396,   # x^2 - y^2
])


def spherical_harmonics(l: int, v: jax.Array) -> jax.Array:
    """Real SH of (possibly non-unit) vectors v (..., 3) — normalized to the
    unit sphere first.  Component order m = -l..l; l=1 order is (y, z, x)."""
    r = jnp.linalg.norm(v, axis=-1, keepdims=True)
    u = v / jnp.maximum(r, 1e-9)
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    if l == 0:
        return jnp.full((*v.shape[:-1], 1), SH_C0, v.dtype)
    if l == 1:
        return SH_C1 * jnp.stack([y, z, x], axis=-1)
    if l == 2:
        return jnp.stack(
            [
                SH_C2[0] * x * y,
                SH_C2[1] * y * z,
                SH_C2[2] * (3 * z * z - 1.0),
                SH_C2[3] * x * z,
                SH_C2[4] * (x * x - y * y),
            ],
            axis=-1,
        )
    raise NotImplementedError(f"l={l} > 2")


def wigner_d_from_rotation(l: int, rot: np.ndarray, n_samples: int = 64,
                           seed: int = 0) -> np.ndarray:
    """Solve sh_l(R v) = D sh_l(v) for D by least squares (test utility)."""
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((n_samples, 3))
    a = np.asarray(spherical_harmonics(l, jnp.asarray(v)))          # (S, 2l+1)
    b = np.asarray(spherical_harmonics(l, jnp.asarray(v @ rot.T)))  # (S, 2l+1)
    d, *_ = np.linalg.lstsq(a, b, rcond=None)
    return d.T  # b^T = D a^T


def random_rotation(seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q
