"""RecSys architectures: DLRM (MLPerf), DeepFM, DIN, BERT4Rec.

These are the paper's home domain.  Every model exposes:

    init_params(cfg, key)          — parameter pytree
    loss(params, batch, cfg)       — training objective (BCE / sampled xent)
    serve(params, batch, cfg)      — pointwise scoring (serve_p99/serve_bulk)
    user_vector(params, batch, cfg)— query-side representation for retrieval
    retrieval head                 — see ``retrieval.py`` in this package:
        dense scoring (baseline) and CompresSAE-compressed scoring (the
        paper's production use case: the item catalog is stored as fixed-k
        sparse codes and scored with the sparse_dot SpMV).

Embedding lookups go through repro.layers.embedding (gather + segment_sum —
JAX has no native EmbeddingBag; DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers.embedding import embedding_bag_fixed, embedding_lookup
from repro.layers.mlp import mlp_stack

Params = Dict[str, Any]

# MLPerf DLRM (Criteo Terabyte) per-table vocabulary sizes, 26 tables.
MLPERF_VOCAB_SIZES: Tuple[int, ...] = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


def _bce_with_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def _init_mlp(key, sizes: List[int], dtype) -> Tuple[list, list]:
    ws, bs = [], []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k = jax.random.fold_in(key, i)
        ws.append(jax.random.normal(k, (a, b), dtype) * math.sqrt(2.0 / a))
        bs.append(jnp.zeros((b,), dtype))
    return ws, bs


# =============================================================== DLRM (MLPerf)
@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    vocab_sizes: Tuple[int, ...] = MLPERF_VOCAB_SIZES
    embed_dim: int = 128
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    # fields treated as query-side for the two-tower retrieval head
    n_user_fields: int = 13
    param_dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)


def dlrm_init(cfg: DLRMConfig, key: jax.Array) -> Params:
    kt, kb, ku = jax.random.split(key, 3)
    tables = {
        f"table_{i}": jax.random.normal(
            jax.random.fold_in(kt, i), (v, cfg.embed_dim), cfg.param_dtype
        ) / math.sqrt(cfg.embed_dim)
        for i, v in enumerate(cfg.vocab_sizes)
    }
    bw, bb = _init_mlp(kb, [cfg.n_dense, *cfg.bot_mlp], cfg.param_dtype)
    n_f = cfg.n_sparse + 1
    n_inter = n_f * (n_f - 1) // 2
    tw, tb = _init_mlp(ku, [cfg.bot_mlp[-1] + n_inter, *cfg.top_mlp], cfg.param_dtype)
    return {"tables": tables, "bot_w": bw, "bot_b": bb, "top_w": tw, "top_b": tb}


def _dlrm_features(params: Params, batch: Dict, cfg: DLRMConfig):
    dense_out = mlp_stack(batch["dense"], params["bot_w"], params["bot_b"],
                          final_activation=True)               # (B, 128)
    embs = jnp.stack(
        [embedding_lookup(params["tables"][f"table_{i}"], batch["cat"][:, i])
         for i in range(cfg.n_sparse)],
        axis=1,
    )                                                           # (B, 26, 128)
    return dense_out, embs


def dlrm_forward(params: Params, batch: Dict, cfg: DLRMConfig) -> jax.Array:
    dense_out, embs = _dlrm_features(params, batch, cfg)
    z = jnp.concatenate([dense_out[:, None, :], embs], axis=1)  # (B, 27, 128)
    inter = jnp.einsum("bfd,bgd->bfg", z, z)                    # (B, 27, 27)
    n_f = z.shape[1]
    iu, ju = jnp.triu_indices(n_f, k=1)
    flat_inter = inter[:, iu, ju]                               # (B, 351)
    top_in = jnp.concatenate([dense_out, flat_inter], axis=-1)
    return mlp_stack(top_in, params["top_w"], params["top_b"])[:, 0]


def dlrm_loss(params: Params, batch: Dict, cfg: DLRMConfig):
    logits = dlrm_forward(params, batch, cfg)
    loss = _bce_with_logits(logits, batch["label"])
    return loss, {"loss": loss}


def dlrm_serve(params: Params, batch: Dict, cfg: DLRMConfig) -> jax.Array:
    return jax.nn.sigmoid(dlrm_forward(params, batch, cfg))


def dlrm_user_vector(params: Params, batch: Dict, cfg: DLRMConfig) -> jax.Array:
    """Two-tower query vector: bottom-MLP output + sum of user-side
    embeddings (first n_user_fields tables) — DESIGN.md §Arch-applicability."""
    dense_out, embs = _dlrm_features(params, batch, cfg)
    return dense_out + jnp.sum(embs[:, : cfg.n_user_fields], axis=1)


# ==================================================================== DeepFM
@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str = "deepfm"
    vocab_sizes: Tuple[int, ...] = tuple([1000] * 13 + [100000] * 26)  # 39 fields
    embed_dim: int = 10
    mlp: Tuple[int, ...] = (400, 400, 400)
    n_user_fields: int = 20
    param_dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)


def deepfm_init(cfg: DeepFMConfig, key: jax.Array) -> Params:
    kt, kw, km = jax.random.split(key, 3)
    tables = {
        f"table_{i}": jax.random.normal(
            jax.random.fold_in(kt, i), (v, cfg.embed_dim), cfg.param_dtype
        ) / math.sqrt(cfg.embed_dim)
        for i, v in enumerate(cfg.vocab_sizes)
    }
    lin = {
        f"lin_{i}": jnp.zeros((v, 1), cfg.param_dtype)
        for i, v in enumerate(cfg.vocab_sizes)
    }
    mw, mb = _init_mlp(km, [cfg.n_sparse * cfg.embed_dim, *cfg.mlp, 1],
                       cfg.param_dtype)
    return {"tables": tables, "lin": lin, "bias": jnp.zeros((), cfg.param_dtype),
            "mlp_w": mw, "mlp_b": mb}


def deepfm_forward(params: Params, batch: Dict, cfg: DeepFMConfig) -> jax.Array:
    cat = batch["cat"]                                          # (B, 39)
    embs = jnp.stack(
        [embedding_lookup(params["tables"][f"table_{i}"], cat[:, i])
         for i in range(cfg.n_sparse)],
        axis=1,
    )                                                           # (B, 39, 10)
    first = params["bias"] + sum(
        embedding_lookup(params["lin"][f"lin_{i}"], cat[:, i])[:, 0]
        for i in range(cfg.n_sparse)
    )                                                           # (B,)
    s = jnp.sum(embs, axis=1)
    fm = 0.5 * jnp.sum(jnp.square(s) - jnp.sum(jnp.square(embs), axis=1), axis=-1)
    deep_in = embs.reshape(embs.shape[0], -1)
    deep = mlp_stack(deep_in, params["mlp_w"], params["mlp_b"])[:, 0]
    return first + fm + deep


def deepfm_loss(params: Params, batch: Dict, cfg: DeepFMConfig):
    logits = deepfm_forward(params, batch, cfg)
    loss = _bce_with_logits(logits, batch["label"])
    return loss, {"loss": loss}


def deepfm_serve(params: Params, batch: Dict, cfg: DeepFMConfig) -> jax.Array:
    return jax.nn.sigmoid(deepfm_forward(params, batch, cfg))


def deepfm_user_vector(params: Params, batch: Dict, cfg: DeepFMConfig) -> jax.Array:
    cat = batch["cat"]
    embs = jnp.stack(
        [embedding_lookup(params["tables"][f"table_{i}"], cat[:, i])
         for i in range(cfg.n_user_fields)],
        axis=1,
    )
    return jnp.sum(embs, axis=1)                                # (B, 10)


# ======================================================================== DIN
@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    param_dtype: Any = jnp.float32


def din_init(cfg: DINConfig, key: jax.Array) -> Params:
    kt, ka, km = jax.random.split(key, 3)
    d = cfg.embed_dim
    aw, ab = _init_mlp(ka, [4 * d, *cfg.attn_mlp, 1], cfg.param_dtype)
    mw, mb = _init_mlp(km, [3 * d, *cfg.mlp, 1], cfg.param_dtype)
    return {
        "items": jax.random.normal(kt, (cfg.n_items, d), cfg.param_dtype)
        / math.sqrt(d),
        "attn_w": aw, "attn_b": ab, "mlp_w": mw, "mlp_b": mb,
    }


def _din_user_vec(params: Params, hist_emb, hist_mask, target_emb):
    """Target-aware attention pooling.  hist_emb (B, T, d); target (B, d)
    (or (B, C, d) for batched candidate scoring via leading broadcast)."""
    t = jnp.broadcast_to(target_emb[:, None, :], hist_emb.shape)
    feats = jnp.concatenate(
        [hist_emb, t, hist_emb * t, hist_emb - t], axis=-1
    )                                                           # (B, T, 4d)
    w = mlp_stack(feats, params["attn_w"], params["attn_b"])[..., 0]  # (B, T)
    w = jnp.where(hist_mask, w, 0.0)            # DIN: no softmax normalization
    return jnp.einsum("bt,btd->bd", w, hist_emb)


def din_forward(params: Params, batch: Dict, cfg: DINConfig) -> jax.Array:
    hist = batch["hist"]                                        # (B, T) -1 pad
    target = batch["target"]                                    # (B,)
    hist_emb = embedding_lookup(params["items"], jnp.maximum(hist, 0))
    mask = hist >= 0
    hist_emb = hist_emb * mask[..., None]
    t_emb = embedding_lookup(params["items"], target)
    u = _din_user_vec(params, hist_emb, mask, t_emb)
    x = jnp.concatenate([u, t_emb, u * t_emb], axis=-1)
    return mlp_stack(x, params["mlp_w"], params["mlp_b"])[:, 0]


def din_loss(params: Params, batch: Dict, cfg: DINConfig):
    logits = din_forward(params, batch, cfg)
    loss = _bce_with_logits(logits, batch["label"])
    return loss, {"loss": loss}


def din_serve(params: Params, batch: Dict, cfg: DINConfig) -> jax.Array:
    return jax.nn.sigmoid(din_forward(params, batch, cfg))


def din_score_candidate_embs(
    params: Params, batch: Dict, c_emb: jax.Array, cfg: DINConfig
) -> jax.Array:
    """Exact vectorized DIN scoring given candidate embeddings (C, d):
    target-aware attention recomputed per candidate — batched einsum,
    not a loop.  Returns (1, C)."""
    hist = batch["hist"]                                        # (1, T)
    hist_emb = embedding_lookup(params["items"], jnp.maximum(hist, 0))
    mask = hist >= 0
    hist_emb = hist_emb * mask[..., None]
    t = c_emb[None, :, None, :]                                 # (1, C, 1, d)
    h = hist_emb[:, None, :, :]                                 # (1, 1, T, d)
    hb = jnp.broadcast_to(h, (1, c_emb.shape[0], *hist_emb.shape[1:]))
    tb = jnp.broadcast_to(t, hb.shape)
    feats = jnp.concatenate([hb, tb, hb * tb, hb - tb], axis=-1)
    w = mlp_stack(feats, params["attn_w"], params["attn_b"])[..., 0]  # (1,C,T)
    w = jnp.where(mask[:, None, :], w, 0.0)
    u = jnp.einsum("bct,btd->bcd", w, hist_emb)                 # (1, C, d)
    x = jnp.concatenate([u, tb[:, :, 0, :], u * tb[:, :, 0, :]], axis=-1)
    return mlp_stack(x, params["mlp_w"], params["mlp_b"])[..., 0]  # (1, C)


def din_score_candidates(
    params: Params, batch: Dict, candidates: jax.Array, cfg: DINConfig
) -> jax.Array:
    """retrieval_cand cell: candidates (C,) item ids -> scores (1, C)."""
    c_emb = embedding_lookup(params["items"], candidates)       # (C, d)
    return din_score_candidate_embs(params, batch, c_emb, cfg)


def din_user_vector(params: Params, batch: Dict, cfg: DINConfig) -> jax.Array:
    """Sum-pooled user vector (two-tower mode for compressed retrieval)."""
    hist = batch["hist"]
    hist_emb = embedding_lookup(params["items"], jnp.maximum(hist, 0))
    mask = (hist >= 0)[..., None]
    return jnp.sum(hist_emb * mask, axis=1)


# =================================================================== BERT4Rec
@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff: int = 256
    n_negatives: int = 1024
    param_dtype: Any = jnp.float32

    @property
    def vocab(self) -> int:
        return self.n_items + 2          # + padding id, + [MASK] id

    @property
    def mask_id(self) -> int:
        return self.n_items + 1


def bert4rec_init(cfg: Bert4RecConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 4 + cfg.n_blocks)
    d = cfg.embed_dim
    params: Params = {
        "items": jax.random.normal(ks[0], (cfg.vocab, d), cfg.param_dtype)
        / math.sqrt(d),
        "pos": 0.02 * jax.random.normal(ks[1], (cfg.seq_len, d), cfg.param_dtype),
        "ln_f": jnp.zeros((d,), cfg.param_dtype),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        k = ks[4 + i]
        kk = jax.random.split(k, 6)
        std = 0.02
        params["blocks"].append({
            "ln1": jnp.zeros((d,), cfg.param_dtype),
            "ln2": jnp.zeros((d,), cfg.param_dtype),
            "wq": std * jax.random.normal(kk[0], (d, d), cfg.param_dtype),
            "wk": std * jax.random.normal(kk[1], (d, d), cfg.param_dtype),
            "wv": std * jax.random.normal(kk[2], (d, d), cfg.param_dtype),
            "wo": std * jax.random.normal(kk[3], (d, d), cfg.param_dtype),
            "w_in": std * jax.random.normal(kk[4], (d, cfg.d_ff), cfg.param_dtype),
            "w_out": std * jax.random.normal(kk[5], (cfg.d_ff, d), cfg.param_dtype),
        })
    return params


def bert4rec_encode(params: Params, hist: jax.Array, cfg: Bert4RecConfig) -> jax.Array:
    """Bidirectional encoder over item sequence.  hist (B, S) int32 ids
    (pad id = n_items).  Returns hidden (B, S, d)."""
    from repro.layers.attention import flash_attention
    from repro.layers.norms import layer_norm

    b, s = hist.shape
    d, h = cfg.embed_dim, cfg.n_heads
    x = embedding_lookup(params["items"], hist) + params["pos"][None, :s]
    for blk in params["blocks"]:
        hn = layer_norm(x, 1.0 + blk["ln1"])
        q = (hn @ blk["wq"]).reshape(b, s, h, d // h)
        k = (hn @ blk["wk"]).reshape(b, s, h, d // h)
        v = (hn @ blk["wv"]).reshape(b, s, h, d // h)
        o = flash_attention(q, k, v, causal=False, q_chunk=128, kv_chunk=128)
        x = x + o.reshape(b, s, d) @ blk["wo"]
        hn = layer_norm(x, 1.0 + blk["ln2"])
        x = x + jax.nn.gelu(hn @ blk["w_in"], approximate=True) @ blk["w_out"]
    return layer_norm(x, 1.0 + params["ln_f"])


def bert4rec_loss(params: Params, batch: Dict, cfg: Bert4RecConfig):
    """Masked-item prediction with shared sampled negatives.

    batch: hist (B, S) with [MASK] tokens already substituted;
           masked_positions (B, M) indices of the masked slots (fixed M —
              static shapes; may repeat position 0 with label -1 padding);
           labels (B, M) true ids at those positions, -1 = padding;
           negatives (K,) sampled item ids.

    Scoring only the M masked positions (instead of all S) keeps the
    sampled-softmax logits at (B, M, K) — 5x smaller at the standard 20%
    mask rate.
    """
    hidden = bert4rec_encode(params, batch["hist"], cfg)        # (B, S, d)
    pos_idx = batch["masked_positions"]                          # (B, M)
    labels = batch["labels"]                                     # (B, M)
    h = jnp.take_along_axis(hidden, pos_idx[..., None], axis=1)  # (B, M, d)
    mask = labels >= 0
    pos_emb = embedding_lookup(params["items"], jnp.maximum(labels, 0))
    neg_emb = embedding_lookup(params["items"], batch["negatives"])  # (K, d)
    pos_logit = jnp.sum(h * pos_emb, axis=-1)                    # (B, M)
    neg_logit = jnp.einsum("bmd,kd->bmk", h, neg_emb)            # (B, M, K)
    logz = jax.nn.logsumexp(
        jnp.concatenate([pos_logit[..., None], neg_logit], axis=-1), axis=-1
    )
    nll = (logz - pos_logit) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
    return loss, {"loss": loss}


def bert4rec_user_vector(params: Params, batch: Dict, cfg: Bert4RecConfig) -> jax.Array:
    """Next-item query vector: hidden state at the final ([MASK]) position."""
    hidden = bert4rec_encode(params, batch["hist"], cfg)
    return hidden[:, -1, :]                                     # (B, d)


def bert4rec_serve(params: Params, batch: Dict, cfg: Bert4RecConfig) -> jax.Array:
    """Score a provided candidate set per user: (B, C)."""
    u = bert4rec_user_vector(params, batch, cfg)                # (B, d)
    c_emb = embedding_lookup(params["items"], batch["candidates"])  # (B, C, d)
    return jnp.einsum("bd,bcd->bc", u, c_emb)
