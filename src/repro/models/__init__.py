# Model zoo: transformer (LM family), nequip (equivariant GNN),
# recsys (DLRM / DIN / DeepFM / BERT4Rec).  See repro.models.registry for
# the arch-id -> model mapping used by configs and the launcher.
