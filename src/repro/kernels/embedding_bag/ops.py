"""Public jit'd wrapper for embedding_bag."""
from __future__ import annotations

import functools

import jax

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("mode",))
def embedding_bag(table: jax.Array, ids: jax.Array, mode: str = "sum") -> jax.Array:
    """table (V, dim), ids (B, L) int32 (negative = pad) -> (B, dim)."""
    if mode not in ("sum", "mean"):
        raise ValueError(f"mode must be sum|mean, got {mode}")
    return embedding_bag_pallas(table, ids, mode=mode, interpret=not _on_tpu())
