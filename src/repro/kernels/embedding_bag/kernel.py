"""EmbeddingBag Pallas kernel: HBM-resident table, DMA row gather, bag sum.

Recsys embedding tables (10⁶–10⁹ rows × dim 16–128) never fit VMEM, and
TPUs have no hardware HBM gather — the TPU-native pattern (same as paged-
attention KV fetch) is:

  * the table stays in HBM (`memory_space=ANY`, no BlockSpec tiling),
  * bag indices are **scalar-prefetched** into SMEM
    (`pltpu.PrefetchScalarGridSpec`) so they are available *before* the
    kernel body runs and can drive DMA issue,
  * each grid step owns one bag: L rows are fetched HBM→VMEM with explicit
    `make_async_copy` and accumulated on the VPU; padding ids (< 0) are
    masked, `mean` divides by the live count.

Latency note: per-row DMAs of dim·4 bytes (≥512 B at dim=128) are
latency-bound; a production variant issues the row copies double-buffered.
The interpret-validated single-buffer loop keeps the dataflow identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, table_ref, out_ref, row_scratch, sem, *, l: int, mean: bool):
    bag = pl.program_id(0)

    def body(j, carry):
        acc, count = carry
        idx = ids_ref[bag, j]
        safe = jnp.maximum(idx, 0)
        copy = pltpu.make_async_copy(
            table_ref.at[pl.dslice(safe, 1), :], row_scratch, sem
        )
        copy.start()
        copy.wait()
        live = (idx >= 0).astype(jnp.float32)
        acc = acc + live * row_scratch[...].astype(jnp.float32)
        return acc, count + live

    acc0 = jnp.zeros(out_ref.shape, jnp.float32)
    acc, count = jax.lax.fori_loop(0, l, body, (acc0, jnp.zeros((), jnp.float32)))
    if mean:
        acc = acc / jnp.maximum(count, 1.0)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag_pallas(
    table: jax.Array, ids: jax.Array, *, mode: str = "sum", interpret: bool = False
) -> jax.Array:
    b, l = ids.shape
    _, dim = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((1, dim), lambda i, ids_ref: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, dim), table.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, l=l, mean=(mode == "mean")),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, dim), table.dtype),
        interpret=interpret,
    )(ids, table)
