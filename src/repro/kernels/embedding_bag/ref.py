"""Pure-jnp oracle for embedding_bag (JAX has no native nn.EmbeddingBag).

The gather + masked segment-reduce formulation — this is also the substrate
implementation used by the recsys models (repro.layers.embedding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    table: jax.Array, ids: jax.Array, mode: str = "sum"
) -> jax.Array:
    """table (V, dim); ids (B, L) int32, negative = padding.  -> (B, dim)."""
    v = table.shape[0]
    rows = table[jnp.clip(ids, 0, v - 1)]               # (B, L, dim)
    valid = (ids >= 0)[..., None].astype(table.dtype)   # (B, L, 1)
    out = jnp.sum(rows * valid, axis=1)
    if mode == "mean":
        out = out / jnp.maximum(jnp.sum(valid, axis=1), 1.0)
    return out
