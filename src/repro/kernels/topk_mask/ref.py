"""Pure-jnp oracle for the φ(·, k) abs-top-k activation."""
from __future__ import annotations

import jax

from repro.core.topk import abs_topk


def topk_mask_ref(x: jax.Array, k: int) -> jax.Array:
    """(B, h) -> (B, h): zero all but the k largest-|value| entries per row."""
    return abs_topk(x, k)
