"""Public jit'd wrapper for topk_mask: pads the batch, handles leading dims."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk_mask.kernel import BLOCK_B, topk_mask_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "block_b"))
def topk_mask(x: jax.Array, k: int, *, block_b: int = BLOCK_B) -> jax.Array:
    """φ(x, k) over the last axis; any leading shape."""
    lead = x.shape[:-1]
    h = x.shape[-1]
    flat = x.reshape(-1, h)
    b = flat.shape[0]
    bb = min(block_b, max(8, b))
    pad = (-b) % bb
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    out = topk_mask_pallas(flat, k, interpret=not _on_tpu(), block_b=bb)
    return out[:b].reshape(*lead, h)
