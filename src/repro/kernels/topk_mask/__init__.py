from repro.kernels.topk_mask.ops import topk_mask
from repro.kernels.topk_mask.ref import topk_mask_ref

__all__ = ["topk_mask", "topk_mask_ref"]
