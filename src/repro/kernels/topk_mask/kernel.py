"""φ(·, k) abs-top-k Pallas kernel (paper eq. 1).

TPU mapping:
  * x streams HBM→VMEM in (BLOCK_B, h) tiles; the full latent dim h stays
    resident (h=4096 f32 ⇒ 16 KiB/row; BLOCK_B=256 ⇒ 4 MiB — fits VMEM).
  * Selection is k rounds of masked-argmax on the VPU: per round, a lane
    max-reduction finds the current row max of |x|, a broadcasted-iota
    min-reduction breaks ties toward the lowest index (matching
    jax.lax.top_k), the winner is recorded in the keep-mask and knocked out.
    k ≪ h (32 vs 4096), so k·O(B·h) VPU work beats a full O(B·h·log h) sort
    and — unlike lax.top_k/sort — uses only max/where/iota primitives that
    Mosaic lowers natively.
  * Everything is elementwise/reduction: no MXU, no gather; bound by HBM
    stream of x in/out (roofline: memory term).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 256


def _kernel(x_ref, out_ref, *, k: int):
    x = x_ref[...]                                   # (BLOCK_B, h)
    h = x.shape[-1]
    absx = jnp.abs(x)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    def body(_, carry):
        work, kept = carry
        m = jnp.max(work, axis=-1, keepdims=True)            # row max
        is_max = work == m
        first = jnp.min(jnp.where(is_max, col, h), axis=-1, keepdims=True)
        sel = col == first                                   # one per row
        return jnp.where(sel, -jnp.inf, work), jnp.logical_or(kept, sel)

    _, kept = jax.lax.fori_loop(
        0, k, body, (absx, jnp.zeros(x.shape, dtype=jnp.bool_))
    )
    out_ref[...] = jnp.where(kept, x, 0.0)


@functools.partial(jax.jit, static_argnames=("k", "interpret", "block_b"))
def topk_mask_pallas(
    x: jax.Array, k: int, *, interpret: bool = False, block_b: int = BLOCK_B
) -> jax.Array:
    b, h = x.shape
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(b // block_b,),
        in_specs=[pl.BlockSpec((block_b, h), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h), x.dtype),
        interpret=interpret,
    )(x)
