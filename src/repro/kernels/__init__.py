"""Pallas TPU kernels for CompresSAE's compute hot-spots.

Each subpackage ships:
    kernel.py — pl.pallas_call + BlockSpec VMEM tiling (TPU target)
    ops.py    — jit'd public wrapper with CPU-interpret fallback
    ref.py    — pure-jnp oracle used by tests/benchmarks

Kernels:
    sparse_dot    — scatter-query SpMV: fixed-k sparse candidates × dense
                    query (retrieval scoring, paper §3.2)
    topk_mask     — φ(·, k) abs-top-k activation (paper eq. 1)
    fused_encode  — W_enc matmul + bias + φ(·, k) epilogue emitting sparse
                    codes without materializing (B, h) pre-activations to
                    HBM (beyond-paper memory-roofline optimization)
    embedding_bag — gather + segment-reduce over an HBM-resident embedding
                    table (recsys substrate; JAX has no native EmbeddingBag)
"""
from repro.kernels.sparse_dot import ops as sparse_dot_ops
from repro.kernels.topk_mask import ops as topk_mask_ops
from repro.kernels.fused_encode import ops as fused_encode_ops
from repro.kernels.embedding_bag import ops as embedding_bag_ops

__all__ = [
    "sparse_dot_ops",
    "topk_mask_ops",
    "fused_encode_ops",
    "embedding_bag_ops",
]
