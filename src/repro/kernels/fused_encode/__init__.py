from repro.kernels.fused_encode.ops import fused_encode
from repro.kernels.fused_encode.ref import fused_encode_ref

__all__ = ["fused_encode", "fused_encode_ref"]
