"""Public jit'd wrapper for fused_encode: normalize + pad + dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sae import normalize_input
from repro.core.types import SparseCodes
from repro.kernels.fused_encode.kernel import BLOCK_B, BLOCK_D, fused_encode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "block_b", "block_d"))
def fused_encode(
    x: jax.Array,
    w_enc: jax.Array,
    b_enc: jax.Array,
    k: int,
    *,
    block_b: int = BLOCK_B,
    block_d: int = BLOCK_D,
) -> SparseCodes:
    """Dense (B, d) -> fixed-k SparseCodes without HBM pre-activations.

    Equivalent to repro.core.sae.encode (same selection, same tie-breaks).
    """
    b, d = x.shape
    h = w_enc.shape[1]
    x = normalize_input(x)
    bd = min(block_d, d)
    bb = min(block_b, max(8, b))
    pad_b = (-b) % bb
    pad_d = (-d) % bd
    if pad_b or pad_d:
        x = jnp.pad(x, ((0, pad_b), (0, pad_d)))
    if pad_d:
        w_enc = jnp.pad(w_enc, ((0, pad_d), (0, 0)))
    vals, idx = fused_encode_pallas(
        x, w_enc, b_enc, k, interpret=not _on_tpu(), block_b=bb, block_d=bd
    )
    return SparseCodes(values=vals[:b], indices=idx[:b], dim=h)
