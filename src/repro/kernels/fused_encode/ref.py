"""Pure-jnp oracle for the fused encoder: matmul + bias + abs-top-k codes."""
from __future__ import annotations

import jax

from repro.core.topk import abs_topk_sparse


def fused_encode_ref(
    x_norm: jax.Array, w_enc: jax.Array, b_enc: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """x_norm (B, d) [already L2-normalized], w_enc (d, h), b_enc (h,).

    Returns (values (B, k) f32, indices (B, k) i32) of φ(x̄·W + b, k).
    """
    pre = x_norm @ w_enc + b_enc
    return abs_topk_sparse(pre, k)
