"""Fused CompresSAE encoder: (x̄ @ W_enc + b) → φ(·, k) → sparse codes.

Beyond-paper memory-roofline optimization (DESIGN.md §3, EXPERIMENTS.md
§Perf): the naive encode materializes (B, h) pre-activations to HBM
(B=10⁵, h=4096 f32 ⇒ 1.6 GB written + re-read).  Fusing the abs-top-k
epilogue into the matmul keeps the pre-activation tile in VMEM scratch and
writes only the (B, 2k) sparse codes — a ~64× reduction in epilogue HBM
traffic at h=4096, k=32.

TPU mapping:
  * Grid (B/BLOCK_B, d/BLOCK_D); the d axis is the reduction — 'arbitrary'
    semantics with an fp32 VMEM accumulator (BLOCK_B, h), zeroed on the
    first d-step (classic matmul+epilogue pattern).
  * Each step: (BLOCK_B, BLOCK_D) × (BLOCK_D, h) on the MXU; h=4096 lanes.
  * On the last d-step: add bias, run the same k-round masked-argmax
    selection as topk_mask, but also *record* (value, index) per round via
    dynamic_update_slice into (BLOCK_B, k) staging buffers → HBM.
  * VMEM budget at BLOCK_B=128, BLOCK_D=256, h=4096: acc 2 MiB + W tile
    4 MiB + x tile 128 KiB + outputs ≪ 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_B = 128
BLOCK_D = 256


def _kernel(x_ref, w_ref, b_ref, vals_ref, idx_ref, acc_ref, *, k: int, nd: int):
    d_step = pl.program_id(1)

    @pl.when(d_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(d_step == nd - 1)
    def _epilogue():
        pre = acc_ref[...] + b_ref[...]                  # (BLOCK_B, h)
        h = pre.shape[-1]
        absx = jnp.abs(pre)
        col = jax.lax.broadcasted_iota(jnp.int32, pre.shape, 1)

        def body(j, carry):
            work, vals, idxs = carry
            m = jnp.max(work, axis=-1, keepdims=True)
            is_max = work == m
            first = jnp.min(jnp.where(is_max, col, h), axis=-1, keepdims=True)
            sel = col == first
            v_j = jnp.sum(jnp.where(sel, pre, 0.0), axis=-1, keepdims=True)
            vals = jax.lax.dynamic_update_slice(vals, v_j, (0, j))
            idxs = jax.lax.dynamic_update_slice(idxs, first.astype(jnp.int32), (0, j))
            return jnp.where(sel, -jnp.inf, work), vals, idxs

        init = (
            absx,
            jnp.zeros((pre.shape[0], k), jnp.float32),
            jnp.zeros((pre.shape[0], k), jnp.int32),
        )
        _, vals, idxs = jax.lax.fori_loop(0, k, body, init)
        vals_ref[...] = vals
        idx_ref[...] = idxs


@functools.partial(
    jax.jit, static_argnames=("k", "interpret", "block_b", "block_d")
)
def fused_encode_pallas(
    x_norm: jax.Array,
    w_enc: jax.Array,
    b_enc: jax.Array,
    k: int,
    *,
    interpret: bool = False,
    block_b: int = BLOCK_B,
    block_d: int = BLOCK_D,
) -> tuple[jax.Array, jax.Array]:
    b, d = x_norm.shape
    d2, h = w_enc.shape
    assert d == d2
    nd = d // block_d
    grid = (b // block_b, nd)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, nd=nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_d, h), lambda i, j: (j, 0)),
            pl.BlockSpec((1, h), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_b, h), jnp.float32)],
        interpret=interpret,
    )(x_norm, w_enc, b_enc[None])
