from repro.kernels.sparse_dot.ops import (
    fused_retrieve,
    fused_retrieve_quantized,
    fused_retrieve_quantized_mxu,
    fused_retrieve_quantized_mxu_sparse_q,
    fused_retrieve_quantized_sparse_q,
    fused_retrieve_sparse_q,
    sparse_dot,
)
from repro.kernels.sparse_dot.ref import (
    retrieve_quantized_mxu_ref,
    retrieve_quantized_mxu_sparse_q_ref,
    retrieve_quantized_ref,
    retrieve_quantized_sparse_q_ref,
    retrieve_ref,
    retrieve_sparse_q_ref,
    sparse_dot_ref,
)

__all__ = [
    "sparse_dot",
    "sparse_dot_ref",
    "fused_retrieve",
    "retrieve_ref",
    "fused_retrieve_sparse_q",
    "retrieve_sparse_q_ref",
    "fused_retrieve_quantized",
    "retrieve_quantized_ref",
    "fused_retrieve_quantized_sparse_q",
    "retrieve_quantized_sparse_q_ref",
    "fused_retrieve_quantized_mxu",
    "retrieve_quantized_mxu_ref",
    "fused_retrieve_quantized_mxu_sparse_q",
    "retrieve_quantized_mxu_sparse_q_ref",
]
