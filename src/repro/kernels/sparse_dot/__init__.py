from repro.kernels.sparse_dot.ops import sparse_dot
from repro.kernels.sparse_dot.ref import sparse_dot_ref

__all__ = ["sparse_dot", "sparse_dot_ref"]
