from repro.kernels.sparse_dot.ops import (
    fused_retrieve,
    fused_retrieve_sparse_q,
    sparse_dot,
)
from repro.kernels.sparse_dot.ref import (
    retrieve_ref,
    retrieve_sparse_q_ref,
    sparse_dot_ref,
)

__all__ = [
    "sparse_dot",
    "sparse_dot_ref",
    "fused_retrieve",
    "retrieve_ref",
    "fused_retrieve_sparse_q",
    "retrieve_sparse_q_ref",
]
