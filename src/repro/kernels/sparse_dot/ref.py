"""Pure-jnp oracles for the scatter-query SpMV family.

``sparse_dot_ref``  — materializes the full (Q, N) score matrix (oracle for
                      the blocked scoring kernel).
``retrieve_ref``    — chunked streaming score+select: scans (block_n, k)
                      candidate blocks and carries per-query running top-n
                      (score, id) buffers, merging each block with one
                      ``lax.top_k`` over n + block_n candidates.  This is
                      the CPU serving path AND the oracle for the fused
                      Pallas kernel: same traffic shape (no (Q, N)
                      transient beyond one block) and same tie semantics
                      (running buffer precedes the block in the merge, so
                      equal scores resolve to the lowest candidate id,
                      exactly like a global ``lax.top_k``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def sparse_dot_ref(values: jax.Array, indices: jax.Array, q: jax.Array) -> jax.Array:
    """scores[qi, i] = sum_j values[i, j] * q[qi, indices[i, j]].

    values: (N, k) float; indices: (N, k) int32 in [0, h); q: (Q, h).
    Returns (Q, N) float32.
    """
    gathered = q[:, indices]                      # (Q, N, k)
    return jnp.sum(gathered * values[None].astype(q.dtype), axis=-1)


@functools.partial(jax.jit, static_argnames=("n", "block_n", "q_chunk"))
def retrieve_ref(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Chunked streaming top-n -> ((Q, n) norm-folded scores, (Q, n) ids).

    values (N, k), indices (N, k) i32, inv_norms (N,) reciprocal candidate
    norms, q (Q, h).  Scores are dot · inv_norms; the per-query 1/‖q‖
    factor is the caller's (it cannot reorder a query's top-n).  The gather
    transient is (min(Q, q_chunk), block_n, k) — queries beyond q_chunk are
    processed in chunks, so memory stays bounded for big batches.
    """
    N, k = values.shape
    nq = q.shape[0]
    if nq > q_chunk:
        qpad = (-nq) % q_chunk
        qp = jnp.pad(q, ((0, qpad), (0, 0))) if qpad else q
        chunks = qp.reshape(-1, q_chunk, q.shape[-1])
        bv, bi = jax.lax.map(
            lambda qb: retrieve_ref(
                values, indices, inv_norms, qb,
                n=n, block_n=block_n, q_chunk=q_chunk,
            ),
            chunks,
        )
        return bv.reshape(-1, n)[:nq], bi.reshape(-1, n)[:nq]
    block_n = min(block_n, max(N, 1))
    pad = (-N) % block_n
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        inv_norms = jnp.pad(inv_norms, (0, pad))
    nb = (N + pad) // block_n
    vals_b = values.reshape(nb, block_n, k)
    idx_b = indices.reshape(nb, block_n, k)
    inv_b = inv_norms.reshape(nb, block_n)
    ids_b = jnp.arange(nb * block_n, dtype=jnp.int32).reshape(nb, block_n)

    init = (
        jnp.full((nq, n), -jnp.inf, jnp.float32),
        jnp.zeros((nq, n), jnp.int32),
    )

    def step(carry, blk):
        best_v, best_i = carry
        bv, bi, binv, bids = blk
        gathered = q[:, bi]                                  # (Q, block_n, k)
        s = jnp.sum(gathered * bv[None].astype(q.dtype), axis=-1)
        s = (s * binv[None]).astype(jnp.float32)             # (Q, block_n)
        s = jnp.where(bids[None] < N, s, -jnp.inf)           # mask padding
        cand_v = jnp.concatenate([best_v, s], axis=1)
        cand_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(bids[None], s.shape)], axis=1
        )
        v, p = jax.lax.top_k(cand_v, n)
        return (v, jnp.take_along_axis(cand_i, p, axis=1)), None

    (best_v, best_i), _ = jax.lax.scan(step, init, (vals_b, idx_b, inv_b, ids_b))
    return best_v, best_i
