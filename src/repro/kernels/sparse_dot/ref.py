"""Pure-jnp oracles for the scatter-query SpMV family.

``sparse_dot_ref``  — materializes the full (Q, N) score matrix (oracle for
                      the blocked scoring kernel).
``retrieve_ref``    — chunked streaming score+select: scans (block_n, k)
                      candidate blocks and carries per-query running top-n
                      (score, id) buffers, merging each block with one
                      ``lax.top_k`` over n + block_n candidates.  This is
                      the CPU serving path AND the oracle for the fused
                      Pallas kernel: same traffic shape (no (Q, N)
                      transient beyond one block) and same tie semantics
                      (running buffer precedes the block in the merge, so
                      equal scores resolve to the lowest candidate id,
                      exactly like a global ``lax.top_k``).
``retrieve_sparse_q_ref`` — sparse-query generation: takes (Q, kq)
                      (values, indices) query codes and densifies at most
                      one ≤q_chunk query slab at a time (row-wise
                      scatter-add, identical to ``sparse.densify``) before
                      streaming the same chunked score+select.  CPU mirror
                      of ``fused_retrieve_sparse_q_pallas``: a full (Q, h)
                      dense query matrix never exists.
``retrieve_quantized_ref`` / ``retrieve_quantized_sparse_q_ref`` —
                      quantized-index generation: candidate blocks arrive
                      as int8 values + int16/int32 indices + f32 per-row
                      scales and are dequantized one (block_n, k) block at
                      a time inside the scan (same two ops as the offline
                      dequant, plus the low-16-bit index widen), so an
                      fp32 copy of the index never exists — the CPU mirror
                      of ``fused_retrieve_quantized_pallas``'s VMEM
                      dequant, bit-identical to dequantize-then-
                      ``retrieve_ref`` on the same quantized values.
``retrieve_quantized_mxu_ref`` / ``retrieve_quantized_mxu_sparse_q_ref`` —
                      generation 5, the APPROXIMATE int8-scoring path: the
                      query panel is quantized per row to int8
                      (``_quantize_panel`` — the same symmetric arithmetic
                      as ``quantize_codes``), scores accumulate as
                      int8×int8 products in int32, and one f32 rescale by
                      q_scale·(row_scale·inv_norm) lands in the merge.
                      Because int32 accumulation is exact and
                      order-invariant, this is the one generation whose
                      ref is BIT-identical to its Pallas kernel — the
                      kernel↔exact-f32 relationship, by contrast, is a
                      measured quality bound (``repro.core.eval``), not an
                      equality.
``retrieve_gathered_*_sparse_q_ref`` — generation 6, the GATHER-AWARE
                      re-rank for batched two-stage retrieval: every query
                      brings its own (B,) candidate row set, so candidate
                      arrays carry a leading query axis — values/indices
                      (Q, B, k), inv_norms/scales (Q, B) — and the per-
                      block gather indexes each query's own dense panel.
                      Returned ids are positions WITHIN each query's
                      candidate set (the caller maps them back through its
                      row table).  Per query, the arithmetic is op-for-op
                      the matching per-query generation (``retrieve_
                      sparse_q_ref`` and friends over the pre-gathered
                      sub-arrays), which is what makes batched stage 2
                      bit-identical to PR 7's per-query re-rank loop.

The exact streaming variants share one chunked impl (``_retrieve_chunked``)
and the int8-scoring pair shares ``_retrieve_chunked_mxu``; all differ
only in the per-block dequant / int8-scoring step.  The gathered
generation mirrors the pair as ``_retrieve_gathered_chunked`` /
``_retrieve_gathered_chunked_mxu``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def sparse_dot_ref(values: jax.Array, indices: jax.Array, q: jax.Array) -> jax.Array:
    """scores[qi, i] = sum_j values[i, j] * q[qi, indices[i, j]].

    values: (N, k) float; indices: (N, k) int32 in [0, h); q: (Q, h).
    Returns (Q, N) float32.
    """
    gathered = q[:, indices]                      # (Q, N, k)
    return jnp.sum(gathered * values[None].astype(q.dtype), axis=-1)


def _widen_idx(indices: jax.Array) -> jax.Array:
    """int16-stored (possibly two's-complement-wrapped) indices -> exact
    int32; int32 passes through.  The kernel-package twin of
    ``core.quantized_codes.widen_indices`` (kept local so the kernels stay
    import-cycle-free with repro.core, like ``_densify_rows``); used by
    both the jnp refs and the Pallas ``_dequant_tile``."""
    if indices.dtype == jnp.int32:
        return indices
    return jnp.bitwise_and(indices.astype(jnp.int32), 0xFFFF)


def _quantize_panel(panel: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization of a dense (Q, h) query panel.

    Exactly ``core.quantized_codes.quantize_codes``'s value arithmetic
    (amax/127 scale floored at 1e-12, round, clip to ±127) applied to
    query rows.  Shared by the jnp refs AND the Pallas generation-5
    kernels (the kernel quantizes its VMEM panel with this very function),
    which is one of the two reasons kernel↔ref is bit-identical on the
    int8-scoring path — the other being exact int32 accumulation.
    Rows of zeros (query padding) quantize to all-zero codes.

    Returns ((Q, h) int8 panel, (Q, 1) f32 per-row scales).
    """
    amax = jnp.max(jnp.abs(panel), axis=-1, keepdims=True)         # (Q, 1)
    q_scales = jnp.maximum(amax / 127.0, 1e-12).astype(jnp.float32)
    qi8 = jnp.clip(jnp.round(panel / q_scales), -127, 127).astype(jnp.int8)
    return qi8, q_scales


def _retrieve_chunked_mxu(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    qp_i8: jax.Array,
    q_scales: jax.Array,
    *,
    n: int,
    block_n: int,
    q_chunk: int,
    alive=None,  # None or (N,) f32 1.0/0.0 row-liveness mask
) -> tuple[jax.Array, jax.Array]:
    """Chunked streaming top-n over int8×int8 scores (generation 5).

    q_values (N, k) int8 candidate codes, indices (N, k) int16/int32,
    scales (N,) f32 per-row candidate dequant scales, inv_norms (N,) f32,
    qp_i8 (Q, h) int8 quantized query panel + q_scales (Q, 1) f32 from
    ``_quantize_panel``.  Per block: int8 gather, int32 accumulate (exact),
    then one f32 rescale (acc · q_scale) · (row_scale · inv_norm) — the
    same op order as the kernel's ``_mask_fold_merge`` fold, so the two
    paths agree bit-for-bit.  ``alive`` (segmented indexes' deletion mask)
    rides the padding mask: dead rows score -inf exactly like padding.
    """
    N, k = q_values.shape
    nq = qp_i8.shape[0]
    if nq > q_chunk:
        qpad = (-nq) % q_chunk
        qp = jnp.pad(qp_i8, ((0, qpad), (0, 0))) if qpad else qp_i8
        qs = jnp.pad(q_scales, ((0, qpad), (0, 0))) if qpad else q_scales
        chunks_p = qp.reshape(-1, q_chunk, qp.shape[-1])
        chunks_s = qs.reshape(-1, q_chunk, 1)
        bv, bi = jax.lax.map(
            lambda c: _retrieve_chunked_mxu(
                q_values, indices, scales, inv_norms, c[0], c[1],
                n=n, block_n=block_n, q_chunk=q_chunk, alive=alive,
            ),
            (chunks_p, chunks_s),
        )
        return bv.reshape(-1, n)[:nq], bi.reshape(-1, n)[:nq]
    block_n = min(block_n, max(N, 1))
    pad = (-N) % block_n
    if pad:
        q_values = jnp.pad(q_values, ((0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        scales = jnp.pad(scales, (0, pad))
        inv_norms = jnp.pad(inv_norms, (0, pad))
        if alive is not None:
            alive = jnp.pad(alive, (0, pad))
    nb = (N + pad) // block_n
    vals_b = q_values.reshape(nb, block_n, k)
    idx_b = indices.reshape(nb, block_n, k)
    sc_b = scales.reshape(nb, block_n)
    inv_b = inv_norms.reshape(nb, block_n)
    ids_b = jnp.arange(nb * block_n, dtype=jnp.int32).reshape(nb, block_n)
    alive_b = (jnp.zeros((nb, 0)) if alive is None
               else alive.reshape(nb, block_n))

    init = (
        jnp.full((nq, n), -jnp.inf, jnp.float32),
        jnp.zeros((nq, n), jnp.int32),
    )

    def step(carry, blk):
        best_v, best_i = carry
        bv, bi, bsc, binv, bids, balive = blk
        bi = _widen_idx(bi)
        gathered = qp_i8[:, bi]                              # (Q, block_n, k) i8
        acc = jnp.sum(
            gathered.astype(jnp.int32) * bv.astype(jnp.int32)[None], axis=-1
        )                                                    # (Q, block_n) i32
        s = acc.astype(jnp.float32) * q_scales               # fold q scale
        s = s * (bsc * binv)[None]                           # fold cand rescale
        keep = bids[None] < N                                # mask padding
        if alive is not None:
            keep = keep & (balive[None] > 0.0)               # mask deletions
        s = jnp.where(keep, s, -jnp.inf)
        cand_v = jnp.concatenate([best_v, s], axis=1)
        cand_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(bids[None], s.shape)], axis=1
        )
        v, p = jax.lax.top_k(cand_v, n)
        return (v, jnp.take_along_axis(cand_i, p, axis=1)), None

    (best_v, best_i), _ = jax.lax.scan(
        step, init, (vals_b, idx_b, sc_b, inv_b, ids_b, alive_b)
    )
    return best_v, best_i


@functools.partial(jax.jit, static_argnames=("n", "block_n", "q_chunk"))
def retrieve_quantized_mxu_ref(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
    alive=None,
) -> tuple[jax.Array, jax.Array]:
    """Int8-scoring chunked streaming top-n (generation 5, APPROXIMATE).

    Same signature as ``retrieve_quantized_ref``; the dense (Q, h) query
    is quantized per row (``_quantize_panel`` — row-independent, so query
    chunking cannot change it) and candidates are scored int8×int8 with
    exact int32 accumulation.  Bit-identical to
    ``fused_retrieve_quantized_mxu``; approximate vs the exact quantized
    path with quality measured by ``repro.core.eval``.
    """
    qp_i8, q_scales = _quantize_panel(q.astype(jnp.float32))
    return _retrieve_chunked_mxu(
        q_values, indices, scales, inv_norms, qp_i8, q_scales,
        n=n, block_n=block_n, q_chunk=q_chunk, alive=alive,
    )


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "q_chunk")
)
def retrieve_quantized_mxu_sparse_q_ref(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
    alive=None,
) -> tuple[jax.Array, jax.Array]:
    """Int8-scoring × sparse query codes (generation 5, APPROXIMATE).

    Query slabs (≤ q_chunk) densify row-wise (the same scatter-add as the
    kernel's VMEM panel), quantize per row, then stream the int8 scoring.
    Bit-identical to ``fused_retrieve_quantized_mxu_sparse_q``.
    """
    nq = query_values.shape[0]
    if nq > q_chunk:
        qpad = (-nq) % q_chunk
        qv = (jnp.pad(query_values, ((0, qpad), (0, 0)))
              if qpad else query_values)
        qi = (jnp.pad(query_indices, ((0, qpad), (0, 0)))
              if qpad else query_indices)
        chunks_v = qv.reshape(-1, q_chunk, qv.shape[-1])
        chunks_i = qi.reshape(-1, q_chunk, qi.shape[-1])
        bv, bi = jax.lax.map(
            lambda c: retrieve_quantized_mxu_sparse_q_ref(
                q_values, indices, scales, inv_norms, c[0], c[1], h,
                n=n, block_n=block_n, q_chunk=q_chunk, alive=alive,
            ),
            (chunks_v, chunks_i),
        )
        return bv.reshape(-1, n)[:nq], bi.reshape(-1, n)[:nq]
    qp_i8, q_scales = _quantize_panel(
        _densify_rows(query_values.astype(jnp.float32), query_indices, h)
    )
    return _retrieve_chunked_mxu(
        q_values, indices, scales, inv_norms, qp_i8, q_scales,
        n=n, block_n=block_n, q_chunk=q_chunk, alive=alive,
    )


def _retrieve_chunked(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    scales,  # None (fp32 values) or (N,) f32 per-row dequant scales
    *,
    n: int,
    block_n: int,
    q_chunk: int,
    alive=None,  # None or (N,) f32 1.0/0.0 row-liveness mask
) -> tuple[jax.Array, jax.Array]:
    """Shared chunked streaming top-n (see retrieve_ref for the contract).

    When ``scales`` is given, ``values`` is int8 and ``indices`` may be
    int16: each (block_n, k) block is dequantized inside the scan step —
    the per-block mirror of the fused kernel's VMEM dequant.  ``alive``
    (segmented indexes' deletion mask) rides the padding mask: dead rows
    score -inf exactly like padding, so they can never surface.
    """
    N, k = values.shape
    nq = q.shape[0]
    if nq > q_chunk:
        qpad = (-nq) % q_chunk
        qp = jnp.pad(q, ((0, qpad), (0, 0))) if qpad else q
        chunks = qp.reshape(-1, q_chunk, q.shape[-1])
        bv, bi = jax.lax.map(
            lambda qb: _retrieve_chunked(
                values, indices, inv_norms, qb, scales,
                n=n, block_n=block_n, q_chunk=q_chunk, alive=alive,
            ),
            chunks,
        )
        return bv.reshape(-1, n)[:nq], bi.reshape(-1, n)[:nq]
    block_n = min(block_n, max(N, 1))
    pad = (-N) % block_n
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        inv_norms = jnp.pad(inv_norms, (0, pad))
        if scales is not None:
            scales = jnp.pad(scales, (0, pad))
        if alive is not None:
            alive = jnp.pad(alive, (0, pad))
    nb = (N + pad) // block_n
    vals_b = values.reshape(nb, block_n, k)
    idx_b = indices.reshape(nb, block_n, k)
    inv_b = inv_norms.reshape(nb, block_n)
    ids_b = jnp.arange(nb * block_n, dtype=jnp.int32).reshape(nb, block_n)
    scales_b = (jnp.zeros((nb, 0)) if scales is None
                else scales.reshape(nb, block_n))
    alive_b = (jnp.zeros((nb, 0)) if alive is None
               else alive.reshape(nb, block_n))

    init = (
        jnp.full((nq, n), -jnp.inf, jnp.float32),
        jnp.zeros((nq, n), jnp.int32),
    )

    def step(carry, blk):
        best_v, best_i = carry
        bv, bi, binv, bids, bsc, balive = blk
        if scales is not None:  # per-block dequant, never a full fp32 index
            bv = bv.astype(jnp.float32) * bsc[:, None]
            bi = _widen_idx(bi)
        gathered = q[:, bi]                                  # (Q, block_n, k)
        s = jnp.sum(gathered * bv[None].astype(q.dtype), axis=-1)
        s = (s * binv[None]).astype(jnp.float32)             # (Q, block_n)
        keep = bids[None] < N                                # mask padding
        if alive is not None:
            keep = keep & (balive[None] > 0.0)               # mask deletions
        s = jnp.where(keep, s, -jnp.inf)
        cand_v = jnp.concatenate([best_v, s], axis=1)
        cand_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(bids[None], s.shape)], axis=1
        )
        v, p = jax.lax.top_k(cand_v, n)
        return (v, jnp.take_along_axis(cand_i, p, axis=1)), None

    (best_v, best_i), _ = jax.lax.scan(
        step, init, (vals_b, idx_b, inv_b, ids_b, scales_b, alive_b)
    )
    return best_v, best_i


@functools.partial(jax.jit, static_argnames=("n", "block_n", "q_chunk"))
def retrieve_ref(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
    alive=None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked streaming top-n -> ((Q, n) norm-folded scores, (Q, n) ids).

    values (N, k), indices (N, k) i32, inv_norms (N,) reciprocal candidate
    norms, q (Q, h).  Scores are dot · inv_norms; the per-query 1/‖q‖
    factor is the caller's (it cannot reorder a query's top-n).  The gather
    transient is (min(Q, q_chunk), block_n, k) — queries beyond q_chunk are
    processed in chunks, so memory stays bounded for big batches.
    """
    return _retrieve_chunked(values, indices, inv_norms, q, None,
                             n=n, block_n=block_n, q_chunk=q_chunk,
                             alive=alive)


@functools.partial(jax.jit, static_argnames=("n", "block_n", "q_chunk"))
def retrieve_quantized_ref(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
    alive=None,
) -> tuple[jax.Array, jax.Array]:
    """Quantized-index chunked streaming top-n (see module doc).

    q_values (N, k) int8, indices (N, k) int16/int32, scales (N,) f32
    per-row dequant scales, inv_norms (N,), q (Q, h).  Bit-identical to
    ``retrieve_ref`` over the dequantized arrays; the dequant happens one
    (block_n, k) block at a time inside the scan.
    """
    return _retrieve_chunked(q_values, indices, inv_norms, q, scales,
                             n=n, block_n=block_n, q_chunk=q_chunk,
                             alive=alive)


def _densify_rows(q_values: jax.Array, q_indices: jax.Array, h: int) -> jax.Array:
    """(Q, kq) sparse codes -> (Q, h) dense — the same row-wise scatter-add
    as ``repro.core.sparse.densify`` (duplicate indices sum), inlined here
    so the kernel package stays import-cycle-free with repro.core."""

    def one_row(vals, idx):
        return jnp.zeros((h,), dtype=vals.dtype).at[idx].add(vals)

    return jax.vmap(one_row)(q_values, q_indices)


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "q_chunk")
)
def retrieve_sparse_q_ref(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q_values: jax.Array,
    q_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
    alive=None,
) -> tuple[jax.Array, jax.Array]:
    """Sparse-query chunked streaming top-n -> ((Q, n) scores, (Q, n) ids).

    values (N, k), indices (N, k) i32, inv_norms (N,), q_values (Q, kq) +
    q_indices (Q, kq) i32 query codes over [0, h).  Bit-identical to
    ``retrieve_ref(values, indices, inv_norms, densify(q), n=n)`` — the
    densification happens one ≤q_chunk slab at a time inside the query
    chunking, so the dense transient is (min(Q, q_chunk), h), mirroring the
    Pallas kernel's VMEM-only panel.
    """
    nq = q_values.shape[0]
    if nq > q_chunk:
        qpad = (-nq) % q_chunk
        qv = jnp.pad(q_values, ((0, qpad), (0, 0))) if qpad else q_values
        qi = jnp.pad(q_indices, ((0, qpad), (0, 0))) if qpad else q_indices
        chunks_v = qv.reshape(-1, q_chunk, qv.shape[-1])
        chunks_i = qi.reshape(-1, q_chunk, qi.shape[-1])
        bv, bi = jax.lax.map(
            lambda c: retrieve_sparse_q_ref(
                values, indices, inv_norms, c[0], c[1], h,
                n=n, block_n=block_n, q_chunk=q_chunk, alive=alive,
            ),
            (chunks_v, chunks_i),
        )
        return bv.reshape(-1, n)[:nq], bi.reshape(-1, n)[:nq]
    q_dense = _densify_rows(q_values, q_indices, h)
    return retrieve_ref(
        values, indices, inv_norms, q_dense,
        n=n, block_n=block_n, q_chunk=q_chunk, alive=alive,
    )


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "q_chunk")
)
def retrieve_quantized_sparse_q_ref(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
    alive=None,
) -> tuple[jax.Array, jax.Array]:
    """Quantized candidates × sparse query codes, chunked on both sides:
    query slabs (≤ q_chunk) densify row-wise, candidate blocks dequantize
    inside the scan.  CPU mirror of
    ``fused_retrieve_quantized_sparse_q_pallas`` — neither an fp32 index
    nor a full (Q, h) dense query matrix ever exists.  Bit-identical to
    ``retrieve_sparse_q_ref`` over the dequantized arrays.
    """
    nq = query_values.shape[0]
    if nq > q_chunk:
        qpad = (-nq) % q_chunk
        qv = (jnp.pad(query_values, ((0, qpad), (0, 0)))
              if qpad else query_values)
        qi = (jnp.pad(query_indices, ((0, qpad), (0, 0)))
              if qpad else query_indices)
        chunks_v = qv.reshape(-1, q_chunk, qv.shape[-1])
        chunks_i = qi.reshape(-1, q_chunk, qi.shape[-1])
        bv, bi = jax.lax.map(
            lambda c: retrieve_quantized_sparse_q_ref(
                q_values, indices, scales, inv_norms, c[0], c[1], h,
                n=n, block_n=block_n, q_chunk=q_chunk, alive=alive,
            ),
            (chunks_v, chunks_i),
        )
        return bv.reshape(-1, n)[:nq], bi.reshape(-1, n)[:nq]
    q_dense = _densify_rows(query_values, query_indices, h)
    return _retrieve_chunked(
        q_values, indices, inv_norms, q_dense, scales,
        n=n, block_n=block_n, q_chunk=q_chunk, alive=alive,
    )


# --------------------------------------------------------------------------
# Generation 6: gather-aware re-rank (batched two-stage stage 2)
# --------------------------------------------------------------------------

def _gather_rows(q_dense: jax.Array, bi: jax.Array) -> jax.Array:
    """Per-query panel gather: q_dense (Q, h), bi (Q, block_n, k) →
    (Q, block_n, k).  Each query row gathers from ITS OWN dense panel —
    the gathered twin of ``_retrieve_chunked``'s shared ``q[:, bi]``."""
    return jax.vmap(lambda qd, b: qd[b])(q_dense, bi)


def _retrieve_gathered_chunked(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    scales,  # None (fp32 values) or (Q, B) f32 per-row dequant scales
    *,
    n: int,
    block_n: int,
    q_chunk: int,
) -> tuple[jax.Array, jax.Array]:
    """Gathered chunked streaming top-n: per-query candidate panels.

    values (Q, B, k), indices (Q, B, k), inv_norms (Q, B), q (Q, h) dense
    queries.  Returns ((Q, n) norm-folded scores, (Q, n) ids) where ids
    are candidate POSITIONS in [0, B) — local to each query's panel.
    Per query, block sizing, padding, dequant, masking and the top-k merge
    are op-for-op ``_retrieve_chunked`` over that query's pre-gathered
    sub-arrays, so the result is bit-identical to Q independent per-query
    calls.
    """
    nq, B, k = values.shape
    if nq > q_chunk:
        qpad = (-nq) % q_chunk

        def padq(a, axes=2):
            if not qpad or a is None:
                return a
            return jnp.pad(a, ((0, qpad),) + ((0, 0),) * (a.ndim - 1))

        ch = lambda a: (None if a is None
                        else padq(a).reshape(-1, q_chunk, *a.shape[1:]))
        sc = ch(scales)
        leaves = (ch(values), ch(indices), ch(inv_norms), ch(q)) + (
            () if scales is None else (sc,))

        def body(c):
            csc = c[4] if scales is not None else None
            return _retrieve_gathered_chunked(
                c[0], c[1], c[2], c[3], csc,
                n=n, block_n=block_n, q_chunk=q_chunk,
            )

        bv, bi = jax.lax.map(body, leaves)
        return bv.reshape(-1, n)[:nq], bi.reshape(-1, n)[:nq]
    block_n = min(block_n, max(B, 1))
    pad = (-B) % block_n
    if pad:
        values = jnp.pad(values, ((0, 0), (0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, 0), (0, pad), (0, 0)))
        inv_norms = jnp.pad(inv_norms, ((0, 0), (0, pad)))
        if scales is not None:
            scales = jnp.pad(scales, ((0, 0), (0, pad)))
    nb = (B + pad) // block_n
    # block the candidate axis, scan-major: (nb, Q, block_n, ·)
    vals_b = values.reshape(nq, nb, block_n, k).swapaxes(0, 1)
    idx_b = indices.reshape(nq, nb, block_n, k).swapaxes(0, 1)
    inv_b = inv_norms.reshape(nq, nb, block_n).swapaxes(0, 1)
    ids_b = jnp.arange(nb * block_n, dtype=jnp.int32).reshape(nb, block_n)
    scales_b = (jnp.zeros((nb, nq, 0)) if scales is None
                else scales.reshape(nq, nb, block_n).swapaxes(0, 1))

    init = (
        jnp.full((nq, n), -jnp.inf, jnp.float32),
        jnp.zeros((nq, n), jnp.int32),
    )

    def step(carry, blk):
        best_v, best_i = carry
        bv, bi, binv, bids, bsc = blk
        if scales is not None:  # per-block dequant, per-query scales
            bv = bv.astype(jnp.float32) * bsc[..., None]
            bi = _widen_idx(bi)
        gathered = _gather_rows(q, bi)                       # (Q, block_n, k)
        s = jnp.sum(gathered * bv.astype(q.dtype), axis=-1)
        s = (s * binv).astype(jnp.float32)                   # (Q, block_n)
        s = jnp.where(bids[None] < B, s, -jnp.inf)           # mask padding
        cand_v = jnp.concatenate([best_v, s], axis=1)
        cand_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(bids[None], s.shape)], axis=1
        )
        v, p = jax.lax.top_k(cand_v, n)
        return (v, jnp.take_along_axis(cand_i, p, axis=1)), None

    (best_v, best_i), _ = jax.lax.scan(
        step, init, (vals_b, idx_b, inv_b, ids_b, scales_b)
    )
    return best_v, best_i


def _retrieve_gathered_chunked_mxu(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    qp_i8: jax.Array,
    q_scales: jax.Array,
    *,
    n: int,
    block_n: int,
    q_chunk: int,
) -> tuple[jax.Array, jax.Array]:
    """Gathered int8-scoring chunked streaming top-n (generation 6 × 5).

    q_values (Q, B, k) int8 per-query candidate panels, indices (Q, B, k)
    int16/int32, scales/inv_norms (Q, B) f32, qp_i8 (Q, h) int8 quantized
    query panel + q_scales (Q, 1).  Per query, op-for-op
    ``_retrieve_chunked_mxu`` over the pre-gathered sub-arrays (exact
    int32 accumulation, same f32 rescale order).
    """
    nq, B, k = q_values.shape
    if nq > q_chunk:
        qpad = (-nq) % q_chunk

        def padq(a):
            if not qpad:
                return a
            return jnp.pad(a, ((0, qpad),) + ((0, 0),) * (a.ndim - 1))

        ch = lambda a: padq(a).reshape(-1, q_chunk, *a.shape[1:])
        bv, bi = jax.lax.map(
            lambda c: _retrieve_gathered_chunked_mxu(
                c[0], c[1], c[2], c[3], c[4], c[5],
                n=n, block_n=block_n, q_chunk=q_chunk,
            ),
            (ch(q_values), ch(indices), ch(scales), ch(inv_norms),
             ch(qp_i8), ch(q_scales)),
        )
        return bv.reshape(-1, n)[:nq], bi.reshape(-1, n)[:nq]
    block_n = min(block_n, max(B, 1))
    pad = (-B) % block_n
    if pad:
        q_values = jnp.pad(q_values, ((0, 0), (0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, 0), (0, pad), (0, 0)))
        scales = jnp.pad(scales, ((0, 0), (0, pad)))
        inv_norms = jnp.pad(inv_norms, ((0, 0), (0, pad)))
    nb = (B + pad) // block_n
    vals_b = q_values.reshape(nq, nb, block_n, k).swapaxes(0, 1)
    idx_b = indices.reshape(nq, nb, block_n, k).swapaxes(0, 1)
    sc_b = scales.reshape(nq, nb, block_n).swapaxes(0, 1)
    inv_b = inv_norms.reshape(nq, nb, block_n).swapaxes(0, 1)
    ids_b = jnp.arange(nb * block_n, dtype=jnp.int32).reshape(nb, block_n)

    init = (
        jnp.full((nq, n), -jnp.inf, jnp.float32),
        jnp.zeros((nq, n), jnp.int32),
    )

    def step(carry, blk):
        best_v, best_i = carry
        bv, bi, bsc, binv, bids = blk
        bi = _widen_idx(bi)
        gathered = _gather_rows(qp_i8, bi)                   # (Q, block_n, k) i8
        acc = jnp.sum(
            gathered.astype(jnp.int32) * bv.astype(jnp.int32), axis=-1
        )                                                    # (Q, block_n) i32
        s = acc.astype(jnp.float32) * q_scales               # fold q scale
        s = s * (bsc * binv)                                 # fold cand rescale
        s = jnp.where(bids[None] < B, s, -jnp.inf)           # mask padding
        cand_v = jnp.concatenate([best_v, s], axis=1)
        cand_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(bids[None], s.shape)], axis=1
        )
        v, p = jax.lax.top_k(cand_v, n)
        return (v, jnp.take_along_axis(cand_i, p, axis=1)), None

    (best_v, best_i), _ = jax.lax.scan(
        step, init, (vals_b, idx_b, sc_b, inv_b, ids_b)
    )
    return best_v, best_i


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "q_chunk")
)
def retrieve_gathered_sparse_q_ref(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q_values: jax.Array,
    q_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Gathered sparse-query streaming top-n (generation 6, fp32).

    values (Q, B, k) per-query candidate panels, indices (Q, B, k) i32,
    inv_norms (Q, B), q_values/q_indices (Q, kq) query codes over [0, h).
    Returns ((Q, n) scores, (Q, n) LOCAL candidate positions in [0, B)).
    Bit-identical to Q per-query ``retrieve_sparse_q_ref`` calls over the
    pre-gathered sub-arrays — the batched stage-2 contract.
    """
    q_dense = _densify_rows(q_values.astype(jnp.float32), q_indices, h)
    return _retrieve_gathered_chunked(
        values, indices, inv_norms, q_dense, None,
        n=n, block_n=block_n, q_chunk=q_chunk,
    )


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "q_chunk")
)
def retrieve_gathered_quantized_sparse_q_ref(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Gathered quantized × sparse-query streaming top-n (generation 6).

    q_values (Q, B, k) int8, indices (Q, B, k) int16/int32, scales and
    inv_norms (Q, B) — the candidate panels stay in their quantized
    storage dtypes through the gather; dequant happens per block exactly
    as in ``retrieve_quantized_sparse_q_ref``.
    """
    q_dense = _densify_rows(
        query_values.astype(jnp.float32), query_indices, h
    )
    return _retrieve_gathered_chunked(
        q_values, indices, inv_norms, q_dense, scales,
        n=n, block_n=block_n, q_chunk=q_chunk,
    )


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "q_chunk")
)
def retrieve_gathered_quantized_mxu_sparse_q_ref(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = 8192,
    q_chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Gathered int8-scoring × sparse-query top-n (generation 6 × 5,
    APPROXIMATE vs exact — but bit-identical to Q per-query
    ``retrieve_quantized_mxu_sparse_q_ref`` calls, and to its own Pallas
    kernel, by exact int32 accumulation)."""
    qp_i8, q_scales = _quantize_panel(
        _densify_rows(query_values.astype(jnp.float32), query_indices, h)
    )
    return _retrieve_gathered_chunked_mxu(
        q_values, indices, scales, inv_norms, qp_i8, q_scales,
        n=n, block_n=block_n, q_chunk=q_chunk,
    )
