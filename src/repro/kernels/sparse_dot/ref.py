"""Pure-jnp oracle for the scatter-query SpMV."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sparse_dot_ref(values: jax.Array, indices: jax.Array, q: jax.Array) -> jax.Array:
    """scores[qi, i] = sum_j values[i, j] * q[qi, indices[i, j]].

    values: (N, k) float; indices: (N, k) int32 in [0, h); q: (Q, h).
    Returns (Q, N) float32.
    """
    gathered = q[:, indices]                      # (Q, N, k)
    return jnp.sum(gathered * values[None].astype(q.dtype), axis=-1)
