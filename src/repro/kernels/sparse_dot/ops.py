"""Public jit'd wrappers for the sparse_dot kernel family.

Pads N (and Q) up to tile sizes, dispatches to the Pallas kernels
(interpret=True on CPU so the kernel bodies themselves are what run in
tests), and exposes the same contracts as ref.sparse_dot_ref /
ref.retrieve_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sparse_dot.kernel import (
    BLOCK_N,
    BLOCK_Q,
    fused_retrieve_gathered_quantized_mxu_sparse_q_pallas,
    fused_retrieve_gathered_quantized_sparse_q_pallas,
    fused_retrieve_gathered_sparse_q_pallas,
    fused_retrieve_pallas,
    fused_retrieve_quantized_mxu_pallas,
    fused_retrieve_quantized_mxu_sparse_q_pallas,
    fused_retrieve_quantized_pallas,
    fused_retrieve_quantized_sparse_q_pallas,
    fused_retrieve_sparse_q_pallas,
    sparse_dot_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_n", "block_q"))
def sparse_dot(
    values: jax.Array,
    indices: jax.Array,
    q: jax.Array,
    *,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
) -> jax.Array:
    """scores (Q, N): fixed-k sparse candidates scored against dense queries.

    values (N, k) float32, indices (N, k) int32, q (Q, h) or (h,) float32.
    """
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    n, k = values.shape
    nq = q.shape[0]
    pad = (-n) % block_n
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
    qpad = (-nq) % block_q
    if qpad:
        q = jnp.pad(q, ((0, qpad), (0, 0)))
    out = sparse_dot_pallas(
        values, indices, q,
        interpret=not _on_tpu(), block_n=block_n, block_q=block_q,
    )
    out = out[:nq, :n]
    return out[0] if squeeze else out


def _pad_candidates(values, indices, inv_norms, block_n, scales=None,
                    alive=None):
    """Zero-pad the candidate axis up to a tile multiple — the one padding
    scheme every retrieve wrapper shares (fp32 and quantized alike).
    Padded rows carry value/scale 0 and inv-norm 0 (and alive 0, i.e.
    dead), and are additionally masked to -inf by global id (``n_valid``)
    inside the kernels."""
    n_valid = values.shape[0]
    pad = (-n_valid) % block_n
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        inv_norms = jnp.pad(inv_norms, (0, pad))
        if scales is not None:
            scales = jnp.pad(scales, (0, pad))
        if alive is not None:
            alive = jnp.pad(alive, (0, pad))
    return values, indices, inv_norms, scales, alive, n_valid


@functools.partial(
    jax.jit, static_argnames=("n", "block_n", "block_q", "interpret")
)
def fused_retrieve(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused score+select -> ((Q, n) norm-folded scores, (Q, n) ids).

    values (N, k) f32, indices (N, k) i32, inv_norms (N,) f32 reciprocal
    candidate norms, q (Q, h) or (h,) f32.  n must not exceed N.  The
    (Q, N) score matrix is never materialized — only (Q, n) reaches HBM.
    """
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if n > values.shape[0]:
        raise ValueError(f"top-n {n} exceeds candidate count {values.shape[0]}")
    nq = q.shape[0]
    values, indices, inv_norms, _, _, n_valid = _pad_candidates(
        values, indices, inv_norms, block_n
    )
    qpad = (-nq) % block_q
    if qpad:
        q = jnp.pad(q, ((0, qpad), (0, 0)))
    out_v, out_i = fused_retrieve_pallas(
        values,
        indices,
        inv_norms.astype(jnp.float32).reshape(-1, 1),
        q,
        n=n,
        n_valid=n_valid,
        interpret=not _on_tpu() if interpret is None else interpret,
        block_n=block_n,
        block_q=block_q,
    )
    out_v, out_i = out_v[:nq], out_i[:nq]
    return (out_v[0], out_i[0]) if squeeze else (out_v, out_i)


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "block_q", "interpret")
)
def fused_retrieve_sparse_q(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q_values: jax.Array,
    q_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    interpret: bool | None = None,
    alive: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Sparse-query fused score+select -> ((Q, n) scores, (Q, n) ids).

    values (N, k) f32, indices (N, k) i32, inv_norms (N,) f32, q_values
    (Q, kq) or (kq,) f32 + matching q_indices i32 — k-sparse query codes
    over [0, h), e.g. straight from ``fused_encode``.  Bit-identical to
    ``fused_retrieve(values, indices, inv_norms, densify(q), n=n)``, but
    only the (Q, kq) codes ever touch HBM on the query side.  ``alive``:
    optional (N,) 1.0/0.0 row-liveness mask (segmented-index deletions) —
    dead rows are masked to -inf exactly like padding, so they can never
    appear among the top-n while live rows' scores/ids are untouched.
    """
    squeeze = q_values.ndim == 1
    if squeeze:
        q_values, q_indices = q_values[None], q_indices[None]
    if n > values.shape[0]:
        raise ValueError(f"top-n {n} exceeds candidate count {values.shape[0]}")
    nq = q_values.shape[0]
    values, indices, inv_norms, _, alive, n_valid = _pad_candidates(
        values, indices, inv_norms, block_n, alive=alive
    )
    qpad = (-nq) % block_q
    if qpad:
        q_values = jnp.pad(q_values, ((0, qpad), (0, 0)))
        q_indices = jnp.pad(q_indices, ((0, qpad), (0, 0)))
    out_v, out_i = fused_retrieve_sparse_q_pallas(
        values,
        indices,
        inv_norms.astype(jnp.float32).reshape(-1, 1),
        q_values,
        q_indices,
        h,
        n=n,
        n_valid=n_valid,
        interpret=not _on_tpu() if interpret is None else interpret,
        block_n=block_n,
        block_q=block_q,
        alive=(None if alive is None
               else alive.astype(jnp.float32).reshape(-1, 1)),
    )
    out_v, out_i = out_v[:nq], out_i[:nq]
    return (out_v[0], out_i[0]) if squeeze else (out_v, out_i)


@functools.partial(
    jax.jit, static_argnames=("n", "block_n", "block_q", "interpret")
)
def fused_retrieve_quantized(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantized-index fused score+select -> ((Q, n) scores, (Q, n) ids).

    q_values (N, k) int8, indices (N, k) int16/int32, scales (N,) f32
    per-row dequant scales, inv_norms (N,) f32, q (Q, h) or (h,) f32.
    The index streams from HBM in its quantized dtypes and is dequantized
    per tile in VMEM — bit-identical to
    ``fused_retrieve(dequantize(q_values, scales), widen(indices), ...)``
    without ever materializing that fp32 copy.
    """
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if n > q_values.shape[0]:
        raise ValueError(
            f"top-n {n} exceeds candidate count {q_values.shape[0]}"
        )
    nq = q.shape[0]
    q_values, indices, inv_norms, scales, _, n_valid = _pad_candidates(
        q_values, indices, inv_norms, block_n, scales
    )
    qpad = (-nq) % block_q
    if qpad:
        q = jnp.pad(q, ((0, qpad), (0, 0)))
    out_v, out_i = fused_retrieve_quantized_pallas(
        q_values,
        indices,
        scales.astype(jnp.float32).reshape(-1, 1),
        inv_norms.astype(jnp.float32).reshape(-1, 1),
        q,
        n=n,
        n_valid=n_valid,
        interpret=not _on_tpu() if interpret is None else interpret,
        block_n=block_n,
        block_q=block_q,
    )
    out_v, out_i = out_v[:nq], out_i[:nq]
    return (out_v[0], out_i[0]) if squeeze else (out_v, out_i)


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "block_q", "interpret")
)
def fused_retrieve_quantized_sparse_q(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    interpret: bool | None = None,
    alive: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantized candidates × sparse query codes -> ((Q, n) scores, ids).

    The full-compression serving kernel: candidate tiles stream int8/int16
    and dequantize in VMEM, query codes densify into VMEM scratch.  Only
    the (Q, kq) codes and (Q, n) results touch HBM on the query side, and
    the index never exists in fp32.  Bit-identical to
    ``fused_retrieve_sparse_q`` over the dequantized arrays.  ``alive``:
    optional (N,) 1.0/0.0 row-liveness mask (see
    ``fused_retrieve_sparse_q``).
    """
    squeeze = query_values.ndim == 1
    if squeeze:
        query_values, query_indices = query_values[None], query_indices[None]
    if n > q_values.shape[0]:
        raise ValueError(
            f"top-n {n} exceeds candidate count {q_values.shape[0]}"
        )
    nq = query_values.shape[0]
    q_values, indices, inv_norms, scales, alive, n_valid = _pad_candidates(
        q_values, indices, inv_norms, block_n, scales, alive=alive
    )
    qpad = (-nq) % block_q
    if qpad:
        query_values = jnp.pad(query_values, ((0, qpad), (0, 0)))
        query_indices = jnp.pad(query_indices, ((0, qpad), (0, 0)))
    out_v, out_i = fused_retrieve_quantized_sparse_q_pallas(
        q_values,
        indices,
        scales.astype(jnp.float32).reshape(-1, 1),
        inv_norms.astype(jnp.float32).reshape(-1, 1),
        query_values,
        query_indices,
        h,
        n=n,
        n_valid=n_valid,
        interpret=not _on_tpu() if interpret is None else interpret,
        block_n=block_n,
        block_q=block_q,
        alive=(None if alive is None
               else alive.astype(jnp.float32).reshape(-1, 1)),
    )
    out_v, out_i = out_v[:nq], out_i[:nq]
    return (out_v[0], out_i[0]) if squeeze else (out_v, out_i)


@functools.partial(
    jax.jit, static_argnames=("n", "block_n", "block_q", "interpret")
)
def fused_retrieve_quantized_mxu(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Int8-scoring fused score+select (generation 5, APPROXIMATE).

    Same operands/padding contract as ``fused_retrieve_quantized``, but
    candidate tiles are scored in int8 (query panel quantized per panel in
    VMEM, int32 accumulation, one f32 rescale in the merge) instead of
    being dequantized.  Bit-identical to ``retrieve_quantized_mxu_ref``;
    quality vs the exact quantized path is a measured bound
    (``repro.core.eval``), not an equality.
    """
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if n > q_values.shape[0]:
        raise ValueError(
            f"top-n {n} exceeds candidate count {q_values.shape[0]}"
        )
    nq = q.shape[0]
    q_values, indices, inv_norms, scales, _, n_valid = _pad_candidates(
        q_values, indices, inv_norms, block_n, scales
    )
    qpad = (-nq) % block_q
    if qpad:
        q = jnp.pad(q, ((0, qpad), (0, 0)))
    out_v, out_i = fused_retrieve_quantized_mxu_pallas(
        q_values,
        indices,
        scales.astype(jnp.float32).reshape(-1, 1),
        inv_norms.astype(jnp.float32).reshape(-1, 1),
        q,
        n=n,
        n_valid=n_valid,
        interpret=not _on_tpu() if interpret is None else interpret,
        block_n=block_n,
        block_q=block_q,
    )
    out_v, out_i = out_v[:nq], out_i[:nq]
    return (out_v[0], out_i[0]) if squeeze else (out_v, out_i)


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "block_q", "interpret")
)
def fused_retrieve_quantized_mxu_sparse_q(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    interpret: bool | None = None,
    alive: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Int8-scoring × sparse query codes (generation 5, APPROXIMATE): the
    no-dequant full-compression serving op.  Codes densify + quantize into
    VMEM scratch once per panel; candidates stream and score in int8.
    Bit-identical to ``retrieve_quantized_mxu_sparse_q_ref``.  ``alive``:
    optional (N,) 1.0/0.0 row-liveness mask (see
    ``fused_retrieve_sparse_q``).
    """
    squeeze = query_values.ndim == 1
    if squeeze:
        query_values, query_indices = query_values[None], query_indices[None]
    if n > q_values.shape[0]:
        raise ValueError(
            f"top-n {n} exceeds candidate count {q_values.shape[0]}"
        )
    nq = query_values.shape[0]
    q_values, indices, inv_norms, scales, alive, n_valid = _pad_candidates(
        q_values, indices, inv_norms, block_n, scales, alive=alive
    )
    qpad = (-nq) % block_q
    if qpad:
        query_values = jnp.pad(query_values, ((0, qpad), (0, 0)))
        query_indices = jnp.pad(query_indices, ((0, qpad), (0, 0)))
    out_v, out_i = fused_retrieve_quantized_mxu_sparse_q_pallas(
        q_values,
        indices,
        scales.astype(jnp.float32).reshape(-1, 1),
        inv_norms.astype(jnp.float32).reshape(-1, 1),
        query_values,
        query_indices,
        h,
        n=n,
        n_valid=n_valid,
        interpret=not _on_tpu() if interpret is None else interpret,
        block_n=block_n,
        block_q=block_q,
        alive=(None if alive is None
               else alive.astype(jnp.float32).reshape(-1, 1)),
    )
    out_v, out_i = out_v[:nq], out_i[:nq]
    return (out_v[0], out_i[0]) if squeeze else (out_v, out_i)


def _pad_gathered(block_n, block_q, nq, *arrays):
    """Pad per-query candidate panels for the gathered kernels: the
    candidate axis (axis 1) up to a ``block_n`` multiple on every array,
    then the query axis (axis 0) up to a ``block_q`` multiple — query
    padding covers the candidate panels too, since every input now carries
    the leading Q axis.  Returns (padded arrays..., n_valid)."""
    n_valid = arrays[0].shape[1]
    pad = (-n_valid) % block_n
    qpad = (-nq) % block_q

    def p(a):
        widths = [(0, qpad), (0, pad)] + [(0, 0)] * (a.ndim - 2)
        return jnp.pad(a, widths) if (pad or qpad) else a

    return tuple(p(a) for a in arrays) + (n_valid,)


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "block_q", "interpret")
)
def fused_retrieve_gathered_sparse_q(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q_values: jax.Array,
    q_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Gathered sparse-query fused score+select (generation 6, fp32).

    values (Q, B, k) f32 per-query candidate panels, indices (Q, B, k)
    i32, inv_norms (Q, B) f32, q_values/q_indices (Q, kq) query codes over
    [0, h).  Returns ((Q, n) scores, (Q, n) LOCAL candidate positions in
    [0, B)) — the caller maps positions back to catalog rows through its
    stage-1 row table.  Bit-identical per query to
    ``fused_retrieve_sparse_q`` over the gathered sub-arrays.
    """
    if values.ndim != 3:
        raise ValueError(
            f"gathered retrieve expects (Q, B, k) candidate panels, "
            f"got ndim={values.ndim}"
        )
    if n > values.shape[1]:
        raise ValueError(f"top-n {n} exceeds candidate count {values.shape[1]}")
    nq = q_values.shape[0]
    qpad = (-nq) % block_q
    if qpad:
        q_values = jnp.pad(q_values, ((0, qpad), (0, 0)))
        q_indices = jnp.pad(q_indices, ((0, qpad), (0, 0)))
    values, indices, inv_norms, n_valid = _pad_gathered(
        block_n, block_q, nq,
        values, indices, inv_norms.astype(jnp.float32),
    )
    out_v, out_i = fused_retrieve_gathered_sparse_q_pallas(
        values,
        indices,
        inv_norms,
        q_values,
        q_indices,
        h,
        n=n,
        n_valid=n_valid,
        interpret=not _on_tpu() if interpret is None else interpret,
        block_n=block_n,
        block_q=block_q,
    )
    return out_v[:nq], out_i[:nq]


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "block_q", "interpret")
)
def fused_retrieve_gathered_quantized_sparse_q(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Gathered quantized × sparse query codes (generation 6): per-query
    candidate panels stream in their quantized storage dtypes — q_values
    (Q, B, k) int8, indices (Q, B, k) int16/int32, scales/inv_norms (Q, B)
    f32 — and dequantize per brick in VMEM.  Bit-identical per query to
    ``fused_retrieve_quantized_sparse_q`` over the gathered sub-arrays.
    """
    if q_values.ndim != 3:
        raise ValueError(
            f"gathered retrieve expects (Q, B, k) candidate panels, "
            f"got ndim={q_values.ndim}"
        )
    if n > q_values.shape[1]:
        raise ValueError(
            f"top-n {n} exceeds candidate count {q_values.shape[1]}"
        )
    nq = query_values.shape[0]
    qpad = (-nq) % block_q
    if qpad:
        query_values = jnp.pad(query_values, ((0, qpad), (0, 0)))
        query_indices = jnp.pad(query_indices, ((0, qpad), (0, 0)))
    q_values, indices, scales, inv_norms, n_valid = _pad_gathered(
        block_n, block_q, nq,
        q_values, indices,
        scales.astype(jnp.float32), inv_norms.astype(jnp.float32),
    )
    out_v, out_i = fused_retrieve_gathered_quantized_sparse_q_pallas(
        q_values,
        indices,
        scales,
        inv_norms,
        query_values,
        query_indices,
        h,
        n=n,
        n_valid=n_valid,
        interpret=not _on_tpu() if interpret is None else interpret,
        block_n=block_n,
        block_q=block_q,
    )
    return out_v[:nq], out_i[:nq]


@functools.partial(
    jax.jit, static_argnames=("h", "n", "block_n", "block_q", "interpret")
)
def fused_retrieve_gathered_quantized_mxu_sparse_q(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Gathered int8-scoring × sparse query codes (generation 6 × 5,
    APPROXIMATE vs exact): per-query int8 candidate panels score with
    exact int32 accumulation against the once-per-panel quantized query
    scratch.  Bit-identical per query to
    ``fused_retrieve_quantized_mxu_sparse_q`` over the gathered
    sub-arrays, and to ``retrieve_gathered_quantized_mxu_sparse_q_ref``.
    """
    if q_values.ndim != 3:
        raise ValueError(
            f"gathered retrieve expects (Q, B, k) candidate panels, "
            f"got ndim={q_values.ndim}"
        )
    if n > q_values.shape[1]:
        raise ValueError(
            f"top-n {n} exceeds candidate count {q_values.shape[1]}"
        )
    nq = query_values.shape[0]
    qpad = (-nq) % block_q
    if qpad:
        query_values = jnp.pad(query_values, ((0, qpad), (0, 0)))
        query_indices = jnp.pad(query_indices, ((0, qpad), (0, 0)))
    q_values, indices, scales, inv_norms, n_valid = _pad_gathered(
        block_n, block_q, nq,
        q_values, indices,
        scales.astype(jnp.float32), inv_norms.astype(jnp.float32),
    )
    out_v, out_i = fused_retrieve_gathered_quantized_mxu_sparse_q_pallas(
        q_values,
        indices,
        scales,
        inv_norms,
        query_values,
        query_indices,
        h,
        n=n,
        n_valid=n_valid,
        interpret=not _on_tpu() if interpret is None else interpret,
        block_n=block_n,
        block_q=block_q,
    )
    return out_v[:nq], out_i[:nq]
