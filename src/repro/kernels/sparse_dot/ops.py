"""Public jit'd wrapper for the sparse_dot kernel.

Pads N up to the tile size, dispatches to the Pallas kernel (interpret=True
on CPU so the kernel body itself is what runs in tests), and exposes the
same contract as ref.sparse_dot_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sparse_dot.kernel import BLOCK_N, sparse_dot_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_n",))
def sparse_dot(
    values: jax.Array, indices: jax.Array, q: jax.Array, *, block_n: int = BLOCK_N
) -> jax.Array:
    """scores (Q, N): fixed-k sparse candidates scored against dense queries.

    values (N, k) float32, indices (N, k) int32, q (Q, h) or (h,) float32.
    """
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    n, k = values.shape
    pad = (-n) % block_n
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
    out = sparse_dot_pallas(
        values, indices, q, interpret=not _on_tpu(), block_n=block_n
    )
    out = out[:, :n]
    return out[0] if squeeze else out
