"""Scatter-query SpMV Pallas kernels (DESIGN.md §3) — three generations.

Contract (all): scores[qi, i] = Σ_j values[i, j] · q[qi, indices[i, j]]

Generation 1 — ``sparse_dot_pallas`` (blocked, multi-query):
  * A (BLOCK_Q, h) *panel* of dense queries is VMEM-resident per grid step —
    not a single row.  Each (BLOCK_N, k) candidate tile streams HBM→VMEM
    **once per query panel** and is scored against all BLOCK_Q queries, so
    candidate HBM traffic drops by BLOCK_Q× versus the per-query kernel
    (grid (Q, N/BLOCK_N)) this replaces.
  * The gather runs as k lane-gathers: for sparse column j, the candidate
    tile's index column (BLOCK_N,) addresses the query panel's lanes
    (`jnp.take_along_axis` → tpu.dynamic_gather), FMA'd with the value
    column.  Arithmetic intensity: 2·BLOCK_Q flops per 8 bytes streamed.
  * Grid = (Q/BLOCK_Q, N/BLOCK_N); both axes carry no cross-step state.

Generation 2 — ``fused_retrieve_pallas`` (score + select, streaming top-n):
  * Same blocked scoring, but the (Q, N) score matrix NEVER reaches HBM.
    The per-query-panel running best-(score, id) buffers — shape
    (BLOCK_Q, n) — live in the revisited output block (VMEM-resident across
    the whole candidate axis, index map ignores the candidate grid index)
    and are merged with each tile's (BLOCK_Q, BLOCK_N) scores by an n-step
    select-max-and-mask sweep over the n + BLOCK_N concatenated candidates.
    Only (Q, n) values + ids are ever written back.
  * Per-candidate reciprocal norms stream alongside the values
    ((BLOCK_N, 1) tiles) and fold the cosine denominator into the epilogue;
    the per-query 1/‖q‖ factor is applied outside (it cannot reorder a
    query row's top-n).
  * A whole-tile skip: if no score in the tile beats any query's current
    n-th best, the merge sweep is predicated off (`pl.when`) — the common
    case once the buffers warm up on impact-ordered or clustered data.
  * Tie semantics match `jax.lax.top_k` (lowest candidate id wins): tiles
    arrive in ascending-id order, the running buffer precedes the tile in
    the concatenated sweep, and the sweep selects the *first* position
    attaining the max.
  * Padded candidate rows (N % BLOCK_N) are masked to -inf inside the
    kernel via the static true row count, so they can never surface even
    when all real scores are negative.

Generation 4 — ``fused_retrieve_quantized_pallas`` (+ sparse-query variant):
  * The candidate index streams from HBM in its *quantized* storage dtypes
    — (BLOCK_N, k) int8 values, (BLOCK_N, k) int16/int32 indices, and a
    (BLOCK_N, 1) f32 per-row scale column alongside the reciprocal norms —
    and is dequantized in VMEM (``_dequant_tile``: int8→f32 × scale; int16
    indices widened with the low-16-bit mask that undoes two's-complement
    wrap for h ∈ [32768, 65536)) before the shared scoring + streaming
    top-n epilogue.  Candidate HBM traffic per tile drops from 8k+4 to
    3k+8 bytes/row (~2.6x at k=32) — the compound-compressed format is
    what lives in HBM, not an fp32 copy.
  * Dequantization reproduces ``quantize_codes``'s dequant op-for-op
    (int8→f32 exact, one f32 multiply per element), so the kernel is
    bit-identical — scores, ids, ties — to dequantize-then-
    ``fused_retrieve`` on the same quantized values.  Quantization error
    is a build-time choice, never a serving-path one.
  * ``fused_retrieve_quantized_sparse_q_pallas`` composes generation 3's
    VMEM query densification with the quantized candidate stream: neither
    a dense query panel nor an fp32 index ever exists in HBM.

Generation 5 — ``fused_retrieve_quantized_mxu_pallas`` (+ sparse-query
variant): the APPROXIMATE int8-scoring fast path.
  * Candidate tiles stream in the same quantized storage dtypes as
    generation 4, but are never dequantized: scoring runs int8×int8 with
    int32 accumulation — the int8 MXU's native contraction on real
    hardware (one (BLOCK_Q, BLOCK_N) i32 accumulator, k gather-FMA
    rounds), instead of f32 VPU FMAs on dequantized tiles.
  * The query panel is quantized ONCE per panel into VMEM scratch
    (``_quantize_panel``: per-row symmetric amax/127, the same arithmetic
    as ``quantize_codes``): an int8 (BLOCK_Q, h) panel + (BLOCK_Q, 1) f32
    scales.  Per tile, the single f32 rescale
    ``(acc·q_scale) · (row_scale·inv_norm)`` folds into the streaming
    ``_mask_fold_merge`` epilogue — no per-element dequant anywhere.
  * Contract change: this is the first generation whose relationship to
    the exact path is a MEASURED QUALITY BOUND (recall@n / score MAE /
    rank displacement via ``repro.core.eval``), not bit-identity.  What
    *is* bit-identical is kernel↔ref: int32 accumulation is exact and
    order-invariant and the panel quantization is the shared
    ``_quantize_panel``, so the chunked jnp ref reproduces the kernel
    exactly — unlike the f32 generations, where kernel and ref only agree
    to rounding.

Generation 3 — ``fused_retrieve_sparse_q_pallas`` (sparse queries in):
  * The scatter-query SpMV from *both* sides: the query panel arrives as
    (BLOCK_Q, kq) (values, indices) sparse codes — the ``fused_encode``
    output — not as a dense (BLOCK_Q, h) expansion.  Only (Q, kq) query
    codes and the (Q, n) results ever touch HBM; the dense panel exists
    solely as a VMEM scratch, rebuilt once per query panel (on the first
    candidate step) by a kq-round comparison-scatter:
        panel[qi, c] = Σ_l q_vals[qi, l] · [q_idx[qi, l] == c]
    accumulated in l order, so duplicate indices within a code row sum
    exactly like ``sparse.densify``'s sequential scatter-add — the whole
    kernel is bit-identical to densify + fused_retrieve.
  * Scoring, streaming top-n epilogue, norm folding, padding masks and tie
    semantics are shared with generation 2 (same ``_score_tile`` /
    ``_mask_fold_merge`` code paths).
  * Query HBM traffic drops from 4·Q·h bytes to 8·Q·kq — h/(2kq) ≈ 64×
    at h=4096, kq=32 — and the request chain fused_encode →
    fused_retrieve_sparse_q never round-trips a dense query through HBM.

VMEM budget per grid step (f32):
    4·BLOCK_Q·h            query panel        (8 × 4096  → 128 KiB)
  + 8·BLOCK_N·k            candidate tile     (256 × 32  →  64 KiB)
  + 4·BLOCK_N              reciprocal norms   (           →   1 KiB)
  + 8·BLOCK_Q·n            output best-(v,id) (8 × 64    →   4 KiB)
  + 8·BLOCK_Q·(n+BLOCK_N)  merge sweep temp   (8 × 320   →  20 KiB)
  ≈ 0.25 MiB at defaults — far under the ~16 MiB/core VMEM ceiling; h up
  to ~128k or BLOCK_Q up to ~256 stay in budget.  Generation 3 swaps the
  query-panel *input* block for a same-size (BLOCK_Q, h) scratch plus two
  (BLOCK_Q, kq) code tiles — net VMEM unchanged to first order.

Generation 6 — ``fused_retrieve_gathered_*_pallas`` (gather-aware re-rank):
  * The batched two-stage stage 2.  Candidate arrays carry a leading query
    axis — values/indices (Q, B, k) tiles, inv_norms/scales (Q, B) — i.e.
    each query streams ITS OWN pre-gathered candidate panel (the rows its
    stage-1 union selected), and the returned ids are candidate POSITIONS
    in [0, B), local to each query's panel.  Block specs tile both axes:
    (BLOCK_Q, BLOCK_N, k) candidate bricks, (BLOCK_Q, BLOCK_N) norm/scale
    tiles, grid (Q/BLOCK_Q, B/BLOCK_N) with the candidate axis innermost
    as ever.
  * Scoring swaps the shared-column gather for a per-row one
    (``_score_tile_gathered``): sparse column j's index slab (BLOCK_Q,
    BLOCK_N) addresses each query row's own panel lanes — still one
    tpu.dynamic_gather per k round, same FMA count.  The epilogue
    (``_mask_fold_merge_gathered``) folds a per-(query, candidate) norm
    tile instead of a broadcast norm column; merge sweep, padding masks,
    whole-tile skip and tie semantics are generation 2's unchanged.
  * Each query row's arithmetic is op-for-op the per-query generation on
    its gathered sub-arrays, so batched stage 2 is bit-identical — scores,
    ids, ties — to Q independent per-query fused calls (the PR 7 path),
    and to the gathered chunked-jnp refs under the usual generation rules
    (mxu exactly, f32 to rounding).
  * Three variants mirror the two-stage-eligible modes: fp32 sparse-q,
    quantized sparse-q, quantized-mxu sparse-q (two-stage is sparse-mode
    only — the query side always arrives as codes).

Lowering note: the per-column gather lowers to Mosaic's dynamic-gather on
the lane dimension.  The select-max-and-mask sweep uses only max / min /
where / broadcasted_iota — no in-kernel sort or top_k primitive needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sparse_dot.ref import _quantize_panel, _widen_idx

BLOCK_N = 256  # candidate rows per tile (8-sublane multiple)
BLOCK_Q = 8    # query rows per VMEM-resident panel

_NEG_INF = float("-inf")


def _score_tile(vals, idx, q_panel):
    """(BLOCK_Q, BLOCK_N) scores: k lane-gathers from the query panel.

    vals/idx: (BLOCK_N, k); q_panel: (BLOCK_Q, h).
    """
    bn, k = vals.shape
    bq = q_panel.shape[0]

    def body(j, acc):
        col = jax.lax.dynamic_slice_in_dim(idx, j, 1, axis=1)      # (BLOCK_N, 1)
        vcol = jax.lax.dynamic_slice_in_dim(vals, j, 1, axis=1)    # (BLOCK_N, 1)
        gathered = jnp.take_along_axis(
            q_panel, jnp.broadcast_to(col.T, (bq, bn)), axis=1
        )                                                          # (BLOCK_Q, BLOCK_N)
        return acc + gathered * vcol.T

    return jax.lax.fori_loop(0, k, body, jnp.zeros((bq, bn), jnp.float32))


def _dot_kernel(vals_ref, idx_ref, q_ref, out_ref):
    out_ref[...] = _score_tile(vals_ref[...], idx_ref[...], q_ref[...])


@functools.partial(
    jax.jit, static_argnames=("interpret", "block_n", "block_q")
)
def sparse_dot_pallas(
    values: jax.Array,
    indices: jax.Array,
    q: jax.Array,
    *,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
) -> jax.Array:
    """values (N, k) f32, indices (N, k) i32, q (Q, h) f32 -> (Q, N) f32.

    N must be a multiple of block_n and Q of block_q (ops.py pads).
    """
    n, k = values.shape
    nq, h = q.shape
    grid = (nq // block_q, n // block_n)
    return pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_q, h), lambda qi, i: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda qi, i: (qi, i)),
        out_shape=jax.ShapeDtypeStruct((nq, n), jnp.float32),
        interpret=interpret,
    )(values, indices, q.astype(jnp.float32))


def _merge_top_n(best_v, best_i, tile_v, tile_i, out_v_ref, out_i_ref, n):
    """n-step select-max-and-mask over [best | tile] along lanes.

    Writes the refreshed, score-descending (ties: id-ascending) top-n into
    the output refs.  Equivalent to lax.top_k over the n+BLOCK_N candidates.
    """
    cand_v = jnp.concatenate([best_v, tile_v], axis=1)
    cand_i = jnp.concatenate([best_i, tile_i], axis=1)
    bq, width = cand_v.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, width), 1)

    def step(j, cv):
        m = jnp.max(cv, axis=1, keepdims=True)                     # (BQ, 1)
        pos = jnp.min(
            jnp.where(cv == m, col, width), axis=1, keepdims=True
        )                                                          # first argmax
        sel_i = jnp.sum(
            jnp.where(col == pos, cand_i, 0), axis=1, keepdims=True
        )
        out_v_ref[:, pl.ds(j, 1)] = m
        out_i_ref[:, pl.ds(j, 1)] = sel_i
        return jnp.where(col == pos, _NEG_INF, cv)

    jax.lax.fori_loop(0, n, step, cand_v)


def _init_best(out_v_ref, out_i_ref):
    out_v_ref[...] = jnp.full(out_v_ref.shape, _NEG_INF, jnp.float32)
    out_i_ref[...] = jnp.zeros(out_i_ref.shape, jnp.int32)


def _mask_fold_merge(scores, inv, nb, out_v_ref, out_i_ref, *,
                     n, n_valid, block_n, alive=None):
    """Shared streaming-top-n tile epilogue (generations 2 and 3): fold the
    reciprocal candidate norms, mask padded rows by global id, and merge the
    tile into the VMEM-resident running best buffers (whole-tile skip when
    nothing beats the current n-th best).  ``alive`` — a (BLOCK_N, 1) f32
    1.0/0.0 liveness column from a segmented index's deletion mask — rides
    the padding mask: deleted rows score -inf exactly like padding, and a
    fully-deleted tile takes the same whole-tile skip (every score is -inf,
    so nothing can beat the current n-th best)."""
    scores = scores * inv.T                                        # fold 1/‖c‖
    bq, bn = scores.shape
    ids = nb * block_n + jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    keep = ids < n_valid                                           # mask padding
    if alive is not None:
        keep = keep & (alive.T > 0.0)                              # mask deletions
    scores = jnp.where(keep, scores, _NEG_INF)

    cur_min = out_v_ref[:, pl.ds(n - 1, 1)]                        # n-th best

    @pl.when(jnp.any(scores > cur_min))
    def _merge():
        _merge_top_n(
            out_v_ref[...], out_i_ref[...], scores, ids,
            out_v_ref, out_i_ref, n,
        )


def _make_retrieve_kernel(n: int, n_valid: int, block_n: int):
    def kernel(vals_ref, idx_ref, inv_ref, q_ref, out_v_ref, out_i_ref):
        nb = pl.program_id(1)

        @pl.when(nb == 0)
        def _init():
            _init_best(out_v_ref, out_i_ref)

        scores = _score_tile(vals_ref[...], idx_ref[...], q_ref[...])
        _mask_fold_merge(scores, inv_ref[...], nb, out_v_ref, out_i_ref,
                         n=n, n_valid=n_valid, block_n=block_n)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("n", "n_valid", "interpret", "block_n", "block_q")
)
def fused_retrieve_pallas(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    n_valid: int,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
) -> tuple[jax.Array, jax.Array]:
    """Fused score+select: (Q, n) best (norm-folded scores, candidate ids).

    values (N, k) f32, indices (N, k) i32, inv_norms (N, 1) f32 reciprocal
    candidate norms, q (Q, h) f32.  N % block_n == 0, Q % block_q == 0
    (ops.py pads); ``n_valid`` is the true candidate count before padding.
    The (Q, N) score matrix is never materialized.
    """
    N, k = values.shape
    nq, h = q.shape
    grid = (nq // block_q, N // block_n)  # candidate axis innermost
    out_v, out_i = pl.pallas_call(
        _make_retrieve_kernel(n, n_valid, block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_q, h), lambda qi, i: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, n), jnp.float32),
            jax.ShapeDtypeStruct((nq, n), jnp.int32),
        ],
        interpret=interpret,
    )(values, indices, inv_norms, q.astype(jnp.float32))
    return out_v, out_i


def _densify_panel(q_vals, q_idx, h: int):
    """(BLOCK_Q, kq) sparse query codes -> (BLOCK_Q, h) dense panel.

    kq comparison-scatter rounds accumulated in l order: duplicate indices
    within a row sum sequentially, exactly like ``sparse.densify``'s
    scatter-add, so downstream scores are bit-identical to the densified
    path.  Runs once per query panel into VMEM scratch — never HBM.
    """
    bq, kq = q_vals.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, h), 1)

    def body(l, acc):
        v = jax.lax.dynamic_slice_in_dim(q_vals, l, 1, axis=1)     # (BQ, 1)
        i = jax.lax.dynamic_slice_in_dim(q_idx, l, 1, axis=1)      # (BQ, 1)
        return acc + jnp.where(col == i, v, 0.0)

    return jax.lax.fori_loop(0, kq, body, jnp.zeros((bq, h), jnp.float32))


def _make_retrieve_sparse_q_kernel(n: int, n_valid: int, block_n: int, h: int,
                                   with_alive: bool = False):
    def kernel(vals_ref, idx_ref, inv_ref, *rest):
        if with_alive:
            alive_ref, qv_ref, qi_ref, out_v_ref, out_i_ref, panel_ref = rest
        else:
            qv_ref, qi_ref, out_v_ref, out_i_ref, panel_ref = rest
            alive_ref = None
        nb = pl.program_id(1)

        @pl.when(nb == 0)
        def _init():
            _init_best(out_v_ref, out_i_ref)
            panel_ref[...] = _densify_panel(qv_ref[...], qi_ref[...], h)

        scores = _score_tile(vals_ref[...], idx_ref[...], panel_ref[...])
        _mask_fold_merge(scores, inv_ref[...], nb, out_v_ref, out_i_ref,
                         n=n, n_valid=n_valid, block_n=block_n,
                         alive=None if alive_ref is None else alive_ref[...])

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("h", "n", "n_valid", "interpret", "block_n", "block_q"),
)
def fused_retrieve_sparse_q_pallas(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q_values: jax.Array,
    q_indices: jax.Array,
    h: int,
    *,
    n: int,
    n_valid: int,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    alive: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Sparse-query fused score+select: (Q, n) best (scores, candidate ids).

    values (N, k) f32, indices (N, k) i32, inv_norms (N, 1) f32, q_values
    (Q, kq) f32 + q_indices (Q, kq) i32 sparse query codes over [0, h).
    N % block_n == 0, Q % block_q == 0 (ops.py pads).  The dense query
    panel lives only in a (block_q, h) VMEM scratch, rebuilt per panel;
    query HBM traffic is the (Q, kq) codes — never (Q, h).  ``alive``,
    when given, is an (N, 1) f32 1.0/0.0 deletion mask: dead rows are
    masked to -inf alongside padding, and fully-dead tiles take the
    whole-tile skip.
    """
    N, k = values.shape
    nq = q_values.shape[0]
    grid = (nq // block_q, N // block_n)  # candidate axis innermost
    kq = q_values.shape[1]
    in_specs = [
        pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
        pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
    ]
    operands = [values, indices, inv_norms,
                q_values.astype(jnp.float32), q_indices]
    if alive is not None:
        in_specs.insert(3, pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)))
        operands.insert(3, alive)
    out_v, out_i = pl.pallas_call(
        _make_retrieve_sparse_q_kernel(n, n_valid, block_n, h,
                                       with_alive=alive is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, n), jnp.float32),
            jax.ShapeDtypeStruct((nq, n), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_q, h), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out_v, out_i


def _dequant_tile(q_vals, idx, scales):
    """Quantized candidate tile -> (f32 values, i32 indices), in VMEM.

    q_vals (BLOCK_N, k) int8, idx (BLOCK_N, k) int16/int32, scales
    (BLOCK_N, 1) f32.  The value dequant is the same two ops as
    ``quantize_codes``'s offline dequant (int8→f32 exact, one f32 multiply),
    so downstream scores are bit-identical to scoring pre-dequantized
    values.  int16 indices are the low 16 bits of the original index
    (two's-complement wrapped for h >= 32768): the shared widen recovers
    them exactly.
    """
    return q_vals.astype(jnp.float32) * scales, _widen_idx(idx)


def _make_retrieve_quantized_kernel(n: int, n_valid: int, block_n: int):
    def kernel(qvals_ref, idx_ref, scale_ref, inv_ref, q_ref,
               out_v_ref, out_i_ref):
        nb = pl.program_id(1)

        @pl.when(nb == 0)
        def _init():
            _init_best(out_v_ref, out_i_ref)

        vals, idx = _dequant_tile(qvals_ref[...], idx_ref[...], scale_ref[...])
        scores = _score_tile(vals, idx, q_ref[...])
        _mask_fold_merge(scores, inv_ref[...], nb, out_v_ref, out_i_ref,
                         n=n, n_valid=n_valid, block_n=block_n)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("n", "n_valid", "interpret", "block_n", "block_q")
)
def fused_retrieve_quantized_pallas(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    n_valid: int,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
) -> tuple[jax.Array, jax.Array]:
    """Quantized-index fused score+select: (Q, n) best (scores, ids).

    q_values (N, k) int8, indices (N, k) int16/int32, scales (N, 1) f32
    per-row dequant scales, inv_norms (N, 1) f32, q (Q, h) f32.  The index
    streams in its quantized dtypes; dequantization happens per tile in
    VMEM (``_dequant_tile``).  Bit-identical to ``fused_retrieve_pallas``
    over the dequantized arrays.
    """
    N, k = q_values.shape
    nq, h = q.shape
    grid = (nq // block_q, N // block_n)  # candidate axis innermost
    out_v, out_i = pl.pallas_call(
        _make_retrieve_quantized_kernel(n, n_valid, block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_q, h), lambda qi, i: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, n), jnp.float32),
            jax.ShapeDtypeStruct((nq, n), jnp.int32),
        ],
        interpret=interpret,
    )(q_values, indices, scales, inv_norms, q.astype(jnp.float32))
    return out_v, out_i


def _make_retrieve_quantized_sparse_q_kernel(
    n: int, n_valid: int, block_n: int, h: int, with_alive: bool = False
):
    def kernel(qvals_ref, idx_ref, scale_ref, inv_ref, *rest):
        if with_alive:
            alive_ref, qv_ref, qi_ref, out_v_ref, out_i_ref, panel_ref = rest
        else:
            qv_ref, qi_ref, out_v_ref, out_i_ref, panel_ref = rest
            alive_ref = None
        nb = pl.program_id(1)

        @pl.when(nb == 0)
        def _init():
            _init_best(out_v_ref, out_i_ref)
            panel_ref[...] = _densify_panel(qv_ref[...], qi_ref[...], h)

        vals, idx = _dequant_tile(qvals_ref[...], idx_ref[...], scale_ref[...])
        scores = _score_tile(vals, idx, panel_ref[...])
        _mask_fold_merge(scores, inv_ref[...], nb, out_v_ref, out_i_ref,
                         n=n, n_valid=n_valid, block_n=block_n,
                         alive=None if alive_ref is None else alive_ref[...])

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("h", "n", "n_valid", "interpret", "block_n", "block_q"),
)
def fused_retrieve_quantized_sparse_q_pallas(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    n_valid: int,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    alive: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantized candidates × sparse query codes: the full-compression
    serving kernel.  Candidate tiles stream int8/int16 and dequantize in
    VMEM; the (Q, kq) query codes densify into the (block_q, h) VMEM
    scratch panel (generation 3).  Neither an fp32 index nor a dense query
    panel ever exists in HBM.  Bit-identical to
    ``fused_retrieve_sparse_q_pallas`` over the dequantized arrays.
    ``alive``: optional (N, 1) f32 1.0/0.0 deletion mask (see the fp32
    sparse-q wrapper).
    """
    N, k = q_values.shape
    nq = query_values.shape[0]
    grid = (nq // block_q, N // block_n)  # candidate axis innermost
    kq = query_values.shape[1]
    in_specs = [
        pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
        pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
    ]
    operands = [q_values, indices, scales, inv_norms,
                query_values.astype(jnp.float32), query_indices]
    if alive is not None:
        in_specs.insert(4, pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)))
        operands.insert(4, alive)
    out_v, out_i = pl.pallas_call(
        _make_retrieve_quantized_sparse_q_kernel(n, n_valid, block_n, h,
                                                 with_alive=alive is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, n), jnp.float32),
            jax.ShapeDtypeStruct((nq, n), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_q, h), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out_v, out_i


def _score_tile_int8(vals_i8, idx, q_panel_i8):
    """(BLOCK_Q, BLOCK_N) int32 scores: k int8 lane-gathers, exact int32
    accumulation (generation 5).

    vals_i8 (BLOCK_N, k) int8, idx (BLOCK_N, k) i32 (already widened),
    q_panel_i8 (BLOCK_Q, h) int8.  Products are ≤ 127² and k ≤ a few
    hundred, so the int32 accumulator cannot overflow; int32 addition is
    associative, which is what makes the kernel bit-identical to the
    chunked jnp ref's ``jnp.sum`` over the same products.
    """
    bn, k = vals_i8.shape
    bq = q_panel_i8.shape[0]

    def body(j, acc):
        col = jax.lax.dynamic_slice_in_dim(idx, j, 1, axis=1)      # (BLOCK_N, 1)
        vcol = jax.lax.dynamic_slice_in_dim(vals_i8, j, 1, axis=1)
        gathered = jnp.take_along_axis(
            q_panel_i8, jnp.broadcast_to(col.T, (bq, bn)), axis=1
        )                                                          # (BLOCK_Q, BLOCK_N)
        return acc + gathered.astype(jnp.int32) * vcol.T.astype(jnp.int32)

    return jax.lax.fori_loop(0, k, body, jnp.zeros((bq, bn), jnp.int32))


def _make_retrieve_quantized_mxu_kernel(n: int, n_valid: int, block_n: int):
    def kernel(qvals_ref, idx_ref, scale_ref, inv_ref, q_ref,
               out_v_ref, out_i_ref, qi8_ref, qs_ref):
        nb = pl.program_id(1)

        @pl.when(nb == 0)
        def _init():
            _init_best(out_v_ref, out_i_ref)
            qi8, qs = _quantize_panel(q_ref[...])
            qi8_ref[...] = qi8
            qs_ref[...] = qs

        acc = _score_tile_int8(
            qvals_ref[...], _widen_idx(idx_ref[...]), qi8_ref[...]
        )
        scores = acc.astype(jnp.float32) * qs_ref[...]             # fold q scale
        # candidate-side rescale (row dequant scale × reciprocal norm)
        # rides the existing inv-norm fold in the shared epilogue
        _mask_fold_merge(scores, scale_ref[...] * inv_ref[...], nb,
                         out_v_ref, out_i_ref,
                         n=n, n_valid=n_valid, block_n=block_n)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("n", "n_valid", "interpret", "block_n", "block_q")
)
def fused_retrieve_quantized_mxu_pallas(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    q: jax.Array,
    *,
    n: int,
    n_valid: int,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
) -> tuple[jax.Array, jax.Array]:
    """Int8-scoring fused score+select (generation 5, APPROXIMATE).

    Same operands as ``fused_retrieve_quantized_pallas``, but the tile is
    never dequantized: the f32 query panel quantizes once per panel into
    int8 VMEM scratch, scoring runs int8×int8 → int32, and one f32
    rescale folds into the merge.  Bit-identical to
    ``retrieve_quantized_mxu_ref``; quality vs the exact quantized path
    is measured (``repro.core.eval``), not exact.
    """
    N, k = q_values.shape
    nq, h = q.shape
    grid = (nq // block_q, N // block_n)  # candidate axis innermost
    out_v, out_i = pl.pallas_call(
        _make_retrieve_quantized_mxu_kernel(n, n_valid, block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_q, h), lambda qi, i: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, n), jnp.float32),
            jax.ShapeDtypeStruct((nq, n), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, h), jnp.int8),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_values, indices, scales, inv_norms, q.astype(jnp.float32))
    return out_v, out_i


def _make_retrieve_quantized_mxu_sparse_q_kernel(
    n: int, n_valid: int, block_n: int, h: int, with_alive: bool = False
):
    def kernel(qvals_ref, idx_ref, scale_ref, inv_ref, *rest):
        if with_alive:
            (alive_ref, qv_ref, qi_ref,
             out_v_ref, out_i_ref, qi8_ref, qs_ref) = rest
        else:
            qv_ref, qi_ref, out_v_ref, out_i_ref, qi8_ref, qs_ref = rest
            alive_ref = None
        nb = pl.program_id(1)

        @pl.when(nb == 0)
        def _init():
            _init_best(out_v_ref, out_i_ref)
            # densify the code panel (generation 3's scatter) and quantize
            # it in one go — the f32 panel is a temporary value, only the
            # int8 panel + scales persist in scratch
            qi8, qs = _quantize_panel(
                _densify_panel(qv_ref[...], qi_ref[...], h)
            )
            qi8_ref[...] = qi8
            qs_ref[...] = qs

        acc = _score_tile_int8(
            qvals_ref[...], _widen_idx(idx_ref[...]), qi8_ref[...]
        )
        scores = acc.astype(jnp.float32) * qs_ref[...]
        _mask_fold_merge(scores, scale_ref[...] * inv_ref[...], nb,
                         out_v_ref, out_i_ref,
                         n=n, n_valid=n_valid, block_n=block_n,
                         alive=None if alive_ref is None else alive_ref[...])

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("h", "n", "n_valid", "interpret", "block_n", "block_q"),
)
def fused_retrieve_quantized_mxu_sparse_q_pallas(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    n_valid: int,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
    alive: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Int8-scoring × sparse query codes (generation 5, APPROXIMATE): the
    full-compression serving kernel with no dequant anywhere.  The (Q, kq)
    codes densify into a VMEM panel, quantize per row into int8 scratch,
    and score the int8 candidate stream with exact int32 accumulation.
    Bit-identical to ``retrieve_quantized_mxu_sparse_q_ref``.
    ``alive``: optional (N, 1) f32 1.0/0.0 deletion mask (see the fp32
    sparse-q wrapper).
    """
    N, k = q_values.shape
    nq = query_values.shape[0]
    grid = (nq // block_q, N // block_n)  # candidate axis innermost
    kq = query_values.shape[1]
    in_specs = [
        pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)),
        pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
        pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
    ]
    operands = [q_values, indices, scales, inv_norms,
                query_values.astype(jnp.float32), query_indices]
    if alive is not None:
        in_specs.insert(4, pl.BlockSpec((block_n, 1), lambda qi, i: (i, 0)))
        operands.insert(4, alive)
    out_v, out_i = pl.pallas_call(
        _make_retrieve_quantized_mxu_sparse_q_kernel(
            n, n_valid, block_n, h, with_alive=alive is not None
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, n), jnp.float32),
            jax.ShapeDtypeStruct((nq, n), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, h), jnp.int8),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return out_v, out_i


# --------------------------------------------------------------------------
# Generation 6: gather-aware re-rank (batched two-stage stage 2)
# --------------------------------------------------------------------------

def _score_tile_gathered(vals, idx, q_panel):
    """(BLOCK_Q, BLOCK_N) scores from per-query candidate bricks.

    vals/idx: (BLOCK_Q, BLOCK_N, k); q_panel: (BLOCK_Q, h).  Sparse column
    j's (BLOCK_Q, BLOCK_N) index slab gathers each query row's OWN panel
    lanes — the gathered twin of ``_score_tile``, same k-round FMA order,
    so each query row is bit-identical to the per-query kernel on its
    gathered sub-tile.
    """
    bq, bn, k = vals.shape

    def body(j, acc):
        col = jax.lax.dynamic_slice_in_dim(idx, j, 1, axis=2)      # (BQ, BN, 1)
        vcol = jax.lax.dynamic_slice_in_dim(vals, j, 1, axis=2)    # (BQ, BN, 1)
        gathered = jnp.take_along_axis(q_panel, col[..., 0], axis=1)
        return acc + gathered * vcol[..., 0]                       # (BQ, BN)

    return jax.lax.fori_loop(0, k, body, jnp.zeros((bq, bn), jnp.float32))


def _mask_fold_merge_gathered(scores, inv, nb, out_v_ref, out_i_ref, *,
                              n, n_valid, block_n):
    """Generation-2 epilogue with a per-(query, candidate) rescale tile.

    ``inv`` is (BLOCK_Q, BLOCK_N) — each query row folds its own
    candidates' reciprocal norms (× dequant scales for the int8 path)
    instead of a shared broadcast column.  Padding masks against the
    LOCAL candidate position (ids are positions in [0, B), not catalog
    rows); merge sweep and whole-tile skip unchanged.
    """
    scores = scores * inv                                          # fold 1/‖c‖
    bq, bn = scores.shape
    ids = nb * block_n + jax.lax.broadcasted_iota(jnp.int32, (bq, bn), 1)
    scores = jnp.where(ids < n_valid, scores, _NEG_INF)            # mask padding

    cur_min = out_v_ref[:, pl.ds(n - 1, 1)]                        # n-th best

    @pl.when(jnp.any(scores > cur_min))
    def _merge():
        _merge_top_n(
            out_v_ref[...], out_i_ref[...], scores, ids,
            out_v_ref, out_i_ref, n,
        )


def _make_retrieve_gathered_sparse_q_kernel(
    n: int, n_valid: int, block_n: int, h: int
):
    def kernel(vals_ref, idx_ref, inv_ref, qv_ref, qi_ref,
               out_v_ref, out_i_ref, panel_ref):
        nb = pl.program_id(1)

        @pl.when(nb == 0)
        def _init():
            _init_best(out_v_ref, out_i_ref)
            panel_ref[...] = _densify_panel(qv_ref[...], qi_ref[...], h)

        scores = _score_tile_gathered(
            vals_ref[...], idx_ref[...], panel_ref[...]
        )
        _mask_fold_merge_gathered(scores, inv_ref[...], nb,
                                  out_v_ref, out_i_ref,
                                  n=n, n_valid=n_valid, block_n=block_n)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("h", "n", "n_valid", "interpret", "block_n", "block_q"),
)
def fused_retrieve_gathered_sparse_q_pallas(
    values: jax.Array,
    indices: jax.Array,
    inv_norms: jax.Array,
    q_values: jax.Array,
    q_indices: jax.Array,
    h: int,
    *,
    n: int,
    n_valid: int,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
) -> tuple[jax.Array, jax.Array]:
    """Gathered sparse-query fused score+select (generation 6, fp32).

    values (Q, B, k) f32 per-query candidate panels, indices (Q, B, k)
    i32, inv_norms (Q, B) f32, q_values/q_indices (Q, kq) sparse query
    codes over [0, h).  B % block_n == 0, Q % block_q == 0 (ops.py pads);
    ``n_valid`` is the true per-query candidate count before padding.
    Returns (Q, n) best (norm-folded scores, LOCAL candidate positions in
    [0, B)).  Bit-identical per query to ``fused_retrieve_sparse_q_pallas``
    over the gathered sub-arrays.
    """
    nq, B, k = values.shape
    grid = (nq // block_q, B // block_n)  # candidate axis innermost
    kq = q_values.shape[1]
    out_v, out_i = pl.pallas_call(
        _make_retrieve_gathered_sparse_q_kernel(n, n_valid, block_n, h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_n, k), lambda qi, i: (qi, i, 0)),
            pl.BlockSpec((block_q, block_n, k), lambda qi, i: (qi, i, 0)),
            pl.BlockSpec((block_q, block_n), lambda qi, i: (qi, i)),
            pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, n), jnp.float32),
            jax.ShapeDtypeStruct((nq, n), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_q, h), jnp.float32)],
        interpret=interpret,
    )(values, indices, inv_norms,
      q_values.astype(jnp.float32), q_indices)
    return out_v, out_i


def _dequant_tile_gathered(q_vals, idx, scales):
    """Quantized (BLOCK_Q, BLOCK_N, k) brick -> (f32 values, i32 indices).

    Same two dequant ops per element as ``_dequant_tile`` with the scale
    column now a per-(query, candidate) (BLOCK_Q, BLOCK_N) tile.
    """
    return q_vals.astype(jnp.float32) * scales[..., None], _widen_idx(idx)


def _make_retrieve_gathered_quantized_sparse_q_kernel(
    n: int, n_valid: int, block_n: int, h: int
):
    def kernel(qvals_ref, idx_ref, scale_ref, inv_ref, qv_ref, qi_ref,
               out_v_ref, out_i_ref, panel_ref):
        nb = pl.program_id(1)

        @pl.when(nb == 0)
        def _init():
            _init_best(out_v_ref, out_i_ref)
            panel_ref[...] = _densify_panel(qv_ref[...], qi_ref[...], h)

        vals, idx = _dequant_tile_gathered(
            qvals_ref[...], idx_ref[...], scale_ref[...]
        )
        scores = _score_tile_gathered(vals, idx, panel_ref[...])
        _mask_fold_merge_gathered(scores, inv_ref[...], nb,
                                  out_v_ref, out_i_ref,
                                  n=n, n_valid=n_valid, block_n=block_n)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("h", "n", "n_valid", "interpret", "block_n", "block_q"),
)
def fused_retrieve_gathered_quantized_sparse_q_pallas(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    n_valid: int,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
) -> tuple[jax.Array, jax.Array]:
    """Gathered quantized × sparse-query fused score+select (generation 6).

    q_values (Q, B, k) int8, indices (Q, B, k) int16/int32, scales and
    inv_norms (Q, B) f32, query codes (Q, kq).  The per-query candidate
    panels stream in their quantized storage dtypes and dequantize per
    brick in VMEM.  Bit-identical per query to
    ``fused_retrieve_quantized_sparse_q_pallas`` over the gathered
    sub-arrays.
    """
    nq, B, k = q_values.shape
    grid = (nq // block_q, B // block_n)  # candidate axis innermost
    kq = query_values.shape[1]
    out_v, out_i = pl.pallas_call(
        _make_retrieve_gathered_quantized_sparse_q_kernel(
            n, n_valid, block_n, h
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_n, k), lambda qi, i: (qi, i, 0)),
            pl.BlockSpec((block_q, block_n, k), lambda qi, i: (qi, i, 0)),
            pl.BlockSpec((block_q, block_n), lambda qi, i: (qi, i)),
            pl.BlockSpec((block_q, block_n), lambda qi, i: (qi, i)),
            pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, n), jnp.float32),
            jax.ShapeDtypeStruct((nq, n), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_q, h), jnp.float32)],
        interpret=interpret,
    )(q_values, indices, scales, inv_norms,
      query_values.astype(jnp.float32), query_indices)
    return out_v, out_i


def _score_tile_int8_gathered(vals_i8, idx, q_panel_i8):
    """(BLOCK_Q, BLOCK_N) int32 scores from per-query int8 bricks.

    vals_i8 (BLOCK_Q, BLOCK_N, k) int8, idx already widened to i32,
    q_panel_i8 (BLOCK_Q, h) int8.  Exact int32 accumulation — same
    overflow headroom and associativity argument as ``_score_tile_int8``,
    so the kernel stays bit-identical to its chunked jnp ref.
    """
    bq, bn, k = vals_i8.shape

    def body(j, acc):
        col = jax.lax.dynamic_slice_in_dim(idx, j, 1, axis=2)      # (BQ, BN, 1)
        vcol = jax.lax.dynamic_slice_in_dim(vals_i8, j, 1, axis=2)
        gathered = jnp.take_along_axis(q_panel_i8, col[..., 0], axis=1)
        return acc + gathered.astype(jnp.int32) * vcol[..., 0].astype(jnp.int32)

    return jax.lax.fori_loop(0, k, body, jnp.zeros((bq, bn), jnp.int32))


def _make_retrieve_gathered_quantized_mxu_sparse_q_kernel(
    n: int, n_valid: int, block_n: int, h: int
):
    def kernel(qvals_ref, idx_ref, scale_ref, inv_ref, qv_ref, qi_ref,
               out_v_ref, out_i_ref, qi8_ref, qs_ref):
        nb = pl.program_id(1)

        @pl.when(nb == 0)
        def _init():
            _init_best(out_v_ref, out_i_ref)
            qi8, qs = _quantize_panel(
                _densify_panel(qv_ref[...], qi_ref[...], h)
            )
            qi8_ref[...] = qi8
            qs_ref[...] = qs

        acc = _score_tile_int8_gathered(
            qvals_ref[...], _widen_idx(idx_ref[...]), qi8_ref[...]
        )
        scores = acc.astype(jnp.float32) * qs_ref[...]             # fold q scale
        _mask_fold_merge_gathered(scores, scale_ref[...] * inv_ref[...], nb,
                                  out_v_ref, out_i_ref,
                                  n=n, n_valid=n_valid, block_n=block_n)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("h", "n", "n_valid", "interpret", "block_n", "block_q"),
)
def fused_retrieve_gathered_quantized_mxu_sparse_q_pallas(
    q_values: jax.Array,
    indices: jax.Array,
    scales: jax.Array,
    inv_norms: jax.Array,
    query_values: jax.Array,
    query_indices: jax.Array,
    h: int,
    *,
    n: int,
    n_valid: int,
    interpret: bool = False,
    block_n: int = BLOCK_N,
    block_q: int = BLOCK_Q,
) -> tuple[jax.Array, jax.Array]:
    """Gathered int8-scoring × sparse-query fused score+select
    (generation 6 × 5, APPROXIMATE vs exact).  Per-query int8 candidate
    bricks score against the once-per-panel quantized query scratch with
    exact int32 accumulation; one f32 rescale — (acc·q_scale) ·
    (row_scale·inv_norm), the scale/norm factors now per-(query,
    candidate) tiles — folds into the merge.  Bit-identical per query to
    ``fused_retrieve_quantized_mxu_sparse_q_pallas`` over the gathered
    sub-arrays, and to ``retrieve_gathered_quantized_mxu_sparse_q_ref``.
    """
    nq, B, k = q_values.shape
    grid = (nq // block_q, B // block_n)  # candidate axis innermost
    kq = query_values.shape[1]
    out_v, out_i = pl.pallas_call(
        _make_retrieve_gathered_quantized_mxu_sparse_q_kernel(
            n, n_valid, block_n, h
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_n, k), lambda qi, i: (qi, i, 0)),
            pl.BlockSpec((block_q, block_n, k), lambda qi, i: (qi, i, 0)),
            pl.BlockSpec((block_q, block_n), lambda qi, i: (qi, i)),
            pl.BlockSpec((block_q, block_n), lambda qi, i: (qi, i)),
            pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, kq), lambda qi, i: (qi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
            pl.BlockSpec((block_q, n), lambda qi, i: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, n), jnp.float32),
            jax.ShapeDtypeStruct((nq, n), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, h), jnp.int8),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_values, indices, scales, inv_norms,
      query_values.astype(jnp.float32), query_indices)
    return out_v, out_i
