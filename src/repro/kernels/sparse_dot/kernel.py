"""Scatter-query SpMV Pallas kernel (DESIGN.md §3).

Contract: scores[qi, i] = Σ_j values[i, j] · q[qi, indices[i, j]]

TPU mapping:
  * The dense query row (h floats, h=4096 ⇒ 16 KiB) is VMEM-resident for the
    whole pass — the "scatter-query" trick that turns the paper's CSR SpMV
    (gather from sparse rows) into a regular per-row VMEM gather the VPU can
    vectorize (`jnp.take_along_axis` → tpu.dynamic_gather along lanes).
  * Candidate (values, indices) stream HBM→VMEM in (BLOCK_N, k) tiles via
    BlockSpec; arithmetic intensity is 2 flops per 8 bytes streamed, i.e.
    the kernel is HBM-bandwidth-bound by construction (roofline: memory
    term), which is the point — it reads 12× fewer bytes than a dense scan.
  * Grid = (Q, N / BLOCK_N); the query axis is 'parallel', the candidate
    axis 'arbitrary' (no cross-block state).

Lowering note: the per-element gather lowers to Mosaic's dynamic-gather on
the lane dimension.  If a target generation lacks it, the fallback is the
one-hot-matmul formulation (MXU) — see ref.py discussion in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256  # candidate rows per tile (8-sublane multiple)


def _kernel(vals_ref, idx_ref, q_ref, out_ref):
    vals = vals_ref[...]                       # (BLOCK_N, k)
    idx = idx_ref[...]                         # (BLOCK_N, k) int32
    q = q_ref[...]                             # (1, h)
    qb = jnp.broadcast_to(q, (vals.shape[0], q.shape[1]))
    gathered = jnp.take_along_axis(qb, idx, axis=1)       # (BLOCK_N, k)
    out_ref[...] = jnp.sum(gathered * vals, axis=1, keepdims=True).T  # (1, BLOCK_N)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def sparse_dot_pallas(
    values: jax.Array,
    indices: jax.Array,
    q: jax.Array,
    *,
    interpret: bool = False,
    block_n: int = BLOCK_N,
) -> jax.Array:
    """values (N, k) f32, indices (N, k) i32, q (Q, h) f32 -> (Q, N) f32.

    N must be a multiple of block_n (ops.py pads).
    """
    n, k = values.shape
    nq, h = q.shape
    grid = (nq, n // block_n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda qi, i: (i, 0)),
            pl.BlockSpec((1, h), lambda qi, i: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda qi, i: (qi, i)),
        out_shape=jax.ShapeDtypeStruct((nq, n), jnp.float32),
        interpret=interpret,
    )(values, indices, q)
