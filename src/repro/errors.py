"""Typed serving/retrieval errors (ISSUE 6).

One hierarchy for every failure the serving stack can name, rooted at
``RetrievalError`` so callers can catch "anything the retrieval path
classified" with a single except clause while still dispatching on the
concrete type.  The module lives at the repo root of the package — below
``core``, ``distributed`` and ``serving`` alike — so every layer can
raise these without an import cycle (``serving.guard`` re-exports them
as its public admission-error API).

Two deliberate multiple-inheritance choices:

* Validation errors (``EngineConfigError``, ``InvalidQueryError``) also
  subclass ``ValueError``: pre-ISSUE-6 callers that caught/matched
  ``ValueError`` keep working, while new callers get the typed class.
* ``DeadlineExceededError`` also subclasses ``TimeoutError`` for the
  same reason (standard-library timeout semantics).
"""
from __future__ import annotations

from typing import Optional


class RetrievalError(Exception):
    """Base of every typed failure raised by the serving stack."""


class EngineConfigError(RetrievalError, ValueError):
    """Engine/request CONSTRUCTION is invalid (bad mode, precision,
    missing params/norms) — the caller's configuration, not the data."""


class InvalidQueryError(RetrievalError, ValueError):
    """A request failed admission: non-finite values, wrong shape/dtype,
    or an unservable top-n.  Messages name the offending argument and
    the expected vs actual value."""


class InvalidCodesError(RetrievalError, ValueError):
    """Sparse codes are structurally invalid for the operation — e.g. a
    code index outside ``[0, h)`` handed to the inverted-index builder.
    Messages name the offending row/slot and the out-of-range latent.
    Also a ``ValueError`` for callers matching the stdlib taxonomy."""


class IndexIntegrityError(RetrievalError):
    """Index content does not match its build-time checksum (corruption,
    out-of-band mutation, or a checksum-less index where one is
    required)."""


class SegmentMutationError(RetrievalError, ValueError):
    """A segmented-index lifecycle op is invalid: adding an item id that
    is already alive, deleting an unknown or already-deleted id, or
    handing ``add_items`` codes whose shape/dim disagree with the index.
    Messages name the offending id/argument.  Also a ``ValueError`` for
    callers matching the stdlib taxonomy."""


class DeadlineExceededError(RetrievalError, TimeoutError):
    """The per-request deadline budget ran out at the recorded stage."""


class QueueFullError(RetrievalError):
    """The microbatching front shed this request at admission: the queue
    already holds ``queued_rows`` >= its ``max_queue_rows`` bound.  The
    typed overload signal — callers retry (with backoff) or downgrade;
    the server never buffers unboundedly.  ``queued_rows`` /
    ``max_queue_rows`` let callers size their backoff."""

    def __init__(self, message: str, *, queued_rows: int = 0,
                 max_queue_rows: int = 0):
        super().__init__(message)
        self.queued_rows = queued_rows
        self.max_queue_rows = max_queue_rows


class ShardFailureError(RetrievalError):
    """A candidate shard failed to answer.  ``shard`` is the failing
    shard's mesh position when known, else None."""

    def __init__(self, message: str, shard: Optional[int] = None):
        super().__init__(message)
        self.shard = shard


class KernelFaultError(RetrievalError):
    """The kernel serving path raised (or fault injection simulated it
    raising) — the degradation ladder's cue to step down a generation."""


class SelfCheckError(RetrievalError):
    """The startup self-check's canary batch failed: the configured
    serving path disagrees with its reference contract."""


class DegradationExhaustedError(RetrievalError):
    """Every rung of the degradation ladder failed for one request; the
    message chains each rung's fault reason."""
