"""Retrieval-quality evaluation harness (ISSUE 5).

Generation 5 of the fused retrieve scores in int8 — the first serving
path whose contract against the exact path is a *measured quality bound*
rather than bit-identity.  The paper's claim is that compression
preserves retrieval QUALITY, not bit-exact scores, so approximate paths
are gated on the three metrics here instead of ``array_equal``:

``recall_at_n``        — fraction of the reference top-n ids the
                         approximate list recovered (order-insensitive).
``score_mae``          — positional mean-absolute-error between the two
                         rank-sorted top-n score curves.
``rank_displacement``  — mean |rank in approximate − rank in reference|
                         over the approximate list; ids missing from the
                         reference list are charged the worst case n.
``retrieval_quality``  — the bundle, taking the two ``(scores, ids)``
                         pairs exactly as the serving APIs return them.

Shared infrastructure: tests (``tests/test_retrieval_quality.py`` gates
the int8 path's recall@32 in tier-1), benchmarks
(``benchmarks/retrieval_modes.py`` reports the metrics on the
``retrieval_sparse_quantized_mxu`` row), and any future approximate
generation.  Everything is plain numpy on host — these are offline
metrics, never part of a serving computation — and accepts jax arrays,
numpy arrays, or nested lists, in single-query (n,) or batched (Q, n)
layout.

Edge semantics (pinned by tests/test_eval_harness.py):
  * n > the rows' length clamps to what is actually there — asking for
    recall@10 of 7-long lists measures the 7 present matches, it does not
    deflate the denominator with phantom misses.
  * duplicate ids in a reference row (possible with hand-built inputs)
    count once: the denominator is the number of DISTINCT reference ids.
  * exact score ties cost nothing in ``score_mae`` (equal values compare
    positionally after both sides sort) and tie-reordered ids cost their
    true positional distance in ``rank_displacement`` — ties are not
    special-cased away, they are simply cheap.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def _as_2d(x) -> np.ndarray:
    a = np.asarray(x)
    if a.ndim == 1:
        a = a[None]
    if a.ndim != 2:
        raise ValueError(f"expected (n,) or (Q, n) array, got shape {a.shape}")
    return a


def recall_at_n(ids, ref_ids, n: Optional[int] = None) -> float:
    """Mean fraction of the reference top-n ids present in ``ids``.

    ids / ref_ids: (n?,) or (Q, n?) candidate-id arrays, highest-ranked
    first.  Both are truncated to their first ``n`` entries (default: the
    reference row length); ``n`` beyond a row's length clamps.  The
    denominator is the number of distinct reference ids per row, so the
    metric stays in [0, 1] even on degenerate hand-built inputs.
    """
    got = _as_2d(ids)
    ref = _as_2d(ref_ids)
    if got.shape[0] != ref.shape[0]:
        raise ValueError(
            f"query-count mismatch: {got.shape[0]} vs {ref.shape[0]}"
        )
    if n is None:
        n = ref.shape[1]
    got = got[:, : min(n, got.shape[1])]
    ref = ref[:, : min(n, ref.shape[1])]
    recs = []
    for g, r in zip(got, ref):
        want = set(r.tolist())
        recs.append(len(want & set(g.tolist())) / max(len(want), 1))
    return float(np.mean(recs)) if recs else 0.0


def score_mae(scores, ref_scores, n: Optional[int] = None) -> float:
    """Positional MAE between two rank-sorted top-n score curves.

    Both inputs are sorted descending per row before comparison (serving
    outputs already are; sorting makes the metric insensitive to provider
    order) and truncated to the shorter of the two rows (or ``n``).
    Measures how far the approximate score CURVE sits from the exact one
    — rank-agnostic by construction, so pair it with
    ``rank_displacement`` for ordering damage.
    """
    s = _as_2d(np.asarray(scores, dtype=np.float64))
    r = _as_2d(np.asarray(ref_scores, dtype=np.float64))
    if s.shape[0] != r.shape[0]:
        raise ValueError(f"query-count mismatch: {s.shape[0]} vs {r.shape[0]}")
    width = min(s.shape[1], r.shape[1])
    if n is not None:
        width = min(width, n)
    s = -np.sort(-s, axis=1)[:, :width]
    r = -np.sort(-r, axis=1)[:, :width]
    return float(np.mean(np.abs(s - r))) if width else 0.0


def rank_displacement(ids, ref_ids, n: Optional[int] = None) -> float:
    """Mean |approximate rank − reference rank| over the approximate list.

    For each id in the (truncated-to-n) approximate row: its absolute
    rank distance to the same id's position in the reference row, or the
    worst case ``n`` when the reference row does not contain it (it
    displaced a reference id by at least the list length).  Duplicate
    reference ids resolve to their FIRST (best) rank.  0.0 means the two
    rankings agree exactly.
    """
    got = _as_2d(ids)
    ref = _as_2d(ref_ids)
    if got.shape[0] != ref.shape[0]:
        raise ValueError(
            f"query-count mismatch: {got.shape[0]} vs {ref.shape[0]}"
        )
    if n is None:
        n = min(got.shape[1], ref.shape[1])
    got = got[:, : min(n, got.shape[1])]
    ref = ref[:, : min(n, ref.shape[1])]
    width = got.shape[1]
    if width == 0:
        return 0.0
    disps = []
    for g, r in zip(got, ref):
        pos: dict = {}
        for j, rid in enumerate(r.tolist()):
            pos.setdefault(rid, j)              # first occurrence wins
        disps.extend(
            abs(i - pos[gid]) if gid in pos else width
            for i, gid in enumerate(g.tolist())
        )
    return float(np.mean(disps))


def retrieval_quality(approx, exact, n: Optional[int] = None) -> dict:
    """The bundle: compare two ``(scores, ids)`` retrieval outputs.

    ``approx`` / ``exact``: (scores, ids) pairs as returned by
    ``retrieve``, or ``RetrievalResponse``s from
    ``RetrievalEngine.retrieve_dense`` (scores/ids ride positions 0/1 of
    both) — (n,) or (Q, n).
    Returns ``{"n", "recall", "score_mae", "rank_displacement"}`` with
    ``n`` the effective (clamped) comparison width.
    """
    a_scores, a_ids = approx[0], approx[1]
    e_scores, e_ids = exact[0], exact[1]
    a_ids2, e_ids2 = _as_2d(a_ids), _as_2d(e_ids)
    width = min(a_ids2.shape[1], e_ids2.shape[1])
    if n is not None:
        width = min(width, n)
    return {
        "n": int(width),
        "recall": recall_at_n(a_ids, e_ids, n=width),
        "score_mae": score_mae(a_scores, e_scores, n=width),
        "rank_displacement": rank_displacement(a_ids, e_ids, n=width),
    }
