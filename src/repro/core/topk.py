"""φ(·, k): the abs-top-k activation (paper eq. 1).

Keeps the k entries with the largest |value| and zeroes the rest —
sign-preserving, replacing ReLU+TopK of prior SAEs.  Two public forms:

  * ``abs_topk(x, k)``          — dense in, dense out (the activation).
  * ``abs_topk_sparse(x, k)``   — dense in, (values, indices) out (encoder
                                  output in the fixed-k sparse layout).

A straight-through estimator is used for the backward pass of the *mask*
(standard for k-sparse autoencoders: gradients flow only through the kept
entries, which is exactly d/dx of the masked identity almost everywhere —
so plain autodiff through ``where`` is already correct; no custom VJP
needed).  ``jax.lax.top_k`` on |x| supplies the selection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def abs_topk_sparse(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Return (values, indices) of the k largest-|x| entries per row.

    x: (..., h).  values: (..., k) same dtype, indices: (..., k) int32.
    Ties broken by lax.top_k's deterministic lowest-index-first rule.
    """
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def abs_topk(x: jax.Array, k: int, groups: int = 0) -> jax.Array:
    """Dense φ(x, k): zero all but the k largest-|value| entries per row.
    groups > 0 selects the exact two-stage grouped algorithm (shardable)."""
    if groups:
        vals, idx = abs_topk_sparse_grouped(x, k, groups)
    else:
        vals, idx = abs_topk_sparse(x, k)
    zeros = jnp.zeros_like(x)
    return jnp.put_along_axis(zeros, idx, vals, axis=-1, inplace=False)


def abs_topk_mask(x: jax.Array, k: int) -> jax.Array:
    """Boolean mask of kept entries; useful for telemetry (dead neurons)."""
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    mask = jnp.zeros(x.shape, dtype=bool)
    ones = jnp.ones(idx.shape, dtype=bool)
    return jnp.put_along_axis(mask, idx, ones, axis=-1, inplace=False)


def abs_topk_sparse_grouped(
    x: jax.Array, k: int, groups: int
) -> tuple[jax.Array, jax.Array]:
    """Two-stage EXACT abs-top-k: per-group local top-k, then a global
    re-selection over the groups·k candidates.

    Equivalent to ``abs_topk_sparse`` (the global top-k set is a subset of
    the union of per-group top-k sets) but expressible as ``groups`` local
    sorts over h/groups lanes + one tiny global sort — under pjit with h
    sharded over a mesh axis of size ``groups`` the heavy stage is fully
    local and only (…, groups·k·2) values cross the interconnect, versus
    all-gathering the (…, h) pre-activations (DESIGN.md §3; the paper-cell
    hillclimb in EXPERIMENTS.md §Perf).
    """
    *lead, h = x.shape
    assert h % groups == 0 and groups * k <= h
    xg = x.reshape(*lead, groups, h // groups)
    lv, li = jax.lax.top_k(jnp.abs(xg), k)               # (..., G, k)
    vals_g = jnp.take_along_axis(xg, li, axis=-1)
    offs = (jnp.arange(groups, dtype=jnp.int32) * (h // groups))[:, None]
    gi = li.astype(jnp.int32) + offs                     # global column ids
    cand_v = vals_g.reshape(*lead, groups * k)
    cand_i = gi.reshape(*lead, groups * k)
    _, sel = jax.lax.top_k(jnp.abs(cand_v), k)
    vals = jnp.take_along_axis(cand_v, sel, axis=-1)
    idx = jnp.take_along_axis(cand_i, sel, axis=-1)
    return vals, idx


def distributed_abs_topk_sparse(
    x_local: jax.Array, k: int, *, axis_name: str, shard_offset: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Distributed φ(·,k) for h sharded over a mesh axis (beyond-paper §Perf).

    Instead of all-gathering the full (B, h) pre-activations to run a global
    top-k (B·h·4 bytes over ICI), each shard takes its local top-k
    (k candidates out of h/n_shards), then the 2·k·n_shards candidate
    (value, global_index) pairs are all-gathered and reduced with a second
    top-k.  Correct because the global top-k set is a subset of the union of
    per-shard top-k sets.  Collective bytes drop from B·h·4 to B·n·k·8.

    Must be called inside shard_map with ``axis_name`` bound; ``x_local`` is
    the (B, h_local) shard and ``shard_offset`` the global column offset of
    this shard (e.g. ``jax.lax.axis_index(axis_name) * h_local``).
    Returns *replicated* (values, global_indices) of shape (B, k).
    """
    local_vals, local_idx = abs_topk_sparse(x_local, k)
    global_idx = local_idx + shard_offset.astype(jnp.int32)
    # all-gather the candidate sets along the sharded axis: (n, B, k)
    cand_vals = jax.lax.all_gather(local_vals, axis_name)
    cand_idx = jax.lax.all_gather(global_idx, axis_name)
    n = cand_vals.shape[0]
    cand_vals = jnp.moveaxis(cand_vals, 0, -2).reshape(*x_local.shape[:-1], n * k)
    cand_idx = jnp.moveaxis(cand_idx, 0, -2).reshape(*x_local.shape[:-1], n * k)
    _, sel = jax.lax.top_k(jnp.abs(cand_vals), k)
    vals = jnp.take_along_axis(cand_vals, sel, axis=-1)
    idx = jnp.take_along_axis(cand_idx, sel, axis=-1)
    return vals, idx
