"""Losses (paper §3, eq. 3).

L_cosine(x, x̂) = 1 − xᵀx̂ / (‖x‖‖x̂‖); the final loss sums the cosine loss of
the k-sparse reconstruction and the 4k-sparse auxiliary reconstruction
(multi-k training, prevents dead neurons — analogue of Gao et al.'s AuxK).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import sae
from repro.core.types import SAEConfig


def cosine_distance(x: jax.Array, x_hat: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Per-row 1 − cos(x, x̂); shape (...,)."""
    num = jnp.sum(x * x_hat, axis=-1)
    den = jnp.linalg.norm(x, axis=-1) * jnp.linalg.norm(x_hat, axis=-1)
    return 1.0 - num / jnp.maximum(den, eps)


def compressae_loss(
    params: sae.Params, x: jax.Array, cfg: SAEConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Total loss = L_cos(x, f(x;k)) + aux_weight · L_cos(x, f(x;4k)).

    Shares one matmul: pre-activations computed once, both sparsities are
    masks of the same tensor.  Returns (scalar loss, metrics dict).
    """
    from repro.core.topk import abs_topk
    from repro.distributed.sharding import shard_hint

    pre = shard_hint(sae.preactivations(params, x), "logits")   # (B, h)
    s_k = abs_topk(pre, cfg.k, cfg.topk_groups)
    s_aux = abs_topk(pre, cfg.aux_k, cfg.topk_groups)
    xh_k = sae.decode_dense(params, s_k)
    xh_aux = sae.decode_dense(params, s_aux)
    l_k = jnp.mean(cosine_distance(x, xh_k))
    l_aux = jnp.mean(cosine_distance(x, xh_aux))
    loss = l_k + cfg.aux_weight * l_aux
    # Dead-neuron telemetry: which latents fired (under the wider aux mask)
    # anywhere in the batch.  Returned for train_step's staleness counter.
    fired = jax.lax.stop_gradient((s_aux != 0).any(axis=tuple(range(s_aux.ndim - 1))))
    metrics = {
        "loss": loss,
        "cos_loss_k": l_k,
        "cos_loss_aux": l_aux,
        "frac_active_latents": jnp.mean(fired.astype(jnp.float32)),
        "fired": fired,
    }
    return loss, metrics
