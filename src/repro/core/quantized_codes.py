"""Beyond-paper extension: quantized sparse codes (compound compression).

The paper stores codes as fp32 values + int32 indices (2·k·4 B/row) and
positions quantization as a *separate* related-work technique.  The two
compose: within a row, the k surviving values have similar magnitude
(they are the top-|k| of a normalized input), so per-row symmetric int8
quantization of VALUES costs little; INDICES fit int16 whenever h < 65536
(h = 4096 in the paper).  Bytes per row:

    paper:      k·(4 + 4)            = 8k      (12.0x vs 768-d fp32)
    compound:   k·(1 + 2) + 4(scale) = 3k + 4  (~31x at k = 32)

Since ISSUE 4 the quantized format is a first-class *serving* format:
``core.retrieval.build_index(..., quantize=True)`` produces a
``QuantizedIndex`` whose arrays stay int8/int16 in HBM, and the fused
retrieval kernels (``kernels/sparse_dot.fused_retrieve_quantized`` and
its sparse-query variant) dequantize candidate tiles in VMEM scratch —
the serving path never materializes an fp32 copy of the index.
Dequantized-space scoring is exactly what serving computes, so retrieval
from the quantized index is bit-identical to dequantize-then-retrieve on
the same quantized values (quantization error is a build-time choice,
never a serving-path one).  Measured recall impact: see
benchmarks/quantized_codes_bench.py (≤1 recall point at int8 in our
offline proxy).

Storage note on int16 indices: signed int16 only *represents* [−32768,
32767], but it *stores* any 16-bit pattern — indices in [32768, 65536)
wrap to negative two's-complement values on the way in and are recovered
exactly by ``widen_indices`` (astype int32, mask the low 16 bits) on the
way out.  The kernel package carries one identical twin of this helper
(``kernels.sparse_dot.ref._widen_idx``, shared by the jnp refs and the
Pallas VMEM dequant) so it stays import-cycle-free with repro.core; any
change to the wraparound scheme must update both.
"""
from __future__ import annotations

import zlib
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import SparseCodes


class QuantizedCodes(NamedTuple):
    q_values: jax.Array    # (N, k) int8
    indices: jax.Array     # (N, k) int16 bit pattern (h < 65536) or int32
    scales: jax.Array      # (N,) float32 per-row symmetric scale
    dim: int

    @property
    def n(self) -> int:
        return self.q_values.shape[0]

    @property
    def k(self) -> int:
        return self.q_values.shape[1]

    @property
    def nbytes_logical(self) -> int:
        """Storage bytes of the compound-compressed representation
        (values + indices + per-row scales): k·(1 + idx_bytes) + 4 per row."""
        return (self.q_values.size * 1
                + self.indices.size * self.indices.dtype.itemsize
                + self.scales.size * 4)


def widen_indices(indices: jax.Array) -> jax.Array:
    """int16-stored (possibly wrapped) column indices -> exact int32.

    int16 holds the low 16 bits of the original index; masking after the
    widening undoes the two's-complement wrap for indices >= 32768.
    int32 indices pass through unchanged.
    """
    if indices.dtype == jnp.int32:
        return indices
    return jnp.bitwise_and(indices.astype(jnp.int32), 0xFFFF)


def quantize_codes(codes: SparseCodes) -> QuantizedCodes:
    """Per-row symmetric int8 quantization of the k values."""
    amax = jnp.max(jnp.abs(codes.values), axis=-1)            # (N,)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(codes.values / scale[:, None]), -127, 127)
    idx_dtype = jnp.int16 if codes.dim < 65536 else jnp.int32
    return QuantizedCodes(
        q_values=q.astype(jnp.int8),
        indices=codes.indices.astype(idx_dtype),
        scales=scale.astype(jnp.float32),
        dim=codes.dim,
    )


def dequantize_codes(q: QuantizedCodes) -> SparseCodes:
    vals = q.q_values.astype(jnp.float32) * q.scales[:, None]
    return SparseCodes(values=vals, indices=widen_indices(q.indices),
                       dim=q.dim)


def content_checksum(named_arrays) -> Optional[int]:
    """CRC32 over the byte content of ``(name, array)`` pairs — the
    integrity fingerprint stored on an index at build time (ISSUE 6).

    The digest covers each array's field name, dtype, shape, AND raw
    bytes, so a single flipped bit anywhere in the stored codes changes
    it, and so do shape/dtype edits that leave bytes coincidentally
    equal.  ``None`` entries are skipped (optional index fields).
    Returns ``None`` when any array is an abstract tracer (checksums are
    a host-side build/startup concern, never part of a traced
    computation).
    """
    crc = 0
    for name, arr in named_arrays:
        if arr is None:
            continue
        try:
            a = np.asarray(arr)
        except Exception:  # jax tracer under jit — no concrete bytes
            return None
        crc = zlib.crc32(
            f"{name}:{a.dtype}:{a.shape}:".encode(), crc
        )
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
    return crc


def codes_checksum(codes) -> Optional[int]:
    """Content checksum of a ``SparseCodes`` or ``QuantizedCodes``."""
    if isinstance(codes, QuantizedCodes):
        fields = [("q_values", codes.q_values), ("indices", codes.indices),
                  ("scales", codes.scales)]
    else:
        fields = [("values", codes.values), ("indices", codes.indices)]
    crc = content_checksum(fields)
    if crc is None:
        return None
    return zlib.crc32(f"dim:{codes.dim}".encode(), crc)


def compression_ratio(d: int, k: int, h: int) -> float:
    """Dense fp32 bytes / compound-quantized bytes."""
    idx_b = 2 if h < 65536 else 4
    return d * 4 / (k * (1 + idx_b) + 4)
