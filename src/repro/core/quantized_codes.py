"""Beyond-paper extension: quantized sparse codes (compound compression).

The paper stores codes as fp32 values + int32 indices (2·k·4 B/row) and
positions quantization as a *separate* related-work technique.  The two
compose: within a row, the k surviving values have similar magnitude
(they are the top-|k| of a normalized input), so per-row symmetric int8
quantization of VALUES costs little; INDICES fit int16 whenever h < 65536
(h = 4096 in the paper).  Bytes per row:

    paper:      k·(4 + 4)            = 8k      (12.0x vs 768-d fp32)
    compound:   k·(1 + 2) + 4(scale) = 3k + 4  (~31x at k = 32)

Retrieval runs on the dequantized values with the same scatter-query SpMV;
the index build is unchanged.  Measured recall impact: see
benchmarks/quantized_codes_bench.py (≤1 recall point at int8 in our
offline proxy).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import SparseCodes


class QuantizedCodes(NamedTuple):
    q_values: jax.Array    # (N, k) int8
    indices: jax.Array     # (N, k) int16 (h < 65536) or int32
    scales: jax.Array      # (N,) float32 per-row symmetric scale
    dim: int

    @property
    def nbytes_logical(self) -> int:
        return (self.q_values.size * 1
                + self.indices.size * self.indices.dtype.itemsize
                + self.scales.size * 4)


def quantize_codes(codes: SparseCodes) -> QuantizedCodes:
    """Per-row symmetric int8 quantization of the k values."""
    amax = jnp.max(jnp.abs(codes.values), axis=-1)            # (N,)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(codes.values / scale[:, None]), -127, 127)
    idx_dtype = jnp.int16 if codes.dim < 65536 else jnp.int32
    return QuantizedCodes(
        q_values=q.astype(jnp.int8),
        indices=codes.indices.astype(idx_dtype),
        scales=scale.astype(jnp.float32),
        dim=codes.dim,
    )


def dequantize_codes(q: QuantizedCodes) -> SparseCodes:
    vals = q.q_values.astype(jnp.float32) * q.scales[:, None]
    return SparseCodes(values=vals, indices=q.indices.astype(jnp.int32),
                       dim=q.dim)


def compression_ratio(d: int, k: int, h: int) -> float:
    """Dense fp32 bytes / compound-quantized bytes."""
    idx_b = 2 if h < 65536 else 4
    return d * 4 / (k * (1 + idx_b) + 4)
