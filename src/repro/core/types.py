"""Shared core types for CompresSAE.

The sparse code produced by the encoder is *fixed-k*: every row has exactly
``k`` nonzero entries.  That makes the natural storage format an ELL layout —
``values[N, k]`` + ``indices[N, k]`` — which is byte-identical to a CSR matrix
with a uniform row length (the paper's storage format) while keeping every
shape static for XLA.  ``sparse.py`` provides lossless CSR conversion.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SparseCodes(NamedTuple):
    """Fixed-k sparse embedding batch (uniform-CSR / ELL layout).

    values:  (N, k) float — nonzero values, arbitrary order within a row.
    indices: (N, k) int32 — column index in [0, h) of each value.  Rows with
             duplicate indices are not produced by the encoder but are
             tolerated by every consumer (contributions sum).
    dim:     h, the latent dimensionality (static python int).
    """

    values: jax.Array
    indices: jax.Array
    dim: int

    @property
    def n(self) -> int:
        return self.values.shape[0]

    @property
    def k(self) -> int:
        return self.values.shape[1]

    @property
    def nbytes_logical(self) -> int:
        """Storage bytes of the compressed representation (paper §3.2)."""
        return self.values.size * 4 + self.indices.size * 4


@dataclasses.dataclass(frozen=True)
class SAEConfig:
    """CompresSAE hyperparameters (paper §3)."""

    d: int = 768          # dense input dimensionality
    h: int = 4096         # sparse latent dimensionality (h >> d)
    k: int = 32           # nonzeros kept by the abs-top-k activation
    aux_k_mult: int = 4   # auxiliary reconstruction uses k * aux_k_mult
    aux_weight: float = 1.0
    dtype: jnp.dtype = jnp.float32
    # 0 = single-stage top-k; >0 = exact two-stage grouped top-k with this
    # many groups (match the mesh 'model' size so the heavy stage shards —
    # DESIGN.md §3, EXPERIMENTS.md §Perf hillclimb 4)
    topk_groups: int = 0

    def __post_init__(self):
        if self.k <= 0 or self.h < self.d or self.k > self.h:
            raise ValueError(f"invalid SAEConfig: d={self.d} h={self.h} k={self.k}")
        if self.k * self.aux_k_mult > self.h:
            raise ValueError("aux_k_mult * k must not exceed h")

    @property
    def aux_k(self) -> int:
        return self.k * self.aux_k_mult

    @property
    def compression_ratio(self) -> float:
        """Dense fp32 bytes / sparse bytes (values+indices), paper's 12x."""
        return (self.d * 4) / (2 * self.k * 4)
