"""CompresSAE training step (paper §3.1).

The model is tiny (two (d×h) matrices) and batches are huge (the paper uses
100k rows/step), so the step is bandwidth-bound on the batch.  Under pjit we
shard the batch over (pod, data) and h over model; gradients all-reduce over
the batch axes only (the params' own axes are sharded, not replicated, along
model).

``train_step`` is mesh-agnostic: pure function of (state, batch), safe to
jax.jit with in_shardings/out_shardings supplied by the launcher.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import sae
from repro.core.losses import compressae_loss
from repro.core.types import SAEConfig
from repro.optim import AdamConfig, AdamState, adam_init, adam_update


class TrainState(NamedTuple):
    params: sae.Params
    opt: AdamState
    # Exponential counter of steps since each latent last fired; drives the
    # dead-neuron telemetry the multi-k loss is designed to keep at ~0.
    steps_since_fired: jax.Array   # (h,) int32


def init_train_state(cfg: SAEConfig, key: jax.Array) -> TrainState:
    params = sae.init_params(cfg, key)
    return TrainState(
        params=params,
        opt=adam_init(params),
        steps_since_fired=jnp.zeros((cfg.h,), jnp.int32),
    )


def train_step(
    state: TrainState,
    batch: jax.Array,
    cfg: SAEConfig,
    opt_cfg: AdamConfig,
    lr_scale: jax.Array | float = 1.0,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One optimizer step on a (B, d) batch of dense embeddings."""
    (loss, metrics), grads = jax.value_and_grad(compressae_loss, has_aux=True)(
        state.params, batch, cfg
    )
    new_params, new_opt = adam_update(grads, state.opt, state.params, opt_cfg, lr_scale)
    # Paper: W_dec row-normalized — project after every update.
    new_params = sae.normalize_decoder(new_params)

    # Dead-neuron telemetry from the aux (4k) activation pattern (computed
    # inside the loss — no extra matmul).
    metrics = dict(metrics)
    fired = metrics.pop("fired")
    ssf = jnp.where(fired, 0, state.steps_since_fired + 1)
    metrics["dead_latents_1k"] = jnp.sum((ssf > 1000).astype(jnp.int32))
    metrics["grad_norm"] = _global_norm(grads)
    return TrainState(new_params, new_opt, ssf), metrics


def _global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def eval_step(params: sae.Params, batch: jax.Array, cfg: SAEConfig) -> Dict[str, jax.Array]:
    """Reconstruction metrics on held-out embeddings."""
    from repro.core.losses import cosine_distance

    x_hat = sae.reconstruct(params, batch, cfg.k)
    x_hat_aux = sae.reconstruct(params, batch, cfg.aux_k)
    return {
        "eval_cos_loss_k": jnp.mean(cosine_distance(batch, x_hat)),
        "eval_cos_loss_aux": jnp.mean(cosine_distance(batch, x_hat_aux)),
    }
