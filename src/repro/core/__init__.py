"""CompresSAE — the paper's primary contribution as a composable JAX module.

Public API:
    SAEConfig, SparseCodes                   — types
    init_params, encode, decode, reconstruct — model
    compressae_loss, cosine_distance         — training objective
    train_step, init_train_state, TrainState — optimization
    build_index, score_sparse, score_reconstructed, top_n — retrieval
"""
from repro.core.types import SAEConfig, SparseCodes
from repro.core.topk import abs_topk, abs_topk_sparse, abs_topk_mask
from repro.core.sae import (
    init_params,
    encode,
    decode,
    decode_dense,
    encode_dense,
    reconstruct,
    kernel_matrix,
    normalize_decoder,
    normalize_input,
    preactivations,
)
from repro.core.losses import compressae_loss, cosine_distance
from repro.core.train import TrainState, init_train_state, train_step, eval_step
from repro.core.retrieval import (
    QuantizedIndex,
    SparseIndex,
    build_index,
    dequantize_index,
    index_checksum,
    retrieve,
    score_sparse,
    score_reconstructed,
    score_dense,
    sparse_dot_dense_query,
    top_n,
    verify_index,
)
from repro.core.quantized_codes import (
    QuantizedCodes,
    codes_checksum,
    content_checksum,
    dequantize_codes,
    quantize_codes,
)
from repro.core.eval import (
    rank_displacement,
    recall_at_n,
    retrieval_quality,
    score_mae,
)
from repro.core import sparse, baselines

__all__ = [
    "SAEConfig", "SparseCodes", "abs_topk", "abs_topk_sparse", "abs_topk_mask",
    "init_params", "encode", "decode", "decode_dense", "encode_dense",
    "reconstruct", "kernel_matrix", "normalize_decoder", "normalize_input",
    "preactivations", "compressae_loss", "cosine_distance", "TrainState",
    "init_train_state", "train_step", "eval_step", "SparseIndex",
    "QuantizedIndex", "QuantizedCodes", "quantize_codes", "dequantize_codes",
    "dequantize_index", "index_checksum", "verify_index",
    "codes_checksum", "content_checksum",
    "build_index", "retrieve", "score_sparse", "score_reconstructed", "score_dense",
    "sparse_dot_dense_query", "top_n", "sparse", "baselines",
    "recall_at_n", "score_mae", "rank_displacement", "retrieval_quality",
]
