"""Segmented mutable index: base + delta segments, deletion masks,
compaction (ISSUE 9 tentpole; ROADMAP "Streaming index mutation").

A recommender catalog churns constantly, but every index format in
``core.retrieval`` is immutable — any add/remove previously meant a full
``build_index``.  ``SegmentedIndex`` makes the index mutable without
giving up a single bit of the immutable contract:

* **base segment** — an immutable ``SparseIndex`` or ``QuantizedIndex``
  exactly as ``build_index`` produced it (quantized in the serving
  format, content-checksummed).
* **delta segment** — a small append-only segment holding rows added
  since the last compaction.  The fp32 rows are retained as the
  authoritative copy (``delta_codes``); the SERVING arrays are derived
  per add via ``build_index`` in the base's format, so a quantized
  segmented index serves its delta quantized too.  Per-row symmetric
  quantization is row-local, which is what makes "quantize at add" and
  "re-quantize at compaction" produce the same bytes.
* **deletion masks** — one liveness bit per row in each segment.  The
  mask is folded into the streaming kernels' masking epilogue
  (``alive`` operand on the sparse-query generations): dead rows score
  -inf exactly like tile padding, and a fully-deleted candidate tile
  takes the kernels' existing whole-tile skip (nothing in an all--inf
  tile can beat the current n-th best).
* **retrieve = per-segment streaming top-n + merge.**  Each segment runs
  the SAME kernel/ref generation the equivalent immutable index would
  (``serving.engine.select_retrieve_fn``), producing RAW norm-folded
  scores; the per-segment lists are concatenated base-then-delta and
  merged by one ``lax.top_k`` (segments are shards — the ragged-aware
  ``sharded_top_n`` contract, inlined here because segments live on one
  device).  The query-norm division happens once, after the merge, on
  the (Q, n) panel — dividing per segment could collapse distinct raw
  scores into equal quotients and flip tie order vs the oracle.

**The binding contract** (tier-1, ``tests/test_segments*.py``): after
ANY interleaving of ``add_items`` / ``delete_items`` / ``compact``,
``retrieve`` over (base + delta + mask) is bit-identical — scores, ids,
ties — to a fresh ``build_index`` over the surviving fp32 rows (base
survivors then delta survivors, in original order), across
{exact, quantized, int8} × {ref, fused}; and ``compact()`` output is
bit-identical (arrays AND checksum) to that rebuilt index.  The proof
obligations, in code order:

* per-row scores are row-local in every generation (a row's score
  depends only on its own values/indices/inv-norm and the query panel),
  so a surviving row scores identically wherever it lives;
* within a segment the streaming merge resolves ties to the lowest
  position, and dead rows never surface, so surviving-position order ==
  compacted-position order;
* across segments, base survivors precede delta survivors in both the
  concat and the rebuilt index, and ``lax.top_k`` prefers the lowest
  concat index on ties;
* quantization and norms are row-local, so gathering STORED serving
  arrays at compaction equals re-quantizing the surviving fp32 rows.

Sparse-query single-stage serving only (the production fused path);
reconstructed-mode norms are dropped at wrap time.  Item ids are stable
across mutations — ``retrieve`` returns ITEM ids, not positions, with
(-inf, -1) padding for unfilled slots (n > surviving rows included).
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quantized_codes import QuantizedCodes
from repro.core.retrieval import (
    NORM_EPS,
    Index,
    SparseIndex,
    build_index,
    index_checksum,
    take_index_rows,
    verify_index,
)
from repro.core.types import SparseCodes
from repro.errors import SegmentMutationError

_NEG_INF = float("-inf")


def _as_id_array(ids) -> np.ndarray:
    arr = np.asarray(ids, dtype=np.int64)
    if arr.ndim != 1:
        raise SegmentMutationError(
            f"ids: expected a 1-D sequence of item ids, got shape "
            f"{arr.shape}"
        )
    return arr


def _concat_field(a, b):
    if a is None and b is None:
        return None
    if a is None or b is None:
        raise SegmentMutationError(
            "cannot concatenate segments: one carries a norm array the "
            "other lacks (mixed build configurations)"
        )
    return jnp.concatenate([a, b], axis=0)


def concat_indexes(a: Index, b: Index) -> Index:
    """Row-concatenate two indexes of the same format (a's rows first).

    Every per-candidate array concatenates; ``dim`` must agree.  The
    result carries a freshly computed content checksum — by row-locality
    of quantization and norms this equals ``build_index`` over the
    concatenated fp32 rows (the compaction bit-identity contract).
    """
    if type(a) is not type(b):
        raise SegmentMutationError(
            f"cannot concatenate {type(a).__name__} with {type(b).__name__}"
        )
    if a.codes.dim != b.codes.dim:
        raise SegmentMutationError(
            f"latent dim mismatch: {a.codes.dim} vs {b.codes.dim}"
        )
    if isinstance(a.codes, QuantizedCodes):
        codes = QuantizedCodes(
            q_values=_concat_field(a.codes.q_values, b.codes.q_values),
            indices=_concat_field(a.codes.indices, b.codes.indices),
            scales=_concat_field(a.codes.scales, b.codes.scales),
            dim=a.codes.dim,
        )
    else:
        codes = SparseCodes(
            values=_concat_field(a.codes.values, b.codes.values),
            indices=_concat_field(a.codes.indices, b.codes.indices),
            dim=a.codes.dim,
        )
    idx = a._replace(
        codes=codes,
        sparse_norms=_concat_field(a.sparse_norms, b.sparse_norms),
        recon_norms=_concat_field(a.recon_norms, b.recon_norms),
        inv_sparse_norms=_concat_field(
            a.inv_sparse_norms, b.inv_sparse_norms
        ),
        inv_recon_norms=_concat_field(a.inv_recon_norms, b.inv_recon_norms),
        checksum=None,
    )
    return idx._replace(checksum=index_checksum(idx))


class SegmentedIndex:
    """Base + delta segments with deletion masks (see module doc).

    Lifecycle ops are FUNCTIONAL — each returns a new ``SegmentedIndex``
    sharing unchanged arrays with its parent — so a serving engine can
    swap atomically and a guard can hold the previous generation as a
    fallback.  Construct via ``SegmentedIndex.from_index``.
    """

    def __init__(
        self,
        base: Index,
        base_ids: np.ndarray,
        base_alive: np.ndarray,
        delta: Optional[Index] = None,
        delta_codes: Optional[SparseCodes] = None,
        delta_ids: Optional[np.ndarray] = None,
        delta_alive: Optional[np.ndarray] = None,
    ):
        self.base = base
        self.base_ids = np.asarray(base_ids, dtype=np.int64)
        self.base_alive = np.asarray(base_alive, dtype=bool)
        self.delta = delta
        self.delta_codes = delta_codes
        self.delta_ids = (np.zeros((0,), np.int64) if delta_ids is None
                          else np.asarray(delta_ids, dtype=np.int64))
        self.delta_alive = (np.zeros((0,), bool) if delta_alive is None
                            else np.asarray(delta_alive, dtype=bool))
        if self.base_ids.shape[0] != base.codes.n:
            raise SegmentMutationError(
                f"base_ids has {self.base_ids.shape[0]} entries for "
                f"{base.codes.n} base rows"
            )
        if delta is not None and self.delta_ids.shape[0] != delta.codes.n:
            raise SegmentMutationError(
                f"delta_ids has {self.delta_ids.shape[0]} entries for "
                f"{delta.codes.n} delta rows"
            )
        # alive item id -> (segment, position); latest add wins by
        # construction (an id is never alive in two places)
        self._loc: dict[int, tuple[str, int]] = {}
        for pos in np.flatnonzero(self.base_alive):
            self._loc[int(self.base_ids[pos])] = ("base", int(pos))
        for pos in np.flatnonzero(self.delta_alive):
            self._loc[int(self.delta_ids[pos])] = ("delta", int(pos))

    # ------------------------------------------------------------- builders
    @classmethod
    def from_index(
        cls, index: Index, ids: Optional[Sequence[int]] = None
    ) -> "SegmentedIndex":
        """Wrap an immutable index as the base segment (all rows alive).

        ``ids`` defaults to ``arange(N)``.  Reconstructed-mode norms are
        dropped — segmented serving is sparse-query only — and the base
        checksum is recomputed over the retained arrays.
        """
        if index.recon_norms is not None or index.inv_recon_norms is not None:
            index = index._replace(
                recon_norms=None, inv_recon_norms=None, checksum=None
            )
            index = index._replace(checksum=index_checksum(index))
        n = index.codes.n
        base_ids = (np.arange(n, dtype=np.int64) if ids is None
                    else _as_id_array(ids))
        if base_ids.shape[0] != n:
            raise SegmentMutationError(
                f"ids has {base_ids.shape[0]} entries for {n} index rows"
            )
        if np.unique(base_ids).shape[0] != base_ids.shape[0]:
            raise SegmentMutationError("ids must be unique")
        return cls(index, base_ids, np.ones(n, dtype=bool))

    # ----------------------------------------------------------- inspection
    @property
    def quantized(self) -> bool:
        return isinstance(self.base.codes, QuantizedCodes)

    @property
    def dim(self) -> int:
        return self.base.codes.dim

    @property
    def n_alive(self) -> int:
        return int(self.base_alive.sum()) + int(self.delta_alive.sum())

    @property
    def n_rows(self) -> int:
        """Physical rows across segments, dead included."""
        return self.base.codes.n + self.delta_ids.shape[0]

    @property
    def shape_key(self) -> tuple[int, int]:
        """(base rows, delta rows) — what the jit caches key on."""
        return (self.base.codes.n, self.delta_ids.shape[0])

    @property
    def base_coverage(self) -> float:
        """Fraction of alive items servable from the base segment alone —
        the ``ServingStatus.coverage`` a base-only shed reports."""
        alive = self.n_alive
        return 1.0 if alive == 0 else float(self.base_alive.sum()) / alive

    def alive_ids(self) -> np.ndarray:
        """Surviving item ids in compaction order (base then delta)."""
        return np.concatenate([
            self.base_ids[self.base_alive], self.delta_ids[self.delta_alive]
        ])

    def verify(self, *, require: bool = True) -> bool:
        """Per-segment content-checksum verification (CRC32 via
        ``verify_index``): a flipped byte in EITHER segment is a typed
        ``IndexIntegrityError`` naming the segment."""
        ok = verify_index(self.base, require=require)
        if self.delta is not None:
            ok = verify_index(self.delta, require=require) and ok
        return ok

    def base_only(self) -> "SegmentedIndex":
        """Drop the delta segment — the guard's shed when delta bytes
        fail integrity.  Items only alive in delta become unservable
        (``base_coverage < 1.0``); base rows and masks are untouched."""
        return SegmentedIndex(self.base, self.base_ids, self.base_alive)

    # ------------------------------------------------------------ lifecycle
    def add_items(self, codes: SparseCodes, ids) -> "SegmentedIndex":
        """Append rows to the delta segment.  ``codes``: fp32 (m, k)
        SparseCodes with ``dim`` matching the index; ``ids``: m unique
        item ids, none currently alive (re-adding a DELETED id is fine —
        the dead row stays masked, the new row serves)."""
        new_ids = _as_id_array(ids)
        if codes.values.ndim != 2:
            raise SegmentMutationError(
                f"codes: expected (m, k) values, got shape "
                f"{tuple(codes.values.shape)}"
            )
        if codes.values.shape[0] != new_ids.shape[0]:
            raise SegmentMutationError(
                f"codes has {codes.values.shape[0]} rows for "
                f"{new_ids.shape[0]} ids"
            )
        if codes.dim != self.dim:
            raise SegmentMutationError(
                f"codes dim {codes.dim} != index dim {self.dim}"
            )
        if np.unique(new_ids).shape[0] != new_ids.shape[0]:
            raise SegmentMutationError("ids must be unique within one add")
        for i in new_ids:
            if int(i) in self._loc:
                seg, pos = self._loc[int(i)]
                raise SegmentMutationError(
                    f"item id {int(i)} is already alive "
                    f"({seg} segment, row {pos}); delete it first"
                )
        vals = jnp.asarray(codes.values, dtype=jnp.float32)
        idx = jnp.asarray(codes.indices, dtype=jnp.int32)
        if self.delta_codes is None:
            delta_codes = SparseCodes(values=vals, indices=idx, dim=self.dim)
        else:
            delta_codes = SparseCodes(
                values=jnp.concatenate([self.delta_codes.values, vals]),
                indices=jnp.concatenate([self.delta_codes.indices, idx]),
                dim=self.dim,
            )
        # re-derive the serving-format delta from the retained fp32 rows:
        # the delta is small, and build_index is row-local, so already
        # present rows re-produce their exact previous bytes
        delta = build_index(delta_codes, quantize=self.quantized)
        return SegmentedIndex(
            self.base, self.base_ids, self.base_alive,
            delta=delta, delta_codes=delta_codes,
            delta_ids=np.concatenate([self.delta_ids, new_ids]),
            delta_alive=np.concatenate([
                self.delta_alive, np.ones(new_ids.shape[0], bool)
            ]),
        )

    def delete_items(self, ids) -> "SegmentedIndex":
        """Mark items dead.  Unknown or already-deleted ids are typed
        errors — a delete that silently no-ops would desynchronize the
        caller's view of the catalog."""
        dead = _as_id_array(ids)
        base_alive = self.base_alive.copy()
        delta_alive = self.delta_alive.copy()
        seen = set()
        for i in dead:
            key = int(i)
            if key in seen:
                raise SegmentMutationError(
                    f"item id {key} listed twice in one delete"
                )
            seen.add(key)
            loc = self._loc.get(key)
            if loc is None:
                raise SegmentMutationError(
                    f"item id {key} is not alive in this index "
                    "(unknown or already deleted)"
                )
            seg, pos = loc
            if seg == "base":
                base_alive[pos] = False
            else:
                delta_alive[pos] = False
        return SegmentedIndex(
            self.base, self.base_ids, base_alive,
            delta=self.delta, delta_codes=self.delta_codes,
            delta_ids=self.delta_ids, delta_alive=delta_alive,
        )

    def compact(self) -> "SegmentedIndex":
        """Fold survivors into a fresh all-alive base; empty delta.

        Gathers the STORED serving arrays (base survivors then delta
        survivors) — never a dequantize/re-quantize round trip — so by
        row-locality the result is bit-identical, checksum included, to
        ``build_index`` over the surviving fp32 rows in the same order.
        """
        rows_b = np.flatnonzero(self.base_alive)
        new_base = take_index_rows(self.base, jnp.asarray(rows_b))
        if self.delta is not None:
            rows_d = np.flatnonzero(self.delta_alive)
            new_base = concat_indexes(
                new_base, take_index_rows(self.delta, jnp.asarray(rows_d))
            )
        else:
            new_base = new_base._replace(
                checksum=index_checksum(new_base)
            )
        return SegmentedIndex(
            new_base, self.alive_ids(),
            np.ones(new_base.codes.n, dtype=bool),
        )

    # -------------------------------------------------------------- serving
    def _segment_list(
        self, index: Index, alive: np.ndarray, item_ids: np.ndarray,
        qv, qi, n: int, *, use_fused: bool, precision: str,
    ):
        """One segment's raw top-n list: ((Q, n) raw norm-folded scores,
        (Q, n) ITEM ids), padded with the (-inf, -1) contract.  Lists are
        score-desc with ties in ascending segment position — which, dead
        rows never surfacing, equals ascending surviving position."""
        from repro.serving.engine import select_retrieve_fn

        fn = select_retrieve_fn(
            sparse_query=True,
            quantized=isinstance(index.codes, QuantizedCodes),
            int8_scoring=precision == "int8",
            use_fused=use_fused,
        )
        if isinstance(index.codes, QuantizedCodes):
            cand = (index.codes.q_values, index.codes.indices,
                    index.codes.scales)
        else:
            cand = (index.codes.values, index.codes.indices)
        inv = index.inv_sparse_norms
        if inv is None:
            inv = 1.0 / jnp.maximum(index.sparse_norms, NORM_EPS)
        n_seg = min(n, index.codes.n)
        alive_arr = (None if alive.all()
                     else jnp.asarray(alive.astype(np.float32)))
        vals, ids = fn(
            *cand, inv, qv, qi, index.codes.dim, n=n_seg, alive=alive_arr
        )
        # unfilled streaming slots are (-inf, id 0); normalize to the
        # (-inf, -1) contract BEFORE translating positions to item ids
        ids = jnp.where(vals == _NEG_INF, -1, ids)
        table = jnp.asarray(item_ids)
        ids = jnp.where(ids >= 0, table[jnp.maximum(ids, 0)], -1)
        if n_seg < n:
            pad = [(0, 0)] * (vals.ndim - 1) + [(0, n - n_seg)]
            vals = jnp.pad(vals, pad, constant_values=_NEG_INF)
            ids = jnp.pad(ids, pad, constant_values=-1)
        return vals, ids

    def retrieve(
        self, q: SparseCodes, n: int, *,
        use_fused: bool = False, precision: str = "exact",
    ) -> tuple[jax.Array, jax.Array]:
        """Top-n over all surviving rows: ((Q?, n) cosine scores, (Q?, n)
        ITEM ids), bit-identical to retrieving from ``build_index`` over
        the surviving fp32 rows with the same generation (module doc).

        Per-segment streaming top-n on RAW norm-folded scores, one
        merge, then one query-norm division.  ``n`` may exceed the
        surviving row count — unfilled slots come back (-inf, -1).
        """
        squeeze = q.values.ndim == 1
        qv = q.values[None] if squeeze else q.values
        qi = q.indices[None] if squeeze else q.indices
        lists = [self._segment_list(
            self.base, self.base_alive, self.base_ids, qv, qi, n,
            use_fused=use_fused, precision=precision,
        )]
        if self.delta is not None and self.delta_ids.shape[0] > 0:
            lists.append(self._segment_list(
                self.delta, self.delta_alive, self.delta_ids, qv, qi, n,
                use_fused=use_fused, precision=precision,
            ))
        all_vals = jnp.concatenate([v for v, _ in lists], axis=-1)
        all_ids = jnp.concatenate([i for _, i in lists], axis=-1)
        if all_vals.shape[-1] < n:
            pad = [(0, 0)] * (all_vals.ndim - 1)
            pad += [(0, n - all_vals.shape[-1])]
            all_vals = jnp.pad(all_vals, pad, constant_values=_NEG_INF)
            all_ids = jnp.pad(all_ids, pad, constant_values=-1)
        vals, pos = jax.lax.top_k(all_vals, n)
        ids = jnp.take_along_axis(all_ids, pos, axis=-1)
        norm = jnp.linalg.norm(qv, axis=-1)
        scores = vals / jnp.maximum(norm[..., None], NORM_EPS)
        return (scores[0], ids[0]) if squeeze else (scores, ids)


SegmentedOrIndex = Union[SegmentedIndex, Index]
