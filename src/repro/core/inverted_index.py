"""Beyond-paper: inverted-file retrieval over sparse codes.

The paper scores every candidate (O(N·k) per query, exact).  Production
sparse-retrieval systems (SPLADE / pgvector sparsevec / Lucene impact
search) instead build an INVERTED INDEX over the h latent dimensions: for
each latent j, a posting list of the candidates whose code activates j.
A query with k active latents only touches the union of its k posting
lists — expected |union| ≈ N·k²/h ≪ N when codes spread over h
(h=4096, k=32: ~25% of the catalog per query, and far less under a
Zipfian latent distribution with per-list caps).

JAX adaptation: posting lists are built host-side (numpy) and stored as a
dense (h, cap) id matrix padded with -1 — static shapes.  Scoring gathers
the ≤ k·cap union, scores it with the same scatter-query SpMV, and top-n's
the partial scores.  This is APPROXIMATE when lists overflow `cap`
(truncated by descending |value| — impact ordering); recall vs the exact
scan is measured in benchmarks/inverted_index_bench.py.
"""
from __future__ import annotations

import zlib
from functools import partial
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quantized_codes import codes_checksum, content_checksum
from repro.core.retrieval import top_n
from repro.core.types import SparseCodes
from repro.errors import IndexIntegrityError, InvalidCodesError


class InvertedIndex(NamedTuple):
    postings: jax.Array      # (h, cap) int32 candidate ids, -1 padded
    codes: SparseCodes       # the full codes (for scoring gathered ids)
    norms: jax.Array         # (N,) ‖s_c‖
    # build-time content CRC over postings + codes + norms (same scheme as
    # ``core.retrieval.index_checksum``); ``verify_inverted_index``
    # recomputes and compares it so corrupted postings are a typed STARTUP
    # error, not a first-request one.  None for hand-built instances.
    checksum: Optional[int] = None

    @property
    def cap(self) -> int:
        return self.postings.shape[1]


def inverted_index_checksum(inv: InvertedIndex) -> Optional[int]:
    """Recompute the content CRC of an inverted index (postings + codes +
    norms).  Pure function of array content — independent of the stored
    ``checksum`` field — so ``verify_inverted_index`` can diff stored vs
    actual.  ``None`` when the arrays are abstract tracers (integrity is a
    host-side build/startup concern, never part of a traced computation)."""
    base = codes_checksum(inv.codes)
    if base is None:
        return None
    extra = content_checksum([
        ("postings", inv.postings),
        ("norms", inv.norms),
    ])
    if extra is None:
        return None
    return zlib.crc32(f"{base:08x}:{extra:08x}".encode())


def verify_inverted_index(inv: InvertedIndex, *, require: bool = True) -> bool:
    """Check the inverted index's content against its build-time checksum.

    Mirrors ``core.retrieval.verify_index``: returns True on a match,
    raises ``IndexIntegrityError`` on a mismatch, and treats a missing
    checksum as an error when ``require=True`` (the startup self-check's
    default) or as False when ``require=False``."""
    if inv.checksum is None:
        if require:
            raise IndexIntegrityError(
                "InvertedIndex has no stored checksum — hand-constructed "
                "or built under tracing; rebuild with "
                "build_inverted_index(...) to make integrity verifiable"
            )
        return False
    got = inverted_index_checksum(inv)
    if got is None:
        raise IndexIntegrityError(
            "InvertedIndex content is not concrete (traced arrays); "
            "integrity can only be verified on host-resident bytes"
        )
    if got != inv.checksum:
        raise IndexIntegrityError(
            f"InvertedIndex content checksum mismatch: stored "
            f"0x{inv.checksum:08x}, recomputed 0x{got:08x} "
            f"(h={inv.postings.shape[0]}, cap={inv.cap}) — postings "
            "corrupted since build; refusing to serve stage 1 from them"
        )
    return True


def build_inverted_index(codes: SparseCodes, cap: int = 2048) -> InvertedIndex:
    """Host-side build: posting list per latent, impact-ordered, capped.

    Fully vectorized (one lexsort + bincount over the N·k nonzeros) — the
    former per-entry Python loop dominated index-build time at the paper's
    N=100k, k=32.  Entries sort by (latent, |value| desc, row desc), the
    same order the loop's ``entries.sort(reverse=True)`` produced; the
    position of each entry within its latent group comes from subtracting
    the group's cumulative start, and entries past ``cap`` are dropped.
    """
    vals = np.asarray(codes.values)
    idx = np.asarray(codes.indices)
    n, k = vals.shape
    h = codes.dim
    # out-of-range latents would index bincount/postings wrongly (negative
    # indices silently wrap; >= h crashes with an opaque numpy error) —
    # reject them up front, naming the offending entry
    bad = (idx < 0) | (idx >= h)
    if bad.any():
        r, s = (int(v) for v in np.argwhere(bad)[0])
        raise InvalidCodesError(
            f"codes.indices[{r}, {s}] = {int(idx[r, s])} is outside the "
            f"latent range [0, {h}) — cannot bucket this entry into a "
            "posting list (corrupted codes or a dim mismatch)"
        )
    flat_lat = idx.reshape(-1)
    flat_abs = np.abs(vals.reshape(-1))
    flat_row = np.repeat(np.arange(n, dtype=np.int32), k)
    # lexsort: last key is primary — latent asc, then impact desc, row desc
    order = np.lexsort((-flat_row, -flat_abs, flat_lat))
    sorted_lat = flat_lat[order]
    sorted_row = flat_row[order]
    counts = np.bincount(flat_lat, minlength=h)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    within = np.arange(n * k, dtype=np.int64) - starts[sorted_lat]
    keep = within < cap
    postings = np.full((h, cap), -1, dtype=np.int32)
    postings[sorted_lat[keep], within[keep]] = sorted_row[keep]
    norms = jnp.linalg.norm(codes.values, axis=-1)
    inv = InvertedIndex(postings=jnp.asarray(postings), codes=codes,
                        norms=norms)
    return inv._replace(checksum=inverted_index_checksum(inv))


def search_inverted(
    index: InvertedIndex, q: SparseCodes, n: int, *, block: int = 2048
) -> tuple[jax.Array, jax.Array]:
    """Approximate top-n: score only the union of the query's posting lists.

    q: single-query codes (k,) or batched (Q, k).  Returns (scores, ids)
    of shape (Q?, n); padded/duplicate candidates are masked/deduped by
    keeping each id's score once (max over duplicates is identical —
    scores are id-determined).

    Selection runs through the same streaming top-n epilogue as the fused
    serving path (retrieve_ref / the Pallas kernel): the k·cap posting
    union is scanned in ``block``-sized slices, each slice gathered,
    scored and merged into a running (n,) best buffer with one
    ``lax.top_k`` over n + block candidates — the full union's scores
    (and its (block, k) gather transient) never exist at once.  Exactly
    equivalent to the one-shot ``lax.top_k`` over all k·cap scores
    (``_search_inverted_fullsort``, the parity oracle in
    tests/test_inverted_index.py): per-candidate scores are identical,
    the running buffer precedes each slice in the merge so ties resolve
    to the earliest union position either way, and duplicates are
    suppressed by slice-local first-occurrence dedup plus masking against
    ids already in the buffer (a duplicate whose earlier occurrence was
    cut can never outscore the buffer floor — duplicate scores are equal
    and the floor is monotone).
    """
    squeeze = q.values.ndim == 1
    q_vals = q.values[None] if squeeze else q.values       # (Q, k)
    q_idx = q.indices[None] if squeeze else q.indices

    def one(qv, qi):
        cand = index.postings[qi].reshape(-1)              # (k·cap,)
        q_dense = jnp.zeros((index.codes.dim,), qv.dtype).at[qi].add(qv)
        q_norm = jnp.linalg.norm(qv)
        u = cand.shape[0]
        blk = min(block, u)
        pad = (-u) % blk
        if pad:
            cand = jnp.pad(cand, (0, pad), constant_values=-1)
        cand_b = cand.reshape(-1, blk)

        init = (
            jnp.full((n,), -jnp.inf, jnp.float32),
            jnp.full((n,), -1, jnp.int32),
        )

        def step(carry, cb):
            best_v, best_i = carry
            safe = jnp.maximum(cb, 0)
            c_vals = index.codes.values[safe]              # (blk, k)
            c_idx = index.codes.indices[safe]
            dots = jnp.sum(q_dense[c_idx] * c_vals, axis=-1)
            scores = (dots / jnp.maximum(q_norm * index.norms[safe], 1e-8)
                      ).astype(jnp.float32)
            valid = cb >= 0
            # slice-local dedup: keep the first occurrence of each id
            order = jnp.argsort(cb)
            sorted_cb = cb[order]
            first = jnp.concatenate(
                [jnp.array([True]), sorted_cb[1:] != sorted_cb[:-1]]
            )
            keep = jnp.zeros_like(valid).at[order].set(first) & valid
            # cross-slice dedup: ids already held by the running buffer
            keep &= ~jnp.any(cb[:, None] == best_i[None, :], axis=-1)
            scores = jnp.where(keep, scores, -jnp.inf)
            cand_v = jnp.concatenate([best_v, scores])
            # padding contract (pinned, matches core.retrieve at n>matches):
            # masked entries surface as (score −inf, id −1) and sort after
            # every real match — never a real id with a −inf score
            cand_i = jnp.concatenate([best_i, jnp.where(keep, cb, -1)])
            v, p = jax.lax.top_k(cand_v, n)
            return (v, cand_i[p]), None

        (best_v, best_i), _ = jax.lax.scan(step, init, cand_b)
        return best_v, best_i

    vs, ids = jax.vmap(one)(q_vals, q_idx)
    return (vs[0], ids[0]) if squeeze else (vs, ids)


def _search_inverted_fullsort(
    index: InvertedIndex, q: SparseCodes, n: int
) -> tuple[jax.Array, jax.Array]:
    """Pre-streaming selection: one ``lax.top_k`` over all k·cap gathered
    union scores.  Kept as the parity oracle for ``search_inverted``'s
    streaming epilogue (tests/test_inverted_index.py)."""
    squeeze = q.values.ndim == 1
    q_vals = q.values[None] if squeeze else q.values       # (Q, k)
    q_idx = q.indices[None] if squeeze else q.indices

    def one(qv, qi):
        cand = index.postings[qi].reshape(-1)              # (k·cap,)
        safe = jnp.maximum(cand, 0)
        c_vals = index.codes.values[safe]                  # (k·cap, k)
        c_idx = index.codes.indices[safe]
        q_dense = jnp.zeros((index.codes.dim,), qv.dtype).at[qi].add(qv)
        dots = jnp.sum(q_dense[c_idx] * c_vals, axis=-1)
        scores = dots / jnp.maximum(
            jnp.linalg.norm(qv) * index.norms[safe], 1e-8
        )
        # mask padding; dedupe by keeping the first occurrence of each id
        # (scores are identical for duplicates, so top-k just needs one)
        valid = cand >= 0
        order = jnp.argsort(cand)
        sorted_cand = cand[order]
        first = jnp.concatenate(
            [jnp.array([True]), sorted_cand[1:] != sorted_cand[:-1]]
        )
        keep = jnp.zeros_like(valid).at[order].set(first) & valid
        scores = jnp.where(keep, scores, -jnp.inf)
        # same padding contract as the streaming path: (−inf, −1) pairs
        cand = jnp.where(keep, cand, -1)
        v, pos = jax.lax.top_k(scores, n)
        return v, cand[pos]

    vs, ids = jax.vmap(one)(q_vals, q_idx)
    return (vs[0], ids[0]) if squeeze else (vs, ids)


def expected_scan_fraction(codes: SparseCodes, cap: int) -> float:
    """Fraction of the catalog touched per query (host-side estimate).

    Independence approximation: a uniformly chosen latent's capped posting
    list covers p = E[min(len, cap)] / N of the catalog, so a query
    hitting k latents misses a given item with probability ~ (1 − p)^k
    and the expected union covers 1 − (1 − p)^k.  The former k·p estimate
    ignored union overlap and could exceed 1.0 on dense-latent corpora
    (e.g. all activity on a handful of latents); this form is always in
    [0, 1], still monotone in ``cap``, and bounded above by k·p.  The
    approximation assumes the query's k latents are drawn independently
    of each other and of per-item co-activation — real corpora correlate
    latents, so treat this as an estimate, not a guarantee (the measured
    number lives in benchmarks/inverted_index_bench.py).
    """
    idx = np.asarray(codes.indices).reshape(-1)
    counts = np.bincount(idx, minlength=codes.dim).astype(np.float64)
    counts = np.minimum(counts, cap)
    k = codes.k
    p = float(np.clip(counts.mean() / codes.n, 0.0, 1.0))
    return float(np.clip(1.0 - (1.0 - p) ** k, 0.0, 1.0))


def candidate_union(
    index: InvertedIndex, q_indices: np.ndarray, budget: int
) -> np.ndarray:
    """Stage 1 of two-stage retrieval: per-query candidate row sets.

    Host-side (numpy) — posting lists live as a static (h, cap) matrix,
    but the union/dedup/truncate logic is data-dependent and cheap, so it
    runs outside jit.  For each query row the k posting lists are
    concatenated in impact order, deduplicated keeping first occurrence
    (so higher-impact entries win the truncation race), truncated to
    ``budget`` rows, then padded back up to ``budget`` with *real* filler
    catalog rows not already present (padding with repeats or sentinels
    would give stage 2's kernels out-of-range or duplicate rows; real
    fillers merely add candidates that honestly compete and lose).
    Each row is finally sorted ascending so that stage 2's sub-index
    position order equals global-id order — ``lax.top_k`` ties then
    resolve to the lowest global id, exactly matching the single-stage
    path's tie semantics.

    Filler rule (pinned — the device path must agree bit-for-bit): the
    ``need`` fillers are the first ``need`` NON-MEMBER catalog ids in
    ascending order over the full ``[0, N)`` range.  Implementation note:
    the candidate pool only materializes ``arange(budget)`` because the
    rule provably never reaches past it — the kept set holds
    ``budget − need`` ids, so ``[0, budget)`` always contains at least
    ``need`` non-members, and the ``need``-th smallest non-member of the
    whole catalog is therefore < ``budget``.  The regression test
    ``tests/test_two_stage_device.py::test_filler_rule_is_first_non_members_over_full_catalog``
    pins the equivalence against a brute-force setdiff over ``[0, N)``.

    Raises ``IndexIntegrityError`` if the posting matrix holds ids
    outside [−1, N) — the signature of postings corruption, and the
    guard ladder's cue to fall back to single-stage retrieval.  The
    integrity check runs ONCE over the whole gathered (Q, k, cap) matrix,
    not per query row.

    Returns (Q, budget) int32, every entry a valid catalog row, each row
    sorted ascending with no duplicates.  Requires budget ≤ N.
    """
    n_items = index.codes.n
    if budget > n_items:
        raise ValueError(
            f"candidate budget {budget} exceeds catalog size {n_items}"
        )
    qi = np.asarray(q_indices)
    if qi.ndim == 1:
        qi = qi[None]
    postings = np.asarray(index.postings)
    qp = postings[qi]                                      # (Q, k, cap)
    _check_posting_ids(qp, n_items)
    out = np.empty((qi.shape[0], budget), dtype=np.int32)
    for r in range(qi.shape[0]):
        cand = qp[r].reshape(-1)                           # (k·cap,)
        valid = cand[cand >= 0]
        # first-occurrence dedup preserving impact/concatenation order
        _, first = np.unique(valid, return_index=True)
        uniq = valid[np.sort(first)][:budget]
        need = budget - uniq.shape[0]
        if need:
            # first `need` non-members ascending (bounded pool, see above)
            fillers = np.setdiff1d(
                np.arange(budget, dtype=np.int32), uniq
            )[:need]
            uniq = np.concatenate([uniq, fillers])
        out[r] = np.sort(uniq)
    return out


def _check_posting_ids(gathered: np.ndarray, n_items: int) -> None:
    """One vectorized integrity check over a whole gathered posting
    matrix (any shape).  Raises ``IndexIntegrityError`` naming the first
    out-of-range id in row-major order — the same id the former
    per-query rescan reported."""
    flat = gathered.reshape(-1)
    bad_mask = (flat < -1) | (flat >= n_items)
    if bad_mask.any():
        bad = flat[int(np.argmax(bad_mask))]
        raise IndexIntegrityError(
            f"inverted index posting id {int(bad)} outside [-1, "
            f"{n_items}) — postings corrupted since build"
        )


@partial(jax.jit, static_argnames=("budget", "n_items"))
def _device_union(postings, qi, *, budget: int, n_items: int):
    """Jitted core of ``device_candidate_union``: one batched pass over
    the gathered (Q, k, cap) posting rows.  Returns (rows, any_bad,
    bad_val); the host wrapper turns the corruption flag into the typed
    error (control flow can't live inside jit)."""
    qp = postings[qi]                                      # (Q, k, cap)
    flat = qp.reshape(-1)
    bad_mask = (flat < -1) | (flat >= n_items)
    any_bad = jnp.any(bad_mask)
    bad_val = flat[jnp.argmax(bad_mask)]                   # first, row-major

    def one(cand):                                         # (u,) = (k·cap,)
        u = cand.shape[0]
        # ids keyed with padding pushed past every real id; the stable
        # argsort groups duplicates while remembering original positions
        key = jnp.where(cand >= 0, cand, n_items)
        order = jnp.argsort(key)                           # stable
        sk = key[order]
        # group leaders: the first slot of each distinct real id.  With a
        # stable sort the leader's `order` entry is the id's SMALLEST
        # original position — i.e. its first occurrence in the impact-
        # ordered concatenation, exactly the host oracle's dedup rule.
        first = jnp.concatenate([
            sk[:1] < n_items,
            (sk[1:] != sk[:-1]) & (sk[1:] < n_items),
        ])
        lead_pos = jnp.where(first, order, u)
        # budget smallest first-occurrence positions win the truncation
        # race (higher-impact entries appear earlier in the concat); pad
        # so budget > u still yields a (budget,) selection
        lead_pad = jnp.concatenate(
            [lead_pos, jnp.full((budget,), u, lead_pos.dtype)]
        )
        sel = jnp.sort(lead_pad)[:budget]
        kept = jnp.where(
            sel < u, cand[jnp.minimum(sel, u - 1)], n_items
        ).astype(jnp.int32)
        kept_sorted = jnp.sort(kept)          # real ids asc, sentinels last
        n_real = jnp.sum(sel < u)
        need = budget - n_real
        # fillers: first non-members ascending.  The pool is
        # arange(budget) — provably sufficient, see candidate_union's
        # filler-rule note (the host oracle uses the identical pool).
        pool = jnp.arange(budget, dtype=jnp.int32)
        pos = jnp.searchsorted(kept_sorted, pool)
        member = (pos < budget) & (
            kept_sorted[jnp.minimum(pos, budget - 1)] == pool
        )
        rank = jnp.cumsum(~member) - 1                     # among non-members
        filler = jnp.where(
            ~member & (rank < need), pool, jnp.int32(n_items)
        )
        # budget real ids total; sentinels sort past them and fall off
        return jnp.sort(jnp.concatenate([kept_sorted, filler]))[:budget]

    rows = jax.vmap(one)(qp.reshape(qp.shape[0], -1))
    return rows, any_bad, bad_val


def device_candidate_union(
    index: InvertedIndex, q_indices, budget: int
) -> jax.Array:
    """Stage 1 on device: the batched, jitted twin of ``candidate_union``.

    One vmapped pass gathers the (Q, k, cap) posting rows, stable-sorts
    each query's concatenated lists, marks first occurrences (so
    higher-impact entries win the truncation race exactly as the host
    oracle's ``np.unique``-based dedup does), selects the ``budget``
    earliest-first-occurrence unique ids, fills shortfalls with the first
    non-member catalog ids ascending, and emits the same ascending-sorted
    (Q, budget) int32 contract — BIT-IDENTICAL to ``candidate_union``
    (rows, order, fillers; pinned by tests/test_two_stage_device.py).
    The host version stays as the parity oracle and the guard ladder's
    fallback rung.

    No per-query Python work: stage-1 cost is one device sort over k·cap
    entries per query, batched across Q — the host loop's O(Q) ·
    (unique + setdiff) serialization is gone, which is what lets the
    N-sweep reach 1M+ catalogs (benchmarks/inverted_index_bench.py).

    Raises the same typed errors as the host path: ``ValueError`` when
    ``budget`` exceeds the catalog and ``IndexIntegrityError`` (same
    message, naming the first bad id in row-major order) when the
    gathered postings hold ids outside [−1, N).
    """
    n_items = index.codes.n
    if budget > n_items:
        raise ValueError(
            f"candidate budget {budget} exceeds catalog size {n_items}"
        )
    qi = jnp.asarray(q_indices)
    if qi.ndim == 1:
        qi = qi[None]
    rows, any_bad, bad_val = _device_union(
        index.postings, qi, budget=budget, n_items=n_items
    )
    if bool(any_bad):
        raise IndexIntegrityError(
            f"inverted index posting id {int(bad_val)} outside [-1, "
            f"{n_items}) — postings corrupted since build"
        )
    return rows
