"""Beyond-paper: inverted-file retrieval over sparse codes.

The paper scores every candidate (O(N·k) per query, exact).  Production
sparse-retrieval systems (SPLADE / pgvector sparsevec / Lucene impact
search) instead build an INVERTED INDEX over the h latent dimensions: for
each latent j, a posting list of the candidates whose code activates j.
A query with k active latents only touches the union of its k posting
lists — expected |union| ≈ N·k²/h ≪ N when codes spread over h
(h=4096, k=32: ~25% of the catalog per query, and far less under a
Zipfian latent distribution with per-list caps).

JAX adaptation: posting lists are built host-side (numpy) and stored as a
dense (h, cap) id matrix padded with -1 — static shapes.  Scoring gathers
the ≤ k·cap union, scores it with the same scatter-query SpMV, and top-n's
the partial scores.  This is APPROXIMATE when lists overflow `cap`
(truncated by descending |value| — impact ordering); recall vs the exact
scan is measured in benchmarks/inverted_index_bench.py.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.retrieval import top_n
from repro.core.types import SparseCodes


class InvertedIndex(NamedTuple):
    postings: jax.Array      # (h, cap) int32 candidate ids, -1 padded
    codes: SparseCodes       # the full codes (for scoring gathered ids)
    norms: jax.Array         # (N,) ‖s_c‖

    @property
    def cap(self) -> int:
        return self.postings.shape[1]


def build_inverted_index(codes: SparseCodes, cap: int = 2048) -> InvertedIndex:
    """Host-side build: posting list per latent, impact-ordered, capped.

    Fully vectorized (one lexsort + bincount over the N·k nonzeros) — the
    former per-entry Python loop dominated index-build time at the paper's
    N=100k, k=32.  Entries sort by (latent, |value| desc, row desc), the
    same order the loop's ``entries.sort(reverse=True)`` produced; the
    position of each entry within its latent group comes from subtracting
    the group's cumulative start, and entries past ``cap`` are dropped.
    """
    vals = np.asarray(codes.values)
    idx = np.asarray(codes.indices)
    n, k = vals.shape
    h = codes.dim
    flat_lat = idx.reshape(-1)
    flat_abs = np.abs(vals.reshape(-1))
    flat_row = np.repeat(np.arange(n, dtype=np.int32), k)
    # lexsort: last key is primary — latent asc, then impact desc, row desc
    order = np.lexsort((-flat_row, -flat_abs, flat_lat))
    sorted_lat = flat_lat[order]
    sorted_row = flat_row[order]
    counts = np.bincount(flat_lat, minlength=h)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    within = np.arange(n * k, dtype=np.int64) - starts[sorted_lat]
    keep = within < cap
    postings = np.full((h, cap), -1, dtype=np.int32)
    postings[sorted_lat[keep], within[keep]] = sorted_row[keep]
    norms = jnp.linalg.norm(codes.values, axis=-1)
    return InvertedIndex(postings=jnp.asarray(postings), codes=codes,
                         norms=norms)


def search_inverted(
    index: InvertedIndex, q: SparseCodes, n: int
) -> tuple[jax.Array, jax.Array]:
    """Approximate top-n: score only the union of the query's posting lists.

    q: single-query codes (k,) or batched (Q, k).  Returns (scores, ids)
    of shape (Q?, n); padded/duplicate candidates are masked/deduped by
    keeping each id's score once (max over duplicates is identical —
    scores are id-determined).
    """
    squeeze = q.values.ndim == 1
    q_vals = q.values[None] if squeeze else q.values       # (Q, k)
    q_idx = q.indices[None] if squeeze else q.indices

    def one(qv, qi):
        cand = index.postings[qi].reshape(-1)              # (k·cap,)
        safe = jnp.maximum(cand, 0)
        c_vals = index.codes.values[safe]                  # (k·cap, k)
        c_idx = index.codes.indices[safe]
        q_dense = jnp.zeros((index.codes.dim,), qv.dtype).at[qi].add(qv)
        dots = jnp.sum(q_dense[c_idx] * c_vals, axis=-1)
        scores = dots / jnp.maximum(
            jnp.linalg.norm(qv) * index.norms[safe], 1e-8
        )
        # mask padding; dedupe by keeping the first occurrence of each id
        # (scores are identical for duplicates, so top-k just needs one)
        valid = cand >= 0
        order = jnp.argsort(cand)
        sorted_cand = cand[order]
        first = jnp.concatenate(
            [jnp.array([True]), sorted_cand[1:] != sorted_cand[:-1]]
        )
        keep = jnp.zeros_like(valid).at[order].set(first) & valid
        scores = jnp.where(keep, scores, -jnp.inf)
        v, pos = jax.lax.top_k(scores, n)
        return v, cand[pos]

    vs, ids = jax.vmap(one)(q_vals, q_idx)
    return (vs[0], ids[0]) if squeeze else (vs, ids)


def expected_scan_fraction(codes: SparseCodes, cap: int) -> float:
    """Fraction of the catalog touched per query (host-side estimate)."""
    idx = np.asarray(codes.indices).reshape(-1)
    counts = np.bincount(idx, minlength=codes.dim).astype(np.float64)
    counts = np.minimum(counts, cap)
    k = codes.k
    # expected union size for a query hitting k latents ~ k·E[list len]
    return float(k * counts.mean() / codes.n)
