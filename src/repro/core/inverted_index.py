"""Beyond-paper: inverted-file retrieval over sparse codes.

The paper scores every candidate (O(N·k) per query, exact).  Production
sparse-retrieval systems (SPLADE / pgvector sparsevec / Lucene impact
search) instead build an INVERTED INDEX over the h latent dimensions: for
each latent j, a posting list of the candidates whose code activates j.
A query with k active latents only touches the union of its k posting
lists — expected |union| ≈ N·k²/h ≪ N when codes spread over h
(h=4096, k=32: ~25% of the catalog per query, and far less under a
Zipfian latent distribution with per-list caps).

JAX adaptation: posting lists are built host-side (numpy) and stored as a
dense (h, cap) id matrix padded with -1 — static shapes.  Scoring gathers
the ≤ k·cap union, scores it with the same scatter-query SpMV, and top-n's
the partial scores.  This is APPROXIMATE when lists overflow `cap`
(truncated by descending |value| — impact ordering); recall vs the exact
scan is measured in benchmarks/inverted_index_bench.py.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.retrieval import top_n
from repro.core.types import SparseCodes
from repro.errors import IndexIntegrityError, InvalidCodesError


class InvertedIndex(NamedTuple):
    postings: jax.Array      # (h, cap) int32 candidate ids, -1 padded
    codes: SparseCodes       # the full codes (for scoring gathered ids)
    norms: jax.Array         # (N,) ‖s_c‖

    @property
    def cap(self) -> int:
        return self.postings.shape[1]


def build_inverted_index(codes: SparseCodes, cap: int = 2048) -> InvertedIndex:
    """Host-side build: posting list per latent, impact-ordered, capped.

    Fully vectorized (one lexsort + bincount over the N·k nonzeros) — the
    former per-entry Python loop dominated index-build time at the paper's
    N=100k, k=32.  Entries sort by (latent, |value| desc, row desc), the
    same order the loop's ``entries.sort(reverse=True)`` produced; the
    position of each entry within its latent group comes from subtracting
    the group's cumulative start, and entries past ``cap`` are dropped.
    """
    vals = np.asarray(codes.values)
    idx = np.asarray(codes.indices)
    n, k = vals.shape
    h = codes.dim
    # out-of-range latents would index bincount/postings wrongly (negative
    # indices silently wrap; >= h crashes with an opaque numpy error) —
    # reject them up front, naming the offending entry
    bad = (idx < 0) | (idx >= h)
    if bad.any():
        r, s = (int(v) for v in np.argwhere(bad)[0])
        raise InvalidCodesError(
            f"codes.indices[{r}, {s}] = {int(idx[r, s])} is outside the "
            f"latent range [0, {h}) — cannot bucket this entry into a "
            "posting list (corrupted codes or a dim mismatch)"
        )
    flat_lat = idx.reshape(-1)
    flat_abs = np.abs(vals.reshape(-1))
    flat_row = np.repeat(np.arange(n, dtype=np.int32), k)
    # lexsort: last key is primary — latent asc, then impact desc, row desc
    order = np.lexsort((-flat_row, -flat_abs, flat_lat))
    sorted_lat = flat_lat[order]
    sorted_row = flat_row[order]
    counts = np.bincount(flat_lat, minlength=h)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    within = np.arange(n * k, dtype=np.int64) - starts[sorted_lat]
    keep = within < cap
    postings = np.full((h, cap), -1, dtype=np.int32)
    postings[sorted_lat[keep], within[keep]] = sorted_row[keep]
    norms = jnp.linalg.norm(codes.values, axis=-1)
    return InvertedIndex(postings=jnp.asarray(postings), codes=codes,
                         norms=norms)


def search_inverted(
    index: InvertedIndex, q: SparseCodes, n: int, *, block: int = 2048
) -> tuple[jax.Array, jax.Array]:
    """Approximate top-n: score only the union of the query's posting lists.

    q: single-query codes (k,) or batched (Q, k).  Returns (scores, ids)
    of shape (Q?, n); padded/duplicate candidates are masked/deduped by
    keeping each id's score once (max over duplicates is identical —
    scores are id-determined).

    Selection runs through the same streaming top-n epilogue as the fused
    serving path (retrieve_ref / the Pallas kernel): the k·cap posting
    union is scanned in ``block``-sized slices, each slice gathered,
    scored and merged into a running (n,) best buffer with one
    ``lax.top_k`` over n + block candidates — the full union's scores
    (and its (block, k) gather transient) never exist at once.  Exactly
    equivalent to the one-shot ``lax.top_k`` over all k·cap scores
    (``_search_inverted_fullsort``, the parity oracle in
    tests/test_inverted_index.py): per-candidate scores are identical,
    the running buffer precedes each slice in the merge so ties resolve
    to the earliest union position either way, and duplicates are
    suppressed by slice-local first-occurrence dedup plus masking against
    ids already in the buffer (a duplicate whose earlier occurrence was
    cut can never outscore the buffer floor — duplicate scores are equal
    and the floor is monotone).
    """
    squeeze = q.values.ndim == 1
    q_vals = q.values[None] if squeeze else q.values       # (Q, k)
    q_idx = q.indices[None] if squeeze else q.indices

    def one(qv, qi):
        cand = index.postings[qi].reshape(-1)              # (k·cap,)
        q_dense = jnp.zeros((index.codes.dim,), qv.dtype).at[qi].add(qv)
        q_norm = jnp.linalg.norm(qv)
        u = cand.shape[0]
        blk = min(block, u)
        pad = (-u) % blk
        if pad:
            cand = jnp.pad(cand, (0, pad), constant_values=-1)
        cand_b = cand.reshape(-1, blk)

        init = (
            jnp.full((n,), -jnp.inf, jnp.float32),
            jnp.full((n,), -1, jnp.int32),
        )

        def step(carry, cb):
            best_v, best_i = carry
            safe = jnp.maximum(cb, 0)
            c_vals = index.codes.values[safe]              # (blk, k)
            c_idx = index.codes.indices[safe]
            dots = jnp.sum(q_dense[c_idx] * c_vals, axis=-1)
            scores = (dots / jnp.maximum(q_norm * index.norms[safe], 1e-8)
                      ).astype(jnp.float32)
            valid = cb >= 0
            # slice-local dedup: keep the first occurrence of each id
            order = jnp.argsort(cb)
            sorted_cb = cb[order]
            first = jnp.concatenate(
                [jnp.array([True]), sorted_cb[1:] != sorted_cb[:-1]]
            )
            keep = jnp.zeros_like(valid).at[order].set(first) & valid
            # cross-slice dedup: ids already held by the running buffer
            keep &= ~jnp.any(cb[:, None] == best_i[None, :], axis=-1)
            scores = jnp.where(keep, scores, -jnp.inf)
            cand_v = jnp.concatenate([best_v, scores])
            # padding contract (pinned, matches core.retrieve at n>matches):
            # masked entries surface as (score −inf, id −1) and sort after
            # every real match — never a real id with a −inf score
            cand_i = jnp.concatenate([best_i, jnp.where(keep, cb, -1)])
            v, p = jax.lax.top_k(cand_v, n)
            return (v, cand_i[p]), None

        (best_v, best_i), _ = jax.lax.scan(step, init, cand_b)
        return best_v, best_i

    vs, ids = jax.vmap(one)(q_vals, q_idx)
    return (vs[0], ids[0]) if squeeze else (vs, ids)


def _search_inverted_fullsort(
    index: InvertedIndex, q: SparseCodes, n: int
) -> tuple[jax.Array, jax.Array]:
    """Pre-streaming selection: one ``lax.top_k`` over all k·cap gathered
    union scores.  Kept as the parity oracle for ``search_inverted``'s
    streaming epilogue (tests/test_inverted_index.py)."""
    squeeze = q.values.ndim == 1
    q_vals = q.values[None] if squeeze else q.values       # (Q, k)
    q_idx = q.indices[None] if squeeze else q.indices

    def one(qv, qi):
        cand = index.postings[qi].reshape(-1)              # (k·cap,)
        safe = jnp.maximum(cand, 0)
        c_vals = index.codes.values[safe]                  # (k·cap, k)
        c_idx = index.codes.indices[safe]
        q_dense = jnp.zeros((index.codes.dim,), qv.dtype).at[qi].add(qv)
        dots = jnp.sum(q_dense[c_idx] * c_vals, axis=-1)
        scores = dots / jnp.maximum(
            jnp.linalg.norm(qv) * index.norms[safe], 1e-8
        )
        # mask padding; dedupe by keeping the first occurrence of each id
        # (scores are identical for duplicates, so top-k just needs one)
        valid = cand >= 0
        order = jnp.argsort(cand)
        sorted_cand = cand[order]
        first = jnp.concatenate(
            [jnp.array([True]), sorted_cand[1:] != sorted_cand[:-1]]
        )
        keep = jnp.zeros_like(valid).at[order].set(first) & valid
        scores = jnp.where(keep, scores, -jnp.inf)
        # same padding contract as the streaming path: (−inf, −1) pairs
        cand = jnp.where(keep, cand, -1)
        v, pos = jax.lax.top_k(scores, n)
        return v, cand[pos]

    vs, ids = jax.vmap(one)(q_vals, q_idx)
    return (vs[0], ids[0]) if squeeze else (vs, ids)


def expected_scan_fraction(codes: SparseCodes, cap: int) -> float:
    """Fraction of the catalog touched per query (host-side estimate).

    Independence approximation: a uniformly chosen latent's capped posting
    list covers p = E[min(len, cap)] / N of the catalog, so a query
    hitting k latents misses a given item with probability ~ (1 − p)^k
    and the expected union covers 1 − (1 − p)^k.  The former k·p estimate
    ignored union overlap and could exceed 1.0 on dense-latent corpora
    (e.g. all activity on a handful of latents); this form is always in
    [0, 1], still monotone in ``cap``, and bounded above by k·p.  The
    approximation assumes the query's k latents are drawn independently
    of each other and of per-item co-activation — real corpora correlate
    latents, so treat this as an estimate, not a guarantee (the measured
    number lives in benchmarks/inverted_index_bench.py).
    """
    idx = np.asarray(codes.indices).reshape(-1)
    counts = np.bincount(idx, minlength=codes.dim).astype(np.float64)
    counts = np.minimum(counts, cap)
    k = codes.k
    p = float(np.clip(counts.mean() / codes.n, 0.0, 1.0))
    return float(np.clip(1.0 - (1.0 - p) ** k, 0.0, 1.0))


def candidate_union(
    index: InvertedIndex, q_indices: np.ndarray, budget: int
) -> np.ndarray:
    """Stage 1 of two-stage retrieval: per-query candidate row sets.

    Host-side (numpy) — posting lists live as a static (h, cap) matrix,
    but the union/dedup/truncate logic is data-dependent and cheap, so it
    runs outside jit.  For each query row the k posting lists are
    concatenated in impact order, deduplicated keeping first occurrence
    (so higher-impact entries win the truncation race), truncated to
    ``budget`` rows, then padded back up to ``budget`` with *real* filler
    catalog rows not already present (padding with repeats or sentinels
    would give stage 2's kernels out-of-range or duplicate rows; real
    fillers merely add candidates that honestly compete and lose).
    Each row is finally sorted ascending so that stage 2's sub-index
    position order equals global-id order — ``lax.top_k`` ties then
    resolve to the lowest global id, exactly matching the single-stage
    path's tie semantics.

    Raises ``IndexIntegrityError`` if the posting matrix holds ids
    outside [−1, N) — the signature of postings corruption, and the
    guard ladder's cue to fall back to single-stage retrieval.

    Returns (Q, budget) int32, every entry a valid catalog row, each row
    sorted ascending with no duplicates.  Requires budget ≤ N.
    """
    n_items = index.codes.n
    if budget > n_items:
        raise ValueError(
            f"candidate budget {budget} exceeds catalog size {n_items}"
        )
    qi = np.asarray(q_indices)
    if qi.ndim == 1:
        qi = qi[None]
    postings = np.asarray(index.postings)
    out = np.empty((qi.shape[0], budget), dtype=np.int32)
    for r in range(qi.shape[0]):
        cand = postings[qi[r]].reshape(-1)                 # (k·cap,)
        if ((cand < -1) | (cand >= n_items)).any():
            bad = cand[(cand < -1) | (cand >= n_items)][0]
            raise IndexIntegrityError(
                f"inverted index posting id {int(bad)} outside [-1, "
                f"{n_items}) — postings corrupted since build"
            )
        valid = cand[cand >= 0]
        # first-occurrence dedup preserving impact/concatenation order
        _, first = np.unique(valid, return_index=True)
        uniq = valid[np.sort(first)][:budget]
        need = budget - uniq.shape[0]
        if need:
            fillers = np.setdiff1d(
                np.arange(budget, dtype=np.int32), uniq
            )[:need]
            uniq = np.concatenate([uniq, fillers])
        out[r] = np.sort(uniq)
    return out
