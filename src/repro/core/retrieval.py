"""Retrieval from compressed space (paper §3.2).

Three scoring modes, all returning cosine similarities against a candidate
database stored as fixed-k SparseCodes:

1. ``score_sparse``        — similarity directly between sparse codes
                             (the paper's fast O(k) SpMV mode).
2. ``score_reconstructed`` — kernel-trick similarity in the reconstructed
                             space, cos(x̂_q, x̂_c) = s_qᵀKs_c / (‖·‖‖·‖),
                             K = W_dec W_decᵀ (paper's high-fidelity mode).
3. ``score_dense``         — exact dense baseline for evaluation.

TPU adaptation (DESIGN.md §3): both sparse modes reduce to one primitive —
a *dense query vector* dotted against fixed-k sparse candidate rows
("scatter-query SpMV").  For mode 1 the dense query is densify(s_q); for
mode 2 it is z = K s_q = W_decᵀ(W_dec s_q), computed with two thin MXU
matmuls, with candidate norms √(s_cᵀKs_c) precomputed at index-build time.
Mode 2 therefore costs the same per-candidate work as mode 1 — this is an
exact refactoring (associativity), not an approximation.

Serving goes through ``retrieve(index, q, n, mode)`` — the one-call
score+select API, now a thin functional wrapper over the serving engine
(``repro.serving.engine.RetrievalEngine``): it preps the query into the
mode's scoring representation (sparse mode keeps the (Q, k) codes — the
sparse-query kernel densifies in VMEM; reconstructed mode computes the
dense z = W_decᵀ(W_dec s_q)) and dispatches on ``use_kernel``:

  * ``"auto"`` (default) — the fused Pallas kernels
    (repro.kernels.sparse_dot.fused_retrieve_sparse_q / fused_retrieve:
    candidate tiles streamed once per query panel, streaming top-n
    epilogue, no (Q, N) materialization) on TPU; the equivalent
    chunked-jnp refs elsewhere.
  * ``True`` / ``False`` — force the kernel (interpret mode off-TPU; slow,
    for tests) or the jnp path.

End-to-end serving (dense embeddings in, no code round-trip through HBM)
lives on the engine object itself: ``RetrievalEngine.retrieve_dense``.

Indexes come in two serving formats — ``SparseIndex`` (fp32 codes) and
``QuantizedIndex`` (``build_index(..., quantize=True)``: int8 values +
int16/int32 indices + fp32 per-row scales, served directly — the fused
kernels dequantize candidate tiles in VMEM, never materializing an fp32
index in HBM).  Every API here accepts either; quantized serving is
bit-identical to retrieval from ``dequantize_index(...)``.

Both paths fold precomputed *reciprocal* candidate norms into the scoring
epilogue and divide by ‖q‖ on the final (Q, n) panel only, so they agree to
f32 rounding and return identical ids away from ties.

``score_sparse`` / ``score_reconstructed`` return full (Q, N) score
matrices for evaluation; they accept the same ``use_kernel`` switch to
route the SpMV through the blocked Pallas kernel or the pure-jnp path.
"""
from __future__ import annotations

import zlib
from typing import NamedTuple, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import sae, sparse
from repro.core.quantized_codes import (
    QuantizedCodes,
    codes_checksum,
    content_checksum,
    dequantize_codes,
    quantize_codes,
)
from repro.errors import IndexIntegrityError
from repro.core.types import SparseCodes
from repro.kernels.sparse_dot import sparse_dot as sparse_dot_kernel

NORM_EPS = 1e-8
UseKernel = Union[str, bool]  # "auto" | True | False


def kernel_path(use_kernel: UseKernel) -> bool:
    """Resolve the ``use_kernel`` dispatch switch to a concrete backend
    decision (True = fused/blocked Pallas kernel).  Public so entry points
    (launch/serve.py) can report which path serves."""
    if use_kernel == "auto":
        return jax.default_backend() == "tpu"
    if not isinstance(use_kernel, bool):
        raise ValueError(f"use_kernel must be 'auto', True or False: {use_kernel!r}")
    return use_kernel


def sparse_dot_dense_query(
    codes: SparseCodes, q_dense: jax.Array, q_chunk: int = 16
) -> jax.Array:
    """scores[i] = Σ_j codes.values[i,j] · q_dense[codes.indices[i,j]].

    codes: (N, k); q_dense: (h,) or (Q, h).  Returns (N,) or (Q, N).
    Pure-jnp reference path (gather + FMA); the Pallas kernel in
    repro.kernels.sparse_dot implements the same contract blockwise in
    VMEM.  The jnp gather materializes (q_chunk, N, k) — large Q is
    processed in chunks so the transient stays bounded (the kernel never
    materializes it at all).
    """
    if q_dense.ndim == 1:
        gathered = q_dense[codes.indices]                 # (N, k)
        return jnp.sum(gathered * codes.values, axis=-1)  # (N,)
    q = q_dense.shape[0]
    if q <= q_chunk:
        gathered = q_dense[:, codes.indices]              # (Q, N, k)
        return jnp.sum(gathered * codes.values[None], axis=-1)
    pad = (-q) % q_chunk
    qp = jnp.pad(q_dense, ((0, pad), (0, 0))) if pad else q_dense
    blocks = qp.reshape(-1, q_chunk, qp.shape[-1])

    def block(qb):
        g = qb[:, codes.indices]
        return jnp.sum(g * codes.values[None], axis=-1)

    out = jax.lax.map(block, blocks).reshape(-1, codes.values.shape[0])
    return out[:q]


def _sparse_dot(
    codes: SparseCodes, q_dense: jax.Array, use_kernel: UseKernel
) -> jax.Array:
    """Full-score SpMV dispatch: blocked Pallas kernel or pure jnp."""
    if kernel_path(use_kernel):
        return sparse_dot_kernel(codes.values, codes.indices, q_dense)
    return sparse_dot_dense_query(codes, q_dense)


class SparseIndex(NamedTuple):
    """A retrieval index over a compressed candidate database.

    codes:        fixed-k sparse codes of all N candidates.
    sparse_norms: ‖s_c‖₂ per candidate (sparse-space cosine denominators).
    recon_norms:  ‖W_dec s_c‖₂ = √(s_cᵀ K s_c) per candidate (kernel trick),
                  None if the index was built without decoder weights.
    inv_sparse_norms / inv_recon_norms: precomputed 1/max(norm, NORM_EPS),
                  streamed alongside candidate values by the fused
                  retrieval kernel (division folded into the epilogue).
    checksum:     build-time content CRC over codes + norms (ISSUE 6);
                  ``verify_index`` recomputes and compares it so a flipped
                  byte is a typed startup error, never a silently wrong
                  result.  None for hand-built or traced indexes.
    """

    codes: SparseCodes
    sparse_norms: jax.Array
    recon_norms: Optional[jax.Array]
    inv_sparse_norms: Optional[jax.Array] = None
    inv_recon_norms: Optional[jax.Array] = None
    checksum: Optional[int] = None


class QuantizedIndex(NamedTuple):
    """A retrieval index whose candidate codes live in HBM in the
    compound-compressed storage format (int8 values + int16/int32 indices
    + fp32 per-row scales — ``core.quantized_codes.QuantizedCodes``).

    Serving streams these quantized arrays straight into the fused
    retrieval kernels, which dequantize candidate tiles in VMEM — the
    index is never materialized in fp32.  All norms (and reciprocals) are
    computed on the DEQUANTIZED values at build time, so quantized serving
    is exactly self-consistent: scores/ids/ties are bit-identical to
    dequantize-then-retrieve on the same quantized values.  Field names
    mirror ``SparseIndex`` so the serving engine and the distributed
    retrieve treat both index formats uniformly (``checksum`` included —
    see ``SparseIndex``; here it fingerprints the int8/int16 bytes that
    actually live in HBM).
    """

    codes: QuantizedCodes
    sparse_norms: jax.Array
    recon_norms: Optional[jax.Array]
    inv_sparse_norms: Optional[jax.Array] = None
    inv_recon_norms: Optional[jax.Array] = None
    checksum: Optional[int] = None


Index = Union[SparseIndex, QuantizedIndex]


def index_checksum(index: Index) -> Optional[int]:
    """Recompute the content CRC of an index (codes + every norm array).

    Pure function of the index's array content — independent of the
    stored ``checksum`` field — so ``verify_index`` can diff stored vs
    actual.  ``None`` when the arrays are abstract tracers (integrity is
    a host-side concern; never checked inside a traced computation).
    """
    base = codes_checksum(index.codes)
    if base is None:
        return None
    extra = content_checksum([
        ("sparse_norms", index.sparse_norms),
        ("recon_norms", index.recon_norms),
        ("inv_sparse_norms", index.inv_sparse_norms),
        ("inv_recon_norms", index.inv_recon_norms),
    ])
    if extra is None:
        return None
    # mix: order-stable combination of the two digests
    return zlib.crc32(f"{base:08x}:{extra:08x}".encode())


def verify_index(index: Index, *, require: bool = True) -> bool:
    """Check the index's content against its build-time checksum.

    Returns True when the stored checksum matches the recomputed one.
    A mismatch raises ``IndexIntegrityError`` (a single flipped byte in
    any stored array is caught).  An index with no stored checksum
    raises when ``require=True`` (the startup self-check's default:
    don't accept traffic on unverifiable bytes) and returns False when
    ``require=False`` (opportunistic callers).
    """
    fmt = type(index).__name__
    if index.checksum is None:
        if require:
            raise IndexIntegrityError(
                f"{fmt} has no stored checksum — built before ISSUE 6, "
                "hand-constructed, or built under tracing; rebuild with "
                "build_index(...) to make integrity verifiable"
            )
        return False
    got = index_checksum(index)
    if got is None:
        raise IndexIntegrityError(
            f"{fmt} content is not concrete (traced arrays); integrity "
            "can only be verified on host-resident index bytes"
        )
    if got != index.checksum:
        raise IndexIntegrityError(
            f"{fmt} content checksum mismatch: stored 0x{index.checksum:08x}, "
            f"recomputed 0x{got:08x} (N={index.codes.n}, k={index.codes.k}) — "
            "the index bytes changed since build_index (corruption or "
            "out-of-band mutation); refusing to serve from them"
        )
    return True


def build_index(
    codes: SparseCodes,
    params: Optional[sae.Params] = None,
    *,
    quantize: bool = False,
) -> Index:
    """Precompute per-candidate norms (and reciprocals for the fused
    kernel).  recon_norms needs W_dec: ‖x̂_c‖ is the norm of a k-atom
    combination, computed by a k-row gather of W_dec — O(N·k·d) once at
    build time, never per query.

    ``quantize=True`` returns a ``QuantizedIndex``: the codes are
    compound-compressed (int8 values + int16/int32 indices + per-row
    scales, ~2.6x smaller than fp32 codes at k=32) and SERVED in that
    format — the fused kernels dequantize tiles in VMEM.  Norms are
    computed on the dequantized values, so retrieval from the quantized
    index is bit-identical to retrieval from
    ``dequantize_index(quantized_index)``.
    """
    if quantize:
        q_codes = quantize_codes(codes)
        base = build_index(dequantize_codes(q_codes), params)
        idx = QuantizedIndex(
            codes=q_codes,
            sparse_norms=base.sparse_norms,
            recon_norms=base.recon_norms,
            inv_sparse_norms=base.inv_sparse_norms,
            inv_recon_norms=base.inv_recon_norms,
        )
        return idx._replace(checksum=index_checksum(idx))
    sparse_norms = jnp.linalg.norm(codes.values, axis=-1)
    recon_norms = None
    inv_recon_norms = None
    if params is not None:
        x_hat = sae.decode(params, codes)                 # (N, d)
        recon_norms = jnp.linalg.norm(x_hat, axis=-1)
        inv_recon_norms = 1.0 / jnp.maximum(recon_norms, NORM_EPS)
    idx = SparseIndex(
        codes=codes,
        sparse_norms=sparse_norms,
        recon_norms=recon_norms,
        inv_sparse_norms=1.0 / jnp.maximum(sparse_norms, NORM_EPS),
        inv_recon_norms=inv_recon_norms,
    )
    return idx._replace(checksum=index_checksum(idx))


def dequantize_index(index: QuantizedIndex) -> SparseIndex:
    """The fp32 ``SparseIndex`` a ``QuantizedIndex`` serves identically to.

    Dequantizes the codes and carries the stored norms over unchanged —
    they were computed on these exact dequantized values at build time, so
    the twin agrees bit-for-bit on every serving path (the exactness
    oracle used by tests and benchmarks), including reconstructed mode
    when the original build had params, with no decoder recompute.
    """
    idx = SparseIndex(
        codes=dequantize_codes(index.codes),
        sparse_norms=index.sparse_norms,
        recon_norms=index.recon_norms,
        inv_sparse_norms=index.inv_sparse_norms,
        inv_recon_norms=index.inv_recon_norms,
    )
    # fresh digest: the fp32 twin's bytes differ from the quantized ones
    return idx._replace(checksum=index_checksum(idx))


def index_codes_f32(index: Index) -> SparseCodes:
    """The index's codes as fp32 ``SparseCodes`` — dequantizing if needed.

    For full-score evaluation paths (``score_sparse`` /
    ``score_reconstructed``) only; the serving paths keep quantized codes
    quantized all the way into the kernels.
    """
    if isinstance(index.codes, QuantizedCodes):
        return dequantize_codes(index.codes)
    return index.codes


def take_index_rows(index: Index, rows: jax.Array) -> Index:
    """Sub-index over the given catalog rows (gathered, ids re-based).

    Gathers every per-candidate array of the index — codes (quantized or
    fp32), norms, reciprocal norms — at ``rows``, producing an index whose
    candidate ``i`` is the original index's candidate ``rows[i]``.  The
    serving formats gather AS-IS: a ``QuantizedIndex`` stays int8/int16 +
    scales, so downstream kernels run their usual generation unchanged.
    The sub-index carries no checksum (its byte content is a per-call
    gather; integrity is the full index's concern).  Callers map returned
    ids back with ``rows[ids]``.  jit-safe: ``rows`` may be traced.

    Shared by degraded partial retrieval over surviving shards
    (``distributed.retrieve.partial_retrieve_prepped``) and stage 2 of
    two-stage retrieval (``two_stage_retrieve``).
    """
    take = lambda a: None if a is None else jnp.take(a, rows, axis=0)
    codes = index.codes
    if isinstance(codes, QuantizedCodes):
        sub_codes = QuantizedCodes(
            q_values=take(codes.q_values), indices=take(codes.indices),
            scales=take(codes.scales), dim=codes.dim,
        )
    else:
        sub_codes = SparseCodes(
            values=take(codes.values), indices=take(codes.indices),
            dim=codes.dim,
        )
    return index._replace(
        codes=sub_codes,
        sparse_norms=take(index.sparse_norms),
        recon_norms=take(index.recon_norms),
        inv_sparse_norms=take(index.inv_sparse_norms),
        inv_recon_norms=take(index.inv_recon_norms),
        checksum=None,
    )


def two_stage_budget(n_items: int, n: int, candidate_fraction: float) -> int:
    """Static stage-2 candidate count: ``candidate_fraction`` of the
    catalog, at least ``n``, rounded up to a BLOCK_N multiple (the fused
    kernels' candidate tile) and capped at the catalog size.  Static so
    the stage-2 jit compiles once per (n, budget) shape."""
    from repro.kernels.sparse_dot.kernel import BLOCK_N

    if not 0.0 < candidate_fraction <= 1.0:
        raise ValueError(
            f"candidate_fraction must be in (0, 1]: {candidate_fraction}"
        )
    if n > n_items:
        raise ValueError(f"top-n {n} exceeds candidate count {n_items}")
    budget = max(n, int(np.ceil(candidate_fraction * n_items)))
    budget = -(-budget // BLOCK_N) * BLOCK_N
    return min(n_items, max(budget, n))


STAGE1_CHOICES = ("auto", "device", "host")


def _gather_candidate_panels(index: Index, rows_b: jax.Array, inv_norms):
    """Batched stage-2 gather: per-query (budget,) row tables -> per-query
    candidate panels with a leading Q axis, in the index's SERVING dtypes
    (a ``QuantizedIndex`` stays int8/int16 + scales — the gathered kernels
    dequantize per brick in VMEM, the f32 copy never exists).  Returns
    (cand_tuple, (Q, budget) gathered inv norms).  jit-safe."""
    take = lambda a: jnp.take(a, rows_b, axis=0)
    codes = index.codes
    if isinstance(codes, QuantizedCodes):
        cand = (take(codes.q_values), take(codes.indices), take(codes.scales))
    else:
        cand = (take(codes.values), take(codes.indices))
    return cand, take(inv_norms)


def two_stage_retrieve(
    index: Index,
    inv,
    q: SparseCodes,
    n: int,
    *,
    use_fused: bool,
    precision: str = "exact",
    candidate_fraction: float = 0.25,
    cache: Optional[dict] = None,
    stage1: str = "auto",
    stage2: str = "batched",
) -> tuple[jax.Array, jax.Array]:
    """Two-stage sparse retrieval: inverted-index candidate generation,
    then the fused re-rank over only the gathered candidate rows.

    Stage 1: union the query's k posting lists from ``inv`` (an
    ``InvertedIndex`` built over this index's codes), dedup in impact
    order, truncate/pad to a static budget of
    ``two_stage_budget(N, n, candidate_fraction)`` real catalog rows,
    sorted ascending per query.  ``stage1`` picks the implementation:
    ``"device"`` (and ``"auto"``, its alias) runs the batched jitted
    union (``core.inverted_index.device_candidate_union`` — one vmapped
    sort per call, no per-query Python); ``"host"`` runs the numpy
    oracle (``candidate_union``).  The two are BIT-IDENTICAL (rows,
    order, fillers) — the host path survives as the parity oracle and
    the guard ladder's fallback rung.

    Stage 2 (``stage2="batched"``, the default): gather every query's
    candidate panel in one batched device gather — (Q, budget, k) values/
    indices (+ scales) and (Q, budget) reciprocal norms, quantized codes
    staying quantized — and run ONE gather-aware fused re-rank
    (generation 6: ``fused_retrieve_gathered_*`` /
    ``retrieve_gathered_*_ref``, dispatched by
    ``serving.engine.select_gathered_retrieve_fn``) over the whole panel.
    ``stage2="per_query"`` keeps the PR 7 path — a Python loop of
    per-query ``take_index_rows`` + ``retrieve_prepped`` jits — as the
    parity oracle; the batched panel is BIT-IDENTICAL to it (scores,
    ids, ties, the (−inf, −1) padding contract) across every mode ×
    precision.  Both map local candidate positions back through the
    row table.  Because candidate rows are sorted ascending, panel
    position order equals global-id order and ``lax.top_k`` ties
    resolve to the lowest global id — the single-stage tie rule.

    APPROXIMATE in general: an item outside every queried posting list
    (posting-cap truncation, or budget < |union|) can't be returned.
    With untruncated lists and budget ≥ |union| it is EXACT — any item
    with positive sparse-cosine score shares ≥ 1 latent with the query,
    so the true top-n is inside the union whenever ≥ n positive-score
    items exist.  ``candidate_fraction=1.0`` is always bit-identical to
    single-stage.  Quality is measured per-build by
    ``benchmarks/retrieval_modes.py`` (recall_vs_exact gate).

    O(budget·k) per query instead of O(N·k) — the catalog-scaling path.
    Cost is ``budget/N`` of a full scan (= the reported scanned
    fraction); with device stage 1 + batched stage 2 the whole request
    is two device dispatches, no per-query host work — what lets the
    N-sweep reach 1M+ catalogs (benchmarks/inverted_index_bench.py).

    ``cache`` (dict, caller-owned — the serving engine passes its own)
    memoizes the stage-2 jit by (stage2, n, budget, ...) so repeated
    calls at one shape compile once.  Sparse mode only (q are (Q?, k)
    query codes).
    """
    from repro.core.inverted_index import (
        candidate_union, device_candidate_union,
    )
    from repro.serving.engine import (
        PreppedQuery, check_precision, retrieve_prepped,
        select_gathered_retrieve_fn,
    )

    if stage1 not in STAGE1_CHOICES:
        raise ValueError(
            f"unknown stage1 {stage1!r} (expected one of {STAGE1_CHOICES})"
        )
    if stage2 not in ("batched", "per_query"):
        raise ValueError(
            f"unknown stage2 {stage2!r} (expected 'batched' or 'per_query')"
        )
    check_precision(index, precision)
    n_items = index.codes.n
    budget = two_stage_budget(n_items, n, candidate_fraction)

    squeeze = q.values.ndim == 1
    qv = q.values[None] if squeeze else q.values           # (Q, k)
    qi = q.indices[None] if squeeze else q.indices
    if stage1 == "host":
        rows_b = jnp.asarray(candidate_union(inv, np.asarray(qi), budget))
    else:
        rows_b = device_candidate_union(inv, qi, budget)   # (Q, budget)

    if cache is None:
        cache = {}

    if stage2 == "batched":
        key = ("batched", n, budget, use_fused, precision)
        fn = cache.get(key)
        if fn is None:
            quantized = isinstance(index.codes, QuantizedCodes)
            g_fn = select_gathered_retrieve_fn(
                quantized=quantized,
                int8_scoring=precision == "int8",
                use_fused=use_fused,
            )
            inv_norms = index.inv_sparse_norms
            if inv_norms is None:
                inv_norms = 1.0 / jnp.maximum(index.sparse_norms, NORM_EPS)

            @jax.jit
            def fn(rows_all, qv_all, qi_all):
                cand, inv_g = _gather_candidate_panels(
                    index, rows_all, inv_norms
                )
                vals, ids = g_fn(
                    *cand, inv_g, qv_all, qi_all, index.codes.dim, n=n
                )
                norm = jnp.linalg.norm(qv_all, axis=-1)
                scores = vals / jnp.maximum(norm[..., None], NORM_EPS)
                # map panel positions back to global ids, preserving the
                # padding contract: id −1 stays −1
                gids = jnp.where(
                    ids >= 0,
                    jnp.take_along_axis(
                        rows_all, jnp.maximum(ids, 0), axis=1
                    ),
                    -1,
                )
                return scores, gids

            cache[key] = fn

        scores, ids = fn(rows_b, qv, qi)
        return (scores[0], ids[0]) if squeeze else (scores, ids)

    key = (n, budget, use_fused, precision)
    fn = cache.get(key)
    if fn is None:
        @jax.jit
        def fn(rows_one, qv_one, qi_one):
            sub = take_index_rows(index, rows_one)
            pq = PreppedQuery(
                values=qv_one[None], indices=qi_one[None], dense=None,
                norm=jnp.linalg.norm(qv_one)[None],
            )
            s, ids = retrieve_prepped(
                sub, pq, n, use_fused=use_fused, precision=precision,
            )
            # map sub-index positions back to global ids, preserving the
            # padding contract: id −1 stays −1
            gids = jnp.where(ids[0] >= 0, rows_one[ids[0]], -1)
            return s[0], gids

        cache[key] = fn

    rows = np.asarray(rows_b)
    outs = [fn(jnp.asarray(rows[r]), qv[r], qi[r]) for r in range(qv.shape[0])]
    scores = jnp.stack([s for s, _ in outs])
    ids = jnp.stack([g for _, g in outs])
    return (scores[0], ids[0]) if squeeze else (scores, ids)


def retrieve(
    index: Index,
    q: SparseCodes,
    n: int,
    mode: str = "sparse",
    params: Optional[sae.Params] = None,
    *,
    use_kernel: UseKernel = "auto",
    mesh=None,
    shard_axis: str = "cand",
    precision: str = "exact",
) -> tuple[jax.Array, jax.Array]:
    """One-call serving API: top-n (cosine scores, candidate ids).

    Thin functional wrapper over the serving engine
    (``repro.serving.engine.RetrievalEngine.retrieve_codes``): constructs a
    per-call engine and serves one request through it.  Long-lived callers
    should hold a ``RetrievalEngine`` instead and use ``retrieve_dense``
    for whole requests (dense embeddings in; returns a typed
    ``RetrievalResponse``) — this adapter deliberately keeps the plain
    tuple contract.

    q: (Q?, k) query codes; returns (Q?, n) scores and int32 ids.  The
    (Q, N) score matrix is never materialized on either path, and in
    sparse mode the query codes are scored directly (VMEM-densified panel)
    — no dense (Q, h) query round-trip through HBM.  Equivalent (to f32
    rounding; identical ids away from ties) to
    ``top_n(score_<mode>(index, q), n)``.

    ``mesh`` routes through candidate-sharded distributed retrieval
    (``repro.distributed.retrieve``): the index is sharded along
    ``mesh[shard_axis]``, the prepped query is replicated, each shard runs
    the same fused/ref streaming retrieve over its slice, and per-shard
    top-n sets merge via ``sharded_top_n`` — bit-identical (scores, ids,
    ties) to the single-device path.

    ``precision="int8"`` (QuantizedIndex only) serves the APPROXIMATE
    generation-5 int8-scoring fast path instead of the exact one —
    quality vs ``"exact"`` is a measured bound (``repro.core.eval``),
    everything else about the call is unchanged.
    """
    from repro.serving.config import EngineConfig
    from repro.serving.engine import RetrievalEngine

    engine = RetrievalEngine(
        index, params,
        config=EngineConfig(
            mode=mode, use_kernel=use_kernel, mesh=mesh,
            shard_axis=shard_axis, precision=precision,
        ),
    )
    return engine.retrieve_codes(q, n)


def _cosine_normalize(
    dots: jax.Array, q_norm: jax.Array, cand_norms: jax.Array
) -> jax.Array:
    """dots / max(‖q‖·‖c‖, eps), broadcasting over (N,) and (Q, N) alike:
    a scalar ‖q‖ becomes (1,), a (Q,) batch becomes (Q, 1) — one expression
    covers the single-query and batched layouts."""
    return dots / jnp.maximum(q_norm[..., None] * cand_norms, NORM_EPS)


def score_sparse(
    index: Index, q: SparseCodes, *, use_kernel: UseKernel = "auto"
) -> jax.Array:
    """Cosine similarity in the sparse compressed space.  q: (Q?, k) codes.
    Returns (N,) for a single query or (Q, N)."""
    q_dense = sparse.densify(q)                            # (Q?, h)
    q_norm = jnp.linalg.norm(q.values, axis=-1)            # (Q?,)
    dots = _sparse_dot(index_codes_f32(index), q_dense, use_kernel)
    return _cosine_normalize(dots, q_norm, index.sparse_norms)


def score_reconstructed(
    index: Index,
    q: SparseCodes,
    params: sae.Params,
    *,
    use_kernel: UseKernel = "auto",
) -> jax.Array:
    """Kernel-trick cosine in reconstructed space (paper §3.2, exact).

    z = K s_q computed as W_decᵀ(W_dec s_q): decode the query (k-atom gather,
    (…,d)), then one (d,)·(h,d)ᵀ matmul.  Scoring then reuses the same
    sparse-dot primitive as sparse-space retrieval.
    """
    if index.recon_norms is None:
        raise ValueError("index built without params; recon norms missing")
    x_hat_q = sae.decode(params, q)                        # (Q?, d)
    z = x_hat_q @ params["w_dec"].T                        # (Q?, h) == K s_q
    q_norm = jnp.linalg.norm(x_hat_q, axis=-1)             # ‖W_dec s_q‖
    dots = _sparse_dot(index_codes_f32(index), z, use_kernel)  # s_cᵀ K s_q
    return _cosine_normalize(dots, q_norm, index.recon_norms)


def score_dense(database: jax.Array, q: jax.Array) -> jax.Array:
    """Exact dense cosine baseline.  database (N, d), q (Q?, d)."""
    db = database / jnp.maximum(jnp.linalg.norm(database, axis=-1, keepdims=True), NORM_EPS)
    qq = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), NORM_EPS)
    return qq @ db.T if q.ndim > 1 else db @ qq


def top_n(scores: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """Exact top-n over the last axis -> (scores, candidate_ids)."""
    vals, idx = jax.lax.top_k(scores, n)
    return vals, idx


def sharded_top_n(scores_local: jax.Array, ids_local: jax.Array, n: int, *, axis_name: str):
    """Distributed exact top-n: local top-n per shard, all-gather the
    n·n_shards candidates, merge.  For use inside shard_map when the
    candidate database is sharded (serving path).

    ``ids_local`` maps local score positions to global candidate ids:
    either a 1-D (N_loc,) lookup table, or an array of the same shape as
    ``scores_local`` (pre-selected (score, id) pairs, e.g. the output of a
    per-shard streaming retrieve).  Tie semantics match a single global
    ``lax.top_k``: shards are concatenated in ascending shard order and
    each local list is score-desc / ties-id-asc, so equal scores resolve
    to the lowest global id.

    Shards may be RAGGED: a local slice narrower than ``n`` (a tiny delta
    segment next to a huge base, or an uneven final shard) is padded out
    to ``n`` with the (-inf, -1) contract before the local top-k —
    ``lax.top_k`` would otherwise reject k > width.  Padded slots can
    never win the merge over any real candidate, and surface as
    (-inf, -1) only when the merged result itself is underfull.
    """
    width = scores_local.shape[-1]
    if width < n:
        grow = n - width
        if ids_local.ndim == 1:
            ids_local = jnp.pad(ids_local, (0, grow), constant_values=-1)
        else:
            ids_local = jnp.pad(
                ids_local,
                [(0, 0)] * (ids_local.ndim - 1) + [(0, grow)],
                constant_values=-1,
            )
        scores_local = jnp.pad(
            scores_local,
            [(0, 0)] * (scores_local.ndim - 1) + [(0, grow)],
            constant_values=-jnp.inf,
        )
    lv, li = jax.lax.top_k(scores_local, n)
    if ids_local.shape == scores_local.shape:
        gid = jnp.take_along_axis(ids_local, li, axis=-1)
    else:
        gid = ids_local[li]
    av = jax.lax.all_gather(lv, axis_name, axis=-1, tiled=True)
    ai = jax.lax.all_gather(gid, axis_name, axis=-1, tiled=True)
    fv, fi = jax.lax.top_k(av, n)
    return fv, jnp.take_along_axis(ai, fi, axis=-1)
