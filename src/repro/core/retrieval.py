"""Retrieval from compressed space (paper §3.2).

Three scoring modes, all returning cosine similarities against a candidate
database stored as fixed-k SparseCodes:

1. ``score_sparse``        — similarity directly between sparse codes
                             (the paper's fast O(k) SpMV mode).
2. ``score_reconstructed`` — kernel-trick similarity in the reconstructed
                             space, cos(x̂_q, x̂_c) = s_qᵀKs_c / (‖·‖‖·‖),
                             K = W_dec W_decᵀ (paper's high-fidelity mode).
3. ``score_dense``         — exact dense baseline for evaluation.

TPU adaptation (DESIGN.md §3): both sparse modes reduce to one primitive —
a *dense query vector* dotted against fixed-k sparse candidate rows
("scatter-query SpMV").  For mode 1 the dense query is densify(s_q); for
mode 2 it is z = K s_q = W_decᵀ(W_dec s_q), computed with two thin MXU
matmuls, with candidate norms √(s_cᵀKs_c) precomputed at index-build time.
Mode 2 therefore costs the same per-candidate work as mode 1 — this is an
exact refactoring (associativity), not an approximation.

The primitive has a Pallas kernel (repro.kernels.sparse_dot) and a pure-jnp
path (used on CPU / in tests); ``use_kernel`` selects.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sae, sparse
from repro.core.types import SparseCodes


def sparse_dot_dense_query(
    codes: SparseCodes, q_dense: jax.Array, q_chunk: int = 16
) -> jax.Array:
    """scores[i] = Σ_j codes.values[i,j] · q_dense[codes.indices[i,j]].

    codes: (N, k); q_dense: (h,) or (Q, h).  Returns (N,) or (Q, N).
    Pure-jnp reference path (gather + FMA); the Pallas kernel in
    repro.kernels.sparse_dot implements the same contract blockwise in
    VMEM.  The jnp gather materializes (q_chunk, N, k) — large Q is
    processed in chunks so the transient stays bounded (the kernel never
    materializes it at all).
    """
    if q_dense.ndim == 1:
        gathered = q_dense[codes.indices]                 # (N, k)
        return jnp.sum(gathered * codes.values, axis=-1)  # (N,)
    q = q_dense.shape[0]
    if q <= q_chunk:
        gathered = q_dense[:, codes.indices]              # (Q, N, k)
        return jnp.sum(gathered * codes.values[None], axis=-1)
    pad = (-q) % q_chunk
    qp = jnp.pad(q_dense, ((0, pad), (0, 0))) if pad else q_dense
    blocks = qp.reshape(-1, q_chunk, qp.shape[-1])

    def block(qb):
        g = qb[:, codes.indices]
        return jnp.sum(g * codes.values[None], axis=-1)

    out = jax.lax.map(block, blocks).reshape(-1, codes.values.shape[0])
    return out[:q]


class SparseIndex(NamedTuple):
    """A retrieval index over a compressed candidate database.

    codes:        fixed-k sparse codes of all N candidates.
    sparse_norms: ‖s_c‖₂ per candidate (sparse-space cosine denominators).
    recon_norms:  ‖W_dec s_c‖₂ = √(s_cᵀ K s_c) per candidate (kernel trick),
                  None if the index was built without decoder weights.
    """

    codes: SparseCodes
    sparse_norms: jax.Array
    recon_norms: Optional[jax.Array]


def build_index(
    codes: SparseCodes, params: Optional[sae.Params] = None
) -> SparseIndex:
    """Precompute per-candidate norms.  recon_norms needs W_dec: ‖x̂_c‖ is the
    norm of a k-atom combination, computed by a k-row gather of W_dec —
    O(N·k·d) once at build time, never per query."""
    sparse_norms = jnp.linalg.norm(codes.values, axis=-1)
    recon_norms = None
    if params is not None:
        x_hat = sae.decode(params, codes)                 # (N, d)
        recon_norms = jnp.linalg.norm(x_hat, axis=-1)
    return SparseIndex(codes=codes, sparse_norms=sparse_norms, recon_norms=recon_norms)


def score_sparse(index: SparseIndex, q: SparseCodes) -> jax.Array:
    """Cosine similarity in the sparse compressed space.  q: (Q?, k) codes.
    Returns (N,) for a single query or (Q, N)."""
    q_dense = sparse.densify(q)                            # (Q?, h)
    q_norm = jnp.linalg.norm(q.values, axis=-1)            # (Q?,)
    dots = sparse_dot_dense_query(index.codes, q_dense)    # (Q?, N)
    denom = jnp.maximum(q_norm[..., None] * index.sparse_norms, 1e-8)
    return dots / denom if q.values.ndim > 1 else dots / jnp.maximum(q_norm * index.sparse_norms, 1e-8)


def score_reconstructed(
    index: SparseIndex, q: SparseCodes, params: sae.Params
) -> jax.Array:
    """Kernel-trick cosine in reconstructed space (paper §3.2, exact).

    z = K s_q computed as W_decᵀ(W_dec s_q): decode the query (k-atom gather,
    (…,d)), then one (d,)·(h,d)ᵀ matmul.  Scoring then reuses the same
    sparse-dot primitive as sparse-space retrieval.
    """
    if index.recon_norms is None:
        raise ValueError("index built without params; recon norms missing")
    x_hat_q = sae.decode(params, q)                        # (Q?, d)
    z = x_hat_q @ params["w_dec"].T                        # (Q?, h) == K s_q
    q_norm = jnp.linalg.norm(x_hat_q, axis=-1)             # ‖W_dec s_q‖
    dots = sparse_dot_dense_query(index.codes, z)          # s_cᵀ K s_q
    denom = jnp.maximum(q_norm[..., None] * index.recon_norms, 1e-8) \
        if q.values.ndim > 1 else jnp.maximum(q_norm * index.recon_norms, 1e-8)
    return dots / denom


def score_dense(database: jax.Array, q: jax.Array) -> jax.Array:
    """Exact dense cosine baseline.  database (N, d), q (Q?, d)."""
    db = database / jnp.maximum(jnp.linalg.norm(database, axis=-1, keepdims=True), 1e-8)
    qq = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-8)
    return qq @ db.T if q.ndim > 1 else db @ qq


def top_n(scores: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """Exact top-n over the last axis -> (scores, candidate_ids)."""
    vals, idx = jax.lax.top_k(scores, n)
    return vals, idx


def sharded_top_n(scores_local: jax.Array, ids_local: jax.Array, n: int, *, axis_name: str):
    """Distributed exact top-n: local top-n per shard, all-gather the
    n·n_shards candidates, merge.  For use inside shard_map when the
    candidate database is sharded (serving path)."""
    lv, li = jax.lax.top_k(scores_local, n)
    gid = ids_local[li]
    av = jax.lax.all_gather(lv, axis_name, axis=-1, tiled=True)
    ai = jax.lax.all_gather(gid, axis_name, axis=-1, tiled=True)
    fv, fi = jax.lax.top_k(av, n)
    return fv, jnp.take_along_axis(ai, fi, axis=-1)
