"""Compression baselines the paper compares against (§2, Fig 1 / Fig 3).

* Matryoshka-style prefix truncation — keep the first m dims.  (True
  Matryoshka retrains the backbone; on a variance-ordered corpus prefix
  truncation is its no-retrain analogue, and we additionally provide PCA.)
* PCA projection to m dims — the strongest classical no-retrain truncation.
* int8 / int4 post-training quantization (per-dim symmetric scales).

All expose bytes_per_vector() so the trade-off benchmark compares at
matched byte budgets.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- truncation
def truncate(x: jax.Array, m: int) -> jax.Array:
    """Prefix truncation to m dims (Matryoshka-style inference)."""
    return x[..., :m]


def truncation_bytes(m: int) -> int:
    return m * 4


# ----------------------------------------------------------------------- PCA
@dataclasses.dataclass(frozen=True)
class PCAModel:
    mean: jax.Array        # (d,)
    components: jax.Array  # (d, m) top-m right singular vectors


def pca_fit(x: jax.Array, m: int) -> PCAModel:
    mean = jnp.mean(x, axis=0)
    xc = x - mean
    # economy SVD; d is small (<= a few thousand)
    _, _, vt = jnp.linalg.svd(xc, full_matrices=False)
    return PCAModel(mean=mean, components=vt[:m].T)


def pca_encode(model: PCAModel, x: jax.Array) -> jax.Array:
    return (x - model.mean) @ model.components


def pca_decode(model: PCAModel, z: jax.Array) -> jax.Array:
    return z @ model.components.T + model.mean


# -------------------------------------------------------------- quantization
@dataclasses.dataclass(frozen=True)
class QuantModel:
    scale: jax.Array   # (d,) per-dim symmetric scale
    bits: int

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def quant_fit(x: jax.Array, bits: int) -> QuantModel:
    amax = jnp.max(jnp.abs(x), axis=0)
    qmax = 2 ** (bits - 1) - 1
    return QuantModel(scale=jnp.maximum(amax / qmax, 1e-12), bits=bits)


def quant_encode(model: QuantModel, x: jax.Array) -> jax.Array:
    q = jnp.round(x / model.scale)
    return jnp.clip(q, -model.qmax - 1, model.qmax).astype(jnp.int8)


def quant_decode(model: QuantModel, q: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * model.scale


def quant_bytes(d: int, bits: int) -> float:
    return d * bits / 8


# ------------------------------------------------------------------ registry
def sparse_bytes(k: int) -> int:
    """CompresSAE storage: k fp32 values + k int32 indices (paper §3.2)."""
    return 2 * k * 4
