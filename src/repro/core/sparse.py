"""Fixed-k sparse layout utilities (uniform-CSR == ELL).

The paper stores codes in CSR; with a global sparsity k every row has
exactly k nonzeros, so CSR's indptr is the arithmetic sequence 0, k, 2k, …
and carries no information.  We therefore keep (values, indices) only —
byte-identical to the paper's 2·k·4 B/row — and provide lossless CSR
import/export for interop (scipy/pgvector-style consumers).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import SparseCodes


def densify(codes: SparseCodes) -> jax.Array:
    """(..., k) sparse -> (..., h) dense. Duplicate indices sum."""
    lead = codes.values.shape[:-1]
    k = codes.values.shape[-1]

    def one_row(vals: jax.Array, idx: jax.Array) -> jax.Array:
        return jnp.zeros((codes.dim,), dtype=vals.dtype).at[idx].add(vals)

    if not lead:
        return one_row(codes.values, codes.indices)
    flat = jax.vmap(one_row)(
        codes.values.reshape(-1, k), codes.indices.reshape(-1, k)
    )
    return flat.reshape(*lead, codes.dim)


def from_dense(dense: jax.Array, k: int) -> SparseCodes:
    """Dense (N, h) with ≤k nonzeros per row -> SparseCodes (lossy if >k)."""
    from repro.core.topk import abs_topk_sparse

    vals, idx = abs_topk_sparse(dense, k)
    return SparseCodes(values=vals, indices=idx, dim=dense.shape[-1])


def to_csr(codes: SparseCodes) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Export to classic CSR (data, indices, indptr) numpy arrays.

    Rows are sorted by column index (canonical CSR).  Host-side (numpy).
    """
    vals = np.asarray(codes.values)
    idx = np.asarray(codes.indices)
    order = np.argsort(idx, axis=-1, kind="stable")
    data = np.take_along_axis(vals, order, axis=-1).reshape(-1)
    indices = np.take_along_axis(idx, order, axis=-1).reshape(-1)
    n, k = vals.shape
    indptr = np.arange(0, (n + 1) * k, k, dtype=np.int64)
    return data, indices.astype(np.int64), indptr


def from_csr(
    data: np.ndarray, indices: np.ndarray, indptr: np.ndarray, dim: int
) -> SparseCodes:
    """Import uniform-row-length CSR.  Raises if rows are ragged."""
    row_len = np.diff(indptr)
    if row_len.size == 0:
        raise ValueError("empty CSR")
    k = int(row_len[0])
    if not (row_len == k).all():
        raise ValueError("CSR is ragged; CompresSAE codes are fixed-k")
    n = row_len.size
    return SparseCodes(
        values=jnp.asarray(data, dtype=jnp.float32).reshape(n, k),
        indices=jnp.asarray(indices, dtype=jnp.int32).reshape(n, k),
        dim=dim,
    )
