"""CompresSAE model (paper §3).

    s  = φ(W_enc · x̄ + b_enc, k)          x̄ = x / ‖x‖₂        (eq. 1)
    x̂  = W_dec · s                         W_dec row-normalized  (eq. 2)

Parameters are a plain dict pytree so they shard cleanly under pjit:

    params = {
      "w_enc": (d, h),   # stored input-major: x̄ @ w_enc == W_enc x̄
      "b_enc": (h,),
      "w_dec": (h, d),   # row i is latent-i's unit-norm dictionary atom
    }

Storage convention: both matrices are stored with h on the *sharded* axis
(w_enc axis 1, w_dec axis 0) so that TP over h never splits d.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.topk import abs_topk_sparse
from repro.core.types import SAEConfig, SparseCodes

Params = Dict[str, jax.Array]


def init_params(cfg: SAEConfig, key: jax.Array) -> Params:
    """Initialize per Gao et al. practice: W_dec rows unit-norm random,
    W_enc = W_dec.T (tied at init, untied during training), b_enc = 0."""
    kd, = jax.random.split(key, 1)
    w_dec = jax.random.normal(kd, (cfg.h, cfg.d), dtype=cfg.dtype)
    w_dec = w_dec / jnp.linalg.norm(w_dec, axis=-1, keepdims=True)
    return {
        "w_enc": w_dec.T.astype(cfg.dtype),   # (d, h)
        "b_enc": jnp.zeros((cfg.h,), dtype=cfg.dtype),
        "w_dec": w_dec.astype(cfg.dtype),     # (h, d)
    }


def normalize_decoder(params: Params) -> Params:
    """Project W_dec rows back onto the unit sphere (paper: row-normalized
    decoder).  Applied after each optimizer update, the standard SAE
    constraint-projection."""
    w = params["w_dec"]
    norm = jnp.linalg.norm(w, axis=-1, keepdims=True)
    return {**params, "w_dec": w / jnp.maximum(norm, 1e-8)}


def normalize_input(x: jax.Array) -> jax.Array:
    """x̄ = x / ‖x‖₂ (paper normalizes instead of standardizing)."""
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-8)


def preactivations(params: Params, x: jax.Array) -> jax.Array:
    """W_enc x̄ + b_enc, shape (..., h)."""
    return normalize_input(x) @ params["w_enc"] + params["b_enc"]


def encode(params: Params, x: jax.Array, k: int,
           groups: int = 0) -> SparseCodes:
    """f_enc: dense (..., d) -> fixed-k SparseCodes.  groups > 0 uses the
    exact two-stage grouped top-k (shardable; DESIGN.md §3)."""
    pre = preactivations(params, x)
    if groups:
        from repro.core.topk import abs_topk_sparse_grouped

        vals, idx = abs_topk_sparse_grouped(pre, k, groups)
    else:
        vals, idx = abs_topk_sparse(pre, k)
    return SparseCodes(values=vals, indices=idx, dim=pre.shape[-1])


def encode_chunked(params: Params, x: jax.Array, k: int,
                   chunk: int = 8192, groups: int = 0) -> SparseCodes:
    """Bulk-compression encode: processes rows in chunks so the (B, h)
    pre-activations never exist at once (jnp analogue of the fused_encode
    Pallas kernel's VMEM epilogue; use for offline catalog jobs)."""
    n = x.shape[0]
    h = params["w_enc"].shape[1]
    if n <= chunk:
        return encode(params, x, k, groups)
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    blocks = xp.reshape(-1, chunk, x.shape[-1])

    def block(xb):
        c = encode(params, xb, k, groups)
        return c.values, c.indices

    vals, idx = jax.lax.map(block, blocks)
    return SparseCodes(values=vals.reshape(-1, k)[:n],
                       indices=idx.reshape(-1, k)[:n], dim=h)


def decode(params: Params, codes: SparseCodes) -> jax.Array:
    """f_dec: sparse codes -> dense reconstruction (..., d).

    x̂ = Σ_j vals_j · W_dec[idx_j] — a k-row gather of W_dec followed by a
    weighted sum; never materializes the dense (…, h) code.
    """
    atoms = params["w_dec"][codes.indices]            # (..., k, d)
    return jnp.einsum("...k,...kd->...d", codes.values, atoms)


def decode_dense(params: Params, s: jax.Array) -> jax.Array:
    """f_dec on a dense latent (training path): x̂ = s @ W_dec."""
    return s @ params["w_dec"]


def encode_dense(params: Params, x: jax.Array, k: int) -> jax.Array:
    """Dense-latent encoder (training path): φ applied, zeros kept."""
    from repro.core.topk import abs_topk

    return abs_topk(preactivations(params, x), k)


def encode_sharded(
    params: Params,
    x: jax.Array,
    k: int,
    *,
    batch_axes: tuple = ("data",),
    model_axis: str = "model",
    chunk: int = 8192,
) -> SparseCodes:
    """Distributed bulk encode via shard_map (DESIGN.md §3).

    W_enc is h-sharded over ``model_axis``; each device computes only its
    (B_loc, h/n) pre-activation slice and its local top-k; the global
    top-k then merges the n·k candidate (value, index) pairs with one tiny
    all-gather — B·n·k·8 bytes over ICI instead of all-gathering the
    (B, h) pre-activations (B·h·4 bytes), an h/(2nk) ≈ 4x collective
    reduction at h=4096, k=32, n=16.  Under plain pjit GSPMD instead
    replicates W_enc and computes the full h per device (16x redundant
    FLOPs, measured — EXPERIMENTS.md §Perf hillclimb 4).
    """
    from repro.core.topk import distributed_abs_topk_sparse

    h = params["w_enc"].shape[1]
    bspec = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]

    def local(w_enc_l, b_enc_l, x_l):
        h_loc = w_enc_l.shape[1]
        off = jax.lax.axis_index(model_axis) * h_loc

        def block(xb):
            pre = normalize_input(xb) @ w_enc_l + b_enc_l
            vals, idx = distributed_abs_topk_sparse(
                pre, k, axis_name=model_axis, shard_offset=off
            )
            return vals, idx

        n_loc = x_l.shape[0]
        if n_loc <= chunk:
            vals, idx = block(x_l)
        else:
            blocks = x_l.reshape(-1, chunk, x_l.shape[-1])
            vals, idx = jax.lax.map(block, blocks)
            vals = vals.reshape(n_loc, k)
            idx = idx.reshape(n_loc, k)
        return vals, idx

    from repro import compat
    from repro.compat import P

    vals, idx = compat.shard_map(
        local,
        in_specs=(P(None, model_axis), P(model_axis), P(bspec, None)),
        out_specs=(P(bspec, None), P(bspec, None)),
        # outputs ARE replicated over model (post-all_gather global top-k),
        # but the static varying-axes check can't prove it
        check=False,
    )(params["w_enc"], params["b_enc"], x)
    return SparseCodes(values=vals, indices=idx, dim=h)


def reconstruct(params: Params, x: jax.Array, k: int) -> jax.Array:
    """f = f_dec ∘ f_enc at sparsity k (dense-latent path, differentiable)."""
    return decode_dense(params, encode_dense(params, x, k))


def kernel_matrix(params: Params) -> jax.Array:
    """K = W_dec W_decᵀ ∈ R^{h×h} for reconstructed-space retrieval (§3.2).

    NOTE the storage convention: paper writes K = W_decᵀW_dec with
    W_dec ∈ R^{d×h}; ours is (h, d), hence the transpose flip.  K[i,j] is
    the inner product of dictionary atoms i and j either way.
    """
    return params["w_dec"] @ params["w_dec"].T


def config_like(params: Params, k: int, **kw: Any) -> SAEConfig:
    d, h = params["w_enc"].shape
    return SAEConfig(d=d, h=h, k=k, **kw)
