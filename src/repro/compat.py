"""jax-version shim for the distributed surface (ISSUE 2).

The distributed code in this repo was written against the jax >= 0.6 API
surface (``jax.shard_map``, ``jax.set_mesh``, ``jax.sharding.AxisType``,
``jax.sharding.get_abstract_mesh``, ``jax.P``); the container pins jax
0.4.37 where the same capabilities live under different names
(``jax.experimental.shard_map.shard_map`` with a mandatory ``mesh``
argument and ``check_rep``, the ``Mesh`` context manager, no axis types).
Everything distributed routes through this module so one import works on
both:

    from repro import compat
    from repro.compat import P

    mesh = compat.make_mesh((4, 2), ("data", "model"))
    with compat.set_mesh(mesh):
        out = compat.shard_map(f, in_specs=..., out_specs=...)(x)

Semantics on both jax lines:
  * ``set_mesh(mesh)`` — context manager that makes ``mesh`` the ambient
    mesh: ``shard_map`` calls without an explicit ``mesh=`` pick it up, and
    bare-``PartitionSpec`` ``with_sharding_constraint`` resolves against it
    (on 0.4.x this is the classic ``with mesh:`` context).
  * ``shard_map(f, *, mesh=None, in_specs, out_specs, check=True)`` —
    ``check`` maps to ``check_vma`` on new jax and ``check_rep`` on 0.4.x
    (both are the "outputs really are replicated as claimed" validator,
    which cannot see through ``all_gather``-based replication — pass
    ``check=False`` exactly where the old code passed ``check_vma=False``).
  * mesh resolution is deferred to *call* time, so a shard-mapped function
    can be built once and traced under whichever mesh is ambient.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable, Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "P", "HAS_NATIVE_SHARD_MAP", "make_mesh", "set_mesh", "current_mesh",
    "shard_map", "axis_size",
]

# jax >= 0.6 exposes the new spellings at top level
HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")

# the replication/varying-axes validator kwarg was renamed check_rep ->
# check_vma across jax lines; resolve whichever the native shard_map takes
_NATIVE_CHECK_KW = None
if HAS_NATIVE_SHARD_MAP:
    import inspect

    _params = inspect.signature(jax.shard_map).parameters
    for _kw in ("check_vma", "check_rep"):
        if _kw in _params:
            _NATIVE_CHECK_KW = _kw
            break

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "compat_mesh", default=None
)


def make_mesh(axis_shapes, axis_names, *, auto_axes: bool = True) -> Mesh:
    """``jax.make_mesh`` on both lines; on new jax the axes are created as
    ``AxisType.Auto`` (the 0.4.x behavior) so GSPMD propagation still runs
    outside explicit shard_map regions."""
    if _HAS_AXIS_TYPE and auto_axes:
        types = (jax.sharding.AxisType.Auto,) * len(tuple(axis_names))
        return jax.make_mesh(axis_shapes, axis_names, axis_types=types)
    return jax.make_mesh(axis_shapes, axis_names)


@contextlib.contextmanager
def set_mesh(mesh: Mesh):
    """Ambient-mesh context: ``jax.set_mesh`` on new jax, the ``Mesh``
    context manager (plus our own contextvar, for ``shard_map``/
    ``current_mesh`` resolution) on 0.4.x."""
    token = _MESH.set(mesh)
    try:
        if _HAS_SET_MESH:
            with jax.set_mesh(mesh):
                yield mesh
        else:
            with mesh:
                yield mesh
    finally:
        _MESH.reset(token)


def current_mesh() -> Optional[Mesh]:
    """The ambient mesh (``set_mesh`` context), else None.

    Replaces ``jax.sharding.get_abstract_mesh()`` call sites: callers only
    read ``.shape`` / ``.axis_names``, which agree between the physical
    mesh and its abstract view.
    """
    mesh = _MESH.get()
    if mesh is not None:
        return mesh
    if hasattr(jax.sharding, "get_abstract_mesh"):
        am = jax.sharding.get_abstract_mesh()
        if am is not None and getattr(am, "shape", None):
            return am
    # 0.4.x: a bare `with mesh:` entered outside set_mesh()
    try:
        from jax._src import mesh as mesh_lib

        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        if not env_mesh.empty:
            return env_mesh
    except Exception:
        pass
    return None


def _require_mesh(mesh: Optional[Mesh]) -> Mesh:
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        raise ValueError(
            "no mesh: pass mesh= explicitly or enter repro.compat.set_mesh(...)"
        )
    return mesh


def shard_map(
    f: Callable,
    *,
    mesh: Optional[Mesh] = None,
    in_specs: Any,
    out_specs: Any,
    check: bool = True,
) -> Callable:
    """Version-portable ``shard_map``.

    Mesh resolution happens when the returned callable is invoked, so the
    ambient ``set_mesh`` context at *trace* time wins — matching the new-jax
    behavior of ``jax.shard_map`` without an explicit mesh.
    """
    if HAS_NATIVE_SHARD_MAP:

        def call_new(*args):
            kw = dict(in_specs=in_specs, out_specs=out_specs)
            if _NATIVE_CHECK_KW is not None:
                kw[_NATIVE_CHECK_KW] = check
            if mesh is not None:
                kw["mesh"] = mesh
            return jax.shard_map(f, **kw)(*args)

        return call_new

    from jax.experimental.shard_map import shard_map as _shard_map

    def call_old(*args):
        m = _require_mesh(mesh)
        return _shard_map(
            f, mesh=m, in_specs=in_specs, out_specs=out_specs, check_rep=check
        )(*args)

    return call_old


def axis_size(name: str):
    """``jax.lax.axis_size`` where it exists; the ``psum(1, name)`` identity
    (constant-folded to the axis size) on 0.4.x."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)
