"""Dynamic microbatching serving front (ISSUE 10 tentpole).

Production retrieval traffic is thousands of concurrent single-user
requests; the kernels want BLOCK_Q-aligned query panels.
``MicrobatchServer`` is the admission layer between the two: a request
queue plus one dispatcher thread that coalesces concurrent arrivals into
bucketed panels and serves each panel with ONE call into the existing
``RetrievalEngine``/``GuardedEngine`` stack.

Semantics, in order of what matters:

* **Bit-identity.**  A request's rows ride a shared panel, padded with
  zero rows up to the smallest configured bucket that fits; responses
  are sliced back per request before the padding can leak.  Because
  every scoring path is row-independent, the sliced (scores, ids) are
  bit-identical — ties included — to a per-request ``retrieve_dense``
  call at ANY bucket size (gated by ``tests/test_batcher.py``).
* **Bounded tail latency.**  The dispatcher waits for more arrivals only
  until the OLDEST queued request is ``max_wait_us`` old, then fires a
  partial panel — a lone trickle request is never starved waiting for a
  batch that isn't coming.  A full bucket fires immediately.
* **One jit per bucket.**  Buckets are the only panel shapes the engine
  ever sees (requests wider than the largest bucket are rejected at
  submit as ``InvalidQueryError``), so the engine's per-``n`` jit
  retraces exactly ``len(buckets)`` times and steady state is a cache
  hit regardless of arrival pattern.  ``warmup(n)`` pre-compiles all of
  them before traffic.
* **Typed overload shedding.**  ``submit`` raises ``QueueFullError``
  (never blocks, never buffers unboundedly) once ``max_queue_rows`` rows
  are already queued.  A shed-then-retried request flows through the
  normal path and still carries its ``ServingStatus``.
* **The unified response.**  Every request resolves to the same
  ``RetrievalResponse`` the engine and guard return, with ``queue_us``
  (submit → dispatch) and ``compute_us`` (the blocked panel round-trip,
  shared by the panel's requests) filled in and the underlying layer's
  ``ServingStatus`` passed through — batching is invisible to the
  response surface.

Requests with different ``n`` never share a panel (the top-n width is a
compile-time constant of the serve jit); the queue stays FIFO per ``n``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.errors import EngineConfigError, InvalidQueryError, QueueFullError
from repro.kernels.sparse_dot.kernel import BLOCK_Q
from repro.serving.response import RetrievalResponse

DEFAULT_BUCKETS = (BLOCK_Q, 2 * BLOCK_Q, 4 * BLOCK_Q, 8 * BLOCK_Q)


class _Request:
    """One queued submission: rows + bookkeeping + the caller's future."""

    __slots__ = ("x", "n", "rows", "squeeze", "t_submit", "future")

    def __init__(self, x, n: int, rows: int, squeeze: bool):
        self.x = x
        self.n = n
        self.rows = rows
        self.squeeze = squeeze
        self.t_submit = time.monotonic()
        self.future: Future = Future()


class MicrobatchServer:
    """Coalesce concurrent ``retrieve_dense`` submissions into
    BLOCK_Q-aligned panels served by one underlying engine.

    engine:        a ``RetrievalEngine`` or ``GuardedEngine`` — anything
                   whose ``retrieve_dense(x, n)`` returns a
                   ``RetrievalResponse``.
    buckets:       ascending panel sizes, each a BLOCK_Q multiple; a
                   panel pads to the smallest bucket that fits its rows.
    max_wait_us:   how long the oldest queued request may age before a
                   partial panel fires (the trickle-latency bound).
    max_queue_rows: admission bound — ``submit`` sheds with a typed
                   ``QueueFullError`` once this many rows are queued.
    """

    def __init__(
        self,
        engine,
        *,
        buckets: Optional[Sequence[int]] = None,
        max_wait_us: float = 2000.0,
        max_queue_rows: int = 256,
    ):
        buckets = tuple(int(b) for b in (buckets or DEFAULT_BUCKETS))
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise EngineConfigError(
                f"buckets must be ascending and distinct: {buckets}"
            )
        bad = [b for b in buckets if b < 1 or b % BLOCK_Q]
        if bad:
            raise EngineConfigError(
                f"buckets must be positive multiples of BLOCK_Q="
                f"{BLOCK_Q}: {bad}"
            )
        if max_wait_us < 0:
            raise EngineConfigError(
                f"max_wait_us must be >= 0, got {max_wait_us}"
            )
        if max_queue_rows < buckets[-1]:
            raise EngineConfigError(
                f"max_queue_rows ({max_queue_rows}) must fit at least one "
                f"largest-bucket panel ({buckets[-1]} rows)"
            )
        self.engine = engine
        self.buckets = buckets
        self.max_wait_us = float(max_wait_us)
        self.max_queue_rows = int(max_queue_rows)
        self._queue: deque[_Request] = deque()
        self._queued_rows = 0
        self._cond = threading.Condition()
        self._closed = False
        self._stats = {
            "requests": 0, "rows": 0, "shed": 0, "panels": 0,
            "padded_rows": 0, "occupancy_sum": 0.0,
            "panels_by_bucket": {b: 0 for b in buckets},
        }
        self._dispatcher = threading.Thread(
            target=self._loop, name="microbatch-dispatcher", daemon=True
        )
        self._dispatcher.start()

    # ----------------------------------------------------------- lifecycle
    def __enter__(self) -> "MicrobatchServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Stop accepting work, drain what is queued, join the
        dispatcher.  Idempotent."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._dispatcher.join()

    # ------------------------------------------------------------- serving
    def submit(self, x, n: int) -> Future:
        """Enqueue one request; returns a ``Future`` resolving to its
        ``RetrievalResponse`` (or raising the engine's typed error).

        Raises ``QueueFullError`` immediately when ``max_queue_rows``
        rows are already queued (overload shedding — never blocks the
        caller), and ``InvalidQueryError`` for malformed queries, so bad
        or shed requests never occupy panel slots.
        """
        x = jnp.asarray(x) if isinstance(x, (list, np.ndarray)) else x
        if not hasattr(x, "ndim") or x.ndim not in (1, 2):
            raise InvalidQueryError(
                "x: expected a (d,) query or a (q, d) batch, got "
                f"{type(x).__name__}"
                + (f" of rank {x.ndim}" if hasattr(x, "ndim") else "")
            )
        squeeze = x.ndim == 1
        rows = 1 if squeeze else int(x.shape[0])
        if rows == 0:
            raise InvalidQueryError("x: empty query batch (0 rows)")
        if rows > self.buckets[-1]:
            raise InvalidQueryError(
                f"x: {rows} query rows exceed the largest panel bucket "
                f"({self.buckets[-1]}) — split the batch or configure "
                "larger buckets"
            )
        req = _Request(x[None] if squeeze else x, int(n), rows, squeeze)
        with self._cond:
            if self._closed:
                raise EngineConfigError("MicrobatchServer is closed")
            if self._queued_rows + rows > self.max_queue_rows:
                self._stats["shed"] += 1
                raise QueueFullError(
                    f"queue full: {self._queued_rows} rows queued + "
                    f"{rows} submitted > max_queue_rows="
                    f"{self.max_queue_rows}; request shed",
                    queued_rows=self._queued_rows,
                    max_queue_rows=self.max_queue_rows,
                )
            self._queue.append(req)
            self._queued_rows += rows
            self._stats["requests"] += 1
            self._stats["rows"] += rows
            self._cond.notify_all()
        return req.future

    def serve(self, x, n: int, timeout: Optional[float] = None
              ) -> RetrievalResponse:
        """Synchronous convenience: ``submit`` + wait."""
        return self.submit(x, n).result(timeout=timeout)

    def warmup(self, n: int) -> None:
        """Pre-compile the serve jit at every bucket size for top-``n``
        (zero panels through the real path), so first-traffic latency is
        a cache hit, not a trace."""
        core = getattr(self.engine, "engine", self.engine)  # unwrap guard
        d = core.params["w_enc"].shape[0]
        for b in self.buckets:
            resp = self.engine.retrieve_dense(jnp.zeros((b, d)), n)
            jax.block_until_ready(resp.ids)

    def stats(self) -> dict:
        """A consistent snapshot of the serving counters, with the mean
        panel occupancy (real rows / bucket rows) derived."""
        with self._cond:
            s = dict(self._stats)
            s["panels_by_bucket"] = dict(self._stats["panels_by_bucket"])
        s["occupancy_mean"] = (
            s.pop("occupancy_sum") / s["panels"] if s["panels"] else 0.0
        )
        return s

    # ---------------------------------------------------------- dispatcher
    def _rows_ready(self, n: int) -> int:
        """Rows queued for panels of top-``n`` (lock held)."""
        return sum(r.rows for r in self._queue if r.n == n)

    def _drain(self, n: int) -> list[_Request]:
        """Pop the FIFO prefix of ``n``-compatible requests that fits the
        largest bucket (lock held).  Requests for other ``n`` keep their
        queue positions."""
        batch, taken, keep = [], 0, deque()
        cap = self.buckets[-1]
        while self._queue:
            req = self._queue.popleft()
            if req.n == n and taken + req.rows <= cap:
                batch.append(req)
                taken += req.rows
            else:
                keep.append(req)
        self._queue = keep
        self._queued_rows -= taken
        return batch

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closed:
                    self._cond.wait()
                if not self._queue and self._closed:
                    return
                head = self._queue[0]
                deadline = head.t_submit + self.max_wait_us * 1e-6
                # coalesce until the largest bucket fills or the oldest
                # request has waited its bound (close drains immediately)
                while (not self._closed
                       and self._rows_ready(head.n) < self.buckets[-1]):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0.0:
                        break
                    self._cond.wait(timeout=remaining)
                batch = self._drain(head.n)
            if batch:
                self._dispatch(batch)

    def _dispatch(self, batch: list[_Request]) -> None:
        """Serve one coalesced panel and slice the responses back out."""
        t_dispatch = time.monotonic()
        rows = sum(r.rows for r in batch)
        bucket = next(b for b in self.buckets if b >= rows)
        try:
            panel = jnp.concatenate([r.x for r in batch], axis=0)
            if bucket > rows:
                # zero padding rows: scored and discarded — they can
                # never appear in any request's slice below
                panel = jnp.concatenate(
                    [panel, jnp.zeros((bucket - rows, panel.shape[1]),
                                      dtype=panel.dtype)], axis=0
                )
            resp = self.engine.retrieve_dense(panel, batch[0].n)
            jax.block_until_ready(resp.ids)
        except BaseException as err:  # noqa: BLE001 — the caller's error
            for r in batch:
                r.future.set_exception(err)
            return
        t_done = time.monotonic()
        with self._cond:
            self._stats["panels"] += 1
            self._stats["panels_by_bucket"][bucket] += 1
            self._stats["padded_rows"] += bucket - rows
            self._stats["occupancy_sum"] += rows / bucket
        compute_us = (t_done - t_dispatch) * 1e6
        off = 0
        for r in batch:
            s = resp.scores[off:off + r.rows]
            i = resp.ids[off:off + r.rows]
            off += r.rows
            if r.squeeze:
                s, i = s[0], i[0]
            r.future.set_result(RetrievalResponse(
                scores=s, ids=i, status=resp.status,
                queue_us=(t_dispatch - r.t_submit) * 1e6,
                compute_us=compute_us,
            ))
