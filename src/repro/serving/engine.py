"""End-to-end dense-query serving engine (ISSUE 3 tentpole).

``RetrievalEngine`` is the serving API as an object with a lifecycle: it
owns ``(params, index, mode, use_kernel, mesh)`` at construction and
exposes

    engine.retrieve_dense(x, n)   # dense embeddings in, RetrievalResponse out

with **no SparseCodes→dense-query round-trip through HBM**.  On the TPU
kernel path a request flows

    fused_encode  →  fused_retrieve_sparse_q

so only the (Q, k) query codes and the (Q, n) results ever touch HBM: the
encoder's abs-top-k epilogue stays in VMEM (no (B, h) pre-activations) and
the retrieval kernel densifies the query panel into VMEM scratch instead
of reading a dense (Q, h) matrix.  The chunked-jnp path mirrors the same
contract on CPU (``sae.encode`` + ``retrieve_sparse_q_ref``) and is
bit-identical to the composed ``encode()`` + ``retrieve()`` pipeline.

The per-request data flow is factored into two functional pieces that the
older call-sites (``core.retrieval.retrieve``,
``distributed.retrieve.distributed_retrieve``) now wrap:

``prep_query(index, q, mode, params)``
    -> ``PreppedQuery``: the mode's query representation + ‖q‖.  Sparse
    mode keeps the (Q, k) codes as-is; reconstructed mode folds the
    kernel-trick query z = W_decᵀ(W_dec s_q) (dense by construction) into
    the prep, with ‖q‖ = ‖W_dec s_q‖.

``retrieve_prepped(index, pq, n, use_fused=...)``
    single-device streaming score+select over either representation.

Distributed serving replicates the *prepped* query into the
candidate-sharded shard_map (``distributed.retrieve
.distributed_retrieve_prepped``) — for sparse mode that is the (Q, k)
codes, an h/(2k)× smaller replication payload than the dense panel the
previous generation broadcast.
"""
from __future__ import annotations

import time
import warnings
from collections.abc import Mapping
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sae
from repro.core.quantized_codes import QuantizedCodes
from repro.core.retrieval import (
    NORM_EPS, index_codes_f32, kernel_path, two_stage_retrieve,
)
from repro.core.segments import SegmentedIndex
from repro.core.types import SparseCodes
from repro.errors import EngineConfigError, InvalidQueryError
from repro.kernels.fused_encode import fused_encode
from repro.kernels.sparse_dot.kernel import BLOCK_Q
from repro.serving.config import (  # noqa: F401 — re-exported API
    PRECISIONS,
    EngineConfig,
    check_precision,
)
from repro.serving.response import RetrievalResponse, ServingStatus
from repro.kernels.sparse_dot import (
    fused_retrieve,
    fused_retrieve_gathered_quantized_mxu_sparse_q,
    fused_retrieve_gathered_quantized_sparse_q,
    fused_retrieve_gathered_sparse_q,
    fused_retrieve_quantized,
    fused_retrieve_quantized_mxu,
    fused_retrieve_quantized_mxu_sparse_q,
    fused_retrieve_quantized_sparse_q,
    fused_retrieve_sparse_q,
    retrieve_gathered_quantized_mxu_sparse_q_ref,
    retrieve_gathered_quantized_sparse_q_ref,
    retrieve_gathered_sparse_q_ref,
    retrieve_quantized_mxu_ref,
    retrieve_quantized_mxu_sparse_q_ref,
    retrieve_quantized_ref,
    retrieve_quantized_sparse_q_ref,
    retrieve_ref,
    retrieve_sparse_q_ref,
)

def resolve_stage1(stage1: str) -> str:
    """The stage-1 implementation a ``stage1`` knob actually runs
    ("auto" resolves to the device union)."""
    return "device" if stage1 == "auto" else stage1


def path_name(engine: "RetrievalEngine") -> str:
    """The canonical serving-path name of an engine's configuration —
    what a healthy ``ServingStatus.path`` reports and what the guard
    ladder's rung names are built from."""
    quantized = isinstance(engine.index.codes, QuantizedCodes)
    fmt = ("int8" if engine.precision == "int8"
           else "quantized" if quantized else "fp32")
    backend = "kernel" if engine.use_fused else "ref"
    sharded = "-sharded" if engine.mesh is not None else ""
    prefix = (f"two-stage-{resolve_stage1(engine.stage1)}-"
              if engine.stage == "two_stage" else "")
    return f"{prefix}{fmt}-{backend}{sharded}"


def validate_topn(n, n_candidates: int) -> int:
    """Admission check for the ``n`` of a top-n request (typed, named)."""
    if isinstance(n, bool) or not isinstance(n, (int, np.integer)):
        raise InvalidQueryError(
            f"n: expected a Python int, got {type(n).__name__} ({n!r})"
        )
    if n < 1:
        raise InvalidQueryError(f"n: top-n must be >= 1, got {n}")
    if n > n_candidates:
        raise InvalidQueryError(
            f"n: top-n {n} exceeds candidate count {n_candidates}"
        )
    return int(n)


def validate_dense_query(
    x, *, d: Optional[int] = None, name: str = "x"
):
    """Trace-safe admission checks for a dense query batch: array-ness,
    rank, embedding dim, floating dtype.  Every failure is an
    ``InvalidQueryError`` naming the offending argument and the expected
    vs actual shape/dtype.  Value checks (finiteness) are the guard
    layer's job — they need concrete bytes and never belong under jit.
    """
    if not hasattr(x, "shape") or not hasattr(x, "dtype"):
        raise InvalidQueryError(
            f"{name}: expected an array of dense embeddings, got "
            f"{type(x).__name__}"
        )
    if x.ndim not in (1, 2):
        raise InvalidQueryError(
            f"{name}: expected shape (d,) or (Q, d), got rank-{x.ndim} "
            f"shape {tuple(x.shape)}"
        )
    if d is not None and x.shape[-1] != d:
        raise InvalidQueryError(
            f"{name}: embedding dim mismatch — expected last axis {d} "
            f"(the SAE input dim), got {x.shape[-1]} "
            f"(shape {tuple(x.shape)})"
        )
    if not jnp.issubdtype(x.dtype, jnp.floating):
        raise InvalidQueryError(
            f"{name}: expected a floating dtype, got {x.dtype}"
        )
    return x


def validate_query_codes(
    q: SparseCodes, *, h: int, name: str = "q"
) -> SparseCodes:
    """Trace-safe admission checks for query ``SparseCodes``: matching
    values/indices shapes, integer indices, code dim == index dim."""
    if tuple(q.values.shape) != tuple(q.indices.shape):
        raise InvalidQueryError(
            f"{name}: values shape {tuple(q.values.shape)} != indices "
            f"shape {tuple(q.indices.shape)} (fixed-k codes must pair "
            "one index per value)"
        )
    if q.values.ndim not in (1, 2):
        raise InvalidQueryError(
            f"{name}: expected code shape (k,) or (Q, k), got rank-"
            f"{q.values.ndim} shape {tuple(q.values.shape)}"
        )
    if not jnp.issubdtype(q.indices.dtype, jnp.integer):
        raise InvalidQueryError(
            f"{name}: indices must be an integer dtype, got "
            f"{q.indices.dtype}"
        )
    # dim rides the SparseCodes pytree as a leaf, so a jit'd producer
    # (fused_encode) hands it back traced — the check only applies where
    # dim is still concrete (every external entry point)
    try:
        dim = int(q.dim)
    except jax.errors.ConcretizationTypeError:
        dim = h
    if dim != h:
        raise InvalidQueryError(
            f"{name}: code dim mismatch — query codes address a "
            f"{dim}-wide latent space, index stores {h}"
        )
    return q


class PreppedQuery(NamedTuple):
    """A query batch in the representation its retrieval mode scores with.

    Exactly one of (``values`` + ``indices``) or ``dense`` is set:
    sparse mode carries the (Q?, k) codes straight through (the sparse-query
    kernel densifies in VMEM); reconstructed mode carries the dense
    z = W_decᵀ(W_dec s_q) — dense by construction, same shape economics as
    the kernel-trick identity.  ``norm`` is the per-query cosine
    denominator ‖q‖ (sparse: ‖s_q‖; reconstructed: ‖W_dec s_q‖).
    """

    values: Optional[jax.Array]
    indices: Optional[jax.Array]
    dense: Optional[jax.Array]
    norm: jax.Array

    @property
    def is_sparse(self) -> bool:
        return self.values is not None


def mode_inv_norms(index, mode: str) -> jax.Array:
    """The index's reciprocal candidate norms for a scoring mode."""
    if mode == "sparse":
        inv = index.inv_sparse_norms
        if inv is None:
            inv = 1.0 / jnp.maximum(index.sparse_norms, NORM_EPS)
        return inv
    if mode == "reconstructed":
        if index.recon_norms is None:
            raise EngineConfigError(
                "index built without params; recon norms missing"
            )
        inv = index.inv_recon_norms
        if inv is None:
            inv = 1.0 / jnp.maximum(index.recon_norms, NORM_EPS)
        return inv
    raise EngineConfigError(f"unknown retrieval mode: {mode!r}")


def prep_query(
    index,
    q: SparseCodes,
    mode: str,
    params: Optional[sae.Params] = None,
) -> PreppedQuery:
    """Query codes -> the mode's scoring representation (see module doc)."""
    if mode == "sparse":
        return PreppedQuery(
            values=q.values, indices=q.indices, dense=None,
            norm=jnp.linalg.norm(q.values, axis=-1),
        )
    if mode == "reconstructed":
        if params is None:
            raise EngineConfigError("mode='reconstructed' requires SAE params")
        x_hat_q = sae.decode(params, q)                    # (Q?, d)
        z = x_hat_q @ params["w_dec"].T                    # (Q?, h) == K s_q
        return PreppedQuery(
            values=None, indices=None, dense=z,
            norm=jnp.linalg.norm(x_hat_q, axis=-1),
        )
    raise EngineConfigError(f"unknown retrieval mode: {mode!r}")


def select_retrieve_fn(
    *, sparse_query: bool, quantized: bool, int8_scoring: bool,
    use_fused: bool,
):
    """THE kernel-generation dispatch table, in one place.

    Maps (query representation, index format, scoring precision, backend)
    to the streaming retrieve callable.  ``retrieve_prepped``, the
    distributed shard body, and the partial-merge recovery path all select
    through here, so the three serving paths cannot drift onto different
    generations for the same configuration.
    """
    if int8_scoring:
        if sparse_query:
            return (fused_retrieve_quantized_mxu_sparse_q if use_fused
                    else retrieve_quantized_mxu_sparse_q_ref)
        return (fused_retrieve_quantized_mxu if use_fused
                else retrieve_quantized_mxu_ref)
    if quantized:
        if sparse_query:
            return (fused_retrieve_quantized_sparse_q if use_fused
                    else retrieve_quantized_sparse_q_ref)
        return fused_retrieve_quantized if use_fused else retrieve_quantized_ref
    if sparse_query:
        return fused_retrieve_sparse_q if use_fused else retrieve_sparse_q_ref
    return fused_retrieve if use_fused else retrieve_ref


def select_gathered_retrieve_fn(
    *, quantized: bool, int8_scoring: bool, use_fused: bool,
):
    """Generation-6 dispatch: the gather-aware re-rank for batched
    two-stage stage 2.  Candidate arrays carry a leading query axis
    ((Q, B, k) panels, (Q, B) norms/scales) and ids come back as LOCAL
    panel positions.  Always sparse-query — two-stage retrieval is
    sparse-mode only — so the table is the sparse-q column of
    ``select_retrieve_fn`` with the gathered twins substituted.  Kept
    beside it so the two tables cannot drift."""
    if int8_scoring:
        return (fused_retrieve_gathered_quantized_mxu_sparse_q if use_fused
                else retrieve_gathered_quantized_mxu_sparse_q_ref)
    if quantized:
        return (fused_retrieve_gathered_quantized_sparse_q if use_fused
                else retrieve_gathered_quantized_sparse_q_ref)
    return (fused_retrieve_gathered_sparse_q if use_fused
            else retrieve_gathered_sparse_q_ref)


def retrieve_prepped(
    index,
    pq: PreppedQuery,
    n: int,
    *,
    use_fused: bool,
    inv_norms: Optional[jax.Array] = None,
    precision: str = "exact",
) -> tuple[jax.Array, jax.Array]:
    """Single-device streaming score+select over a prepped query batch.

    Dispatches the sparse-query kernel/ref pair when ``pq`` carries codes,
    the dense-query pair when it carries z; folds ‖q‖ on the (Q, n) panel
    only.  Bit-identical to densifying first (the kernels guarantee it).
    The candidate inv norms default to the mode the prepped representation
    implies (codes → sparse-space, dense z → reconstructed-space).

    A ``QuantizedIndex`` routes to the quantized kernel/ref generation:
    the candidate side streams int8/int16 + per-row scales and dequantizes
    in VMEM (kernel) or per block (ref) — bit-identical to serving the
    dequantized index, with the index never materialized in fp32.

    ``precision="int8"`` (QuantizedIndex only) instead routes to
    generation 5: candidate tiles are scored in int8 (query panel
    quantized in VMEM, int32 accumulation, one f32 rescale in the merge)
    — an APPROXIMATE path whose quality vs ``"exact"`` is measured by
    ``repro.core.eval`` and gated on recall, not bit-identity.  Kernel
    and ref remain bit-identical to each other on this path too.
    """
    check_precision(index, precision)
    if inv_norms is None:
        inv_norms = mode_inv_norms(
            index, "sparse" if pq.is_sparse else "reconstructed"
        )
    squeeze = pq.norm.ndim == 0
    quantized = isinstance(index.codes, QuantizedCodes)
    int8_scoring = precision == "int8"
    if quantized:
        cand = (index.codes.q_values, index.codes.indices, index.codes.scales)
    else:
        cand = (index.codes.values, index.codes.indices)
    fn = select_retrieve_fn(
        sparse_query=pq.is_sparse, quantized=quantized,
        int8_scoring=int8_scoring, use_fused=use_fused,
    )
    if pq.is_sparse:
        qv = pq.values[None] if squeeze else pq.values
        qi = pq.indices[None] if squeeze else pq.indices
        vals, ids = fn(*cand, inv_norms, qv, qi, index.codes.dim, n=n)
    else:
        qd = pq.dense[None] if squeeze else pq.dense
        vals, ids = fn(*cand, inv_norms, qd, n=n)
    norm = pq.norm[None] if squeeze else pq.norm
    scores = vals / jnp.maximum(norm[..., None], NORM_EPS)
    if squeeze:
        scores, ids = scores[0], ids[0]
    return scores, ids


_LEGACY_ENGINE_KWARGS = frozenset((
    "mode", "use_kernel", "mesh", "shard_axis", "k", "precision",
    "stage", "stage1", "candidate_fraction", "inverted_cap",
))


def _looks_like_index(obj) -> bool:
    return isinstance(obj, SegmentedIndex) or hasattr(obj, "codes")


def _looks_like_params(obj) -> bool:
    return isinstance(obj, Mapping) and "w_enc" in obj


def _normalize_ctor_order(index, params):
    """Accept both ``RetrievalEngine(index, params)`` (primary) and the
    legacy ``RetrievalEngine(params, index)`` order.  The two argument
    kinds are structurally unambiguous — an index carries ``.codes`` (or
    is a ``SegmentedIndex``), params are a mapping with ``"w_enc"`` — so
    detection is type-based, and the legacy order earns a
    ``DeprecationWarning``."""
    if _looks_like_index(index) and (params is None
                                     or _looks_like_params(params)):
        return index, params
    if _looks_like_params(index) or _looks_like_index(params):
        warnings.warn(
            "RetrievalEngine(params, index) argument order is deprecated; "
            "use RetrievalEngine(index, params, config=...)",
            DeprecationWarning, stacklevel=3,
        )
        return params, index
    raise EngineConfigError(
        "RetrievalEngine(index, params): could not identify an index "
        f"(needs .codes or SegmentedIndex) in ({type(index).__name__}, "
        f"{type(params).__name__})"
    )


class RetrievalEngine:
    """One object owns the serving lifecycle: index, params, and one
    ``EngineConfig`` naming every knob (mode, backend, precision, staging,
    mesh).  Construct once — ``RetrievalEngine(index, params,
    config=EngineConfig(...))`` — then serve ``retrieve_dense(x, n)``: raw
    dense embeddings in, a ``RetrievalResponse`` (top-n cosine scores,
    candidate ids, ``ServingStatus``, latency split) out.  The legacy
    ``RetrievalEngine(params, index, mode=..., ...)`` spelling still
    works through a shim that emits ``DeprecationWarning``.

    ``use_kernel``: "auto" (fused Pallas chain on TPU, chunked jnp
    elsewhere) | True | False — same switch as ``core.retrieve``.
    ``index``: a ``SparseIndex`` or a ``QuantizedIndex``
    (``build_index(..., quantize=True)``) — the quantized format is served
    AS-IS: its int8/int16 arrays are what lives in HBM (and what a mesh
    shards), dequantized tile-by-tile in VMEM by the quantized kernel
    generation, bit-identical to serving the dequantized index.
    ``mesh``: a mesh with a ``shard_axis`` axis routes every request
    through candidate-sharded distributed retrieval, with the prepped
    query replicated (for sparse mode: just the (Q, k) codes).
    ``precision``: ``"exact"`` (default; bit-identical to the fp32 path)
    or ``"int8"`` (generation 5's approximate int8-scoring fast path —
    QuantizedIndex only, quality gated on recall via ``repro.core.eval``).
    ``stage``: ``"single"`` (default; every request scores the full
    catalog) or ``"two_stage"`` — stage 1 unions the query's posting
    lists from an inverted index built at engine construction into a
    bounded candidate set (``candidate_fraction`` of the catalog,
    posting lists capped at ``inverted_cap``), stage 2 gathers every
    query's candidate panel in one batched device gather and runs ONE
    gather-aware fused re-rank over the whole (Q, budget) panel
    (``core.retrieval.two_stage_retrieve``, generation-6 kernels).
    Sub-linear in catalog size and APPROXIMATE (recall-gated in
    benchmarks); sparse mode, unsharded only — sharding composes with
    single-stage instead.
    ``stage1``: ``"auto"``/``"device"`` (default; the batched jitted
    ``device_candidate_union`` — no per-query host work) or ``"host"``
    (the numpy ``candidate_union`` parity oracle — bit-identical rows,
    and the guard ladder's fallback between device two-stage and
    single-stage).

    ``retrieve_dense`` jit-compiles the whole request (encode → score →
    select) once per distinct ``n`` and caches the executable, so steady
    -state serving is a single dispatch.  (Two-stage requests compile
    two cached jits — encode and the batched stage-2 re-rank — with
    the candidate union between them.)
    """

    def __init__(self, index=None, params: Optional[sae.Params] = None,
                 *, config: Optional[EngineConfig] = None, **legacy):
        index, params = _normalize_ctor_order(index, params)
        if legacy:
            unknown = set(legacy) - _LEGACY_ENGINE_KWARGS
            if unknown:
                raise TypeError(
                    "RetrievalEngine got unexpected keyword argument(s) "
                    f"{sorted(unknown)}"
                )
            if config is not None:
                raise EngineConfigError(
                    "pass either config=EngineConfig(...) or the legacy "
                    f"keyword knobs {sorted(legacy)}, not both"
                )
            warnings.warn(
                "RetrievalEngine(..., mode=/use_kernel=/...) keyword knobs "
                "are deprecated; pass config=EngineConfig(...) instead",
                DeprecationWarning, stacklevel=2,
            )
            config = EngineConfig(**legacy)
        cfg = EngineConfig() if config is None else config
        cfg.validate(index, params)

        self.config = cfg
        self.segments: Optional[SegmentedIndex] = None
        if isinstance(index, SegmentedIndex):
            self.segments = index
            index = index.base
        self.params = params
        self.index = index
        self.mode = cfg.mode
        self.use_kernel = cfg.use_kernel
        self.use_fused = kernel_path(cfg.use_kernel)
        self.mesh = cfg.mesh
        self.shard_axis = cfg.shard_axis
        self.k = index.codes.k if cfg.k is None else cfg.k
        self.precision = cfg.precision
        self.stage = cfg.stage
        self.stage1 = cfg.stage1
        self.candidate_fraction = cfg.candidate_fraction
        self.inverted_cap = cfg.inverted_cap
        self._inv_norms = mode_inv_norms(index, cfg.mode)
        self._serve_cache: dict[int, callable] = {}
        self.inverted = None
        if cfg.stage == "two_stage":
            from repro.core.inverted_index import build_inverted_index

            self.inverted = build_inverted_index(
                index_codes_f32(index), cap=cfg.inverted_cap
            )
            self._two_stage_cache: dict = {}

    # ------------------------------------------------------------- mutation
    def apply_update(self, op: str, *, codes=None, ids=None):
        """Apply one catalog mutation to a segmented engine, atomically.

        ``op``: ``"add"`` (requires ``codes`` — fp32 (m, k) SparseCodes —
        and ``ids``), ``"delete"`` (requires ``ids``), or ``"compact"``.
        The lifecycle ops are functional, so the engine swaps to the new
        ``SegmentedIndex`` only after the op succeeded — a rejected
        mutation (``SegmentMutationError``) leaves serving untouched.
        Returns the new ``SegmentedIndex``.

        No jit cache is invalidated: the serving path deliberately never
        bakes segment arrays into a per-engine jit (see
        ``retrieve_dense``), and the module-level retrieve jits key on
        array shapes — an add/compact that changes the delta shape
        retraces exactly those, a delete (same shapes, new mask) reuses
        everything.
        """
        if self.segments is None:
            raise EngineConfigError(
                "apply_update requires an engine constructed over a "
                "SegmentedIndex (core.segments); this engine serves an "
                f"immutable {type(self.index).__name__}"
            )
        if op == "add":
            if codes is None or ids is None:
                raise EngineConfigError("op='add' requires codes and ids")
            seg = self.segments.add_items(codes, ids)
        elif op == "delete":
            if ids is None:
                raise EngineConfigError("op='delete' requires ids")
            seg = self.segments.delete_items(ids)
        elif op == "compact":
            seg = self.segments.compact()
        else:
            raise EngineConfigError(
                f"unknown update op {op!r} "
                "(expected 'add', 'delete' or 'compact')"
            )
        self.segments = seg
        self.index = seg.base
        self._inv_norms = mode_inv_norms(seg.base, self.mode)
        return seg

    # ---------------------------------------------------------- request flow
    def encode_queries(self, x: jax.Array) -> SparseCodes:
        """Dense (Q?, d) embeddings -> fixed-k query codes.  Kernel path:
        ``fused_encode`` (abs-top-k epilogue in VMEM, no (Q, h)
        pre-activations in HBM); jnp path: ``sae.encode``."""
        if self.params is None:
            raise EngineConfigError("encoding queries requires SAE params")
        if self.use_fused:
            return fused_encode(
                x, self.params["w_enc"], self.params["b_enc"], self.k
            )
        return sae.encode(self.params, x, self.k)

    def prep_query(self, q: SparseCodes) -> PreppedQuery:
        return prep_query(self.index, q, self.mode, self.params)

    def retrieve_codes(
        self, q: SparseCodes, n: int
    ) -> tuple[jax.Array, jax.Array]:
        """Serve a request whose queries are already compressed codes."""
        if self.segments is not None:
            n = validate_topn(n, self.segments.n_rows)
            validate_query_codes(q, h=self.index.codes.dim)
            return self.segments.retrieve(
                q, n, use_fused=self.use_fused, precision=self.precision
            )
        n = validate_topn(n, self.index.codes.n)
        validate_query_codes(q, h=self.index.codes.dim)
        if self.stage == "two_stage":
            return two_stage_retrieve(
                self.index, self.inverted, q, n,
                use_fused=self.use_fused, precision=self.precision,
                candidate_fraction=self.candidate_fraction,
                cache=self._two_stage_cache, stage1=self.stage1,
            )
        pq = self.prep_query(q)
        if self.mesh is not None:
            from repro.distributed.retrieve import distributed_retrieve_prepped

            return distributed_retrieve_prepped(
                self.index, pq, n,
                mesh=self.mesh, axis_name=self.shard_axis,
                use_fused=self.use_fused, inv_norms=self._inv_norms,
                precision=self.precision,
            )
        return retrieve_prepped(
            self.index, pq, n,
            use_fused=self.use_fused, inv_norms=self._inv_norms,
            precision=self.precision,
        )

    def retrieve_dense(self, x: jax.Array, n: int) -> RetrievalResponse:
        """The end-to-end request: dense embeddings in, a
        ``RetrievalResponse`` out — one jit per distinct ``n``.

        ``resp.scores``/``resp.ids`` (equivalently ``resp[:2]``) are
        exactly the panels the tuple-era API returned.  The stamped
        ``ServingStatus`` is the healthy configured path (step 0, not
        degraded) — the guard layer replaces it with what actually
        happened when serving degrades.  ``compute_us`` records host
        dispatch time; device completion stays the caller's
        ``block_until_ready``, as before.
        """
        t0 = time.monotonic()
        d = None if self.params is None else self.params["w_enc"].shape[0]
        validate_dense_query(x, d=d)
        validate_topn(
            n,
            self.index.codes.n if self.segments is None
            else self.segments.n_rows,
        )
        squeeze = x.ndim == 1
        xb = x[None] if squeeze else x
        # Shape-stable serve path: every panel the jit sees is padded to
        # a BLOCK_Q multiple with zero rows (scored and sliced off), so
        # a lone request and a coalesced microbatch panel of the same
        # bucket compile and compute IDENTICALLY — the bit-identity the
        # batcher promises is structural, not an XLA accident — and
        # per-request traffic of varied widths retraces once per bucket,
        # not once per width.
        rows = int(xb.shape[0])
        pad = (-rows) % BLOCK_Q
        if pad:
            xb = jnp.concatenate(
                [xb, jnp.zeros((pad, xb.shape[1]), dtype=xb.dtype)],
                axis=0,
            )
        if self.segments is not None or self.stage == "two_stage":
            # segment content mutates between requests, and two-stage
            # runs host work between its two jits — neither request can
            # be one monolithic jit (segments: arrays would bake in as
            # constants; the per-segment retrieves are module-level jits
            # keyed on segment array SHAPES, so shape-preserving
            # mutations recompile nothing and ``apply_update`` never
            # invalidates).  The encode is its own cached jit.
            fn = self._serve_cache.get("encode")
            if fn is None:
                fn = jax.jit(lambda xb: self.encode_queries(xb))
                self._serve_cache["encode"] = fn
            codes = fn(xb)
            scores, ids = self.retrieve_codes(codes, n)
        else:
            fn = self._serve_cache.get(n)
            if fn is None:
                def _serve(xb):
                    return self.retrieve_codes(self.encode_queries(xb), n)

                fn = jax.jit(_serve)
                self._serve_cache[n] = fn
            scores, ids = fn(xb)
        if pad:
            scores, ids = scores[:rows], ids[:rows]
        if squeeze:
            scores, ids = scores[0], ids[0]
        return RetrievalResponse(
            scores=scores, ids=ids,
            status=ServingStatus(path=path_name(self)),
            queue_us=0.0,
            compute_us=(time.monotonic() - t0) * 1e6,
        )
