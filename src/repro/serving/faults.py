"""Deterministic fault injection for the hardened serving stack (ISSUE 6).

Production failure modes, reproduced on demand so the fault-matrix suite
(tests/test_fault_matrix.py, benchmarks/fault_matrix.py) can assert the
degradation ladder's contract — recover bit-identically or degrade with a
measured quality bound, never crash, never silently serve wrong results:

    corrupt-index      a single flipped bit in the index's stored bytes
                       (the startup self-check must catch it by checksum)
    nonfinite-query    NaN/Inf planted at a known position in the request
                       (admission must reject or sanitize it)
    dead-shard         one mesh shard never answers (retry, then partial
                       merge over the survivors)
    slow-shard         one shard answers after a delay (deadline budget)
    kernel-exception   the kernel serving path raises mid-request (ladder
                       steps down a generation)
    corrupt-postings   out-of-range candidate ids planted in the two-stage
                       engine's inverted-index posting lists (stage 1's
                       integrity check must trip, and the ladder must fall
                       back to the exact single-stage scan)
    corrupt-delta      a single flipped bit in a segmented index's DELTA
                       segment (the per-segment CRC in the startup
                       self-check must catch it, and serving must shed to
                       base-only with coverage < 1.0 — partial catalog,
                       never corrupt bytes)

Everything here is host-side and deterministic: the same ``FaultInjector``
configuration produces the same failure at the same step every run — no
randomness, no monkeypatching of jax internals.  The injector is a plain
collaborator object the ``GuardedEngine`` consults at its decision points;
``None`` (the default everywhere) means production behaviour.
"""
from __future__ import annotations

import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.retrieval import Index
from repro.errors import KernelFaultError

FAULTS = (
    "corrupt-index",
    "nonfinite-query",
    "dead-shard",
    "slow-shard",
    "kernel-exception",
    "corrupt-postings",
    "corrupt-delta",
)


class FaultInjector:
    """One configured fault, injected deterministically.

    fault:          one of ``FAULTS`` (or None — injects nothing).
    shard:          which mesh position misbehaves (dead-/slow-shard).
    recover_after:  for dead-shard — the retry attempt (0-based) at which
                    the shard comes back.  None = permanently dead, which
                    forces the partial-result merge over the survivors.
    delay_s:       for slow-shard — how long the shard stalls on the
                    first attempt.
    trip_once:     for kernel-exception — raise only on the first request
                    (the ladder's fallback then serves; a subsequent
                    request on the same rung would trip again if False).
    """

    def __init__(
        self,
        fault: Optional[str] = None,
        *,
        shard: int = 0,
        recover_after: Optional[int] = None,
        delay_s: float = 0.05,
        trip_once: bool = True,
    ):
        if fault is not None and fault not in FAULTS:
            raise ValueError(
                f"unknown fault {fault!r} (expected one of {FAULTS})"
            )
        self.fault = fault
        self.shard = shard
        self.recover_after = recover_after
        self.delay_s = delay_s
        self.trip_once = trip_once
        self.kernel_trips = 0

    # ------------------------------------------------------- ladder hooks
    def before_step(self, step: int) -> None:
        """Called by the ladder immediately before serving on rung
        ``step`` (0 = the configured primary path).  kernel-exception
        raises on the primary rung so the ladder must step down."""
        if self.fault != "kernel-exception" or step != 0:
            return
        if self.trip_once and self.kernel_trips > 0:
            return
        self.kernel_trips += 1
        raise KernelFaultError(
            "injected kernel fault on the primary serving path "
            f"(trip {self.kernel_trips})"
        )

    def dead_shards(self, attempt: int) -> frozenset[int]:
        """Mesh positions that do not answer on retry ``attempt``."""
        if self.fault != "dead-shard":
            return frozenset()
        if self.recover_after is not None and attempt >= self.recover_after:
            return frozenset()
        return frozenset({self.shard})

    def shard_delay(self, attempt: int) -> float:
        """Seconds shard ``self.shard`` stalls before answering."""
        if self.fault == "slow-shard" and attempt == 0:
            return self.delay_s
        return 0.0

    def stall(self, attempt: int) -> float:
        """Simulate the slow shard's stall (host-side sleep); returns the
        seconds slept so the caller can charge them to the deadline."""
        delay = self.shard_delay(attempt)
        if delay > 0.0:
            time.sleep(delay)
        return delay


def flip_index_byte(index: Index, *, byte: int = 0, bit: int = 0) -> Index:
    """A copy of ``index`` with ONE bit flipped in its stored code bytes.

    Flips bit ``bit`` of byte ``byte`` in the primary value array
    (``q_values`` for a QuantizedIndex, fp32 ``values`` otherwise) and
    leaves the stored checksum stale — exactly what in-place corruption
    looks like, so ``verify_index`` must raise ``IndexIntegrityError``.
    """
    codes = index.codes
    primary = "q_values" if hasattr(codes, "q_values") else "values"
    arr = np.asarray(getattr(codes, primary)).copy()
    flat = arr.view(np.uint8).reshape(-1)
    flat[byte % flat.size] ^= np.uint8(1 << (bit % 8))
    return index._replace(
        codes=codes._replace(**{primary: jnp.asarray(arr)})
    )


def flip_delta_byte(segments, *, byte: int = 0, bit: int = 0):
    """A copy of a ``SegmentedIndex`` with ONE bit flipped in its delta
    segment's stored code bytes (checksum left stale, exactly like
    ``flip_index_byte``) — what in-place delta corruption looks like, so
    the per-segment CRC in ``SegmentedIndex.verify`` must raise
    ``IndexIntegrityError`` while the base still verifies clean.
    """
    from repro.core.segments import SegmentedIndex

    if segments.delta is None:
        raise ValueError(
            "segments has no delta segment to corrupt — add items first"
        )
    return SegmentedIndex(
        segments.base, segments.base_ids, segments.base_alive,
        delta=flip_index_byte(segments.delta, byte=byte, bit=bit),
        delta_codes=segments.delta_codes,
        delta_ids=segments.delta_ids,
        delta_alive=segments.delta_alive,
    )


def corrupt_postings(inv, *, bad_id: Optional[int] = None):
    """A copy of an ``InvertedIndex`` with out-of-range candidate ids
    planted in its posting lists — what silent in-place postings
    corruption looks like to stage 1 of two-stage retrieval.

    Every posting list's first slot is overwritten (deterministic, and
    guarantees ANY query's candidate union sees a corrupted entry, so the
    fault fires on the first request regardless of its latents).
    ``bad_id`` defaults to N + 7, safely outside the valid ``[-1, N)``
    id range; ``candidate_union`` must raise ``IndexIntegrityError``.
    """
    post = np.asarray(inv.postings).copy()
    if bad_id is None:
        bad_id = inv.codes.n + 7
    post[:, 0] = np.int32(bad_id)
    return inv._replace(postings=jnp.asarray(post))


def poison_queries(
    x, *, kind: str = "nan", position: tuple[int, int] = (0, 0)
):
    """A copy of the dense query batch with one non-finite value planted
    at ``position`` (row, col).  ``kind``: "nan" | "inf" | "-inf"."""
    bad = {"nan": np.nan, "inf": np.inf, "-inf": -np.inf}[kind]
    arr = np.asarray(x).copy()
    if arr.ndim == 1:
        arr[position[-1] % arr.shape[0]] = bad
    else:
        arr[position[0] % arr.shape[0], position[1] % arr.shape[1]] = bad
    return jnp.asarray(arr)
