"""The unified serving response surface (ISSUE 10 api_redesign).

Every serving layer — the bare ``RetrievalEngine``, the hardened
``GuardedEngine``, and the microbatching ``MicrobatchServer`` — answers a
dense request with the same typed object:

    RetrievalResponse(scores, ids, status, queue_us, compute_us)

replacing the old bare-``(scores, ids)`` vs ``(scores, ids, status)``
mismatch between the engine and the guard.  ``ServingStatus`` lives here
(not in ``serving.guard``) so the bare engine can stamp a healthy status
without importing the guard layer above it; ``serving.guard`` re-exports
it unchanged.

``RetrievalResponse`` is a NamedTuple with ``scores`` and ``ids`` first,
so positional access from the tuple era keeps meaning the same thing:
``resp[0]``/``resp[1]`` are the scores/ids panels and ``resp[:2]`` is the
old pair.  Full-tuple unpacking now yields five fields — legacy
two/three-target unpacks migrate to ``scores, ids, *_ = resp`` or
attribute access.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax


class ServingStatus(NamedTuple):
    """How a request was actually served — attached to every response.

    path:      name of the ladder rung that produced the answer.
    step:      rung index (0 = the configured primary path).
    degraded:  True whenever the answer differs in ANY way from what the
               healthy primary path would have returned (stepped-down
               rung, sanitized inputs, partial shard coverage).
    fault:     why serving left the primary path (None when healthy).
    shards_total / shards_used: mesh shard accounting (1/1 unsharded).
    coverage:  fraction of the candidate catalog actually scored — the
               recall bound for partial results (1.0 = full catalog).
    retries:   shard retry attempts spent before this answer.
    sanitized: count of non-finite query values zeroed at admission.
    deadline_exceeded: the budget ran out; the answer came from the
               cheapest remaining path rather than being dropped.
    """

    path: str
    step: int = 0
    degraded: bool = False
    fault: Optional[str] = None
    shards_total: int = 1
    shards_used: int = 1
    coverage: float = 1.0
    retries: int = 0
    sanitized: int = 0
    deadline_exceeded: bool = False


class RetrievalResponse(NamedTuple):
    """One served retrieval request: the answer plus how it was produced.

    scores / ids: the (Q?, n) top-n panels — exactly what the tuple-era
        API returned, in the same positions (``resp[0]``/``resp[1]``).
    status: the ``ServingStatus`` describing the path taken.  A bare
        ``RetrievalEngine`` stamps a healthy status (its configured path,
        step 0); the guard and the batcher stamp what actually happened.
    queue_us: host wall-clock the request spent queued before dispatch —
        0.0 for direct (unbatched) calls; the microbatcher fills it in.
    compute_us: host wall-clock of the serve itself.  Direct engine calls
        record dispatch time (device completion is the caller's
        ``block_until_ready``, as before); the batcher records the
        blocked panel round-trip.
    """

    scores: jax.Array
    ids: jax.Array
    status: ServingStatus
    queue_us: float = 0.0
    compute_us: float = 0.0

    @property
    def pair(self) -> tuple[jax.Array, jax.Array]:
        """The tuple-era ``(scores, ids)`` view."""
        return self.scores, self.ids
