from repro.serving.engine import (
    PRECISIONS,
    PreppedQuery,
    RetrievalEngine,
    check_precision,
    mode_inv_norms,
    prep_query,
    retrieve_prepped,
    select_retrieve_fn,
    validate_dense_query,
    validate_query_codes,
    validate_topn,
)
from repro.serving.faults import (
    FAULTS,
    FaultInjector,
    flip_index_byte,
    poison_queries,
)
from repro.serving.guard import (
    Deadline,
    GuardedEngine,
    SelfCheckReport,
    ServingStatus,
    self_check,
)

__all__ = [
    "RetrievalEngine",
    "PreppedQuery",
    "prep_query",
    "retrieve_prepped",
    "select_retrieve_fn",
    "mode_inv_norms",
    "check_precision",
    "PRECISIONS",
    "validate_dense_query",
    "validate_query_codes",
    "validate_topn",
    "FAULTS",
    "FaultInjector",
    "flip_index_byte",
    "poison_queries",
    "Deadline",
    "GuardedEngine",
    "SelfCheckReport",
    "ServingStatus",
    "self_check",
]
