from repro.serving.engine import (
    PreppedQuery,
    RetrievalEngine,
    mode_inv_norms,
    prep_query,
    retrieve_prepped,
)

__all__ = [
    "RetrievalEngine",
    "PreppedQuery",
    "prep_query",
    "retrieve_prepped",
    "mode_inv_norms",
]
