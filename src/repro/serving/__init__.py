from repro.serving.engine import (
    PRECISIONS,
    PreppedQuery,
    RetrievalEngine,
    check_precision,
    mode_inv_norms,
    prep_query,
    retrieve_prepped,
)

__all__ = [
    "RetrievalEngine",
    "PreppedQuery",
    "prep_query",
    "retrieve_prepped",
    "mode_inv_norms",
    "check_precision",
    "PRECISIONS",
]
