from repro.serving.batcher import DEFAULT_BUCKETS, MicrobatchServer
from repro.serving.config import (
    PRECISIONS,
    EngineConfig,
    check_precision,
)
from repro.serving.engine import (
    PreppedQuery,
    RetrievalEngine,
    mode_inv_norms,
    path_name,
    prep_query,
    resolve_stage1,
    retrieve_prepped,
    select_retrieve_fn,
    validate_dense_query,
    validate_query_codes,
    validate_topn,
)
from repro.serving.faults import (
    FAULTS,
    FaultInjector,
    corrupt_postings,
    flip_delta_byte,
    flip_index_byte,
    poison_queries,
)
from repro.serving.guard import (
    Deadline,
    GuardedEngine,
    SelfCheckReport,
    self_check,
)
from repro.serving.response import RetrievalResponse, ServingStatus

__all__ = [
    "RetrievalEngine",
    "EngineConfig",
    "RetrievalResponse",
    "MicrobatchServer",
    "DEFAULT_BUCKETS",
    "PreppedQuery",
    "prep_query",
    "retrieve_prepped",
    "select_retrieve_fn",
    "mode_inv_norms",
    "path_name",
    "resolve_stage1",
    "check_precision",
    "PRECISIONS",
    "validate_dense_query",
    "validate_query_codes",
    "validate_topn",
    "FAULTS",
    "FaultInjector",
    "corrupt_postings",
    "flip_delta_byte",
    "flip_index_byte",
    "poison_queries",
    "Deadline",
    "GuardedEngine",
    "SelfCheckReport",
    "ServingStatus",
    "self_check",
]
