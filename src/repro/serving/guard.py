"""Request guards + degradation ladder: hardened serving (ISSUE 6 tentpole).

``GuardedEngine`` wraps a ``RetrievalEngine`` with everything the bare
engine deliberately does not do:

* **admission** — shape/dtype/top-n validation raising typed
  ``InvalidQueryError``s that name the offending argument, plus a
  host-side finiteness check on the dense query bytes (reject, or
  sanitize-to-zero with the count reported) so NaN/Inf never reaches a
  kernel;
* **a per-request deadline budget** — ``Deadline`` tracks a monotonic
  budget; slow paths (shard retry backoff, injected stalls) are abandoned
  when it runs out.  The deadline never abandons the *final* answer: the
  remaining ladder rungs still serve, and the response is tagged
  ``deadline_exceeded`` instead of timing out empty-handed;
* **the degradation ladder** — on a fault, serving steps down
  ``two-stage-device → two-stage-host → sharded → unsharded → int8 →
  exact-quantized → fp32 ref → full-score floor`` (whichever rungs the
  engine's configuration actually has),
  re-serving the SAME request on the next-safest path.  Every response
  carries a ``ServingStatus`` naming the path taken, whether it is
  degraded, and why — a fault is an annotated answer, never a crash and
  never a silently wrong result;
* **startup self-check** — ``self_check`` verifies the index checksum
  (``core.retrieval.verify_index``: a single flipped byte is a typed
  ``IndexIntegrityError``), the inverted-index checksum when the engine
  serves two-stage (``core.inverted_index.verify_inverted_index`` — so
  ``corrupt-postings`` is a startup failure, not a first-request
  surprise), and runs a deterministic canary batch through
  the configured path, asserting it against the reference contract
  (int8: kernel↔ref bit-equality; exact: f32-rounding agreement) before
  the engine accepts traffic;
* **distributed hardening** — a dead shard gets bounded retry with
  exponential backoff; if it stays dead, the request is served by a
  partial merge over the surviving shards
  (``distributed.retrieve.partial_retrieve_prepped``) with the achieved
  coverage (the recall bound) reported in the status.

Fault injection (``serving.faults.FaultInjector``) plugs into the same
decision points deterministically, which is how the fault-matrix suite
exercises every rung without a real outage.
"""
from __future__ import annotations

import math
import time
from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import sae
from repro.core.quantized_codes import QuantizedCodes
from repro.core.inverted_index import verify_inverted_index
from repro.core.retrieval import (
    dequantize_index,
    index_codes_f32,
    score_reconstructed,
    score_sparse,
    top_n,
    verify_index,
)
from repro.core.types import SparseCodes
from repro.errors import (
    DeadlineExceededError,
    DegradationExhaustedError,
    IndexIntegrityError,
    InvalidQueryError,
    RetrievalError,
    SelfCheckError,
    ShardFailureError,
)
from repro.serving.config import EngineConfig
from repro.serving.engine import (
    RetrievalEngine,
    path_name,
    resolve_stage1,
    validate_dense_query,
    validate_topn,
)
from repro.serving.response import (  # noqa: F401 — re-exported API
    RetrievalResponse,
    ServingStatus,
)


class Deadline:
    """A per-request wall-clock budget on the host's monotonic clock.

    ``budget_ms=None`` never expires (the default: guards should not
    impose latency policy unless asked).  ``check(stage)`` raises a
    typed ``DeadlineExceededError`` naming the stage that overran.
    """

    def __init__(self, budget_ms: Optional[float] = None):
        self.budget_ms = budget_ms
        self._t0 = time.monotonic()

    @property
    def elapsed_ms(self) -> float:
        return (time.monotonic() - self._t0) * 1e3

    @property
    def remaining_ms(self) -> float:
        if self.budget_ms is None:
            return math.inf
        return self.budget_ms - self.elapsed_ms

    @property
    def expired(self) -> bool:
        return self.remaining_ms <= 0.0

    def check(self, stage: str) -> None:
        if self.expired:
            raise DeadlineExceededError(
                f"deadline budget {self.budget_ms}ms exhausted at "
                f"{stage} ({self.elapsed_ms:.1f}ms elapsed)"
            )


class SelfCheckReport(NamedTuple):
    """What the startup self-check verified before accepting traffic."""

    index_verified: bool      # content checksum matched
    canary_q: int             # canary batch size served
    canary_n: int             # top-n of the canary request
    path: str                 # primary path description
    kernel_vs_ref: Optional[str]  # "bit-identical" | "allclose" | None
                              # (None: primary already IS the ref path)
    max_abs_diff: float       # worst canary score delta vs reference


def _canary_queries(engine: RetrievalEngine, canary_q: int):
    """A deterministic canary batch (no RNG — self-checks must be
    reproducible): the first decoder atoms as dense embeddings when the
    engine can encode, else the index's own first rows as query codes."""
    if engine.params is not None:
        q = min(canary_q, engine.params["w_dec"].shape[0])
        return engine.params["w_dec"][:q, :], None
    codes = index_codes_f32(engine.index)
    q = min(canary_q, codes.values.shape[0])
    return None, SparseCodes(
        values=codes.values[:q], indices=codes.indices[:q], dim=codes.dim
    )


def self_check(
    engine: RetrievalEngine,
    *,
    canary_q: int = 4,
    canary_n: int = 8,
    require_checksum: bool = True,
) -> SelfCheckReport:
    """Verify index integrity, then serve a canary batch and hold it to
    the configured path's reference contract.

    Index bytes are checked against the build-time checksum first
    (``IndexIntegrityError`` on mismatch — a single flipped byte fails
    here, before any kernel runs).  The canary then asserts:

    * sanity on the primary path's own output — finite scores, ids in
      range, scores sorted descending (catches poisoned norms that a
      checksumless index could smuggle in);
    * when the primary path is a fused kernel, agreement with the jnp
      reference twin: **bit-equality** for int8 precision (generation
      5's kernel↔ref contract) and f32-rounding agreement (allclose +
      id-set overlap) for the exact generations.

    Raises ``SelfCheckError`` / ``IndexIntegrityError``; returns a
    ``SelfCheckReport`` when the engine is fit to accept traffic.
    """
    segments = getattr(engine, "segments", None)
    if segments is not None:
        # segmented engines verify EVERY segment's content CRC32 — a
        # flipped byte in the small delta is caught with the same
        # startup rigor as one in the base
        segments.verify(require=require_checksum)
    else:
        verify_index(engine.index, require=require_checksum)
    if engine.inverted is not None:
        # two-stage engines also serve from posting lists: hold them to
        # the same build-time checksum contract so corrupt-postings is a
        # startup failure, not a first-request surprise
        verify_inverted_index(engine.inverted, require=require_checksum)
    canary_n = min(canary_n, engine.index.codes.n)
    if segments is not None:
        # dead rows never surface; an underfull canary would trip the
        # finiteness check on its (-inf, -1) padding
        canary_n = max(1, min(canary_n, segments.n_alive))

    xq, qcodes = _canary_queries(engine, canary_q)
    serve = ((lambda e: e.retrieve_dense(xq, canary_n).pair)
             if xq is not None
             else (lambda e: e.retrieve_codes(qcodes, canary_n)))
    scores, ids = serve(engine)
    s = np.asarray(scores)
    i = np.asarray(ids)
    n_cand = engine.index.codes.n
    if not np.all(np.isfinite(s)):
        raise SelfCheckError(
            "canary produced non-finite scores — index norms or params "
            "are poisoned"
        )
    if segments is not None:
        # segmented retrieval returns ITEM ids — the valid set is the
        # alive ids, not a contiguous [0, N) range
        valid = set(int(v) for v in segments.alive_ids())
        bad = [int(v) for v in i.ravel() if int(v) not in valid]
        if bad:
            raise SelfCheckError(
                f"canary returned ids outside the alive item set "
                f"(first: {bad[0]})"
            )
    elif np.any(i < 0) or np.any(i >= n_cand):
        raise SelfCheckError(
            f"canary returned candidate ids outside [0, {n_cand})"
        )
    if np.any(np.diff(s, axis=-1) > 1e-6):
        raise SelfCheckError("canary scores are not sorted descending")

    kernel_vs_ref = None
    max_diff = 0.0
    # two-stage engines skip the kernel-vs-ref comparison: the path is
    # structurally approximate (candidate generation, not scoring, is
    # what differs from the reference), so bit/allclose contracts don't
    # apply — its quality bound is recall-gated in benchmarks instead.
    # The sanity checks above (finite, in-range, sorted) still ran.
    if (engine.use_fused or engine.mesh is not None) \
            and engine.stage == "single":
        ref = RetrievalEngine(
            segments if segments is not None else engine.index,
            engine.params,
            config=EngineConfig(mode=engine.mode, use_kernel=False,
                                precision=engine.precision),
        )
        rs, ri = serve(ref)
        rs, ri = np.asarray(rs), np.asarray(ri)
        max_diff = float(np.max(np.abs(s - rs)))
        if engine.precision == "int8":
            # generation 5 contract: kernel and ref are BIT-identical
            if not (np.array_equal(s, rs) and np.array_equal(i, ri)):
                raise SelfCheckError(
                    "int8 canary: kernel and reference disagree — the "
                    "gen-5 contract is bit-equality (max |Δscore| "
                    f"{max_diff:.3e})"
                )
            kernel_vs_ref = "bit-identical"
        else:
            overlap = np.mean([
                len(set(a) & set(b)) / len(a) for a, b in zip(i, ri)
            ])
            if not np.allclose(s, rs, rtol=1e-5, atol=1e-5) or overlap < 0.9:
                raise SelfCheckError(
                    "exact canary: kernel and reference disagree beyond "
                    f"f32 rounding (max |Δscore| {max_diff:.3e}, id "
                    f"overlap {overlap:.2f})"
                )
            kernel_vs_ref = "allclose"

    return SelfCheckReport(
        index_verified=engine.index.checksum is not None,
        canary_q=int(s.shape[0]), canary_n=canary_n,
        path=path_name(engine), kernel_vs_ref=kernel_vs_ref,
        max_abs_diff=max_diff,
    )


def _stage1_impl(cfg) -> Optional[str]:
    """The resolved stage-1 implementation of a ladder config (None for
    single-stage rungs) — part of the rung identity, so a device and a
    host two-stage rung never dedup into one."""
    if cfg.get("stage") != "two_stage":
        return None
    return resolve_stage1(cfg.get("stage1", "auto"))


class GuardedEngine:
    """A ``RetrievalEngine`` behind admission control, a deadline budget,
    and the degradation ladder.  See the module docstring for semantics.

    engine:       the configured primary serving engine.
    deadline_ms:  default per-request budget (None = unbounded);
                  per-call override via ``retrieve_dense(...,
                  deadline_ms=...)``.
    on_invalid:   "reject" (typed error on non-finite queries — the
                  default; bad bytes are the caller's bug) or "sanitize"
                  (zero them, serve, and report the count as degraded).
    retries:      shard retry attempts before the partial-merge fallback.
    backoff_s:    base of the exponential retry backoff.
    injector:     a ``serving.faults.FaultInjector`` (None in
                  production) consulted at each decision point.
    fallback_index: served from (precision forced to its best exact
                  setting) if the PRIMARY index fails its integrity
                  check at startup — the "stale-but-verified replica"
                  pattern; requests are then degraded from the start.
    run_self_check: run ``self_check`` at construction and refuse to
                  build a guard over an engine that fails it.
    """

    def __init__(
        self,
        engine: RetrievalEngine,
        *,
        deadline_ms: Optional[float] = None,
        on_invalid: str = "reject",
        retries: int = 2,
        backoff_s: float = 0.01,
        injector=None,
        fallback_index=None,
        run_self_check: bool = False,
        canary_q: int = 4,
        canary_n: int = 8,
    ):
        if on_invalid not in ("reject", "sanitize"):
            raise ValueError(
                f"on_invalid must be 'reject' or 'sanitize', got "
                f"{on_invalid!r}"
            )
        self.deadline_ms = deadline_ms
        self.on_invalid = on_invalid
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.injector = injector
        self.degraded_from_start: Optional[str] = None
        self.counters = {
            "requests": 0, "degraded": 0, "rejected": 0, "sanitized": 0,
        }
        self.self_check_report: Optional[SelfCheckReport] = None
        # fraction of the alive catalog a segmented engine still serves
        # (< 1.0 only after a corrupt-delta shed to base-only)
        self._segment_coverage: float = 1.0

        if run_self_check:
            try:
                self.self_check_report = self_check(
                    engine, canary_q=canary_q, canary_n=canary_n
                )
            except IndexIntegrityError as err:
                seg = getattr(engine, "segments", None)
                shed = None
                if seg is not None and seg.delta is not None:
                    # a segmented engine carries its own stale-but-verified
                    # replica: the immutable base.  If the base's CRC still
                    # holds, drop the corrupt delta and serve base-only —
                    # partial coverage, never corrupt bytes.
                    try:
                        verify_index(seg.base)
                    except IndexIntegrityError:
                        pass  # base is poisoned too — fall to the replica
                    else:
                        shed = seg.base_only()
                if shed is not None:
                    engine = RetrievalEngine(
                        shed, engine.params,
                        config=EngineConfig(
                            mode=engine.mode,
                            use_kernel=engine.use_kernel,
                            precision=engine.precision,
                        ),
                    )
                    self.self_check_report = self_check(
                        engine, canary_q=canary_q, canary_n=canary_n
                    )
                    self._segment_coverage = float(seg.base_coverage)
                    self.degraded_from_start = (
                        f"delta segment failed integrity check ({err}); "
                        "serving base-only at coverage "
                        f"{self._segment_coverage:.3f}"
                    )
                    self.engine = engine
                    self._ladder = self._build_ladder()
                    self._rung_engines = {0: engine}
                    return
                if fallback_index is None:
                    raise
                verify_index(fallback_index)
                engine = RetrievalEngine(
                    fallback_index, engine.params,
                    config=EngineConfig(
                        mode=engine.mode, use_kernel=engine.use_kernel,
                        mesh=engine.mesh, shard_axis=engine.shard_axis,
                        precision=(engine.precision if isinstance(
                            fallback_index.codes, QuantizedCodes)
                            else "exact"),
                    ),
                )
                self.self_check_report = self_check(
                    engine, canary_q=canary_q, canary_n=canary_n
                )
                self.degraded_from_start = (
                    f"primary index failed integrity check ({err}); "
                    "serving from verified fallback index"
                )
        self.engine = engine
        self._ladder = self._build_ladder()
        self._rung_engines: dict[int, Optional[RetrievalEngine]] = {
            0: engine
        }

    # ------------------------------------------------------------- ladder
    def _build_ladder(self):
        """(name, config) per rung, primary first, strictly safer as the
        step index grows; the kernel-free full-score floor is always
        last.  Configs that coincide with an earlier rung are dropped,
        so the ladder only contains genuinely distinct paths."""
        e = self.engine
        quantized = isinstance(e.index.codes, QuantizedCodes)
        segmented = getattr(e, "segments", None) is not None
        cfgs = []
        if e.stage == "two_stage":
            # two-stage occupies the TOP rungs: fastest, but approximate
            # and dependent on posting-list integrity.  A device stage-1
            # failure (jit/runtime fault) sheds to the host stage-1
            # oracle first — bit-identical candidates, no device union —
            # and only then to the exact single-stage scan of the same
            # precision/backend (actual postings corruption fails BOTH
            # stage-1 implementations, since they share the one inverted
            # index, and lands there)
            cfgs.append(dict(mesh=None, precision=e.precision,
                             use_fused=e.use_fused, dequant=False,
                             stage="two_stage", stage1=e.stage1))
            cfgs.append(dict(mesh=None, precision=e.precision,
                             use_fused=e.use_fused, dequant=False,
                             stage="two_stage", stage1="host"))
        cfgs += [
            dict(mesh=e.mesh, precision=e.precision,
                 use_fused=e.use_fused, dequant=False, stage="single"),
            # shed the mesh first: a healthy single device beats retrying
            # a broken collective
            dict(mesh=None, precision=e.precision,
                 use_fused=e.use_fused, dequant=False, stage="single"),
        ]
        if e.precision == "int8":
            cfgs.append(dict(mesh=None, precision="exact",
                             use_fused=e.use_fused, dequant=False,
                             stage="single"))
        # the pre-floor rung: fp32 index, jnp reference path.  Segmented
        # engines keep the base's stored format here — dequantizing the
        # base alone would break the quantized-delta parity contract, so
        # their fp32 answer comes from the full-score floor instead.
        cfgs.append(dict(mesh=None, precision="exact",
                         use_fused=False, dequant=quantized and not segmented,
                         stage="single"))
        ladder, seen = [], set()
        for cfg in cfgs:
            key = (cfg["mesh"] is None, cfg["precision"],
                   cfg["use_fused"], cfg["dequant"], cfg["stage"],
                   _stage1_impl(cfg))
            if key in seen:
                continue
            seen.add(key)
            ladder.append((self._cfg_name(cfg), cfg))
        ladder.append(("fp32-fullscore", None))
        return ladder

    def _cfg_name(self, cfg) -> str:
        quantized = (isinstance(self.engine.index.codes, QuantizedCodes)
                     and not cfg["dequant"])
        fmt = ("int8" if cfg["precision"] == "int8"
               else "quantized" if quantized else "fp32")
        backend = "kernel" if cfg["use_fused"] else "ref"
        sharded = "-sharded" if cfg["mesh"] is not None else ""
        impl = _stage1_impl(cfg)
        prefix = f"two-stage-{impl}-" if impl is not None else ""
        return f"{prefix}{fmt}-{backend}{sharded}"

    @property
    def ladder(self) -> tuple[str, ...]:
        """The rung names, primary first (for logs/docs/tests)."""
        return tuple(name for name, _ in self._ladder)

    def _engine_for(self, step: int) -> Optional[RetrievalEngine]:
        """Lazily build (and memoize) the rung's engine; None = the
        kernel-free full-score floor."""
        if step in self._rung_engines:
            return self._rung_engines[step]
        _, cfg = self._ladder[step]
        if cfg is None:
            eng = None
        else:
            e = self.engine
            seg = getattr(e, "segments", None)
            if seg is not None:
                # rungs below a segmented primary serve the SAME segments
                # (base + delta + deletion masks) at the rung's
                # precision/backend — shedding a kernel generation must
                # not silently resurrect deleted rows or drop the delta
                index = seg
            else:
                index = (dequantize_index(e.index) if cfg["dequant"]
                         else e.index)
            two = cfg.get("stage") == "two_stage"
            rung_cfg = EngineConfig(
                mode=e.mode, use_kernel=cfg["use_fused"], mesh=cfg["mesh"],
                shard_axis=e.shard_axis, precision=cfg["precision"],
                stage=cfg.get("stage", "single"),
                **(dict(candidate_fraction=e.candidate_fraction,
                        inverted_cap=e.inverted_cap,
                        stage1=cfg.get("stage1", "auto")) if two else {}),
            )
            eng = RetrievalEngine(index, e.params, config=rung_cfg)
            if two and e.inverted is not None:
                # every two-stage rung serves from the SAME inverted
                # index as the primary engine (not a private rebuild):
                # the device→host shed covers device-side faults only,
                # and genuine postings corruption must fail both rungs
                eng.inverted = e.inverted
        self._rung_engines[step] = eng
        return eng

    # -------------------------------------------------------------- floor
    def _fullscore(self, x, n: int):
        """The ladder's floor: full-score + top-n with every kernel and
        fusion OFF — the most battle-tested composition in the repo (it
        is the oracle every other path is tested against)."""
        e = self.engine
        codes = sae.encode(e.params, x, e.k)
        seg = getattr(e, "segments", None)
        if seg is not None:
            # the segmented floor full-scores the COMPACTED survivors
            # (base + delta, dead rows dropped) so deleted ids cannot
            # surface even here, then translates positions to item ids
            comp = seg.compact()
            index = comp.base
            if isinstance(index.codes, QuantizedCodes):
                index = dequantize_index(index)
            scores = score_sparse(index, codes, use_kernel=False)
            n_eff = min(n, index.codes.n)
            vals, pos = top_n(scores, n_eff)
            ids = jnp.asarray(np.asarray(comp.base_ids))[pos]
            if n_eff < n:
                pad = [(0, 0)] * (vals.ndim - 1) + [(0, n - n_eff)]
                vals = jnp.pad(vals, pad, constant_values=-jnp.inf)
                ids = jnp.pad(ids, pad, constant_values=-1)
            return vals, ids
        index = (dequantize_index(e.index)
                 if isinstance(e.index.codes, QuantizedCodes) else e.index)
        if e.mode == "reconstructed":
            scores = score_reconstructed(index, codes, e.params,
                                         use_kernel=False)
        else:
            scores = score_sparse(index, codes, use_kernel=False)
        return top_n(scores, n)

    # ---------------------------------------------------------- admission
    def _admit_values(self, x):
        """Host-side finiteness check on the query bytes — the one check
        that cannot be trace-safe.  Reject names the first bad position;
        sanitize zeroes the bad entries and reports how many."""
        arr = np.asarray(x)
        bad = ~np.isfinite(arr)
        nbad = int(bad.sum())
        if nbad == 0:
            return x, 0
        pos = tuple(int(v) for v in np.argwhere(bad)[0])
        if self.on_invalid == "reject":
            raise InvalidQueryError(
                f"x: {nbad} non-finite value(s) in the query batch, "
                f"first at position {pos} ({arr[pos]!r}); rejected at "
                "admission — non-finite embeddings never reach the kernel"
            )
        arr = np.where(bad, 0.0, arr).astype(arr.dtype, copy=False)
        return jnp.asarray(arr), nbad

    # ----------------------------------------------------------- sharding
    def _serve_sharded(self, eng: RetrievalEngine, x, n: int,
                       deadline: Deadline):
        """Bounded retry with exponential backoff, then partial merge.

        Returns ``(scores, ids, retries, coverage, fault_reason)``.  The
        deadline is charged for injected stalls and checked before each
        backoff sleep — an expired budget skips straight to the partial
        merge (serve *something*) rather than burning more wall-clock.
        """
        from repro.distributed.retrieve import (
            mesh_shard_count, partial_retrieve_prepped,
        )

        inj = self.injector
        n_shards = mesh_shard_count(eng.mesh, eng.shard_axis)
        dead: frozenset[int] = frozenset()
        attempt = 0
        for attempt in range(self.retries + 1):
            if inj is not None:
                inj.stall(attempt)        # slow shard: host-visible stall
            dead = inj.dead_shards(attempt) if inj is not None else frozenset()
            if not dead:
                scores, ids, *_ = eng.retrieve_dense(x, n)
                fault = (f"shard recovered after {attempt} retr"
                         f"{'y' if attempt == 1 else 'ies'}"
                         if attempt else None)
                return scores, ids, attempt, 1.0, fault
            if attempt < self.retries:
                deadline.check(f"shard retry backoff (attempt {attempt})")
                time.sleep(self.backoff_s * (2 ** attempt))

        # retries exhausted: merge what survived
        codes = eng.encode_queries(x)
        pq = eng.prep_query(codes)
        scores, ids, coverage = partial_retrieve_prepped(
            eng.index, pq, n,
            n_shards=n_shards, dead_shards=dead, use_fused=eng.use_fused,
            precision=eng.precision,
        )
        fault = (
            f"shard(s) {sorted(dead)} dead after {self.retries} retries; "
            f"partial merge over {n_shards - len(dead)}/{n_shards} shards"
        )
        return scores, ids, attempt, coverage, fault

    # ------------------------------------------------------------ serving
    def retrieve_dense(self, x, n: int, *,
                       deadline_ms: Optional[float] = None
                       ) -> RetrievalResponse:
        """Serve one guarded request: a ``RetrievalResponse`` whose
        ``ServingStatus`` names the ladder rung that actually answered.

        Admission failures raise typed errors (the caller sent garbage);
        every fault PAST admission is absorbed by the ladder — the
        request is re-served on the next rung down and the status says
        so.  Only when every rung fails does ``DegradationExhaustedError``
        surface, chaining each rung's reason.
        """
        t0 = time.monotonic()
        deadline = Deadline(self.deadline_ms if deadline_ms is None
                            else deadline_ms)
        self.counters["requests"] += 1
        try:
            seg = getattr(self.engine, "segments", None)
            n = validate_topn(n, self.engine.index.codes.n if seg is None
                              else seg.n_rows)
            d = (None if self.engine.params is None
                 else self.engine.params["w_enc"].shape[0])
            validate_dense_query(x, d=d)
            x, sanitized = self._admit_values(x)
        except InvalidQueryError:
            self.counters["rejected"] += 1
            raise
        if sanitized:
            self.counters["sanitized"] += 1

        mesh = self.engine.mesh
        shards_total = 1
        if mesh is not None:
            from repro.distributed.retrieve import mesh_shard_count

            shards_total = mesh_shard_count(mesh, self.engine.shard_axis)

        faults: list[str] = []
        for step, (name, _) in enumerate(self._ladder):
            try:
                if self.injector is not None:
                    self.injector.before_step(step)
                eng = self._engine_for(step)
                retries, coverage, fault = 0, 1.0, None
                shards_used = shards_total if step == 0 else 1
                if eng is None:
                    scores, ids = self._fullscore(x, n)
                elif eng.mesh is not None:
                    scores, ids, retries, coverage, fault = (
                        self._serve_sharded(eng, x, n, deadline))
                    dead_now = round(shards_total * (1.0 - coverage))
                    shards_used = shards_total - dead_now
                else:
                    scores, ids, *_ = eng.retrieve_dense(x, n)
            except RetrievalError as err:
                faults.append(f"{name}: {err}")
                continue
            except Exception as err:  # noqa: BLE001 — the ladder exists
                # exactly so an unanticipated kernel/runtime fault on one
                # rung degrades instead of crashing the request
                faults.append(f"{name}: {type(err).__name__}: {err}")
                continue

            # a base-only shed caps coverage at the surviving fraction
            coverage = min(float(coverage), self._segment_coverage)
            reasons = faults + ([fault] if fault else [])
            if self.degraded_from_start:
                reasons.insert(0, self.degraded_from_start)
            if sanitized:
                reasons.insert(
                    0, f"sanitized {sanitized} non-finite query value(s)"
                )
            degraded = bool(
                step > 0 or sanitized or coverage < 1.0
                or self.degraded_from_start
            )
            if degraded:
                self.counters["degraded"] += 1
            status = ServingStatus(
                path=name, step=step, degraded=degraded,
                fault="; ".join(reasons) if reasons else None,
                shards_total=shards_total, shards_used=shards_used,
                coverage=float(coverage), retries=retries,
                sanitized=sanitized,
                deadline_exceeded=deadline.expired,
            )
            return RetrievalResponse(
                scores=scores, ids=ids, status=status,
                queue_us=0.0,
                compute_us=(time.monotonic() - t0) * 1e6,
            )

        raise DegradationExhaustedError(
            "every degradation-ladder rung failed for this request: "
            + " | ".join(faults)
        )

    def self_check(self, **kw) -> SelfCheckReport:
        """Run (or re-run) the startup self-check on the current engine."""
        self.self_check_report = self_check(self.engine, **kw)
        return self.self_check_report
