"""Typed engine configuration (ISSUE 10 api_redesign).

``EngineConfig`` is the one frozen object that names every serving knob
the engine accreted across PRs 3–9 (mode, backend, precision, staging,
sharding), with the cross-field validation that used to live inline in
``RetrievalEngine.__init__`` moved onto the config itself:

* **field-space checks** run in ``__post_init__`` — an invalid
  combination (two-stage + mesh, reconstructed two-stage, a
  candidate_fraction outside (0, 1]) is rejected the moment the config
  exists, before any index or params are in sight;
* **index/params-dependent checks** run in ``validate(index, params)``
  — precision vs index format, segmented-index constraints,
  reconstructed-mode requirements, latent-dim agreement.

``RetrievalEngine(index, params, config=...)`` is the primary
constructor; every entry point (``launch/serve.py``,
``launch/loadtest.py``, benchmarks) builds its config through
``EngineConfig.add_flags`` / ``EngineConfig.from_flags`` so a knob added
here appears everywhere at once, and the per-file duplicated
``ap.error(...)`` validation is gone.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.quantized_codes import QuantizedCodes
from repro.core.segments import SegmentedIndex
from repro.errors import EngineConfigError

PRECISIONS = ("exact", "int8")
MODES = ("sparse", "reconstructed")
STAGES = ("single", "two_stage")
STAGE1S = ("auto", "device", "host")


def check_precision(index, precision: str) -> str:
    """Validate a scoring-precision switch against an index format.

    ``"exact"`` — dequantize-(if needed)-and-score-in-f32, bit-identical
    to the fp32 path (every index).  ``"int8"`` — generation 5's
    approximate int8×int8 scoring; requires a ``QuantizedIndex`` (the
    candidate tiles must already live in int8).
    """
    if precision not in PRECISIONS:
        raise EngineConfigError(
            f"unknown precision {precision!r} (expected one of {PRECISIONS})"
        )
    if precision == "int8" and not isinstance(index.codes, QuantizedCodes):
        raise EngineConfigError(
            "precision='int8' requires a QuantizedIndex "
            "(build_index(..., quantize=True)); got fp32 codes"
        )
    return precision


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Every serving knob of a ``RetrievalEngine``, as one frozen value.

    mode:      "sparse" (direct sparse-space cosine) or "reconstructed"
               (kernel-trick scoring; requires SAE params).
    use_kernel: "auto" | True | False — fused Pallas chain vs chunked jnp.
    precision: "exact" (bit-identical to fp32) or "int8" (approximate
               int8-MXU scoring; QuantizedIndex only).
    stage:     "single" (full-catalog scan) or "two_stage"
               (inverted-index candidate generation + gathered re-rank).
    stage1:    "auto"/"device" (jitted batched union) or "host" (NumPy
               parity oracle) — two-stage only.
    candidate_fraction: two-stage stage-2 budget as a catalog fraction.
    inverted_cap: posting-list length cap of the two-stage inverted index.
    mesh / shard_axis: candidate-sharded serving over ``mesh[shard_axis]``.
    k:         encoder top-k override (defaults to the index's k).
    """

    mode: str = "sparse"
    use_kernel: Any = "auto"
    precision: str = "exact"
    stage: str = "single"
    stage1: str = "auto"
    candidate_fraction: float = 0.25
    inverted_cap: int = 2048
    mesh: Any = None
    shard_axis: str = "cand"
    k: Optional[int] = None

    # ------------------------------------------------- field-space checks
    def __post_init__(self):
        if self.mode not in MODES:
            raise EngineConfigError(f"unknown retrieval mode: {self.mode!r}")
        if self.stage not in STAGES:
            raise EngineConfigError(
                f"unknown stage {self.stage!r} "
                "(expected 'single' or 'two_stage')"
            )
        if self.stage1 not in STAGE1S:
            raise EngineConfigError(
                f"unknown stage1 {self.stage1!r} "
                "(expected 'auto', 'device' or 'host')"
            )
        if self.precision not in PRECISIONS:
            raise EngineConfigError(
                f"unknown precision {self.precision!r} "
                f"(expected one of {PRECISIONS})"
            )
        if self.stage == "two_stage":
            if self.mesh is not None:
                raise EngineConfigError(
                    "stage='two_stage' does not compose with a mesh — "
                    "candidate generation is per-catalog, not per-shard; "
                    "use single-stage sharded serving instead"
                )
            if self.mode != "sparse":
                raise EngineConfigError(
                    "stage='two_stage' requires mode='sparse': posting "
                    "lists index the sparse code latents, and the "
                    "reconstructed-space query is dense by construction"
                )
            if not 0.0 < self.candidate_fraction <= 1.0:
                raise EngineConfigError(
                    "candidate_fraction must be in (0, 1]: "
                    f"{self.candidate_fraction}"
                )

    # --------------------------------------------- index-dependent checks
    def validate(self, index, params=None) -> None:
        """The cross-field checks that need the actual index/params —
        everything ``RetrievalEngine.__init__`` used to do inline."""
        if isinstance(index, SegmentedIndex):
            if self.mode != "sparse":
                raise EngineConfigError(
                    "a SegmentedIndex serves mode='sparse' only "
                    "(reconstructed-space norms are dropped at wrap time)"
                )
            if self.stage != "single":
                raise EngineConfigError(
                    "a SegmentedIndex serves stage='single' only — the "
                    "inverted index does not track segment mutations"
                )
            if self.mesh is not None:
                raise EngineConfigError(
                    "a SegmentedIndex does not compose with a mesh — "
                    "segments already merge like shards on one device"
                )
            index = index.base
        if self.mode == "reconstructed":
            if params is None:
                raise EngineConfigError(
                    "mode='reconstructed' requires SAE params"
                )
            if index.recon_norms is None:
                raise EngineConfigError(
                    "index built without params; recon norms missing"
                )
        if params is not None and index.codes.dim != params["w_enc"].shape[1]:
            raise EngineConfigError(
                "params/index latent-dim mismatch: w_enc encodes into "
                f"h={params['w_enc'].shape[1]} but the index codes address "
                f"h={index.codes.dim}"
            )
        check_precision(index, self.precision)

    def replace(self, **changes) -> "EngineConfig":
        """A modified copy (frozen dataclasses are immutable)."""
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------ CLI plumbing
    @staticmethod
    def add_flags(ap) -> None:
        """Register the shared engine flags on an argparse parser — the
        ONE flag namespace every entry point serves from."""
        ap.add_argument("--mode", choices=list(MODES), default="sparse")
        ap.add_argument("--use-kernel", choices=["auto", "1", "0"],
                        default="auto",
                        help="route scoring+selection through the fused "
                             "Pallas kernel (1), the chunked jnp path (0), "
                             "or pick by backend (auto)")
        ap.add_argument("--shards", type=int, default=1,
                        help="candidate-shard the index over an N-way mesh "
                             "and serve through distributed_retrieve (N>1 "
                             "on CPU forces N host devices when run as a "
                             "fresh process)")
        ap.add_argument("--quantized", action="store_true",
                        help="serve directly from the compound-compressed "
                             "index (int8 values + int16/int32 indices + "
                             "fp32 scales in HBM, dequantized tile-by-tile "
                             "in VMEM) — bit-identical to serving the "
                             "dequantized index")
        ap.add_argument("--precision", choices=list(PRECISIONS),
                        default="exact",
                        help="scoring precision: 'exact' (default; "
                             "bit-identical to the fp32 path) or 'int8' "
                             "(approximate int8-MXU scoring, requires "
                             "--quantized)")
        ap.add_argument("--two-stage", action="store_true",
                        help="serve two-stage: inverted-index candidate "
                             "generation (stage 1) feeding one batched "
                             "fused re-rank over the gathered candidate "
                             "panels (stage 2) — sub-linear in catalog "
                             "size, approximate; sparse mode, unsharded "
                             "only")
        ap.add_argument("--candidate-fraction", type=float, default=0.25,
                        help="two-stage candidate budget as a fraction of "
                             "the catalog (stage 2 scans ~this fraction; "
                             "1.0 is bit-identical to single-stage)")
        ap.add_argument("--inverted-cap", type=int, default=2048,
                        help="two-stage posting-list length cap")
        ap.add_argument("--stage1", choices=list(STAGE1S), default="auto",
                        help="stage-1 candidate-union implementation: the "
                             "jitted device union ('device'; 'auto' "
                             "resolves to it) or the bit-identical NumPy "
                             "oracle ('host'); requires --two-stage")

    @classmethod
    def from_flags(cls, args) -> "EngineConfig":
        """An ``EngineConfig`` from an ``add_flags`` namespace, with the
        flag-level cross checks that used to be duplicated as per-file
        ``ap.error(...)`` calls.  Raises ``EngineConfigError`` — CLI
        mains catch it and hand the message to ``parser.error``."""
        if args.precision == "int8" and not getattr(args, "quantized", True):
            raise EngineConfigError(
                "--precision int8 requires --quantized (the int8 scoring "
                "path reads int8 candidate tiles)"
            )
        if args.two_stage and args.shards > 1:
            raise EngineConfigError(
                "--two-stage does not compose with --shards > 1 "
                "(candidate generation is per-catalog, not per-shard)"
            )
        if args.stage1 != "auto" and not args.two_stage:
            raise EngineConfigError(
                "--stage1 requires --two-stage (stage 1 is the "
                "candidate-union step)"
            )
        mesh = None
        if args.shards > 1:
            from repro.launch.mesh import make_candidate_mesh

            mesh = make_candidate_mesh(args.shards)
        use_kernel = {"auto": "auto", "1": True, "0": False}[args.use_kernel]
        return cls(
            mode=args.mode,
            use_kernel=use_kernel,
            precision=args.precision,
            stage=("two_stage" if args.two_stage else "single"),
            stage1=args.stage1,
            candidate_fraction=args.candidate_fraction,
            inverted_cap=args.inverted_cap,
            mesh=mesh,
        )
