"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192/expert vocab=202048,
MoE 16 routed experts top-1 + 1 shared expert.  The multimodal early-fusion
frontend is a STUB per instructions — input_specs provide token embeddings;
the backbone here is the text/moe transformer."""
import jax.numpy as jnp

from repro.models.transformer import MoESpec, TransformerConfig

ARCH_ID = "llama4-scout-17b-a16e"
FAMILY = "lm"

SKIP = {
    "long_500k": "interleaved-full-attention arch (iRoPE full-attn layers); "
                 "524k decode skipped per instructions (DESIGN.md §4)",
}
GRAD_ACCUM = {"train_4k": 8}


def full() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=202048,
        rope_theta=5e5,
        # iRoPE interleave: 3 chunked-local (8192-token window) layers per
        # 1 full-attention layer
        window_pattern=(8192, 8192, 8192, None),
        moe=MoESpec(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1,
                    capacity_factor=1.25),
        tie_embeddings=False,
        param_dtype=jnp.float32,
        compute_dtype=jnp.bfloat16,
        q_chunk=1024,
        kv_chunk=1024,
        loss_chunk=2048,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab=181,
        moe=MoESpec(n_experts=4, top_k=1, d_ff_expert=32, n_shared=1,
                    capacity_factor=2.0),
        compute_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
        loss_chunk=64,
    )
