"""gemma2-27b [arXiv:2408.00118; hf]
46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000 —
local+global alternating (4096-token sliding window), logit softcap."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "gemma2-27b"
FAMILY = "lm"

SKIP = {
    "long_500k": "alternating local/global stack still contains full global "
                 "attention every other layer — quadratic at 524k; skipped "
                 "per instructions (DESIGN.md §4)",
}
GRAD_ACCUM = {"train_4k": 8}


def full() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab=256000,
        attn_softcap=50.0,
        final_softcap=30.0,
        window_pattern=(4096, None),   # local (sliding 4096), then global
        tie_embeddings=True,
        param_dtype=jnp.float32,
        compute_dtype=jnp.bfloat16,
        residual_hint=False,
        q_chunk=1024,
        kv_chunk=1024,
        loss_chunk=2048,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=223,
        attn_softcap=50.0,
        final_softcap=30.0,
        window_pattern=(16, None),
        tie_embeddings=True,
        compute_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
        loss_chunk=64,
    )
