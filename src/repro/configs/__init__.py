"""One module per assigned architecture.  Each exposes:

    ARCH_ID   — the public --arch id (dashes)
    FAMILY    — "lm" | "gnn" | "recsys"
    full()    — exact literature config
    smoke()   — reduced same-family config for CPU smoke tests
    SKIP      — {shape_name: reason} for documented cell skips
    GRAD_ACCUM— {shape_name: microbatch count} (training cells)
"""
ARCH_IDS = [
    "command-r-35b",
    "gemma2-27b",
    "qwen3-1.7b",
    "qwen3-moe-30b-a3b",
    "llama4-scout-17b-a16e",
    "nequip",
    "dlrm-mlperf",
    "din",
    "deepfm",
    "bert4rec",
]
