"""dlrm-mlperf [arXiv:1906.00091; paper] — MLPerf DLRM (Criteo 1TB):
n_dense=13 n_sparse=26 embed_dim=128 bot=13-512-256-128
top=1024-1024-512-256-1 interaction=dot."""
from repro.models.recsys import DLRMConfig, MLPERF_VOCAB_SIZES

ARCH_ID = "dlrm-mlperf"
FAMILY = "recsys"

SKIP: dict = {}
GRAD_ACCUM: dict = {}


def _pad16(v: int) -> int:
    # vocab rows padded to x16 so tables shard 2-D (rows x data, dim x
    # model): params + fp32 Adam moments for the ~188M-row Criteo tables
    # are 288 GB — 16-way column sharding alone leaves 18 GB/chip
    return ((v + 15) // 16) * 16


def full() -> DLRMConfig:
    return DLRMConfig(
        name=ARCH_ID,
        n_dense=13,
        vocab_sizes=tuple(_pad16(v) for v in MLPERF_VOCAB_SIZES),
        embed_dim=128,
        bot_mlp=(512, 256, 128),
        top_mlp=(1024, 1024, 512, 256, 1),
        n_user_fields=13,
    )


def smoke() -> DLRMConfig:
    return DLRMConfig(
        name=ARCH_ID + "-smoke",
        n_dense=13,
        vocab_sizes=(100, 57, 200, 33, 80, 3),
        embed_dim=16,
        bot_mlp=(32, 16),
        top_mlp=(32, 16, 1),
        n_user_fields=3,
    )
