"""nequip [arXiv:2101.03164; paper]
n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5 E(3)-tensor-product.

Shape cells carry their own graph sizes + task heads (see registry):
  full_graph_sm  2,708 nodes / 10,556 edges / d_feat 1,433 (node classify)
  minibatch_lg   232,965-node graph, batch_nodes=1,024, fanout 15-10
  ogb_products   2,449,029 nodes / 61,859,140 edges / d_feat 100
  molecule       128 graphs x 30 nodes / 64 edges (graph regression)

CompresSAE is inapplicable to this arch (DESIGN.md §Arch-applicability):
implemented without the technique, as instructed.
"""
from repro.models.nequip import NequIPConfig

ARCH_ID = "nequip"
FAMILY = "gnn"

SKIP: dict = {}
GRAD_ACCUM: dict = {}

# per-shape (d_feat, n_out, task) — the generic GNN shape cells assign
# cora/reddit/ogbn-products-like feature widths to this arch.  Web-scale
# cells run features/messages in bf16 (node-feature arrays + their AD
# cotangents dominate HBM at 2.4M nodes; params/head stay f32).
import jax.numpy as _jnp

SHAPE_TASKS = {
    "full_graph_sm": dict(d_feat=1433, n_out=7, task="node_classify"),
    "minibatch_lg": dict(d_feat=602, n_out=41, task="node_classify",
                         feature_dtype=_jnp.bfloat16),
    "ogb_products": dict(d_feat=100, n_out=47, task="node_classify",
                         feature_dtype=_jnp.bfloat16),
    "molecule": dict(d_feat=16, n_out=1, task="graph_regress"),
}


def full(shape: str = "full_graph_sm") -> NequIPConfig:
    t = SHAPE_TASKS[shape]
    return NequIPConfig(
        name=ARCH_ID,
        n_layers=5,
        d_hidden=32,
        l_max=2,
        n_rbf=8,
        cutoff=5.0,
        avg_degree=8.0,
        **t,
    )


def smoke() -> NequIPConfig:
    return NequIPConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_hidden=8,
        l_max=2,
        n_rbf=4,
        cutoff=5.0,
        d_feat=12,
        n_out=5,
        task="node_classify",
        radial_hidden=16,
        avg_degree=4.0,
    )
