"""bert4rec [arXiv:1904.06690; paper]
embed_dim=64 n_blocks=2 n_heads=2 seq_len=200 bidirectional sequence model.
Catalog sized to the retrieval_cand cell (10^6 items); training uses
sampled softmax (1 positive + 1024 shared negatives) — full softmax over a
million-item catalog at batch 65,536 is neither necessary nor lowerable."""
from repro.models.recsys import Bert4RecConfig

ARCH_ID = "bert4rec"
FAMILY = "recsys"

SKIP: dict = {}
GRAD_ACCUM: dict = {}


def full() -> Bert4RecConfig:
    return Bert4RecConfig(
        name=ARCH_ID,
        n_items=1_000_000,
        embed_dim=64,
        n_blocks=2,
        n_heads=2,
        seq_len=200,
        d_ff=256,
        n_negatives=1024,
    )


def smoke() -> Bert4RecConfig:
    return Bert4RecConfig(
        name=ARCH_ID + "-smoke",
        n_items=300,
        embed_dim=32,
        n_blocks=2,
        n_heads=2,
        seq_len=24,
        d_ff=64,
        n_negatives=16,
    )
