"""qwen3-1.7b [hf:Qwen/Qwen3-8B family; hf]
28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936 — qk_norm, GQA."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "qwen3-1.7b"
FAMILY = "lm"

SKIP = {
    "long_500k": "pure full-attention arch; 524k-token decode skipped per "
                 "instructions (DESIGN.md §4)",
}
GRAD_ACCUM = {"train_4k": 2}


def full() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab=151936,
        qk_norm=True,
        rope_theta=1e6,
        tie_embeddings=True,
        param_dtype=jnp.float32,
        compute_dtype=jnp.bfloat16,
        q_chunk=1024,
        kv_chunk=1024,
        loss_chunk=4096,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=199,
        qk_norm=True,
        tie_embeddings=True,
        compute_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
        loss_chunk=64,
    )
