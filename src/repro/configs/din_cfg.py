"""din [arXiv:1706.06978; paper]
embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80 target-attention."""
from repro.models.recsys import DINConfig

ARCH_ID = "din"
FAMILY = "recsys"

SKIP: dict = {}
GRAD_ACCUM: dict = {}


def full() -> DINConfig:
    return DINConfig(
        name=ARCH_ID,
        n_items=10_000_000,     # catalog scale for retrieval_cand
        embed_dim=18,
        seq_len=100,
        attn_mlp=(80, 40),
        mlp=(200, 80),
    )


def smoke() -> DINConfig:
    return DINConfig(
        name=ARCH_ID + "-smoke",
        n_items=500,
        embed_dim=18,
        seq_len=20,
        attn_mlp=(16, 8),
        mlp=(32, 16),
    )
