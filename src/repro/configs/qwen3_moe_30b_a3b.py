"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf]
48L d_model=2048 32H (GQA kv=4) d_ff=768/expert vocab=151936,
MoE 128 experts top-8 — qk_norm."""
import jax.numpy as jnp

from repro.models.transformer import MoESpec, TransformerConfig

ARCH_ID = "qwen3-moe-30b-a3b"
FAMILY = "lm"

SKIP = {
    "long_500k": "pure full-attention arch; 524k-token decode skipped per "
                 "instructions (DESIGN.md §4)",
}
GRAD_ACCUM = {"train_4k": 4}


def full() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab=151936,
        qk_norm=True,
        rope_theta=1e6,
        moe=MoESpec(n_experts=128, top_k=8, d_ff_expert=768,
                    capacity_factor=1.25),
        tie_embeddings=False,
        param_dtype=jnp.float32,
        compute_dtype=jnp.bfloat16,
        q_chunk=1024,
        kv_chunk=1024,
        loss_chunk=4096,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab=191,
        qk_norm=True,
        moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=2.0),
        compute_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
        loss_chunk=64,
    )
