"""deepfm [arXiv:1703.04247; paper]
n_sparse=39 embed_dim=10 mlp=400-400-400 interaction=fm (Criteo fields)."""
from repro.models.recsys import DeepFMConfig

ARCH_ID = "deepfm"
FAMILY = "recsys"

SKIP: dict = {}
GRAD_ACCUM: dict = {}

# Criteo-like field cardinalities: 13 bucketized numeric + 26 categorical.
# Vocab sizes are padded up to multiples of 16 so the embed_dim=10 tables
# can be ROW-sharded over the 16-way model axis (standard vocab padding;
# embed_dim 10 is not divisible, so column sharding is unavailable).
def _pad16(v: int) -> int:
    return ((v + 15) // 16) * 16

CRITEO_39 = tuple(_pad16(v) for v in [1000] * 13 + [
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145,
    5683, 8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4,
    7046547, 18, 15, 286181, 105, 142572,
])

def full() -> DeepFMConfig:
    return DeepFMConfig(
        name=ARCH_ID,
        vocab_sizes=CRITEO_39,
        embed_dim=10,
        mlp=(400, 400, 400),
        n_user_fields=20,
    )


def smoke() -> DeepFMConfig:
    return DeepFMConfig(
        name=ARCH_ID + "-smoke",
        vocab_sizes=tuple([50] * 8),
        embed_dim=10,
        mlp=(32, 16),
        n_user_fields=4,
    )
