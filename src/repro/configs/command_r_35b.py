"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01; unverified]
40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — GQA, no-bias."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "command-r-35b"
FAMILY = "lm"

SKIP = {
    "long_500k": "pure full-attention arch (GQA, no sub-quadratic path); "
                 "524k-token decode skipped per instructions (DESIGN.md §4)",
}
GRAD_ACCUM = {"train_4k": 8}


def full() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22528,
        vocab=256000,
        rope_theta=8e6,
        tie_embeddings=False,
        param_dtype=jnp.float32,
        compute_dtype=jnp.bfloat16,
        residual_hint=False,
        q_chunk=1024,
        kv_chunk=1024,
        loss_chunk=2048,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=1,
        head_dim=8,
        d_ff=160,
        vocab=211,
        rope_theta=8e6,
        compute_dtype=jnp.float32,
        q_chunk=16,
        kv_chunk=16,
        loss_chunk=64,
    )
