"""Production training entry point (CompresSAE + any registry arch).

Fault-tolerance behaviors exercised here (DESIGN.md §5):
  * deterministic resumable data (batch = f(seed, step)),
  * periodic async checkpoints, atomic on disk, keep-N,
  * resume-from-latest on startup — including onto a DIFFERENT device
    count (elastic): checkpoints are mesh-agnostic,
  * step-time watchdog: a step exceeding ``watchdog_factor`` × the rolling
    p50 is logged as a straggler event; after ``max_straggler_steps``
    consecutive events the process exits non-zero so the cluster manager
    reschedules it (the standard large-fleet mitigation — within-step
    recovery is impossible under XLA's static schedule, so mitigation
    happens at the step boundary by design).

CPU-scale demo:
    PYTHONPATH=src python -m repro.launch.train --steps 200 --batch 4096 \
        --d 256 --h 1024 --k 16 --ckpt-dir /tmp/sae_ckpt
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import SAEConfig, eval_step, init_train_state, train_step
from repro.core.train import TrainState
from repro.data import ShardedLoader, clustered_embeddings
from repro.optim import AdamConfig, cosine_decay


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--h", type=int, default=1024)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=25)
    ap.add_argument("--watchdog-factor", type=float, default=5.0)
    ap.add_argument("--max-straggler-steps", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = SAEConfig(d=args.d, h=args.h, k=args.k)
    opt_cfg = AdamConfig(lr=args.lr, grad_clip_norm=1.0)
    state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    start_step = 0

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None:
        restored, meta = mgr.restore(state)
        if restored is not None:
            state, start_step = restored, int(meta["step"])
            print(f"[ckpt] resumed from step {start_step}")

    loader = ShardedLoader(
        generate=lambda key, shard, n: {
            "x": clustered_embeddings(key, args.batch, d=cfg.d)
        },
        seed=args.seed,
    )

    @jax.jit
    def step_fn(state: TrainState, batch, step):
        lr_scale = cosine_decay(step, args.steps, warmup_steps=20)
        return train_step(state, batch, cfg, opt_cfg, lr_scale)

    times = []
    stragglers = 0
    for step in range(start_step, args.steps):
        batch = loader.batch_at(step)["x"]
        t0 = time.time()
        state, metrics = step_fn(state, batch, step)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        times.append(dt)
        med = float(np.median(times[-50:]))
        if len(times) > 10 and dt > args.watchdog_factor * med:
            stragglers += 1
            print(f"[watchdog] step {step} took {dt:.3f}s (p50 {med:.3f}s) "
                  f"— straggler {stragglers}/{args.max_straggler_steps}")
            if stragglers >= args.max_straggler_steps:
                print("[watchdog] persistent straggler — exiting for reschedule")
                if mgr:
                    mgr.wait()
                return 17
        else:
            stragglers = 0
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"cos_k {float(metrics['cos_loss_k']):.4f} "
                  f"active {float(metrics['frac_active_latents']):.3f} "
                  f"({dt*1e3:.0f} ms)")
        if mgr is not None and step and step % args.ckpt_every == 0:
            mgr.save_async(step, state, {"cfg": vars(args)})
    if mgr is not None:
        mgr.wait()
        mgr.save(args.steps, state, {"cfg": vars(args)})
    ev = eval_step(state.params, loader.batch_at(args.steps + 1)["x"], cfg)
    print(f"final eval: cos_loss_k {float(ev['eval_cos_loss_k']):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
