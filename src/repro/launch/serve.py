"""Retrieval serving entry point — the paper's inference section as a
runnable service loop.

Builds a compressed index (CompresSAE codes + norms) over a catalog, then
constructs a ``repro.serving.RetrievalEngine`` — ONE object owning
(params, index, mode, backend, mesh) — and serves batched requests through
``engine.retrieve_dense(x, n)``: raw dense embeddings in, top-n out, the
whole encode→score→select chain under a single jit with no dense-query or
code round-trip through HBM (on TPU: fused_encode → fused_retrieve_sparse_q,
only (Q, k) codes and (Q, n) results touch HBM).  Modes:
  * sparse         — direct sparse-space cosine (fast path; sparse-query
                     kernel, codes scored as-is)
  * reconstructed  — kernel-trick scoring (high-fidelity path; dense
                     z = W_decᵀ(W_dec s_q) folded into the query prep)
and reports recall@n against exact dense retrieval plus latency stats,
including which backend path (fused Pallas kernels vs chunked jnp) served.

    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --requests 20

Candidate-sharded serving (catalogs beyond one chip's HBM): ``--shards N``
shards the index along the candidate axis of an N-way mesh; the engine
replicates the prepped query (sparse mode: just the (Q, k) codes) into the
shard_map and merges per-shard top-n sets with one small all-gather —
bit-identical results to single-device serving:

    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --shards 4

Quantized serving (``--quantized``): the index that lives in HBM is the
compound-compressed format itself — int8 values + int16/int32 indices +
fp32 per-row scales (~2.6x less index traffic than fp32 codes at k=32) —
streamed straight into the quantized fused-retrieve generation, which
dequantizes candidate tiles in VMEM.  Results are bit-identical to
serving the dequantized index; composes with ``--shards``:

    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --quantized

Approximate int8 scoring (``--precision int8``, requires ``--quantized``):
candidate tiles are scored in int8×int8 with int32 accumulation
(generation 5) instead of being dequantized to f32 first — the quality
cost vs exact scoring is reported live as recall@n against the same
exact quantized engine (``repro.core.eval``), alongside the usual recall
against dense truth:

    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --quantized --precision int8

Hardened serving (ISSUE 6): requests flow through a
``repro.serving.GuardedEngine`` — admission guards, an optional
per-request deadline, and the degradation ladder — and the ``[serve]``
line reports degraded/sanitized request counters.  ``--self-check``
verifies the index checksum and runs the canary batch before traffic;
``--inject-fault`` exercises one deterministic failure end to end
(``corrupt-index`` keeps a pristine fallback index so startup degrades
instead of dying; ``dead-shard``/``slow-shard`` need ``--shards > 1``):

    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --self-check
    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --quantized --self-check --inject-fault corrupt-index
    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --shards 4 --inject-fault dead-shard

Two-stage retrieval (ISSUE 7 + 8, ``--two-stage``): stage 1 unions the
query's k posting lists from an inverted index over the latents into a
bounded candidate set (``--candidate-fraction`` of the catalog), stage 2
gathers those rows into (Q, budget) candidate panels in ONE batched
gather and re-ranks the whole panel through a single gather-aware fused
retrieve — sub-linear in catalog size, approximate (recall vs dense
truth reported as usual).  Stage 1 runs on device by default (one jitted
batched union, no per-query host loop); ``--stage1 host`` pins the
bit-identical NumPy oracle instead.  The guard ladder sheds a device
stage-1 fault to host stage 1, then to the exact single-stage scan
(postings corruption fails both stage-1 rungs, e.g. ``--inject-fault
corrupt-postings``; with ``--self-check`` it is already a typed startup
failure via the inverted-index checksum):

    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --two-stage
    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --two-stage --candidate-fraction 0.1
    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --two-stage --stage1 host
    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --two-stage --inject-fault corrupt-postings

Mutable serving (ISSUE 9, ``--mutate``): the engine serves a
``repro.core.segments.SegmentedIndex`` — the built index becomes the
immutable quantized base, and a deterministic add/delete/compact trace is
replayed through ``engine.apply_update`` before traffic: deletes fold into
the kernels' masking epilogue (fully-deleted tiles are skipped on device),
adds land in a small append-only delta segment served as an extra shard of
the same streaming top-n, and ``compact`` folds survivors into a fresh
base bit-identical to rebuilding from scratch.  Recall is reported against
dense truth over the SURVIVING catalog (deleted rows excluded, added rows
included).  ``--inject-fault corrupt-delta`` flips one bit in the delta
segment; the per-segment CRC catches it at startup and serving sheds to
base-only with the lost coverage reported:

    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --mutate
    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --quantized --mutate --self-check
    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --quantized --mutate --inject-fault corrupt-delta

Microbatched loadtest (ISSUE 10, ``--loadtest``): instead of the fixed
recall loop, drive the same hardened stack through the microbatching
front (``repro.serving.MicrobatchServer``) with Zipfian closed-loop
traffic — concurrent single-row requests coalesced into BLOCK_Q-aligned
panels (``--max-wait-us`` bounds how long a lone request waits) — and
report latency percentiles, throughput, mean batch occupancy and shed
rate.  The full traffic-shaped benchmark driver (open-loop Poisson
arrivals, ``BENCH_serving.json``) is ``repro.launch.loadtest``:

    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --loadtest --requests 200
    PYTHONPATH=src python -m repro.launch.serve --catalog 50000 --quantized --loadtest --max-wait-us 500
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _force_host_devices_from_argv() -> None:
    """``--shards N`` on CPU needs N visible devices, and XLA only honours
    the device-count forcing flag before jax initializes — so peek at argv
    at module-import time, before the jax import below.  No-op when the
    flag is already present (e.g. under the tier-1 conftest) or on real
    multi-device backends."""
    n = None
    for i, tok in enumerate(sys.argv):
        try:
            if tok == "--shards":
                n = int(sys.argv[i + 1])
            elif tok.startswith("--shards="):
                n = int(tok.split("=", 1)[1])
        except (IndexError, ValueError):
            return
    if n is None:
        return
    flag = "xla_force_host_platform_device_count"
    if n > 1 and flag not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"{os.environ.get('XLA_FLAGS', '')} --{flag}={n}"
        ).strip()


if __name__ == "__main__":
    _force_host_devices_from_argv()

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig,
    build_index,
    encode,
    init_train_state,
    score_dense,
    top_n,
    train_step,
)
from repro.core.retrieval import kernel_path
from repro.core.eval import recall_at_n, retrieval_quality
from repro.data import clustered_embeddings
from repro.errors import EngineConfigError
from repro.optim import AdamConfig
from repro.serving import (
    FAULTS,
    EngineConfig,
    FaultInjector,
    GuardedEngine,
    RetrievalEngine,
    corrupt_postings,
    flip_delta_byte,
    flip_index_byte,
    poison_queries,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    EngineConfig.add_flags(ap)  # the shared engine-knob namespace
    ap.add_argument("--catalog", type=int, default=50000)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--h", type=int, default=1024)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--topn", type=int, default=20)
    ap.add_argument("--loadtest", action="store_true",
                    help="after building the (possibly hardened/mutated) "
                         "engine, drive it through the microbatching front "
                         "with Zipfian closed-loop traffic instead of the "
                         "fixed recall loop (see repro.launch.loadtest for "
                         "the full benchmark driver)")
    ap.add_argument("--max-wait-us", type=float, default=2000.0,
                    help="loadtest microbatch coalescing deadline for the "
                         "oldest queued request")
    ap.add_argument("--mutate", action="store_true",
                    help="serve a segmented mutable index: the built index "
                         "becomes the immutable base and a deterministic "
                         "add/delete/compact trace is replayed through "
                         "engine.apply_update before traffic (sparse mode, "
                         "unsharded, single-stage)")
    ap.add_argument("--self-check", action="store_true",
                    help="verify the index content checksum and run a "
                         "canary batch against the reference contract "
                         "before accepting traffic (typed error on failure)")
    ap.add_argument("--inject-fault", choices=FAULTS, default=None,
                    help="deterministically inject one serving fault and "
                         "serve through it (demonstrates the degradation "
                         "ladder; see repro.serving.faults)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline budget; slow paths are "
                         "abandoned when it expires and the response is "
                         "tagged deadline_exceeded (default: unbounded)")
    args = ap.parse_args(argv)
    # engine-knob cross checks (int8 vs quantized, two-stage vs shards,
    # stage1 vs two-stage, ...) live on EngineConfig now — one namespace,
    # one validator, every entry point
    try:
        engine_cfg = EngineConfig.from_flags(args)
    except EngineConfigError as e:
        ap.error(str(e))
    # serve-specific combinations stay here: fault fixtures and the
    # mutable-serving trace are this entry point's own surface
    if args.inject_fault in ("dead-shard", "slow-shard") and args.shards < 2:
        ap.error(f"--inject-fault {args.inject_fault} requires --shards > 1")
    if args.inject_fault == "corrupt-postings" and not args.two_stage:
        ap.error("--inject-fault corrupt-postings requires --two-stage "
                 "(the fault lives in stage 1's posting lists)")
    if args.mutate and (args.shards > 1 or args.two_stage
                        or args.mode != "sparse"):
        ap.error("--mutate requires --mode sparse, --shards 1 and no "
                 "--two-stage (the segmented index serves single-stage "
                 "sparse, unsharded)")
    if args.inject_fault == "corrupt-delta" and not args.mutate:
        ap.error("--inject-fault corrupt-delta requires --mutate "
                 "(the fault lives in the segmented index's delta)")

    path = ("fused-kernel" if kernel_path(engine_cfg.use_kernel)
            else "jnp-chunked")
    mesh = engine_cfg.mesh
    if mesh is not None:
        path = f"{path}+sharded"

    cfg = SAEConfig(d=args.d, h=args.h, k=args.k)
    catalog = clustered_embeddings(jax.random.PRNGKey(0), args.catalog, d=cfg.d)

    print(f"[index] training CompresSAE ({cfg.d}->{cfg.h}, k={cfg.k}) "
          f"on {args.catalog} embeddings")
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(args.train_steps):
        idx = jax.random.randint(
            jax.random.PRNGKey(100 + i), (min(8192, args.catalog),), 0, args.catalog
        )
        state, m = step(state, catalog[idx])
    print(f"[index] final cos loss {float(m['loss']):.4f}")

    codes = encode(state.params, catalog, cfg.k)
    index = build_index(codes, state.params, quantize=args.quantized)
    dense_bytes = args.catalog * cfg.d * 4
    sparse_bytes = codes.nbytes_logical
    print(f"[index] dense {dense_bytes/2**20:.1f} MiB -> compressed "
          f"{sparse_bytes/2**20:.1f} MiB ({dense_bytes/sparse_bytes:.1f}x)")
    if args.quantized:
        q_bytes = index.codes.nbytes_logical
        path = f"{path}+quantized"
        print(f"[index] serving format: int8/{index.codes.indices.dtype} "
              f"{q_bytes/2**20:.2f} MiB in HBM "
              f"({100 * q_bytes / sparse_bytes:.0f}% of the fp32 codes, "
              f"{dense_bytes/q_bytes:.1f}x vs dense)")

    if args.precision == "int8":
        path = f"{path}+int8"
    if args.two_stage:
        stage1_impl = "device" if args.stage1 == "auto" else args.stage1
        path = f"{path}+two-stage-{stage1_impl}"

    # ------------------------------------------------ hardened serving setup
    fallback_index = None
    if args.inject_fault == "corrupt-index":
        # serve the corrupted bytes, keep the pristine build as the
        # verified fallback replica — startup must catch the flip by
        # checksum and degrade onto the fallback instead of dying
        fallback_index, index = index, flip_index_byte(index, byte=17, bit=2)
        args.self_check = True
        print("[faults] corrupt-index: flipped one bit in the served "
              "index; pristine fallback retained")
    injector = None
    if args.inject_fault in ("dead-shard", "slow-shard", "kernel-exception"):
        injector = FaultInjector(args.inject_fault, shard=0)
        print(f"[faults] injecting {args.inject_fault} "
              f"(deterministic, shard 0)")

    serve_index = index
    if args.mutate:
        from repro.core.segments import SegmentedIndex

        serve_index = SegmentedIndex.from_index(index)
        path = f"{path}+segmented"

    engine = RetrievalEngine(serve_index, state.params, config=engine_cfg)
    if args.inject_fault == "corrupt-postings":
        # plant out-of-range ids in the posting lists AFTER the build:
        # stage 1's integrity check must trip on every request, and the
        # ladder must re-serve each one on the exact single-stage rung
        engine.inverted = corrupt_postings(engine.inverted)
        print("[faults] corrupt-postings: planted out-of-range ids in "
              "every posting list; expecting per-request fallback to "
              "single-stage")

    # ----------------------------------------------- mutable serving trace
    all_emb, surv_ids, surv_emb = catalog, None, None
    if args.mutate:
        n0 = args.catalog
        new_emb = clustered_embeddings(jax.random.PRNGKey(77), 24, d=cfg.d)
        all_emb = jnp.concatenate([catalog, new_emb], axis=0)
        new_codes = encode(state.params, new_emb, cfg.k)

        def _rows(c, lo, hi):
            return c._replace(values=c.values[lo:hi],
                              indices=c.indices[lo:hi])

        del0 = sorted({int(v) for v in
                       np.linspace(0, n0 - 1, 7).astype(np.int64)})
        engine.apply_update("delete", ids=del0)
        engine.apply_update("add", codes=_rows(new_codes, 0, 16),
                            ids=list(range(n0, n0 + 16)))
        engine.apply_update("delete", ids=[n0 + 3, n0 + 11])
        engine.apply_update("compact")
        engine.apply_update("add", codes=_rows(new_codes, 16, 24),
                            ids=list(range(n0 + 16, n0 + 24)))
        more = [int(v) for v in np.asarray(engine.segments.alive_ids())
                if int(v) < n0][:3]
        engine.apply_update("delete", ids=more)
        seg = engine.segments
        n_del = len(del0) + 2 + len(more)
        print(f"[mutate] trace replayed through apply_update: "
              f"{n0} base rows, +24 added, -{n_del} deleted, 1 compaction "
              f"-> {seg.n_alive} alive "
              f"(base coverage {seg.base_coverage:.3f})")
        # dense truth for recall is the SURVIVING catalog: deleted rows
        # excluded, added rows included, positions translated to item ids
        surv = np.asarray(seg.alive_ids())
        surv_ids = jnp.asarray(surv)
        surv_emb = jnp.asarray(np.asarray(all_emb)[surv])
        if args.inject_fault == "corrupt-delta":
            engine = RetrievalEngine(flip_delta_byte(seg), state.params,
                                     config=engine_cfg)
            args.self_check = True
            print("[faults] corrupt-delta: flipped one bit in the delta "
                  "segment; expecting the per-segment CRC to catch it at "
                  "startup and serving to shed to base-only")

    guard = GuardedEngine(
        engine,
        deadline_ms=args.deadline_ms,
        on_invalid=("sanitize" if args.inject_fault == "nonfinite-query"
                    else "reject"),
        injector=injector,
        fallback_index=fallback_index,
        run_self_check=args.self_check,
    )
    if guard.self_check_report is not None:
        rep = guard.self_check_report
        print(f"[self-check] index checksum verified; canary "
              f"{rep.canary_q}x top-{rep.canary_n} on {rep.path} ok "
              f"(kernel-vs-ref: {rep.kernel_vs_ref or 'same path'}, "
              f"max |Δscore| {rep.max_abs_diff:.2e})")
    if guard.degraded_from_start:
        print(f"[self-check] DEGRADED: {guard.degraded_from_start}")
        engine = guard.engine  # the fallback-backed engine now serves

    # --------------------------------------------- microbatched loadtest
    if args.loadtest:
        # same hardened stack, but traffic-shaped: Zipfian single-row
        # requests coalesced into BLOCK_Q panels by the microbatch front
        from repro.data import ZipfianQueryStream
        from repro.launch.loadtest import run_closed_loop, summarize
        from repro.serving import MicrobatchServer

        users = np.asarray(
            clustered_embeddings(jax.random.PRNGKey(7), 2000, d=cfg.d))
        stream = ZipfianQueryStream(users, seed=0)
        _, queries = stream.sample(max(args.requests, 1))
        with MicrobatchServer(guard,
                              max_wait_us=args.max_wait_us) as server:
            server.warmup(args.topn)
            result = run_closed_loop(server, queries, concurrency=16,
                                     topn=args.topn)
            rec = summarize(result, server, extra={"path": path})
        print(f"[serve] loadtest path={path} closed-loop "
              f"{rec['requests']} requests: "
              f"p50 {rec['p50_ms']:.1f} ms p95 {rec['p95_ms']:.1f} ms "
              f"p99 {rec['p99_ms']:.1f} ms | "
              f"{rec['throughput_rps']:.0f} rps, "
              f"occupancy {rec['occupancy_mean']:.2f}, "
              f"shed {rec['shed_rate']:.3f}, panels {rec['panels']}")
        return 0

    # int8 scoring is approximate: measure its live quality against the
    # SAME engine at exact precision (the harness's reference path)
    exact_engine = None
    if args.precision == "int8" and guard.engine.precision == "int8":
        seg_now = getattr(guard.engine, "segments", None)
        exact_engine = RetrievalEngine(
            seg_now if seg_now is not None else guard.engine.index,
            state.params,
            config=engine_cfg.replace(
                precision="exact", stage="single", stage1="auto",
                mesh=None if seg_now is not None else mesh,
            ),
        )

    lat, recalls, vs_exact = [], [], []
    for r in range(args.requests):
        q = clustered_embeddings(jax.random.PRNGKey(1000 + r), args.batch, d=cfg.d)
        if args.inject_fault == "nonfinite-query":
            q = poison_queries(q, kind="nan" if r % 2 == 0 else "inf",
                               position=(r % args.batch, r % cfg.d))
        t0 = time.time()
        vals, ids, status, *_ = guard.retrieve_dense(q, args.topn)
        jax.block_until_ready(ids)
        lat.append(time.time() - t0)
        if status.degraded and r < 3:
            print(f"[guard] request {r} degraded -> {status.path} "
                  f"({status.fault})")
        if args.mutate:
            _, pos = top_n(score_dense(surv_emb, q), args.topn)
            true_ids = jnp.take(surv_ids, pos)
        else:
            _, true_ids = top_n(score_dense(catalog, q), args.topn)
        recalls.append(recall_at_n(ids, true_ids))
        if exact_engine is not None:
            exact = exact_engine.retrieve_dense(q, args.topn)
            vs_exact.append(retrieval_quality((vals, ids), exact)["recall"])
    lat_ms = np.array(lat[1:]) * 1e3  # drop compile step
    quality = (f"int8-vs-exact recall@{args.topn} {np.mean(vs_exact):.3f} "
               if vs_exact else "")
    c = guard.counters
    guard_stats = (f"degraded {c['degraded']}/{c['requests']} "
                   f"sanitized {c['sanitized']} rejected {c['rejected']} ")
    two_stage_stats = (f"cand_frac {args.candidate_fraction:g} "
                       if args.two_stage else "")
    prefix = (f"[serve] mode={args.mode} path={path} shards={args.shards} "
              f"{two_stage_stats}"
              f"recall@{args.topn} {np.mean(recalls):.3f} {quality}"
              f"{guard_stats}| ")
    if lat_ms.size:
        print(prefix +
              f"latency p50 {np.percentile(lat_ms, 50):.1f} ms "
              f"p99 {np.percentile(lat_ms, 99):.1f} ms over {args.requests} requests")
    else:
        # a single request is all compile: percentiles over zero steady-state
        # samples would raise — report the compile+first-request time instead
        print(prefix +
              f"compile+first-request {lat[0] * 1e3:.1f} ms "
              "(1 request; no steady-state latency percentiles)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
