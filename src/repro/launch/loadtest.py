"""Traffic-shaped serving loadtest (ISSUE 10 tentpole).

Replays a Zipfian user/query distribution (``data.sampler
.ZipfianQueryStream`` over ``data.synthetic.clustered_embeddings`` user
preferences) against the microbatching serving front
(``serving.batcher.MicrobatchServer`` wrapping a ``GuardedEngine``), and
reports what a single cold ``us_per_call`` number cannot: latency
percentiles, throughput, batch occupancy and shed rate under sustained
concurrent load.

Two drivers, both fully seeded on the request-content side:

* **closed loop** — ``--concurrency`` workers, each submitting its next
  request the moment the previous one completes: measures the system's
  sustainable throughput and the latency it costs.
* **open loop** — requests arrive on a Poisson process at
  ``--offered-load`` rps regardless of completions (the honest overload
  model): measures queueing delay, and the shed rate once the offered
  load exceeds what coalescing can absorb.  Latency is measured from the
  *scheduled arrival*, so queue buildup is charged to the system, not
  hidden in the driver.

Results land wholesale in a schema-gated ``BENCH_serving.json``
(``tools/check_bench.py --schema serving``: schema/row-set/shed-rate
gate, latency warn-only — CPU-runner timing is noise):

    PYTHONPATH=src python -m repro.launch.loadtest --smoke
    PYTHONPATH=src python -m repro.launch.loadtest --catalog 50000 \
        --requests 2000 --offered-load 300 --max-wait-us 2000

Engine knobs ride the shared ``EngineConfig.from_flags`` namespace, so
``--quantized --precision int8``, ``--two-stage``, ``--shards N`` etc.
mean exactly what they mean in ``repro.launch.serve``.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import threading
import time


def _force_host_devices_from_argv() -> None:
    """``--shards N`` on CPU needs N visible devices before jax imports —
    same trick as ``repro.launch.serve`` (see there)."""
    n = None
    for i, tok in enumerate(sys.argv):
        try:
            if tok == "--shards":
                n = int(sys.argv[i + 1])
            elif tok.startswith("--shards="):
                n = int(tok.split("=", 1)[1])
        except (IndexError, ValueError):
            return
    if n is None:
        return
    flag = "xla_force_host_platform_device_count"
    if n > 1 and flag not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"{os.environ.get('XLA_FLAGS', '')} --{flag}={n}"
        ).strip()


if __name__ == "__main__":
    _force_host_devices_from_argv()

import numpy as np
import jax

from repro.core import SAEConfig, build_index, encode, init_train_state, train_step
from repro.data import ZipfianQueryStream, clustered_embeddings
from repro.errors import QueueFullError
from repro.optim import AdamConfig
from repro.serving import (
    EngineConfig,
    GuardedEngine,
    MicrobatchServer,
    RetrievalEngine,
    path_name,
)


# --------------------------------------------------------------- drivers
class _Slot:
    """One in-flight open-loop request: scheduled arrival + completion."""

    __slots__ = ("sched", "future", "done_t", "shed")

    def __init__(self, sched: float):
        self.sched = sched
        self.future = None
        self.done_t = None
        self.shed = False


def run_open_loop(server: MicrobatchServer, queries: np.ndarray, *,
                  offered_rps: float, topn: int, seed: int = 0) -> dict:
    """Poisson arrivals at ``offered_rps``; latency from scheduled
    arrival; sheds counted, not retried (the honest overload picture)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / offered_rps, size=len(queries))
    sched = np.cumsum(gaps)
    slots = [_Slot(s) for s in sched]
    t0 = time.monotonic()
    for q, slot in zip(queries, slots):
        now = time.monotonic()
        wait = (t0 + slot.sched) - now
        if wait > 0:
            time.sleep(wait)
        try:
            slot.future = server.submit(q, topn)
        except QueueFullError:
            slot.shed = True
            continue

        def _stamp(fut, slot=slot):
            slot.done_t = time.monotonic()

        slot.future.add_done_callback(_stamp)
    for slot in slots:
        if slot.future is not None:
            slot.future.result(timeout=120)
    wall = time.monotonic() - t0
    lats, statuses = [], []
    for slot in slots:
        if slot.shed:
            continue
        lats.append(slot.done_t - (t0 + slot.sched))
        statuses.append(slot.future.result().status)
    return dict(
        kind="open", lats_s=lats, statuses=statuses, wall_s=wall,
        submitted=len(queries), shed=sum(s.shed for s in slots),
        offered_rps=float(offered_rps),
    )


def run_closed_loop(server: MicrobatchServer, queries: np.ndarray, *,
                    concurrency: int, topn: int) -> dict:
    """``concurrency`` workers in lock-step with completions — measures
    sustainable throughput; queues stay bounded by construction."""
    cursor = {"i": 0}
    lock = threading.Lock()
    lats: list[float] = [None] * len(queries)
    statuses: list = [None] * len(queries)
    shed = {"count": 0}

    def worker():
        while True:
            with lock:
                i = cursor["i"]
                if i >= len(queries):
                    return
                cursor["i"] = i + 1
            t_s = time.monotonic()
            try:
                resp = server.serve(queries[i], topn, timeout=120)
            except QueueFullError:
                with lock:
                    shed["count"] += 1
                continue
            lats[i] = time.monotonic() - t_s
            statuses[i] = resp.status

    t0 = time.monotonic()
    threads = [threading.Thread(target=worker) for _ in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    done_lats = [v for v in lats if v is not None]
    done_status = [s for s in statuses if s is not None]
    return dict(
        kind="closed", lats_s=done_lats, statuses=done_status, wall_s=wall,
        submitted=len(queries), shed=shed["count"],
        offered_rps=(len(done_lats) / wall if wall > 0 else 0.0),
    )


def summarize(result: dict, server: MicrobatchServer, *,
              extra: dict) -> dict:
    """One ``BENCH_serving.json`` row from a driver result + the server's
    panel counters."""
    lats_ms = np.asarray(result["lats_s"], dtype=np.float64) * 1e3
    stats = server.stats()
    completed = int(lats_ms.size)
    degraded = sum(1 for s in result["statuses"] if s.degraded)
    paths = {s.path for s in result["statuses"]}
    rec = {
        "name": f"serving_{result['kind']}_loop",
        "p50_ms": float(np.percentile(lats_ms, 50)) if completed else 0.0,
        "p95_ms": float(np.percentile(lats_ms, 95)) if completed else 0.0,
        "p99_ms": float(np.percentile(lats_ms, 99)) if completed else 0.0,
        "throughput_rps": (completed / result["wall_s"]
                           if result["wall_s"] > 0 else 0.0),
        "offered_rps": result["offered_rps"],
        "occupancy_mean": stats["occupancy_mean"],
        "shed_rate": (result["shed"] / result["submitted"]
                      if result["submitted"] else 0.0),
        "requests": result["submitted"],
        "completed": completed,
        "degraded": degraded,
        "panels": stats["panels"],
        "paths_seen": sorted(paths),
    }
    rec.update(extra)
    return rec


# ------------------------------------------------------------------ main
def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    EngineConfig.add_flags(ap)
    ap.add_argument("--catalog", type=int, default=20000)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--h", type=int, default=1024)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--users", type=int, default=2000,
                    help="Zipf-popular user population size")
    ap.add_argument("--zipf-a", type=float, default=1.1)
    ap.add_argument("--jitter", type=float, default=0.05,
                    help="per-request Gaussian jitter on the user embedding")
    ap.add_argument("--requests", type=int, default=600,
                    help="requests per driver")
    ap.add_argument("--topn", type=int, default=20)
    ap.add_argument("--offered-load", type=float, default=300.0,
                    help="open-loop Poisson arrival rate (requests/s)")
    ap.add_argument("--concurrency", type=int, default=16,
                    help="closed-loop worker count")
    ap.add_argument("--max-wait-us", type=float, default=2000.0,
                    help="microbatch coalescing deadline for the oldest "
                         "queued request")
    ap.add_argument("--max-queue-rows", type=int, default=256,
                    help="admission bound: queued rows beyond this shed "
                         "with a typed QueueFullError")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (same schema, smoke-tagged rows)")
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path("BENCH_serving.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        args.catalog = min(args.catalog, 2000)
        args.users = min(args.users, 200)
        args.requests = min(args.requests, 80)
        args.train_steps = min(args.train_steps, 20)
        args.offered_load = min(args.offered_load, 200.0)
        args.concurrency = min(args.concurrency, 8)
    try:
        engine_cfg = EngineConfig.from_flags(args)
    except Exception as e:  # EngineConfigError -> clean CLI message
        ap.error(str(e))

    # ------------------------------------------------------- build stack
    cfg = SAEConfig(d=args.d, h=args.h, k=args.k)
    key = jax.random.PRNGKey(args.seed)
    catalog = clustered_embeddings(key, args.catalog, d=cfg.d)
    print(f"[loadtest] training CompresSAE ({cfg.d}->{cfg.h}, k={cfg.k}) "
          f"on {args.catalog} embeddings")
    state = init_train_state(cfg, jax.random.PRNGKey(args.seed + 1))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(args.train_steps):
        idx = jax.random.randint(
            jax.random.PRNGKey(100 + i),
            (min(8192, args.catalog),), 0, args.catalog,
        )
        state, _ = step(state, catalog[idx])
    codes = encode(state.params, catalog, cfg.k)
    index = build_index(codes, state.params, quantize=args.quantized)
    engine = RetrievalEngine(index, state.params, config=engine_cfg)
    guard = GuardedEngine(engine)
    print(f"[loadtest] path={path_name(engine)} catalog={args.catalog} "
          f"users={args.users} zipf_a={args.zipf_a} topn={args.topn}")

    users = np.asarray(
        clustered_embeddings(jax.random.PRNGKey(args.seed + 2),
                             args.users, d=cfg.d)
    )
    extra = {
        "path": path_name(engine),
        "shards": args.shards,
        "n": args.catalog,
        "users": args.users,
        "zipf_a": args.zipf_a,
        "topn": args.topn,
        "max_wait_us": args.max_wait_us,
        "max_queue_rows": args.max_queue_rows,
        "smoke": bool(args.smoke),
    }

    records = []
    for kind in ("closed", "open"):
        # a fresh stream (same seed) and a fresh server per driver: both
        # drivers replay the SAME deterministic request sequence, and the
        # occupancy/panel counters are per-driver
        stream = ZipfianQueryStream(users, zipf_a=args.zipf_a,
                                    jitter=args.jitter, seed=args.seed + 3)
        _, queries = stream.sample(args.requests)
        with MicrobatchServer(guard, max_wait_us=args.max_wait_us,
                              max_queue_rows=args.max_queue_rows) as server:
            server.warmup(args.topn)
            if kind == "closed":
                result = run_closed_loop(server, queries,
                                         concurrency=args.concurrency,
                                         topn=args.topn)
            else:
                result = run_open_loop(server, queries,
                                       offered_rps=args.offered_load,
                                       topn=args.topn, seed=args.seed + 4)
            rec = summarize(result, server, extra=extra)
        records.append(rec)
        print(f"[loadtest] {rec['name']}: p50 {rec['p50_ms']:.1f} ms  "
              f"p95 {rec['p95_ms']:.1f} ms  p99 {rec['p99_ms']:.1f} ms  "
              f"{rec['throughput_rps']:.0f} rps "
              f"(offered {rec['offered_rps']:.0f})  "
              f"occupancy {rec['occupancy_mean']:.2f}  "
              f"shed {rec['shed_rate']:.3f}  "
              f"panels {rec['panels']}")

    args.out.write_text(json.dumps(records, indent=2) + "\n")
    print(f"[loadtest] wrote {len(records)} records -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
