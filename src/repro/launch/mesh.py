"""Production mesh construction (DESIGN.md §5).

Defined as functions (never module-level constants) so importing this
module never touches jax device state.

Single pod : (data=16, model=16)          = 256 chips (TPU v5e pod)
Multi pod  : (pod=2, data=16, model=16)   = 512 chips; the 'pod' axis is
             pure data parallelism whose only collective is the gradient
             all-reduce (lowest frequency traffic on the slowest link).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_candidate_mesh(n_shards: int):
    """1-D mesh over the 'cand' axis for candidate-sharded retrieval
    (repro.distributed.retrieve).  Serving entry points build it from
    ``--shards``; on CPU the devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    if n_shards > jax.device_count():
        raise ValueError(
            f"--shards {n_shards} exceeds the {jax.device_count()} visible "
            "device(s); on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_shards} "
            "before jax initializes"
        )
    return compat.make_mesh((n_shards,), ("cand",))


def resolve_pspec(spec: P, mesh) -> P:
    """Strip axis names that don't exist in `mesh` (e.g. 'pod' on the
    single-pod mesh) from a PartitionSpec."""
    names = set(mesh.axis_names)

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in names else None
        kept = tuple(a for a in entry if a in names)
        return kept if len(kept) > 1 else (kept[0] if kept else None)

    return P(*(fix(e) for e in spec))


def to_shardings(spec_tree, mesh):
    """PartitionSpec pytree -> NamedSharding pytree (mesh-resolved)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_pspec(s, mesh)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
