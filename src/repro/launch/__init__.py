# Launch layer: production mesh construction, multi-pod dry-run driver,
# training/serving entry points.  dryrun.py must be executed as a script or
# module FIRST in a fresh process (it sets XLA_FLAGS before importing jax).
