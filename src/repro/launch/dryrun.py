import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)).

For every live (architecture × input-shape) cell, lower + compile the step
function against the production mesh with ShapeDtypeStruct inputs (no
allocation), print memory_analysis() (proves it fits) and cost_analysis()
(feeds §Roofline), and optionally dump artifacts for the roofline pass.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape decode_32k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun

NOTE: the XLA_FLAGS line above MUST run before any jax import — run this
module in a fresh process; don't import it from a session that already
initialized jax.
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro import compat
from repro.launch.mesh import make_production_mesh, to_shardings
from repro.models import registry


def lower_cell(cell, mesh, *, compile_: bool = True, rules=None):
    """Lower (and compile) one cell on `mesh`.  Returns result dict."""
    from repro.distributed.sharding import AxisRules, axis_rules

    in_sh = to_shardings(cell.in_specs, mesh)
    out_sh = to_shardings(cell.out_specs, mesh) if cell.out_specs is not None else None
    # donation mirrors production: train steps update (params, opt) in place,
    # decode steps update KV caches in place — without it the memory
    # analysis double-counts every updated buffer as input + output copy
    donate = {"train": (0, 1), "decode": (2,)}.get(cell.kind, ())
    jitted = jax.jit(cell.fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    if rules is None:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        rules = AxisRules(batch=batch_axes)
    t0 = time.time()
    with compat.set_mesh(mesh), axis_rules(rules):
        lowered = jitted.lower(*cell.abstract_args)
    t_lower = time.time() - t0
    result = {
        "arch": cell.arch, "shape": cell.shape, "kind": cell.kind,
        "mesh": list(mesh.devices.shape), "lower_s": round(t_lower, 1),
    }
    if not compile_:
        return result, lowered, None
    t0 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    result["cost"] = {
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "transcendentals": cost.get("transcendentals"),
    }
    return result, lowered, compiled


def run_cell(arch, shape, multi_pod, out_dir=None, save_hlo=False):
    cell = registry.build_cell(arch, shape, full=True)
    if cell.skip:
        print(f"[SKIP] {arch} × {shape}: {cell.skip}")
        return {"arch": arch, "shape": shape, "skip": cell.skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = "multipod" if multi_pod else "singlepod"
    print(f"[....] {arch} × {shape} ({tag}) lowering…", flush=True)
    try:
        result, lowered, compiled = lower_cell(cell, mesh)
    except Exception as e:
        print(f"[FAIL] {arch} × {shape}: {type(e).__name__}: {e}")
        traceback.print_exc()
        return {"arch": arch, "shape": shape, "error": f"{type(e).__name__}: {e}"}
    mem = result["memory"]
    per_dev = (mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0)
    print(
        f"[ OK ] {arch} × {shape} ({tag}) "
        f"args={_gb(mem['argument_bytes'])} temps={_gb(mem['temp_bytes'])} "
        f"total={_gb(per_dev)} flops={result['cost']['flops']:.3e} "
        f"(lower {result['lower_s']}s compile {result['compile_s']}s)",
        flush=True,
    )
    if out_dir:
        out = pathlib.Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        stem = f"{arch}__{shape}__{tag}"
        (out / f"{stem}.json").write_text(json.dumps(result, indent=2))
        if save_hlo:
            (out / f"{stem}.hlo.txt").write_text(compiled.as_text())
    return result


def _gb(x):
    return f"{(x or 0)/2**30:.2f}GiB"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args(argv)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    if args.all:
        targets = [
            (a, s) for a in registry.all_arch_ids() for s in registry.shapes_for(a)
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        targets = [(args.arch, args.shape)]
    for multi_pod in meshes:
        for arch, shape in targets:
            results.append(run_cell(arch, shape, multi_pod, args.out, args.save_hlo))
    n_fail = sum(1 for r in results if "error" in r)
    n_skip = sum(1 for r in results if "skip" in r)
    n_ok = len(results) - n_fail - n_skip
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} failed ==")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
