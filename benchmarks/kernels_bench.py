"""Kernel microbenchmarks (interpret-mode correctness + jnp-path timing).

On CPU the Pallas kernels run in interpret mode (Python) — wall-times are
NOT meaningful for the TPU target, so we benchmark the pure-jnp reference
paths (what the CPU actually executes) and report kernel/ref agreement.
The TPU-relevant statement is the roofline analysis, not these times.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sae import normalize_input
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.fused_encode.ref import fused_encode_ref
from repro.kernels.sparse_dot.ops import fused_retrieve, sparse_dot
from repro.kernels.sparse_dot.ref import retrieve_ref, sparse_dot_ref
from repro.kernels.topk_mask.ref import topk_mask_ref


def _timeit(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def main(smoke: bool = False):
    key = jax.random.PRNGKey(0)
    print("name,us_per_call,derived")

    # sparse_dot: N=100k catalog, k=32, h=4096 (paper's config)
    n, k, h = (8192, 16, 512) if smoke else (100_000, 32, 4096)
    nq, topn = (16, 5) if smoke else (64, 20)
    kslice = min(n, 4096)
    k1, k2, k3 = jax.random.split(key, 3)
    vals = jax.random.normal(k1, (n, k))
    idx = jax.random.randint(k2, (n, k), 0, h, dtype=jnp.int32)
    q = jax.random.normal(k3, (1, h))
    ref_fn = jax.jit(sparse_dot_ref)
    us = _timeit(ref_fn, vals, idx, q)
    # agreement with the Pallas kernel (interpret mode) on a slice
    got = sparse_dot(vals[:kslice], idx[:kslice], q)
    want = sparse_dot_ref(vals[:kslice], idx[:kslice], q)
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"sparse_dot_{n//1000}k_k{k},{us:.0f},flops={2*n*k:.2e};kernel_err={err:.1e}")

    # fused retrieve: multi-query score+select, streaming top-n (never
    # materializes the (Q, N) score matrix).  jnp chunked path timed; the
    # Pallas kernel checked for agreement on a slice (interpret mode).
    qm = jax.random.normal(k3, (nq, h))
    inv = 1.0 / jnp.maximum(jnp.linalg.norm(vals, axis=-1), 1e-8)
    stream_fn = jax.jit(
        lambda v, i, w, qq: retrieve_ref(v, i, w, qq, n=topn)
    )
    us = _timeit(stream_fn, vals, idx, inv, qm)
    gv, gi = fused_retrieve(vals[:kslice], idx[:kslice], inv[:kslice], qm, n=topn)
    rv, ri = retrieve_ref(vals[:kslice], idx[:kslice], inv[:kslice], qm, n=topn)
    err = float(jnp.max(jnp.abs(gv - rv)))
    id_match = float(jnp.mean((gi == ri).astype(jnp.float32)))
    print(f"fused_retrieve_{n//1000}k_q{nq}_n{topn},{us:.0f},"
          f"flops={2*n*k*nq:.2e};kernel_err={err:.1e};id_match={id_match:.4f}")
    if smoke:
        return 0

    # dense-dot comparison point (the 12x bytes story)
    dense = jax.random.normal(k1, (n, 768))
    qd = jax.random.normal(k3, (1, 768))
    us_d = _timeit(jax.jit(lambda a, b: b @ a.T), dense, qd)
    print(f"dense_dot_100k_768d,{us_d:.0f},flops={2*n*768:.2e}")

    # topk_mask: (8192, 4096) k=32
    x = jax.random.normal(key, (8192, 4096))
    us = _timeit(jax.jit(lambda a: topk_mask_ref(a, 32)), x)
    print(f"topk_mask_8192x4096_k32,{us:.0f},")

    # fused_encode ref: B=8192 batch
    w = jax.random.normal(k2, (768, 4096)) / np.sqrt(768)
    b = jnp.zeros((4096,))
    xx = jax.random.normal(k1, (8192, 768))
    us = _timeit(jax.jit(lambda a: fused_encode_ref(normalize_input(a), w, b, 32)), xx)
    print(f"fused_encode_8192x768to4096,{us:.0f},")

    # embedding_bag ref: DLRM-ish lookup
    table = jax.random.normal(k1, (1_000_000, 128))
    ids = jax.random.randint(k2, (65536, 4), 0, 1_000_000, dtype=jnp.int32)
    us = _timeit(jax.jit(lambda t, i: embedding_bag_ref(t, i, "sum")), table, ids)
    print(f"embedding_bag_65536x4_1M,{us:.0f},")
    return 0


if __name__ == "__main__":
    main()
