"""Fault matrix (ISSUE 6): every injected fault, served through the guard.

One guarded request per fault from ``repro.serving.faults.FAULTS`` (plus
the permanent-dead-shard partial merge), each held to the hardened-serving
contract: the response either

  * **recovers bit-identically** — same scores AND ids as the identically
    configured healthy engine would return (retry recovered the shard,
    the fallback index replaced the corrupted one, a stalled shard still
    answered), or
  * **degrades visibly** — ``ServingStatus.degraded=True`` with the path
    and fault reason named, and measured recall@32 vs the exact engine
    no worse than the path's healthy quality bound (scaled by shard
    coverage for partial results),

and NEVER crashes or silently serves wrong results (any uncaught
exception here fails the whole benchmark harness).

The summary row appended to ``BENCH_retrieval.json``:

    name                retrieval_fault_matrix
    us_per_call         mean guarded-request latency across the matrix
    recall              == recall_vs_exact_min (the gated quality floor)
    faults              the injected faults that ran
    recovered_exact     entries bit-identical to their healthy twin
    degraded            entries answered with ServingStatus.degraded
    recall_vs_exact_min worst recall@32 vs exact over FULL-coverage
                        entries (>= 0.95 gated at full size; recall*
                        fields also gate against the committed baseline
                        via tools/check_bench.py)
    coverage_min        worst shard coverage (the partial-merge entry)

Shard faults need a multi-device mesh; on a single-device process they
are skipped and reported (the CI bench job forces 4 host devices).
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax

from repro.core import SAEConfig, build_index, encode, init_train_state, train_step
from repro.core.eval import retrieval_quality
from repro.core.retrieval import kernel_path
from repro.data import clustered_embeddings
from repro.launch.mesh import make_candidate_mesh
from repro.optim import AdamConfig
from repro.serving import (
    EngineConfig,
    FaultInjector,
    GuardedEngine,
    RetrievalEngine,
    corrupt_postings,
    flip_index_byte,
    poison_queries,
)

D, H, K = 256, 1024, 16
N, Q = 8192, 32
TOPN = 32  # the acceptance criterion is recall@32 vs exact
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_retrieval.json"


def _bit_identical(a, b) -> bool:
    return (np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
            and np.array_equal(np.asarray(a[1]), np.asarray(b[1])))


def main(smoke: bool = False):
    n, q_count = (1024, 16) if smoke else (N, Q)
    train_steps = 40 if smoke else 100
    cfg = SAEConfig(d=D, h=H, k=K)
    corpus = clustered_embeddings(jax.random.PRNGKey(0), n, d=D)
    queries = clustered_embeddings(jax.random.PRNGKey(1), q_count, d=D)
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(train_steps):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(3), i),
                                 (min(4096, n),), 0, n)
        state, _ = step(state, corpus[idx])
    params = state.params
    codes = encode(params, corpus, cfg.k)
    qindex = build_index(codes, params, quantize=True)
    fp_index = build_index(codes, params)

    # the exactness oracle every entry's recall is measured against
    exact_engine = RetrievalEngine(qindex, params)
    exact = exact_engine.retrieve_dense(queries, TOPN)

    n_shards = min(4, jax.device_count())
    mesh = make_candidate_mesh(n_shards) if n_shards > 1 else None

    def guarded(precision="exact", sharded=False, **guard_kw):
        eng = RetrievalEngine(qindex, params, config=EngineConfig(
            precision=precision, mesh=mesh if sharded else None))
        return GuardedEngine(eng, backoff_s=0.001, **guard_kw)

    def corrupted_two_stage():
        eng = RetrievalEngine(qindex, params, config=EngineConfig(
            stage="two_stage", candidate_fraction=0.5))
        eng.inverted = corrupt_postings(eng.inverted)
        return eng

    def healthy_twin(precision="exact", sharded=False):
        eng = RetrievalEngine(qindex, params, config=EngineConfig(
            precision=precision, mesh=mesh if sharded else None))
        return eng.retrieve_dense(queries, TOPN)

    # (fault-entry name, build guard, request queries, needs_mesh)
    entries = [
        # flipped index bit -> startup checksum catches it, the verified
        # fp32 fallback replica serves (exact precision on the fallback)
        ("corrupt-index",
         lambda: GuardedEngine(
             RetrievalEngine(flip_index_byte(qindex, byte=11, bit=5),
                             params,
                             config=EngineConfig(precision="int8")),
             run_self_check=True, fallback_index=fp_index, backoff_s=0.001),
         queries, False),
        # NaN planted in the batch -> sanitized at admission, served degraded
        ("nonfinite-query",
         lambda: guarded(precision="int8", on_invalid="sanitize"),
         poison_queries(queries, kind="nan", position=(1, 3)), False),
        # shard dead on attempt 0, back on attempt 1 -> retry recovers
        ("dead-shard-flaky",
         lambda: guarded(sharded=True, injector=FaultInjector(
             "dead-shard", shard=1, recover_after=1)),
         queries, True),
        # shard permanently dead -> partial merge over the survivors
        ("dead-shard-permanent",
         lambda: guarded(sharded=True, injector=FaultInjector(
             "dead-shard", shard=1)),
         queries, True),
        # shard stalls -> answer still arrives (deadline left unbounded)
        ("slow-shard",
         lambda: guarded(sharded=True, injector=FaultInjector(
             "slow-shard", delay_s=0.01)),
         queries, True),
        # primary kernel path raises -> ladder steps down a generation
        ("kernel-exception",
         lambda: guarded(precision="int8", injector=FaultInjector(
             "kernel-exception")),
         queries, False),
        # planted out-of-range posting id -> stage-1 integrity check
        # fires, the ladder sheds candidate generation and serves the
        # exact single-stage scan (ISSUE 7)
        ("corrupt-postings",
         lambda: GuardedEngine(corrupted_two_stage(), backoff_s=0.001),
         queries, False),
    ]

    faults_run, lat_us = [], []
    recovered_exact = degraded_count = 0
    recall_min, coverage_min = 1.0, 1.0
    print("fault,us_per_call,derived")
    for name, build, req, needs_mesh in entries:
        if needs_mesh and mesh is None:
            print(f"{name},0,SKIPPED (single-device process; CI forces 4)")
            continue
        guard = build()
        t0 = time.time()
        scores, ids, status, *_ = guard.retrieve_dense(req, TOPN)
        jax.block_until_ready(ids)
        us = (time.time() - t0) * 1e6
        lat_us.append(us)
        faults_run.append(name)

        sharded = needs_mesh
        precision = guard.engine.precision
        twin = healthy_twin(precision=precision, sharded=sharded)
        identical = _bit_identical((scores, ids), twin)
        quality = retrieval_quality((scores, ids), exact)
        # the response must be accounted for: bit-identical recovery or a
        # visibly degraded answer — never a silent discrepancy
        assert identical or status.degraded, (
            f"{name}: result differs from the healthy path but "
            f"ServingStatus.degraded is False ({status})")
        recovered_exact += identical
        degraded_count += status.degraded
        coverage_min = min(coverage_min, status.coverage)
        if status.coverage == 1.0:
            recall_min = min(recall_min, quality["recall"])
        else:
            # partial results are gated against what the surviving rows
            # can possibly deliver
            assert quality["recall"] >= status.coverage * (
                0.8 if smoke else 0.95), (
                f"{name}: partial recall {quality['recall']:.3f} below "
                f"coverage bound (coverage {status.coverage:.3f})")
        print(f"{name},{us:.0f},path={status.path} degraded={status.degraded} "
              f"recovered_exact={identical} recall@{TOPN}={quality['recall']:.4f} "
              f"coverage={status.coverage:.3f}")

    if not smoke:
        assert recall_min >= 0.95, (
            f"fault-matrix recall@{TOPN} vs exact {recall_min:.4f} < 0.95 "
            f"at N={n}, Q={q_count}")

    path = "fused-kernel" if kernel_path("auto") else "jnp-chunked"
    record = {
        "name": "retrieval_fault_matrix",
        "us_per_call": round(float(np.mean(lat_us)), 1),
        "recall": round(recall_min, 4),
        "path": path,
        "shards": n_shards,
        "n": n, "q": q_count, "topn": TOPN, "smoke": smoke,
        "faults": faults_run,
        "recovered_exact": int(recovered_exact),
        "degraded": int(degraded_count),
        "recall_vs_exact_min": round(recall_min, 4),
        "coverage_min": round(coverage_min, 4),
    }
    records = (json.loads(BENCH_JSON.read_text())
               if BENCH_JSON.exists() else [])
    records = [r for r in records if r["name"] != "retrieval_fault_matrix"]
    records.append(record)
    BENCH_JSON.write_text(json.dumps(records, indent=2) + "\n")
    print(f"[bench] appended retrieval_fault_matrix to {BENCH_JSON} "
          f"({len(faults_run)} faults, recovered_exact={recovered_exact}, "
          f"degraded={degraded_count}, recall_min={recall_min:.4f})")
    return 0


if __name__ == "__main__":
    main()
