"""Paper Fig 3 (left): training convergence of CompresSAE.

Trains the SAE on a synthetic clustered corpus and logs cosine loss +
retrieval recall@10 vs steps/wall-time, demonstrating the paper's claim of
convergence within a few hundred steps.  CPU-scaled (d=256, h=1024, batch
8192 vs the paper's d=768, h=4096, batch 100k on H100).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, build_index, encode, init_train_state, score_dense,
    score_sparse, top_n, train_step,
)
from repro.data import clustered_embeddings
from repro.optim import AdamConfig


def recall_at(params, corpus, queries, cfg, n=10):
    truth_ids = top_n(score_dense(corpus, queries), n)[1]
    index = build_index(encode(params, corpus, cfg.k))
    got_ids = top_n(score_sparse(index, encode(params, queries, cfg.k)), n)[1]
    hits = sum(len(set(a.tolist()) & set(b.tolist()))
               for a, b in zip(np.asarray(got_ids), np.asarray(truth_ids)))
    return hits / truth_ids.size


def run(steps=300, batch=8192, d=256, h=1024, k=16, eval_every=50, seed=0):
    cfg = SAEConfig(d=d, h=h, k=k)
    corpus = clustered_embeddings(jax.random.PRNGKey(seed), 16384, d=d)
    queries = clustered_embeddings(jax.random.PRNGKey(seed + 1), 256, d=d)
    state = init_train_state(cfg, jax.random.PRNGKey(seed + 2))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    rows = []
    t0 = time.time()
    for i in range(steps + 1):
        if i % eval_every == 0:
            r = recall_at(state.params, corpus, queries, cfg)
            loss = float(train_step(state, corpus[:batch], cfg, AdamConfig())[1]["cos_loss_k"])
            rows.append((i, time.time() - t0, loss, r))
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 3), i)
        idx = jax.random.randint(key, (batch,), 0, corpus.shape[0])
        state, m = step(state, corpus[idx])
    return rows


def main():
    rows = run()
    print("step,seconds,cos_loss_k,recall_at_10")
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]:.4f},{r[3]:.4f}")
    assert rows[-1][3] > rows[0][3], "recall did not improve"
    return rows


if __name__ == "__main__":
    main()
