"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run size_table # one

Modules:
    size_table       — Fig 1 storage table (exact arithmetic vs paper)
    convergence      — Fig 3 left: training convergence
    tradeoff         — Fig 3 center: accuracy-compression trade-off
    retrieval_modes  — §3.2 three retrieval modes (timing + recall + the
                       kernel-trick exactness check)
    kernels_bench    — kernel reference-path microbenches + kernel/ref err
    fault_matrix     — ISSUE 6 hardened serving: every injected fault
                       through the degradation ladder (recover
                       bit-identically or degrade visibly, never crash)

The roofline/dry-run reports are separate (they need a 512-device
process): see benchmarks.roofline and repro.launch.dryrun.
"""
from __future__ import annotations

import sys
import time

MODULES = ["size_table", "convergence", "tradeoff", "retrieval_modes",
           "kernels_bench", "quantized_codes_bench", "inverted_index_bench",
           "fault_matrix"]
# --smoke: tiny-size perf record (writes BENCH_retrieval.json) — wired into
# the tier-1 flow as a non-gating step (tests/test_benchmarks_smoke.py).
# fault_matrix and inverted_index_bench must run AFTER retrieval_modes:
# retrieval_modes rewrites BENCH_retrieval.json wholesale, the other two
# append their rows to it
SMOKE_MODULES = ["retrieval_modes", "kernels_bench", "fault_matrix",
                 "inverted_index_bench"]


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    targets = args if args else (SMOKE_MODULES if smoke else MODULES)
    failures = []
    for name in targets:
        print(f"\n===== benchmarks.{name}{' (smoke)' if smoke else ''} =====",
              flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            # only the SMOKE_MODULES mains take a smoke flag
            if smoke and name in SMOKE_MODULES:
                mod.main(smoke=True)
            else:
                mod.main()
            print(f"===== {name} done in {time.time()-t0:.1f}s =====")
        except Exception as e:  # noqa: BLE001 — harness reports and continues
            failures.append((name, e))
            print(f"===== {name} FAILED: {type(e).__name__}: {e} =====")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: "
                         f"{[n for n, _ in failures]}")


if __name__ == "__main__":
    main()
