"""Beyond-paper: compound compression (sparse codes + int8/int16 quant).

Extends the paper's Fig 3 (center) trade-off with the quantized-codes
point: ~31x compression at k=32 (vs the paper's 12x), measuring the recall
cost of quantization at equal k.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, build_index, encode, init_train_state, score_dense,
    score_sparse, top_n, train_step,
)
from repro.core.quantized_codes import (
    compression_ratio, dequantize_codes, quantize_codes,
)
from repro.data import clustered_embeddings
from repro.optim import AdamConfig

D, H, K = 256, 1024, 16
N, Q, TOPN = 8192, 256, 10


def main():
    cfg = SAEConfig(d=D, h=H, k=K)
    corpus = clustered_embeddings(jax.random.PRNGKey(0), N, d=D)
    queries = clustered_embeddings(jax.random.PRNGKey(1), Q, d=D)
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(250):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(3), i),
                                 (2048,), 0, N)
        state, _ = step(state, corpus[idx])
    params = state.params

    codes = encode(params, corpus, cfg.k)
    qcodes = quantize_codes(codes)
    codes_dq = dequantize_codes(qcodes)
    truth = top_n(score_dense(corpus, queries), TOPN)[1]
    q_enc = encode(params, queries, cfg.k)

    def recall(index):
        ids = top_n(score_sparse(index, q_enc), TOPN)[1]
        return np.mean([len(set(a.tolist()) & set(b.tolist())) / TOPN
                        for a, b in zip(np.asarray(ids), np.asarray(truth))])

    r_fp = recall(build_index(codes))
    r_q = recall(build_index(codes_dq))
    b_fp = codes.nbytes_logical / N
    b_q = qcodes.nbytes_logical / N
    print("name,us_per_call,derived")
    print(f"codes_fp32_int32,0,bytes/vec={b_fp:.0f};ratio={D*4/b_fp:.1f}x;"
          f"recall@{TOPN}={r_fp:.4f}")
    print(f"codes_int8_int16,0,bytes/vec={b_q:.0f};ratio={D*4/b_q:.1f}x;"
          f"recall@{TOPN}={r_q:.4f}")
    print(f"paper_point_768d_k32_h4096,0,ratio_fp={768*4/(32*8):.1f}x;"
          f"ratio_quant={compression_ratio(768, 32, 4096):.1f}x")
    # quantization must cost <2 recall points in this proxy
    assert r_q > r_fp - 0.02, (r_q, r_fp)
    # round-trip integrity
    np.testing.assert_array_equal(np.asarray(codes.indices),
                                  np.asarray(dequantize_codes(qcodes).indices))
    return 0


if __name__ == "__main__":
    main()
