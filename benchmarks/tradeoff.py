"""Paper Fig 3 (center): accuracy-compression trade-off.

Compares, at matched bytes-per-vector budgets:
  * CompresSAE sparse-space retrieval      (the paper)
  * CompresSAE reconstructed-space (kernel trick — paper's best)
  * prefix truncation                      (Matryoshka-style)
  * PCA projection                          (classical truncation)
  * int8 quantization                       (related work)

Two corpus regimes, because they change who wins and mirror the paper's
argument precisely:

  * ``matryoshka``  — variance-ordered dims (what a Matryoshka-RETRAINED
    backbone produces).  Truncation is strong at mild compression here;
    the paper's Fig 3 shows the same (Matryoshka is competitive until the
    high-compression end, where CompresSAE pulls ahead).
  * ``isotropic``   — information spread uniformly over dims (a normal,
    non-retrained encoder).  Truncation collapses; CompresSAE — which
    needs NO backbone retraining — holds.  This is the paper's central
    deployment argument (§1-2).

Metric: recall@10 of compressed retrieval vs exact dense retrieval.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, baselines, build_index, encode, init_train_state,
    score_dense, score_reconstructed, score_sparse, top_n, train_step,
)
from repro.data import clustered_embeddings
from repro.optim import AdamConfig, cosine_decay

D = 256
N_CORPUS = 8192
N_QUERY = 256
TOPN = 10
TRAIN_STEPS = 250


def _recall(ids, truth):
    return sum(len(set(a.tolist()) & set(b.tolist()))
               for a, b in zip(np.asarray(ids), np.asarray(truth))) / truth.size


def _train_sae(cfg, corpus, steps=TRAIN_STEPS, seed=0):
    state = init_train_state(cfg, jax.random.PRNGKey(seed))
    opt = AdamConfig(lr=3e-3)
    step = jax.jit(lambda s, b, t: train_step(s, b, cfg, opt,
                                              cosine_decay(t, steps, 20)))
    for i in range(steps):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(7), i),
                                 (2048,), 0, corpus.shape[0])
        state, _ = step(state, corpus[idx], i)
    return state.params


def run_regime(regime: str, seed=0):
    decay = 0.65 if regime == "matryoshka" else 1.0
    corpus = clustered_embeddings(jax.random.PRNGKey(seed), N_CORPUS, d=D,
                                  spectrum_decay=decay)
    queries = clustered_embeddings(jax.random.PRNGKey(seed + 1), N_QUERY, d=D,
                                   spectrum_decay=decay)
    truth = top_n(score_dense(corpus, queries), TOPN)[1]
    rows = []

    for k in (8, 16, 32):
        cfg = SAEConfig(d=D, h=1024, k=k)
        params = _train_sae(cfg, corpus, seed=seed)
        codes = encode(params, corpus, cfg.k)
        index = build_index(codes, params)
        q = encode(params, queries, cfg.k)
        r_sp = _recall(top_n(score_sparse(index, q), TOPN)[1], truth)
        r_rc = _recall(top_n(score_reconstructed(index, q, params), TOPN)[1], truth)
        rows.append((f"compressae_sparse_k{k}", baselines.sparse_bytes(k), r_sp))
        rows.append((f"compressae_recon_k{k}", baselines.sparse_bytes(k), r_rc))

    for m in (16, 32, 64):
        tq = baselines.truncate(queries, m)
        tc = baselines.truncate(corpus, m)
        ids = top_n(score_dense(tc, tq), TOPN)[1]
        rows.append((f"truncate_{m}d", baselines.truncation_bytes(m),
                     _recall(ids, truth)))

    for m in (16, 32, 64):
        model = baselines.pca_fit(corpus, m)
        ids = top_n(
            score_dense(baselines.pca_encode(model, corpus),
                        baselines.pca_encode(model, queries)), TOPN)[1]
        rows.append((f"pca_{m}d", m * 4, _recall(ids, truth)))

    qm = baselines.quant_fit(corpus, 8)
    cq = baselines.quant_decode(qm, baselines.quant_encode(qm, corpus))
    ids = top_n(score_dense(cq, queries), TOPN)[1]
    rows.append(("int8", baselines.quant_bytes(D, 8), _recall(ids, truth)))
    return rows


def main():
    all_rows = {}
    for regime in ("matryoshka", "isotropic"):
        rows = run_regime(regime)
        all_rows[regime] = {name: (b, r) for name, b, r in rows}
        print(f"-- regime={regime}")
        print("method,bytes_per_vector,recall_at_10")
        for name, b, r in rows:
            print(f"{name},{b:.0f},{r:.4f}")

    # ---- paper-claim assertions (EXPERIMENTS.md §Paper-claims)
    for regime, by in all_rows.items():
        # reconstructed-space >= sparse-space at equal k (Fig 3 center)
        for k in (8, 16, 32):
            assert by[f"compressae_recon_k{k}"][1] >= \
                by[f"compressae_sparse_k{k}"][1] - 0.05, (regime, k)
    bym, byi = all_rows["matryoshka"], all_rows["isotropic"]
    # high-compression regime (64 B/vec = 16x): CompresSAE beats equal-byte
    # truncation EVEN on the Matryoshka-favourable corpus
    assert bym["compressae_recon_k8"][1] > bym["truncate_16d"][1], (
        bym["compressae_recon_k8"], bym["truncate_16d"])
    # non-retrained backbone: CompresSAE dominates truncation everywhere
    for k, m in ((8, 16), (16, 32), (32, 64)):
        assert byi[f"compressae_recon_k{k}"][1] > byi[f"truncate_{m}d"][1], (k, m)
    # and beats PCA at every matched budget on the isotropic corpus
    for k, m in ((8, 16), (16, 32), (32, 64)):
        assert byi[f"compressae_recon_k{k}"][1] > byi[f"pca_{m}d"][1] - 0.02, (k, m)
    return all_rows


if __name__ == "__main__":
    main()
