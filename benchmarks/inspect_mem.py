"""Dry-run memory inspector: top HLO buffer shapes per cell.

    PYTHONPATH=src python -m benchmarks.inspect_mem <arch> <shape> [kinds-json]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json
import math
import re
import sys


def main():
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.launch.dryrun import lower_cell
    from repro.models import registry
    from repro.distributed.sharding import AxisRules

    arch, shape = sys.argv[1], sys.argv[2]
    rules = None
    if len(sys.argv) > 3:
        from jax.sharding import PartitionSpec as P

        kinds = {k: (None if v is None else P(*v))
                 for k, v in json.loads(sys.argv[3]).items()}
        rules = AxisRules(batch=("data",), kinds=kinds)
    mesh = make_production_mesh()
    cell = registry.build_cell(arch, shape, full=True)
    r, lo, co = lower_cell(cell, mesh, rules=rules)
    print("temps GiB:", round(r["memory"]["temp_bytes"] / 2**30, 2),
          "| args GiB:", round(r["memory"]["argument_bytes"] / 2**30, 2))
    txt = co.as_text()
    dt = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "pred": 1, "f16": 2,
          "u8": 1, "s8": 1, "u64": 8, "s64": 8}
    sizes = {}
    for m in re.finditer(r"(f32|bf16|s32|u32|pred|u64|s64)\[([0-9,]+)\]", txt):
        dims = [int(x) for x in m.group(2).split(",") if x]
        b = math.prod(dims) * dt[m.group(1)]
        key = f"{m.group(1)}[{m.group(2)}]"
        if b > 2**28:
            cnt = sizes.get(key, (0, 0))[1]
            sizes[key] = (b, cnt + 1)
    for k, (b, c) in sorted(sizes.items(), key=lambda kv: -kv[1][0])[:12]:
        print(f"  {b/2**30:8.2f} GiB x{c:4d}  {k}")


if __name__ == "__main__":
    main()
