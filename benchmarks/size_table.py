"""Paper Fig 1 (table): storage size per 100M embeddings.

Reproduces the paper's size arithmetic exactly and extends it to the
assigned recsys archs' retrieval catalogs.
"""
from __future__ import annotations

GB = 1e9


def size_gb(n: int, *, dense_dim: int = 0, fp_bytes: int = 4,
            sparse_k: int = 0) -> float:
    if sparse_k:
        return n * 2 * sparse_k * 4 / GB
    return n * dense_dim * fp_bytes / GB


def main():
    n = 100_000_000
    rows = [
        # (model, config, paper value)
        ("SBERT dense", size_gb(n, dense_dim=512), 204.8),
        ("Nomic dense", size_gb(n, dense_dim=768), 307.2),
        ("Nomic Matryoshka-64", size_gb(n, dense_dim=64), 25.6),
        ("Nomic CompresSAE (h=4096, k=32)", size_gb(n, sparse_k=32), 25.6),
    ]
    print("model,size_gb_100m,paper_gb")
    for name, got, want in rows:
        print(f"{name},{got:.1f},{want}")
        assert abs(got - want) < 0.05 * want, (name, got, want)
    # compression ratio claim: 768-d fp32 -> k=32 sparse = 12x
    ratio = size_gb(n, dense_dim=768) / size_gb(n, sparse_k=32)
    print(f"compression_ratio_768d_k32,{ratio:.1f},12.0")
    assert abs(ratio - 12.0) < 0.01

    # assigned-arch catalogs (DESIGN.md §Arch-applicability)
    from repro.models.registry import RETRIEVAL_SAE

    for arch, cfg in RETRIEVAL_SAE.items():
        dense = size_gb(n, dense_dim=cfg.d)
        sparse = size_gb(n, sparse_k=cfg.k)
        print(f"{arch}_catalog_dense_gb,{dense:.1f},")
        print(f"{arch}_catalog_compressed_gb,{sparse:.1f},ratio={dense/sparse:.1f}x")
    return rows


if __name__ == "__main__":
    main()
