"""Paper Fig 1 (table): storage size per 100M embeddings.

Reproduces the paper's size arithmetic exactly and extends it to the
assigned recsys archs' retrieval catalogs and the compound-quantized
format.  Since ISSUE 4 the sparse/quantized bytes come from the storage
types themselves (``SparseCodes.nbytes_logical`` /
``QuantizedCodes.nbytes_logical`` on a one-row instance with the real
dtypes) — the numbers quoted in README/docs are computed here, never
hand-typed.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantized_codes import quantize_codes
from repro.core.types import SparseCodes

GB = 1e9


def sparse_bytes_per_row(k: int, *, h: int = 4096, quantized: bool = False) -> int:
    """Storage bytes of one fixed-k code row, read off the live format:
    fp32 SparseCodes (2·k·4) or compound-quantized QuantizedCodes
    (k·(1 + idx_bytes) + 4, idx_bytes 2 when h < 65536 else 4)."""
    codes = SparseCodes(values=jnp.zeros((1, k), jnp.float32),
                        indices=jnp.zeros((1, k), jnp.int32), dim=h)
    if quantized:
        return quantize_codes(codes).nbytes_logical
    return codes.nbytes_logical


def size_gb(n: int, *, dense_dim: int = 0, fp_bytes: int = 4,
            sparse_k: int = 0, h: int = 4096, quantized: bool = False) -> float:
    if sparse_k:
        return n * sparse_bytes_per_row(sparse_k, h=h, quantized=quantized) / GB
    return n * dense_dim * fp_bytes / GB


def main():
    n = 100_000_000
    rows = [
        # (model, config, paper value)
        ("SBERT dense", size_gb(n, dense_dim=512), 204.8),
        ("Nomic dense", size_gb(n, dense_dim=768), 307.2),
        ("Nomic Matryoshka-64", size_gb(n, dense_dim=64), 25.6),
        ("Nomic CompresSAE (h=4096, k=32)", size_gb(n, sparse_k=32), 25.6),
    ]
    print("model,size_gb_100m,paper_gb")
    for name, got, want in rows:
        print(f"{name},{got:.1f},{want}")
        assert abs(got - want) < 0.05 * want, (name, got, want)
    # compression ratio claim: 768-d fp32 -> k=32 sparse = 12x
    ratio = size_gb(n, dense_dim=768) / size_gb(n, sparse_k=32)
    print(f"compression_ratio_768d_k32,{ratio:.1f},12.0")
    assert abs(ratio - 12.0) < 0.01

    # beyond-paper compound point: int8 values + int16 indices + scales,
    # the serving format of QuantizedIndex (ISSUE 4) — bytes read off the
    # live dtypes, ~31x vs 768-d fp32 dense
    quant_gb = size_gb(n, sparse_k=32, quantized=True)
    quant_ratio = size_gb(n, dense_dim=768) / quant_gb
    print(f"Nomic CompresSAE+int8/int16 (h=4096 k=32),{quant_gb:.1f},"
          f"ratio={quant_ratio:.1f}x")
    assert 30 < quant_ratio < 32, quant_ratio

    # assigned-arch catalogs (DESIGN.md §Arch-applicability)
    from repro.models.registry import RETRIEVAL_SAE

    for arch, cfg in RETRIEVAL_SAE.items():
        dense = size_gb(n, dense_dim=cfg.d)
        sparse = size_gb(n, sparse_k=cfg.k, h=cfg.h)
        quant = size_gb(n, sparse_k=cfg.k, h=cfg.h, quantized=True)
        print(f"{arch}_catalog_dense_gb,{dense:.1f},")
        print(f"{arch}_catalog_compressed_gb,{sparse:.1f},ratio={dense/sparse:.1f}x")
        print(f"{arch}_catalog_quantized_gb,{quant:.1f},ratio={dense/quant:.1f}x")
    return rows


if __name__ == "__main__":
    main()
