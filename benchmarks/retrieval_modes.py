"""Paper §3.2: the three retrieval modes, timed and scored.

name,us_per_call,derived-recall CSV per the benchmark harness convention.
Also verifies the kernel-trick identity numerically at benchmark scale.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, build_index, decode, encode, init_train_state, score_dense,
    score_reconstructed, score_sparse, top_n, train_step,
)
from repro.data import clustered_embeddings
from repro.optim import AdamConfig

D, H, K = 256, 1024, 16
N, Q, TOPN = 16384, 64, 10


def _timeit(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def main():
    cfg = SAEConfig(d=D, h=H, k=K)
    corpus = clustered_embeddings(jax.random.PRNGKey(0), N, d=D)
    queries = clustered_embeddings(jax.random.PRNGKey(1), Q, d=D)
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(200):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(3), i),
                                 (4096,), 0, N)
        state, _ = step(state, corpus[idx])
    params = state.params
    codes = encode(params, corpus, cfg.k)
    index = build_index(codes, params)
    truth = top_n(score_dense(corpus, queries), TOPN)[1]

    def rec(ids):
        return sum(len(set(a.tolist()) & set(b.tolist()))
                   for a, b in zip(np.asarray(ids), np.asarray(truth))) / truth.size

    dense_fn = jax.jit(lambda q: top_n(score_dense(corpus, q), TOPN))
    sparse_fn = jax.jit(lambda q: top_n(score_sparse(index, encode(params, q, K)), TOPN))
    recon_fn = jax.jit(
        lambda q: top_n(score_reconstructed(index, encode(params, q, K), params), TOPN)
    )

    print("name,us_per_call,derived")
    for name, fn in [("retrieval_dense", dense_fn),
                     ("retrieval_sparse", sparse_fn),
                     ("retrieval_reconstructed", recon_fn)]:
        us = _timeit(fn, queries)
        r = rec(fn(queries)[1])
        print(f"{name},{us:.0f},recall@{TOPN}={r:.4f}")

    # kernel-trick exactness at benchmark scale
    q_codes = encode(params, queries, K)
    got = score_reconstructed(index, q_codes, params)
    want = score_dense(decode(params, codes), decode(params, q_codes))
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"kernel_trick_max_abs_err,0,{err:.2e}")
    assert err < 1e-3
    return 0


if __name__ == "__main__":
    main()
