"""Paper §3.2: the retrieval modes, timed and scored through the serving API.

name,us_per_call,derived-recall CSV per the benchmark harness convention,
plus a BENCH_retrieval.json perf record (name, us_per_call, recall, shape)
so later PRs have a trajectory to compare against.

Rows:
  retrieval_dense               — exact dense baseline
  retrieval_sparse_fullscore    — seed path: full (Q, N) score matrix
                                  (sparse_dot_dense_query) + lax.top_k
  retrieval_sparse              — retrieve() fused path (chunked streaming
                                  top-n on CPU, fused Pallas kernel on TPU)
  retrieval_reconstructed       — retrieve() kernel-trick mode
  retrieval_sparse_sharded      — retrieve(..., mesh=...): candidate-sharded
                                  distributed path over a min(4, n_devices)-way
                                  mesh (1-way degenerates to a single shard
                                  when the process has one device)
  retrieval_e2e_dense           — RetrievalEngine.retrieve_dense: the whole
                                  request (dense embeddings in, top-n out)
                                  through the serving engine — encode →
                                  sparse-query score → select with no dense
                                  -query round-trip through HBM; timed
                                  against the composed encode()+retrieve()
                                  request (retrieval_sparse) and asserted
                                  bit-identical to it
  retrieval_sparse_quantized    — the whole request served from the
                                  compound-compressed QuantizedIndex
                                  (int8 values + int16 indices + fp32
                                  scales in HBM, VMEM tile dequant) at the
                                  PAPER's operating point k=32 (the other
                                  rows run the benchmark's k=16); asserted
                                  bit-identical to the engine over the
                                  dequantized index, and its record
                                  carries index_bytes / index_bytes_fp32
                                  (both computed via nbytes_logical, never
                                  hand-typed) with index_bytes <= 40% of
                                  fp32 gated here and in
                                  tests/test_benchmarks_smoke.py
  retrieval_sparse_quantized_mxu— the SAME quantized request served at
                                  precision="int8" (generation 5:
                                  candidate tiles scored int8×int8 with
                                  int32 accumulation, never dequantized).
                                  APPROXIMATE by contract: its record
                                  carries the harness metrics
                                  (repro.core.eval) measured against the
                                  exact quantized engine at recall@32 —
                                  recall_vs_exact / score_mae /
                                  rank_displacement — with
                                  recall_vs_exact >= 0.95 gated at full
                                  size (smoke sizes print the same
                                  fields; schema gated in
                                  tests/test_benchmarks_smoke.py)
  retrieval_two_stage           — ISSUE 7: the same request served
                                  two-stage (stage 1: inverted-index
                                  candidate union, pinned to the HOST
                                  NumPy oracle here so the row keeps its
                                  PR-7 semantics; stage 2: one batched
                                  fused re-rank over the gathered
                                  candidate panels).  APPROXIMATE by
                                  design: the record carries
                                  recall_vs_exact (recall@32 vs the
                                  single-stage engine over the same
                                  index, >= 0.95 gated at full size —
                                  here AND in tools/check_bench.py),
                                  scanned_fraction (stage 2's candidate
                                  budget / N, < 0.5 at full size) and
                                  candidate_fraction (the knob)
  retrieval_two_stage_device    — ISSUE 8: the SAME two-stage request
                                  with stage 1 on device (one jitted
                                  batched union — no per-query host
                                  loop).  Asserted BIT-identical to the
                                  host-stage-1 row end to end, and its
                                  record carries the same quality
                                  fields under the same >= 0.95 floor;
                                  tools/check_bench.py additionally
                                  FAILS if its recall_vs_exact diverges
                                  from the host row's
  retrieval_segmented           — ISSUE 9: the same request served from a
                                  mutable SegmentedIndex (base + delta +
                                  deletion masks) after a deterministic
                                  add/delete/compact trace replayed
                                  through RetrievalEngine.apply_update.
                                  Its ``recall`` is measured against the
                                  SURVIVING catalog's dense truth
                                  (deleted rows out, added rows in); its
                                  record carries recall_vs_exact (recall
                                  @32 vs a fresh build_index over the
                                  surviving fp32 rows — 1.0 by the
                                  bit-identity contract, >= 0.95 gated
                                  at full size) and compaction_parity
                                  (compact().base.checksum equals the
                                  rebuilt index's — gated at EXACT
                                  equality here and in
                                  tools/check_bench.py, smoke included:
                                  checksum equality is size-independent)

Every BENCH_retrieval.json record carries the backend path
("fused-kernel" | "jnp-chunked") and the shard count, so the perf
trajectory stays comparable across PRs and backends.

Also verifies the kernel-trick identity numerically at benchmark scale and
that retrieve() returns the same ids as the full-score path.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    SAEConfig, SparseCodes, build_index, decode, dequantize_index, encode,
    init_train_state, retrieve, score_dense, score_reconstructed,
    score_sparse, top_n, train_step,
)
from repro.core.retrieval import kernel_path
from repro.core.segments import SegmentedIndex
from repro.launch.mesh import make_candidate_mesh
from repro.data import clustered_embeddings
from repro.optim import AdamConfig
from repro.serving import EngineConfig, RetrievalEngine

D, H, K = 256, 1024, 16
N, Q, TOPN = 16384, 64, 10
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_retrieval.json"


def _timeit(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def main(smoke: bool = False):
    n, q_count, topn = (1024, 16, 5) if smoke else (N, Q, TOPN)
    train_steps = 40 if smoke else 200
    cfg = SAEConfig(d=D, h=H, k=K)
    corpus = clustered_embeddings(jax.random.PRNGKey(0), n, d=D)
    queries = clustered_embeddings(jax.random.PRNGKey(1), q_count, d=D)
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    step = jax.jit(lambda s, b: train_step(s, b, cfg, AdamConfig(lr=3e-3)))
    for i in range(train_steps):
        idx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(3), i),
                                 (min(4096, n),), 0, n)
        state, _ = step(state, corpus[idx])
    params = state.params
    codes = encode(params, corpus, cfg.k)
    index = build_index(codes, params)
    truth = top_n(score_dense(corpus, queries), topn)[1]

    def rec(ids, t=None):
        t = truth if t is None else t
        return sum(len(set(a.tolist()) & set(b.tolist()))
                   for a, b in zip(np.asarray(ids), np.asarray(t))) / t.size

    dense_fn = jax.jit(lambda q: top_n(score_dense(corpus, q), topn))
    # seed path: materialize (Q, N) scores, then select
    fullscore_fn = jax.jit(
        lambda q: top_n(score_sparse(index, encode(params, q, K), use_kernel=False), topn)
    )
    # serving path: fused score+select (never materializes (Q, N))
    sparse_fn = jax.jit(
        lambda q: retrieve(index, encode(params, q, K), topn, mode="sparse")
    )
    recon_fn = jax.jit(
        lambda q: retrieve(index, encode(params, q, K), topn,
                           mode="reconstructed", params=params)
    )
    # candidate-sharded distributed path (ISSUE 2): min(4, n_devices)-way
    # mesh; under the tier-1 conftest the forced CPU topology gives 4
    n_shards = min(4, jax.device_count())
    mesh = make_candidate_mesh(n_shards)
    sharded_fn = jax.jit(
        lambda q: retrieve(index, encode(params, q, K), topn, mode="sparse",
                           mesh=mesh)
    )
    # serving-engine whole request (ISSUE 3): dense embeddings in, top-n
    # out, encode folded into the kernel chain — no dense-query HBM trip
    engine = RetrievalEngine(index, params,
                             config=EngineConfig(mode="sparse"))
    e2e_fn = lambda q: engine.retrieve_dense(q, topn)  # noqa: E731
    # quantized serving (ISSUE 4), at the paper's k=32 so the byte ratio is
    # the one the paper's storage arithmetic is quoted at (h < 65536 ->
    # int16 indices); the fp32 byte count comes straight off the fp32
    # codes — no fp32 twin index needs building
    K32 = 32
    codes32 = encode(params, corpus, K32)
    qindex32 = build_index(codes32, params, quantize=True)
    qengine = RetrievalEngine(qindex32, params,
                              config=EngineConfig(mode="sparse"))
    quant_fn = lambda q: qengine.retrieve_dense(q, topn)  # noqa: E731
    q_index_bytes = int(qindex32.codes.nbytes_logical)
    q_index_bytes_fp = int(codes32.nbytes_logical)
    # generation 5 (ISSUE 5): the same quantized request at precision="int8"
    # — candidate tiles scored int8×int8, never dequantized; approximate,
    # measured against the exact quantized engine below
    qengine_mxu = RetrievalEngine(
        qindex32, params,
        config=EngineConfig(mode="sparse", precision="int8"))
    mxu_fn = lambda q: qengine_mxu.retrieve_dense(q, topn)  # noqa: E731
    # two-stage serving (ISSUE 7): inverted-index candidate union (host)
    # feeding the fused re-rank over only the gathered rows.  The budget
    # fraction is sized so stage 2 scans < half the catalog at full size;
    # at smoke sizes the posting union is small enough that the budget
    # covers it entirely (recall_vs_exact is then exactly 1.0)
    cand_frac = 0.4 if smoke else 0.3
    ts_engine = RetrievalEngine(
        index, params,
        config=EngineConfig(mode="sparse", stage="two_stage",
                            candidate_fraction=cand_frac, stage1="host"))
    ts_fn = lambda q: ts_engine.retrieve_dense(q, topn)  # noqa: E731
    # device stage 1 (ISSUE 8): the same request with the candidate union
    # as one jitted batched pass — bit-identical output, no host loop
    ts_dev_engine = RetrievalEngine(
        index, params,
        config=EngineConfig(mode="sparse", stage="two_stage",
                            candidate_fraction=cand_frac, stage1="device"))
    ts_dev_fn = lambda q: ts_dev_engine.retrieve_dense(q, topn)  # noqa: E731
    # segmented mutable serving (ISSUE 9): wrap the same fp32 index as
    # the base segment and replay a deterministic add/delete/compact
    # trace through apply_update before timing, so the timed request
    # spans base + delta + deletion masks (12 base deletes, 16 adds, 2
    # delta deletes, compact, 8 more adds -> an 8-row live delta)
    n_add, n_del = 24, 12
    extra_emb = clustered_embeddings(jax.random.PRNGKey(5), n_add, d=D)
    extra_codes = encode(params, extra_emb, cfg.k)

    def _code_rows(c, rows):
        rows = np.asarray(rows)
        return SparseCodes(values=jnp.asarray(np.asarray(c.values)[rows]),
                           indices=jnp.asarray(np.asarray(c.indices)[rows]),
                           dim=c.dim)

    seg_engine = RetrievalEngine(SegmentedIndex.from_index(index), params,
                                 config=EngineConfig(mode="sparse"))
    seg_engine.apply_update(
        "delete", ids=sorted({int(v) for v in np.linspace(0, n - 1, n_del)}))
    seg_engine.apply_update("add", codes=_code_rows(extra_codes, range(16)),
                            ids=list(range(n, n + 16)))
    seg_engine.apply_update("delete", ids=[n + 3, n + 11])
    seg_engine.apply_update("compact")
    seg_engine.apply_update(
        "add", codes=_code_rows(extra_codes, range(16, n_add)),
        ids=list(range(n + 16, n + n_add)))
    seg = seg_engine.segments
    seg_fn = lambda q: seg_engine.retrieve_dense(q, topn)  # noqa: E731
    # the segmented row's truth is the SURVIVING catalog (deleted rows
    # contribute nothing; added rows compete), positions translated back
    # to item ids through alive_ids()
    surv = np.asarray(seg.alive_ids())
    all_emb = jnp.concatenate([corpus, extra_emb])
    seg_truth = jnp.take(
        jnp.asarray(surv),
        top_n(score_dense(all_emb[jnp.asarray(surv)], queries), topn)[1],
    )

    records = []
    reps = 5 if smoke else 20  # shared-box timing noise: more reps at full size
    path = "fused-kernel" if kernel_path("auto") else "jnp-chunked"
    print("name,us_per_call,derived")
    for name, fn, shards in [("retrieval_dense", dense_fn, 1),
                             ("retrieval_sparse_fullscore", fullscore_fn, 1),
                             ("retrieval_sparse", sparse_fn, 1),
                             ("retrieval_reconstructed", recon_fn, 1),
                             ("retrieval_sparse_sharded", sharded_fn, n_shards),
                             ("retrieval_e2e_dense", e2e_fn, 1),
                             ("retrieval_sparse_quantized", quant_fn, 1),
                             ("retrieval_sparse_quantized_mxu", mxu_fn, 1),
                             ("retrieval_two_stage", ts_fn, 1),
                             ("retrieval_two_stage_device", ts_dev_fn, 1),
                             ("retrieval_segmented", seg_fn, 1)]:
        us = _timeit(fn, queries, reps=reps)
        r = rec(fn(queries)[1],
                seg_truth if name == "retrieval_segmented" else None)
        print(f"{name},{us:.0f},recall@{topn}={r:.4f}")
        record = {"name": name, "us_per_call": round(us, 1),
                  "recall": round(r, 4), "path": path, "shards": shards,
                  "n": n, "q": q_count, "topn": topn, "smoke": smoke}
        if name == "retrieval_sparse_quantized":
            # bytes of index codes resident in HBM, computed from the live
            # arrays (nbytes_logical), never hand-typed; both formats
            # additionally stream 4 B/row of reciprocal norms
            record.update(k=K32, index_bytes=q_index_bytes,
                          index_bytes_fp32=q_index_bytes_fp)
        if name == "retrieval_sparse_quantized_mxu":
            record.update(k=K32, precision="int8")
        if name == "retrieval_segmented":
            record.update(n_alive=int(seg.n_alive), adds=n_add,
                          deletes=n_del + 2,
                          base_coverage=round(seg.base_coverage, 4))
        records.append(record)

    # fused path must agree with the full-score path (same ids away from ties)
    ids_full = fullscore_fn(queries)[1]
    ids_fused = sparse_fn(queries)[1]
    agree = float(jnp.mean((ids_full == ids_fused).astype(jnp.float32)))
    print(f"fused_vs_fullscore_id_agreement,0,{agree:.4f}")
    assert agree > 0.999, f"fused retrieve disagrees with full-score path: {agree}"

    # sharded path must be BIT-identical to the single-shard serving path
    v_1, i_1 = sparse_fn(queries)
    v_s, i_s = sharded_fn(queries)
    assert (np.asarray(i_s) == np.asarray(i_1)).all(), "sharded ids differ"
    assert (np.asarray(v_s) == np.asarray(v_1)).all(), "sharded scores differ"
    print(f"sharded_vs_single_bit_identical,0,shards={n_shards}")

    # engine whole-request must be BIT-identical to the composed
    # encode()+retrieve() request it replaces
    v_e, i_e, *_ = e2e_fn(queries)
    assert (np.asarray(i_e) == np.asarray(i_1)).all(), "engine ids differ"
    assert (np.asarray(v_e) == np.asarray(v_1)).all(), "engine scores differ"
    by_name = {r["name"]: r for r in records}
    ratio = (by_name["retrieval_e2e_dense"]["us_per_call"]
             / max(by_name["retrieval_sparse"]["us_per_call"], 1e-9))
    print(f"engine_vs_composed_bit_identical,0,e2e/composed={ratio:.3f}")

    # quantized serving must be BIT-identical to the engine over the
    # dequantized index (same quantized values) — quantization error is a
    # build-time choice, never a serving-path one
    dengine = RetrievalEngine(dequantize_index(qindex32), params,
                              config=EngineConfig(mode="sparse"))
    v_q, i_q, *_ = quant_fn(queries)
    v_d, i_d, *_ = dengine.retrieve_dense(queries, topn)
    assert (np.asarray(i_q) == np.asarray(i_d)).all(), "quantized ids differ"
    assert (np.asarray(v_q) == np.asarray(v_d)).all(), "quantized scores differ"
    ratio_b = q_index_bytes / q_index_bytes_fp
    print(f"quantized_vs_dequantized_bit_identical,0,"
          f"index_bytes_ratio={ratio_b:.3f}")
    # the compound format must hold >= 2.5x less index HBM at k=32, h<65536
    assert ratio_b <= 0.40, (
        f"quantized index {q_index_bytes} B is {ratio_b:.1%} of fp32 "
        f"{q_index_bytes_fp} B — exceeds the 40% budget at k=32")

    # generation 5 is APPROXIMATE: its contract vs the exact quantized
    # engine is the harness triple at recall@32 (the paper's k), recorded
    # on the row and gated >= 0.95 at full benchmark size
    from repro.core.eval import retrieval_quality

    exact32 = qengine.retrieve_dense(queries, 32)
    approx32 = qengine_mxu.retrieve_dense(queries, 32)
    quality = retrieval_quality(approx32, exact32)
    by_name["retrieval_sparse_quantized_mxu"].update(
        recall_vs_exact=round(quality["recall"], 4),
        score_mae=round(quality["score_mae"], 6),
        rank_displacement=round(quality["rank_displacement"], 3),
        quality_n=quality["n"],
    )
    print(f"int8_vs_exact_quantized,0,recall@32={quality['recall']:.4f} "
          f"mae={quality['score_mae']:.2e} "
          f"displacement={quality['rank_displacement']:.3f}")
    if not smoke:
        assert quality["recall"] >= 0.95, (
            f"int8 scoring recall@32 vs exact quantized path "
            f"{quality['recall']:.4f} < 0.95 at N={n}, Q={q_count}, k=32")

    # two-stage is APPROXIMATE in candidate GENERATION (scoring stays
    # exact): its contract is recall@32 vs the single-stage engine over
    # the same index, gated >= 0.95 at full benchmark size alongside the
    # scanned-fraction bound (< 0.5 of the catalog)
    from repro.core.retrieval import two_stage_budget

    exact32_fp = engine.retrieve_dense(queries, 32)
    ts32 = ts_engine.retrieve_dense(queries, 32)
    ts_quality = retrieval_quality(ts32, exact32_fp)
    scanned = two_stage_budget(n, 32, cand_frac) / n
    by_name["retrieval_two_stage"].update(
        recall_vs_exact=round(ts_quality["recall"], 4),
        scanned_fraction=round(scanned, 4),
        candidate_fraction=cand_frac,
        quality_n=ts_quality["n"],
    )
    print(f"two_stage_vs_single_stage,0,recall@32={ts_quality['recall']:.4f} "
          f"scanned_fraction={scanned:.4f}")
    if not smoke:
        assert ts_quality["recall"] >= 0.95, (
            f"two-stage recall@32 vs single-stage {ts_quality['recall']:.4f}"
            f" < 0.95 at N={n}, Q={q_count}, cand_frac={cand_frac}")
        assert scanned < 0.5, (
            f"two-stage scanned fraction {scanned:.3f} >= 0.5 at N={n} — "
            "the candidate budget defeats the sub-linear point")

    # device stage 1 must be BIT-identical to the host-stage-1 request
    # end to end (the device union is a drop-in, not an approximation of
    # an approximation) — so its record inherits the host row's quality
    # verbatim, and check_bench fails any host/device recall divergence
    v_th, i_th, *_ = ts_fn(queries)
    v_td, i_td, *_ = ts_dev_fn(queries)
    assert (np.asarray(i_td) == np.asarray(i_th)).all(), \
        "device-stage-1 ids differ from host stage 1"
    assert (np.asarray(v_td) == np.asarray(v_th)).all(), \
        "device-stage-1 scores differ from host stage 1"
    print("two_stage_device_vs_host_bit_identical,0,1")
    ts_dev32 = ts_dev_engine.retrieve_dense(queries, 32)
    ts_dev_quality = retrieval_quality(ts_dev32, exact32_fp)
    by_name["retrieval_two_stage_device"].update(
        recall_vs_exact=round(ts_dev_quality["recall"], 4),
        scanned_fraction=round(scanned, 4),
        candidate_fraction=cand_frac,
        quality_n=ts_dev_quality["n"],
    )
    assert ts_dev_quality["recall"] == ts_quality["recall"], (
        "device two-stage recall diverged from host two-stage: "
        f"{ts_dev_quality['recall']:.4f} != {ts_quality['recall']:.4f}")
    if not smoke:
        assert ts_dev_quality["recall"] >= 0.95, (
            f"device two-stage recall@32 {ts_dev_quality['recall']:.4f} "
            f"< 0.95 at N={n}, Q={q_count}, cand_frac={cand_frac}")

    # segmented serving contract (ISSUE 9, pinned bit-exactly by
    # tests/test_segments.py): the mutated SegmentedIndex answers like a
    # fresh build_index over the surviving fp32 rows.  The bench records
    # both halves — recall_vs_exact@32 against the rebuilt-index engine
    # (1.0 when the contract holds), and compaction_parity: compact()'s
    # base checksum must EQUAL the rebuilt index's (row-local
    # quantization/norms make gathering stored rows == re-encoding the
    # survivors).  Checksum equality is deterministic at any size, so
    # the parity assert has no smoke exemption.
    all_codes = SparseCodes(
        values=jnp.concatenate([codes.values, extra_codes.values]),
        indices=jnp.concatenate([codes.indices, extra_codes.indices]),
        dim=codes.dim)
    rebuilt = build_index(_code_rows(all_codes, surv))
    reb_engine = RetrievalEngine(rebuilt, params,
                                 config=EngineConfig(mode="sparse"))
    seg32 = seg_engine.retrieve_dense(queries, 32)
    v_rb, pos_rb, *_ = reb_engine.retrieve_dense(queries, 32)
    seg_quality = retrieval_quality(
        seg32, (v_rb, jnp.take(jnp.asarray(surv), pos_rb)))
    parity = int(seg.compact().base.checksum == rebuilt.checksum)
    by_name["retrieval_segmented"].update(
        recall_vs_exact=round(seg_quality["recall"], 4),
        compaction_parity=parity,
        quality_n=seg_quality["n"],
    )
    print(f"segmented_vs_rebuilt,0,recall@32={seg_quality['recall']:.4f} "
          f"compaction_parity={parity}")
    assert parity == 1, (
        "segmented compact() checksum diverged from build_index over the "
        "surviving rows — the compaction bit-identity contract broke")
    if not smoke:
        assert seg_quality["recall"] >= 0.95, (
            f"segmented recall@32 vs rebuilt index "
            f"{seg_quality['recall']:.4f} < 0.95 at N={n}, Q={q_count}")

    # kernel-trick exactness at benchmark scale
    q_codes = encode(params, queries, K)
    got = score_reconstructed(index, q_codes, params)
    want = score_dense(decode(params, codes), decode(params, q_codes))
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"kernel_trick_max_abs_err,0,{err:.2e}")
    assert err < 1e-3

    BENCH_JSON.write_text(json.dumps(records, indent=2) + "\n")
    print(f"[bench] wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    main()
